//! Fixture-driven rule tests: every rule must fire on its violating fixture
//! at the exact lines, and must stay silent on the clean/allowed fixtures.
//! Fixtures are consumed as text (never compiled), so each one can violate
//! the contract freely.

use spmd_lint::{lint_sources, Finding};
use std::fs;
use std::path::Path;

/// Read a fixture; lint under its *relative* path so `dist/` scoping is
/// exercised exactly as it is on the real tree.
fn fixture(rel: &str) -> (String, String) {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(rel);
    let src = fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("fixture {} unreadable: {e}", p.display()));
    (rel.to_string(), src)
}

fn lint_one(rel: &str) -> Vec<Finding> {
    lint_sources(&[fixture(rel)])
}

fn keys(findings: &[Finding]) -> Vec<(&str, u32)> {
    findings.iter().map(|f| (f.rule.as_str(), f.line)).collect()
}

#[test]
fn r1_fires_on_rank_conditional_collectives() {
    let f = lint_one("r1_divergence.rs");
    assert_eq!(keys(&f), [("R1", 8), ("R1", 16)], "{f:#?}");
    assert!(f[0].message.contains("rank-conditional"), "{f:#?}");
}

#[test]
fn r2_fires_on_panics_in_dist() {
    let f = lint_one("dist/r2_panics.rs");
    assert_eq!(keys(&f), [("R2", 7), ("R2", 8), ("R2", 12)], "{f:#?}");
    assert!(f[0].message.contains("expect"), "{f:#?}");
    assert!(f[1].message.contains("unwrap"), "{f:#?}");
    assert!(f[2].message.contains("panic"), "{f:#?}");
}

#[test]
fn r2_is_scoped_to_dist_paths() {
    // The same source under a non-dist path is out of R2's jurisdiction.
    let (_, src) = fixture("dist/r2_panics.rs");
    let f = lint_sources(&[("lib/r2_panics.rs".to_string(), src)]);
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn r3_fires_on_discarded_collective_results() {
    let f = lint_one("r3_discard.rs");
    assert_eq!(
        keys(&f),
        [("R3", 8), ("R3", 8), ("R3", 12), ("R3", 12)],
        "{f:#?}"
    );
    assert!(f[0].message.contains(".ok()"), "{f:#?}");
    assert!(f[1].message.contains("does not return Result"), "{f:#?}");
    assert!(f[2].message.contains("let _ ="), "{f:#?}");
    assert!(f[3].message.contains("does not return Result"), "{f:#?}");
}

#[test]
fn r4_fires_on_roundkind_coverage_holes() {
    let f = lint_one("r4_rounds.rs");
    assert_eq!(
        keys(&f),
        [("R4", 3), ("R4", 3), ("R4", 10), ("R4", 13), ("R4", 18)],
        "{f:#?}"
    );
    assert!(f[0].message.contains("SampleResponse"), "{f:#?}");
    assert!(f[1].message.contains("GradSync"), "{f:#?}");
    assert!(f[2].message.contains("COUNT is 2"), "{f:#?}");
    assert!(f[3].message.contains("missing from the ALL array"), "{f:#?}");
    assert!(f[4].message.contains("wildcard"), "{f:#?}");
}

#[test]
fn r5_fires_on_sends_under_a_live_guard() {
    let f = lint_one("dist/r5_locks.rs");
    assert_eq!(keys(&f), [("R5", 7), ("R5", 12)], "{f:#?}");
    assert!(f[0].message.contains("`stats` (line 6)"), "{f:#?}");
    assert!(f[1].message.contains("same statement"), "{f:#?}");
}

#[test]
fn r6_fires_on_plane_switches_in_prefetch_code() {
    let f = lint_one("prefetch/r6_planes.rs");
    assert_eq!(keys(&f), [("R6", 4), ("R6", 6)], "{f:#?}");
    assert!(f[0].message.contains(".plane()"), "{f:#?}");
    assert!(f[1].message.contains("Plane::Gradient"), "{f:#?}");
}

#[test]
fn r6_is_scoped_to_prefetch_paths() {
    // The same source under a trainer path may switch planes freely.
    let (_, src) = fixture("prefetch/r6_planes.rs");
    let f = lint_sources(&[("train/trainer.rs".to_string(), src)]);
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn clean_code_produces_no_findings() {
    let f = lint_one("clean.rs");
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn clean_prefetch_code_produces_no_findings() {
    let f = lint_one("prefetch/clean.rs");
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn justified_allow_suppresses_its_finding() {
    let f = lint_one("dist/allowed.rs");
    assert!(f.is_empty(), "{f:#?}");
}

#[test]
fn malformed_allows_are_findings_and_suppress_nothing() {
    let f = lint_one("dist/allow_bad.rs");
    assert_eq!(
        keys(&f),
        [("allow", 5), ("R2", 6), ("allow", 10), ("R2", 11)],
        "{f:#?}"
    );
    assert!(f[0].message.contains("unknown rule `R9`"), "{f:#?}");
    assert!(f[2].message.contains("missing its justification"), "{f:#?}");
}

#[test]
fn all_fixtures_lint_as_one_set_without_cross_talk() {
    // R4 state is cross-file; linting everything together must not change
    // any per-file verdict (only one fixture declares RoundKind).
    let rels = [
        "clean.rs",
        "dist/allow_bad.rs",
        "dist/allowed.rs",
        "dist/r2_panics.rs",
        "dist/r5_locks.rs",
        "prefetch/clean.rs",
        "prefetch/r6_planes.rs",
        "r1_divergence.rs",
        "r3_discard.rs",
        "r4_rounds.rs",
    ];
    let files: Vec<(String, String)> = rels.iter().map(|&r| fixture(r)).collect();
    let f = lint_sources(&files);
    assert_eq!(f.len(), 2 + 3 + 4 + 5 + 2 + 2 + 4, "{f:#?}");
}
