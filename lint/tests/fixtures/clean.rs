//! Clean fixture: the patterns the rules must NOT flag. Never compiled.

use crate::dist::{Comm, CommError, RoundKind};

pub fn lockstep_mean(comm: &mut Comm, grad: &mut [f32]) -> Result<(), CommError> {
    comm.all_reduce_mean_f32(RoundKind::GradSync, grad)?;
    if comm.rank() == 0 {
        log_progress(); // rank-conditional is fine when no collective is inside
    }
    Ok(())
}

pub fn vote(comm: &mut Comm, misses: u64) -> Result<bool, CommError> {
    comm.all_zero_u64(misses)
}

fn log_progress() {}
