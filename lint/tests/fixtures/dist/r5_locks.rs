//! R5 fixture: transport calls while a MutexGuard is live. Never compiled.

use std::sync::Mutex;

pub fn flush_stats(m: &Mutex<u64>, link: &mut Link) -> Result<(), ()> {
    let stats = m.lock().unwrap_or_else(|p| p.into_inner());
    link.send(*stats) // line 7: R5 — `stats` guard still live
}

pub fn flush_inline(m: &Mutex<Link>) {
    // line 12: R5 — the `.lock()` temporary is live across the flush
    m.lock().unwrap_or_else(|p| p.into_inner()).flush();
}

pub fn flush_after_drop(m: &Mutex<u64>, link: &mut Link) -> Result<(), ()> {
    let stats = m.lock().unwrap_or_else(|p| p.into_inner());
    let snapshot = *stats;
    drop(stats);
    link.send(snapshot) // not flagged: guard dropped first
}
