//! Malformed escape hatches: each is itself a finding AND suppresses
//! nothing. Never compiled.

pub fn probe(v: Option<u32>) -> u32 {
    // spmd-lint: allow(R9) — no rule by that name
    v.unwrap() // line 6: R2 still fires (bad directives do not suppress)
}

pub fn probe2(v: Option<u32>) -> u32 {
    // spmd-lint: allow(R2)
    v.unwrap() // line 11: R2 still fires (justification missing above)
}
