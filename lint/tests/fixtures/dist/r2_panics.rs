//! R2 fixture: panics in dist/ library paths (the `dist/` path segment is
//! what puts this file in R2 scope). Never compiled.

use std::sync::Mutex;

pub fn poll(slot: &Mutex<Option<u32>>) -> u32 {
    let v = slot.lock().expect("poisoned"); // line 7: R2 expect
    v.unwrap() // line 8: R2 unwrap
}

pub fn refuse() {
    panic!("unroutable frame") // line 12: R2 panic!
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_unwraps_stay_legal() {
        Some(1).unwrap(); // not flagged: test code
    }
}
