//! Escape-hatch fixture: a justified allow suppresses its finding. Never
//! compiled.

use std::sync::Mutex;

pub fn probe(slot: &Mutex<Option<u32>>) -> Option<u32> {
    // spmd-lint: allow(R2) — lock is private to this fn and never crosses a panic
    *slot.lock().unwrap()
}
