//! R3 fixture: collective results discarded or unpropagatable. Never
//! compiled.

use crate::dist::{Comm, RoundKind};

pub fn sync_loss(comm: &mut Comm, grad: &mut [f32]) {
    // line 8: R3 twice — `.ok()` discard AND the enclosing fn returns ()
    comm.all_reduce_mean_f32(RoundKind::GradSync, grad).ok();
}

pub fn mark(comm: &mut Comm) {
    let _ = comm.barrier(); // line 12: R3 twice — `let _ =` discard + fn returns ()
}
