//! R4 fixture: RoundKind coverage holes. Never compiled.

pub enum RoundKind {
    SampleRequest = 0,
    SampleResponse = 1,
    GradSync = 2,
}

impl RoundKind {
    pub const COUNT: usize = 2; // line 10: R4 — enum has 3 variants

    // line 13: R4 — GradSync missing from the encode-side iteration array
    pub const ALL: [RoundKind; 2] = [RoundKind::SampleRequest, RoundKind::SampleResponse];

    pub fn name(self) -> &'static str {
        match self {
            RoundKind::SampleRequest => "sample-request",
            _ => "other", // line 18: R4 — wildcard defeats exhaustiveness
        }
    }
}
