//! R6 clean fixture: the sampler uses only the handle it was given.

pub fn sampler_epochs(comm: &mut Comm, items: &Sender<u32>) -> Result<(), CommError> {
    let mark = comm.fenced_snapshot()?;
    comm.barrier()?;
    if items.send(mark).is_err() {
        return Ok(());
    }
    Ok(())
}
