//! R6 fixture: sampler-thread code reaching for another plane.

fn sampler_epochs_bad(comm: &mut Comm) -> Result<(), CommError> {
    let mut other = comm.plane(Plane::Sampling);
    other.barrier()?;
    let g = Plane::Gradient;
    let _ = g;
    Ok(())
}
