//! R1 fixture: a collective under rank-conditional control flow.
//! Never compiled — consumed as text by `lint/tests/rules.rs`.

use crate::dist::{Comm, CommError};

pub fn epoch_mark(comm: &mut Comm, rank: usize) -> Result<(), CommError> {
    if rank == 0 {
        comm.barrier()?; // line 8: R1 — only rank 0 reaches the barrier
    }
    Ok(())
}

pub fn staged_sync(comm: &mut Comm) -> Result<(), CommError> {
    match comm.rank() {
        0 => {
            comm.fenced_snapshot()?; // line 16: R1 — match over the rank
        }
        _ => {}
    }
    Ok(())
}
