//! A minimal, dependency-free Rust lexer — just enough token structure for
//! the SPMD rules: identifiers, punctuation (maximal-munch multi-char
//! operators), and correctly *skipped* comments, strings (incl. raw/byte
//! forms), char literals, and lifetimes, each with a 1-based line number.
//!
//! This is deliberately not a full Rust lexer: the rules in
//! [`crate::rules`] only ever look at identifier/punctuation shapes, so
//! literals carry no text and a handful of exotic forms (raw identifiers,
//! exponent floats) degrade gracefully into harmless token splits.

/// Token class. `Str` covers every literal whose content the rules never
/// inspect (strings, chars, byte strings).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kind {
    Ident,
    Num,
    Str,
    Lifetime,
    Punct,
}

#[derive(Clone, Debug)]
pub struct Token {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

/// Multi-char operators, longest first (maximal munch).
const THREE: [&str; 3] = ["..=", "<<=", ">>="];
const TWO: [&str; 19] = [
    "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "+=", "-=", "*=", "/=", "%=", "^=",
    "&=", "|=", "<<", ">>",
];
// ".." is matched after the TWO list on purpose: "..=" wins first.
const TWO_TAIL: [&str; 1] = [".."];

pub fn lex(src: &str) -> Vec<Token> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut out: Vec<Token> = Vec::new();
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Comments (line, and nested block).
        if c == '/' && i + 1 < n {
            if b[i + 1] == '/' {
                while i < n && b[i] != '\n' {
                    i += 1;
                }
                continue;
            }
            if b[i + 1] == '*' {
                let mut depth = 1usize;
                i += 2;
                while i < n && depth > 0 {
                    if b[i] == '\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                continue;
            }
        }
        // Identifiers (and the raw/byte-string prefixes that look like them).
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            let ident: String = b[start..i].iter().collect();
            if (ident == "r" || ident == "br") && i < n && (b[i] == '"' || b[i] == '#') {
                let mut hashes = 0usize;
                while i < n && b[i] == '#' {
                    hashes += 1;
                    i += 1;
                }
                if i < n && b[i] == '"' {
                    i += 1;
                    while i < n {
                        if b[i] == '\n' {
                            line += 1;
                            i += 1;
                            continue;
                        }
                        if b[i] == '"' {
                            let mut k = 0usize;
                            let mut j = i + 1;
                            while j < n && b[j] == '#' && k < hashes {
                                k += 1;
                                j += 1;
                            }
                            if k == hashes {
                                i = j;
                                break;
                            }
                            i += 1;
                            continue;
                        }
                        i += 1;
                    }
                    out.push(Token { kind: Kind::Str, text: String::new(), line });
                    continue;
                }
                out.push(Token { kind: Kind::Ident, text: ident, line });
                continue;
            }
            if ident == "b" && i < n && (b[i] == '"' || b[i] == '\'') {
                // Byte string / byte char: the quote branches below handle
                // the literal; the `b` prefix itself emits nothing.
                continue;
            }
            out.push(Token { kind: Kind::Ident, text: ident, line });
            continue;
        }
        // String literals.
        if c == '"' {
            i += 1;
            while i < n {
                match b[i] {
                    '\\' => i += 2,
                    '"' => {
                        i += 1;
                        break;
                    }
                    '\n' => {
                        line += 1;
                        i += 1;
                    }
                    _ => i += 1,
                }
            }
            out.push(Token { kind: Kind::Str, text: String::new(), line });
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                i += 2;
                while i < n && b[i] != '\'' {
                    i += 1;
                }
                i += 1;
                out.push(Token { kind: Kind::Str, text: String::new(), line });
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                i += 3;
                out.push(Token { kind: Kind::Str, text: String::new(), line });
                continue;
            }
            i += 1;
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            out.push(Token {
                kind: Kind::Lifetime,
                text: b[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Numbers. A `.` joins only when followed by a digit, so `0..n`
        // lexes as `0`, `..`, `n`.
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n {
                let d = b[i];
                if d.is_alphanumeric() || d == '_' {
                    i += 1;
                    continue;
                }
                if d == '.' && i + 1 < n && b[i + 1].is_ascii_digit() {
                    i += 1;
                    continue;
                }
                break;
            }
            out.push(Token {
                kind: Kind::Num,
                text: b[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Punctuation, maximal munch.
        let mut got: Option<&str> = None;
        let peek3: String = b[i..n.min(i + 3)].iter().collect();
        for op in THREE {
            if peek3 == op {
                got = Some(op);
                break;
            }
        }
        if got.is_none() {
            let peek2: String = b[i..n.min(i + 2)].iter().collect();
            for op in TWO.iter().chain(TWO_TAIL.iter()) {
                if peek2 == **op {
                    got = Some(op);
                    break;
                }
            }
        }
        match got {
            Some(op) => {
                i += op.chars().count();
                out.push(Token { kind: Kind::Punct, text: op.to_string(), line });
            }
            None => {
                i += 1;
                out.push(Token { kind: Kind::Punct, text: c.to_string(), line });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn comments_and_strings_vanish() {
        let toks = lex("let x = \"a // not a comment\"; // gone\n/* gone /* nested */ too */ y");
        let idents: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, ["let", "x", "y"]);
    }

    #[test]
    fn char_vs_lifetime() {
        let toks = lex("'a' 'x: &'static str");
        assert_eq!(toks[0].kind, Kind::Str);
        assert_eq!(toks[1].kind, Kind::Lifetime);
        let lt: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == Kind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lt, ["x", "static"]);
    }

    #[test]
    fn ranges_do_not_eat_numbers() {
        assert_eq!(texts("0..world"), ["0", "..", "world"]);
        assert_eq!(texts("1.5..=2.5"), ["1.5", "..=", "2.5"]);
    }

    #[test]
    fn raw_and_byte_strings_skip() {
        let toks = lex(r####"r#"has "quotes" inside"# b"bytes" b'x' tail"####);
        let idents: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, ["tail"]);
    }

    #[test]
    fn lines_are_tracked() {
        let toks = lex("a\nb\n\nc");
        let lines: Vec<u32> = toks.iter().map(|t| t.line).collect();
        assert_eq!(lines, [1, 2, 4]);
    }

    #[test]
    fn multichar_operators_munch_maximally() {
        assert_eq!(texts("a==>b"), ["a", "==", ">", "b"]);
        assert_eq!(texts("x=>y"), ["x", "=>", "y"]);
        assert_eq!(texts("p::<q>()"), ["p", "::", "<", "q", ">", "(", ")"]);
    }
}
