//! spmd-lint — static enforcement of the fastsample SPMD fabric contract.
//!
//! The distributed layer (`rust/src/dist/`) is correct only if every rank
//! walks the same sequence of collectives and every fabric error propagates
//! as a `CommError` instead of a panic or a silent discard. Those are
//! *global* properties that unit tests probe pointwise; this crate checks
//! them lexically over the whole tree on every CI run:
//!
//! | rule | contract |
//! |------|----------|
//! | R1   | no collective under rank-conditional control flow            |
//! | R2   | no `unwrap`/`expect`/panic-family in `dist/` library code    |
//! | R3   | collective results propagate (`Result` fns, no discards)     |
//! | R4   | `RoundKind` coverage: COUNT / ALL / match arms, cross-file   |
//! | R5   | no transport send/flush while a `MutexGuard` is live         |
//! | R6   | sampler-thread code (`prefetch` paths) never switches planes |
//!
//! Run it as `cargo run -p spmd-lint -- rust/src` (add `--json` for machine
//! output), or through the tier-1 test `spmd_lint_clean` which pins the tree
//! at zero findings.

pub mod lexer;
pub mod rules;

pub use rules::{lint_sources, Finding};

use std::fs;
use std::io;
use std::path::Path;

/// Collect `(path, source)` for every `.rs` file under `root` (which may be
/// a single file or a directory), sorted by path.
pub fn collect_sources(root: &Path) -> io::Result<Vec<(String, String)>> {
    let mut files = Vec::new();
    collect_into(root, &mut files)?;
    files.sort();
    Ok(files)
}

fn collect_into(p: &Path, out: &mut Vec<(String, String)>) -> io::Result<()> {
    if p.is_dir() {
        let mut entries = Vec::new();
        for e in fs::read_dir(p)? {
            entries.push(e?.path());
        }
        entries.sort();
        for e in entries {
            collect_into(&e, out)?;
        }
        return Ok(());
    }
    if p.extension().and_then(|e| e.to_str()) == Some("rs") {
        let src = fs::read_to_string(p)?;
        out.push((p.to_string_lossy().into_owned(), src));
    }
    Ok(())
}

/// Lint every `.rs` file under `root`. Findings come back sorted by
/// `(file, line, rule)`; an empty vector means the tree honors the contract.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Finding>> {
    let files = collect_sources(root)?;
    Ok(lint_sources(&files))
}

/// One `path:line: rule: message` line per finding.
pub fn render_human(findings: &[Finding]) -> String {
    let mut s = String::new();
    for f in findings {
        s.push_str(&f.file);
        s.push(':');
        s.push_str(&f.line.to_string());
        s.push_str(": ");
        s.push_str(&f.rule);
        s.push_str(": ");
        s.push_str(&f.message);
        s.push('\n');
    }
    s
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// `{"findings":[{"rule":...,"file":...,"line":...,"message":...}],"count":N}`
pub fn render_json(findings: &[Finding]) -> String {
    let mut s = String::from("{\"findings\":[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("{\"rule\":\"");
        s.push_str(&json_escape(&f.rule));
        s.push_str("\",\"file\":\"");
        s.push_str(&json_escape(&f.file));
        s.push_str("\",\"line\":");
        s.push_str(&f.line.to_string());
        s.push_str(",\"message\":\"");
        s.push_str(&json_escape(&f.message));
        s.push_str("\"}");
    }
    s.push_str("],\"count\":");
    s.push_str(&findings.len().to_string());
    s.push('}');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escaping_covers_specials() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn render_json_is_well_formed_when_empty() {
        assert_eq!(render_json(&[]), "{\"findings\":[],\"count\":0}");
    }

    #[test]
    fn render_human_one_line_per_finding() {
        let f = Finding {
            rule: "R2".to_string(),
            file: "x/dist/y.rs".to_string(),
            line: 7,
            message: "m".to_string(),
        };
        assert_eq!(render_human(&[f]), "x/dist/y.rs:7: R2: m\n");
    }
}
