//! CLI driver: `spmd-lint [--json] <path>...`
//!
//! Exit codes: 0 = clean, 1 = findings, 2 = usage or I/O error. All named
//! paths are linted as ONE source set so the cross-file R4 checks see the
//! whole picture.

use std::env;
use std::path::PathBuf;
use std::process::exit;

const USAGE: &str = "usage: spmd-lint [--json] <path>...\n\
    \n\
    Lints .rs files (recursively for directories) against the SPMD fabric\n\
    contract: R1 rank-divergent collectives, R2 panics in dist/, R3 dropped\n\
    fabric errors, R4 RoundKind coverage, R5 sends under a held lock, R6\n\
    plane switches in sampler-thread (prefetch) code.\n\
    \n\
    exit status: 0 clean, 1 findings, 2 usage/io error";

fn main() {
    let mut json = false;
    let mut roots: Vec<PathBuf> = Vec::new();
    for arg in env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "-h" | "--help" => {
                println!("{USAGE}");
                exit(0);
            }
            other if other.starts_with('-') => {
                eprintln!("spmd-lint: unknown flag `{other}`\n{USAGE}");
                exit(2);
            }
            other => roots.push(PathBuf::from(other)),
        }
    }
    if roots.is_empty() {
        eprintln!("{USAGE}");
        exit(2);
    }

    let mut files: Vec<(String, String)> = Vec::new();
    for root in &roots {
        match spmd_lint::collect_sources(root) {
            Ok(mut f) => files.append(&mut f),
            Err(e) => {
                eprintln!("spmd-lint: {}: {e}", root.display());
                exit(2);
            }
        }
    }
    files.sort();
    files.dedup_by(|a, b| a.0 == b.0);

    let findings = spmd_lint::lint_sources(&files);
    if json {
        println!("{}", spmd_lint::render_json(&findings));
    } else {
        print!("{}", spmd_lint::render_human(&findings));
        println!("{} finding(s) in {} file(s)", findings.len(), files.len());
    }
    exit(if findings.is_empty() { 0 } else { 1 });
}
