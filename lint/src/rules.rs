//! The SPMD rule engine: a single pass over the token stream of each file,
//! tracking a block stack (fn / closure / match-body / other), statement
//! shape, and live Mutex guards. Six rules:
//!
//! - **R1** — no collective call under rank-conditional control flow.
//! - **R2** — no `unwrap`/`expect`/panic-family macros in `dist/` library
//!   code (test modules exempt; `// spmd-lint: allow(R2) — why` escapes).
//! - **R3** — collective results must propagate: no `.ok()` / `let _ =`
//!   discards, and the enclosing `fn` must return `Result`.
//! - **R4** — cross-file `RoundKind` coverage: `COUNT` matches the variant
//!   count, every variant appears in the `ALL` array and in at least one
//!   match arm, and no wildcard arm defeats exhaustiveness.
//! - **R5** — no `Transport` send/flush while a `MutexGuard` is live.
//! - **R6** — sampler-thread code (paths containing `prefetch`) stays on
//!   the one plane handle it was given: no `.plane(...)` re-derivation,
//!   no `Plane::Gradient` reference. A cross-plane collective from the
//!   sampler thread would interleave with the trainer's in-flight round
//!   on the same seq stream and desynchronize the world.
//!
//! The analysis is lexical by design — no type information, no name
//! resolution. Where that approximates (any `Result` return satisfies R3,
//! any `.lock()` binding is a guard for R5), the approximation is
//! deliberately conservative and documented in DESIGN.md.

use crate::lexer::{lex, Kind, Token};
use std::collections::{BTreeMap, BTreeSet};

pub const RULES: [&str; 6] = ["R1", "R2", "R3", "R4", "R5", "R6"];
pub const ALLOW_RULE: &str = "allow";

const COLLECTIVE_EXACT: [&str; 11] = [
    "barrier",
    "fenced_snapshot",
    "all_zero_u64",
    "sample_mfgs_distributed",
    "sample_mfgs_distributed_wire",
    "fetch_features",
    "prefill_cache",
    "sampler_epochs",
    "resume_latest",
    "serve_rank",
    "serve_query_batch",
];
const COLLECTIVE_PREFIX: [&str; 2] = ["all_reduce_", "exchange"];
const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];
const SEND_METHODS: [&str; 3] = ["send", "send_typed", "flush"];

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub rule: String,
    pub file: String,
    pub line: u32,
    pub message: String,
}

fn push(findings: &mut Vec<Finding>, rule: &str, file: &str, line: u32, message: String) {
    findings.push(Finding {
        rule: rule.to_string(),
        file: file.to_string(),
        line,
        message,
    });
}

fn is_collective(name: &str) -> bool {
    COLLECTIVE_EXACT.contains(&name) || COLLECTIVE_PREFIX.iter().any(|p| name.starts_with(p))
}

fn is_dist_path(path: &str) -> bool {
    path.replace('\\', "/").split('/').any(|c| c == "dist")
}

/// Sampler-thread code for R6: any path whose file or directory name
/// mentions `prefetch` (the module the sampler thread runs).
fn is_prefetch_path(path: &str) -> bool {
    path.replace('\\', "/").split('/').any(|c| c.contains("prefetch"))
}

// --- allow directives ------------------------------------------------------

/// Scan comment text for `// spmd-lint: allow(<rule>) — <why>` directives.
/// Well-formed directives suppress findings of `<rule>` on their own line or
/// the line below; malformed ones are themselves findings.
fn parse_allows(path: &str, src: &str, findings: &mut Vec<Finding>) -> BTreeSet<(u32, String)> {
    let mut allows = BTreeSet::new();
    let strip: &[char] = &['—', '-', ':', ' ', '\t'];
    for (idx, raw) in src.lines().enumerate() {
        let ln = idx as u32 + 1;
        let cpos = match raw.find("//") {
            Some(p) => p,
            None => continue,
        };
        let c = &raw[cpos..];
        let p = match c.find("spmd-lint:") {
            Some(p) => p,
            None => continue,
        };
        let rest = c[p + "spmd-lint:".len()..].trim_start();
        let rest = match rest.strip_prefix("allow(") {
            Some(r) => r,
            None => {
                push(
                    findings,
                    ALLOW_RULE,
                    path,
                    ln,
                    "malformed spmd-lint directive (expected `allow(<rule>) — <why>`)".to_string(),
                );
                continue;
            }
        };
        let close = match rest.find(')') {
            Some(c) => c,
            None => {
                push(
                    findings,
                    ALLOW_RULE,
                    path,
                    ln,
                    "malformed spmd-lint directive (unclosed `allow(`)".to_string(),
                );
                continue;
            }
        };
        let rule = rest[..close].trim();
        let just = rest[close + 1..].trim().trim_start_matches(strip).trim();
        if !RULES.contains(&rule) {
            push(
                findings,
                ALLOW_RULE,
                path,
                ln,
                format!("unknown rule `{rule}` in spmd-lint allow directive"),
            );
            continue;
        }
        if just.is_empty() {
            push(
                findings,
                ALLOW_RULE,
                path,
                ln,
                format!("spmd-lint allow({rule}) is missing its justification"),
            );
            continue;
        }
        allows.insert((ln, rule.to_string()));
    }
    allows
}

// --- R4 cross-file state ---------------------------------------------------

#[derive(Default)]
pub struct R4State {
    variants: Vec<String>,
    enum_file: Option<String>,
    enum_line: u32,
    count_decl: Option<(String, u32, u64)>,
    all_refs: Option<(String, u32, BTreeSet<String>)>,
    matched: BTreeSet<String>,
}

fn parse_int(s: &str) -> Option<u64> {
    let s = s.replace('_', "");
    if let Some(hex) = s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).ok()
    } else if let Some(oct) = s.strip_prefix("0o") {
        u64::from_str_radix(oct, 8).ok()
    } else if let Some(bin) = s.strip_prefix("0b") {
        u64::from_str_radix(bin, 2).ok()
    } else {
        s.parse().ok()
    }
}

// --- per-file analysis -----------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq)]
enum BlockKind {
    Fn,
    Closure,
    MatchBody,
    Other,
}

struct Block {
    kind: BlockKind,
    rank_cond: bool,
    cfg_test: bool,
    returns_result: bool,
    fn_name: String,
    guards: Vec<(String, u32)>,
    // MatchBody state: between `{`/`,` and the arm's `=>` we are collecting
    // the pattern; afterwards (non-braced arm) we track expression depth so
    // the `,` ending the arm re-enters pattern mode.
    arm_pattern: bool,
    expr_depth: i32,
    cur_pattern: Vec<String>,
    is_roundkind: bool,
    wildcard_line: u32,
    pat_line: u32,
}

impl Block {
    fn new(kind: BlockKind, rank_cond: bool, cfg_test: bool) -> Self {
        Block {
            kind,
            rank_cond,
            cfg_test,
            returns_result: false,
            fn_name: String::new(),
            guards: Vec::new(),
            arm_pattern: false,
            expr_depth: 0,
            cur_pattern: Vec::new(),
            is_roundkind: false,
            wildcard_line: 0,
            pat_line: 0,
        }
    }
}

fn t_text(toks: &[Token], i: usize) -> &str {
    toks.get(i).map(|t| t.text.as_str()).unwrap_or("")
}

fn t_kind(toks: &[Token], i: usize) -> Kind {
    toks.get(i).map(|t| t.kind).unwrap_or(Kind::Punct)
}

/// `i` points at `(`; returns the index of the matching `)` (or `toks.len()`).
fn find_close_paren(toks: &[Token], mut i: usize) -> usize {
    let mut depth = 0i32;
    while i < toks.len() {
        if toks[i].kind == Kind::Punct {
            match toks[i].text.as_str() {
                "(" => depth += 1,
                ")" => {
                    depth -= 1;
                    if depth == 0 {
                        return i;
                    }
                }
                _ => {}
            }
        }
        i += 1;
    }
    toks.len()
}

/// `toks[i]` is a collective-named Ident. Returns the index of the call's
/// `(`, skipping one `::<...>` turbofish, or None if this is not a call.
fn call_paren_index(toks: &[Token], i: usize) -> Option<usize> {
    let mut j = i + 1;
    if t_kind(toks, j) == Kind::Punct && t_text(toks, j) == "::" {
        if t_kind(toks, j + 1) == Kind::Punct && t_text(toks, j + 1) == "<" {
            let mut depth = 0i32;
            let mut k = j + 1;
            while k < toks.len() {
                if toks[k].kind == Kind::Punct {
                    match toks[k].text.as_str() {
                        "<" => depth += 1,
                        ">" | ">>" => {
                            depth -= if toks[k].text == ">>" { 2 } else { 1 };
                            if depth <= 0 {
                                k += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                }
                k += 1;
            }
            j = k;
        } else {
            // path continues (e.g. `use foo::barrier;` has no call parens)
            return None;
        }
    }
    if t_kind(toks, j) == Kind::Punct && t_text(toks, j) == "(" {
        Some(j)
    } else {
        None
    }
}

#[derive(Default)]
struct Stmt {
    first: Vec<String>,
    has_lock: bool,
    is_let: bool,
    bind: Option<String>,
    line: u32,
}

impl Stmt {
    fn reset(&mut self) {
        *self = Stmt::default();
    }
}

fn end_stmt(stack: &mut [Block], stmt: &mut Stmt) {
    if stmt.is_let && stmt.has_lock {
        if let Some(b) = &stmt.bind {
            if b != "_" {
                let last = stack.len() - 1;
                stack[last].guards.push((b.clone(), stmt.line));
            }
        }
    }
    stmt.reset();
}

fn finalize_arm_pattern(blk: &mut Block, r4: &mut R4State) {
    let mut j = 0;
    while j + 2 < blk.cur_pattern.len() {
        if blk.cur_pattern[j] == "RoundKind" && blk.cur_pattern[j + 1] == "::" {
            blk.is_roundkind = true;
            r4.matched.insert(blk.cur_pattern[j + 2].clone());
            j += 3;
            continue;
        }
        j += 1;
    }
    let stripped: Vec<&String> = blk
        .cur_pattern
        .iter()
        .filter(|p| p.as_str() != ",")
        .collect();
    if stripped.len() == 1 && stripped[0] == "_" {
        blk.wildcard_line = blk.pat_line;
    }
    blk.cur_pattern.clear();
    blk.arm_pattern = false;
    blk.expr_depth = 0;
}

fn analyze_file(path: &str, src: &str, r4: &mut R4State, findings: &mut Vec<Finding>) {
    let toks = lex(src);
    let in_dist = is_dist_path(path);
    let in_prefetch = is_prefetch_path(path);
    let n = toks.len();

    let mut stack: Vec<Block> = vec![Block::new(BlockKind::Other, false, false)];
    let mut pending_cfg_test = false;
    let mut pending_fn: Option<(String, bool)> = None;
    let mut pending_cond: Option<(BlockKind, bool)> = None;
    let mut pending_else_rank = false;

    // condition-collection mode (between `if`/`while`/`match` and its `{`)
    let mut cond_mode = false;
    let mut cond_kind = BlockKind::Other;
    let mut cond_depth = 0i32;
    let mut cond_has_rank = false;

    // fn-signature mode (between `fn name` and the body `{` or decl `;`)
    let mut sig_mode = false;
    let mut sig_name = String::new();
    let mut sig_paren = 0i32;
    let mut sig_angle = 0i32;
    let mut sig_ret_mode = false;
    let mut sig_in_where = false;
    let mut sig_returns_result = false;

    let mut stmt = Stmt::default();

    let mut i = 0usize;
    while i < n {
        let kind = toks[i].kind;
        let text = toks[i].text.as_str();
        let line = toks[i].line;

        // ---------- attribute skip ----------
        if kind == Kind::Punct && text == "#" && !cond_mode && !sig_mode {
            let mut j = i + 1;
            if t_kind(&toks, j) == Kind::Punct && t_text(&toks, j) == "!" {
                j += 1;
            }
            if t_kind(&toks, j) == Kind::Punct && t_text(&toks, j) == "[" {
                let mut depth = 0i32;
                let mut has_cfg = false;
                let mut has_test = false;
                let mut has_not = false;
                while j < n {
                    let tx = t_text(&toks, j);
                    if t_kind(&toks, j) == Kind::Punct && tx == "[" {
                        depth += 1;
                    } else if t_kind(&toks, j) == Kind::Punct && tx == "]" {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    } else {
                        match tx {
                            "cfg" => has_cfg = true,
                            "test" => has_test = true,
                            "not" => has_not = true,
                            _ => {}
                        }
                    }
                    j += 1;
                }
                if has_cfg && has_test && !has_not {
                    pending_cfg_test = true;
                }
                i = j + 1;
                continue;
            }
        }

        // ---------- fn-signature mode ----------
        if sig_mode {
            if kind == Kind::Punct {
                let mut body_opens = false;
                match text {
                    "(" => sig_paren += 1,
                    ")" => sig_paren -= 1,
                    "<" => sig_angle += 1,
                    ">" => sig_angle -= 1,
                    ">>" => sig_angle -= 2,
                    "->" => {
                        if sig_paren == 0 && sig_angle <= 0 && !sig_in_where {
                            sig_ret_mode = true;
                        }
                    }
                    ";" => {
                        if sig_paren == 0 {
                            // declaration only (trait method without body)
                            sig_mode = false;
                            pending_fn = None;
                        }
                    }
                    "{" => {
                        if sig_paren == 0 {
                            sig_mode = false;
                            pending_fn = Some((sig_name.clone(), sig_returns_result));
                            body_opens = true;
                        }
                    }
                    _ => {}
                }
                if !body_opens {
                    i += 1;
                    continue;
                }
                // fall through: the `{` is handled by the block-open branch
            } else {
                if kind == Kind::Ident && text == "where" && sig_paren == 0 {
                    sig_in_where = true;
                    sig_ret_mode = false;
                } else if sig_ret_mode && kind == Kind::Ident && text == "Result" {
                    sig_returns_result = true;
                }
                i += 1;
                continue;
            }
        }

        // ---------- block open ----------
        if kind == Kind::Punct && text == "{" && !cond_mode {
            let (rank, ctest) = {
                let parent = &stack[stack.len() - 1];
                (parent.rank_cond, parent.cfg_test || pending_cfg_test)
            };
            pending_cfg_test = false;
            let blk = if let Some((bkind, crank)) = pending_cond.take() {
                let mut b = Block::new(bkind, rank || crank || pending_else_rank, ctest);
                if bkind == BlockKind::MatchBody {
                    b.arm_pattern = true;
                    b.pat_line = line;
                }
                pending_else_rank = false;
                b
            } else if let Some((name, rr)) = pending_fn.take() {
                let mut b = Block::new(BlockKind::Fn, rank || pending_else_rank, ctest);
                b.returns_result = rr;
                b.fn_name = name;
                pending_else_rank = false;
                b
            } else {
                let mut is_closure = false;
                if i >= 1 {
                    let j = i - 1;
                    let jt = t_text(&toks, j);
                    if t_kind(&toks, j) == Kind::Punct && (jt == "|" || jt == "||") {
                        is_closure = true;
                    } else {
                        // `|args| -> Type {` — walk back over type-ish tokens
                        let mut k = j as isize;
                        let mut steps = 0;
                        while k >= 0 && steps < 12 {
                            let ku = k as usize;
                            let tx = t_text(&toks, ku);
                            let tk = t_kind(&toks, ku);
                            if tk == Kind::Punct && tx == "->" {
                                if ku >= 1 {
                                    let pt = t_text(&toks, ku - 1);
                                    if t_kind(&toks, ku - 1) == Kind::Punct
                                        && (pt == "|" || pt == "||")
                                    {
                                        is_closure = true;
                                    }
                                }
                                break;
                            }
                            let typeish = matches!(tk, Kind::Ident | Kind::Lifetime)
                                || (tk == Kind::Punct
                                    && matches!(
                                        tx,
                                        "::" | "<"
                                            | ">"
                                            | ">>"
                                            | "&"
                                            | "("
                                            | ")"
                                            | "["
                                            | "]"
                                            | ","
                                    ));
                            if typeish {
                                k -= 1;
                                steps += 1;
                                continue;
                            }
                            break;
                        }
                    }
                }
                let b = Block::new(
                    if is_closure {
                        BlockKind::Closure
                    } else {
                        BlockKind::Other
                    },
                    rank || pending_else_rank,
                    ctest,
                );
                pending_else_rank = false;
                b
            };
            stack.push(blk);
            stmt.reset();
            i += 1;
            continue;
        }

        // ---------- inside a MatchBody: pattern mode ----------
        {
            let last = stack.len() - 1;
            if stack[last].kind == BlockKind::MatchBody && stack[last].arm_pattern && !cond_mode {
                if kind == Kind::Punct && text == "=>" {
                    finalize_arm_pattern(&mut stack[last], r4);
                    stmt.reset();
                    i += 1;
                    continue;
                }
                if !(kind == Kind::Punct && text == "}") {
                    if kind == Kind::Punct && text == "," && stack[last].cur_pattern.is_empty() {
                        i += 1;
                        continue;
                    }
                    if stack[last].cur_pattern.is_empty() {
                        stack[last].pat_line = line;
                    }
                    stack[last].cur_pattern.push(text.to_string());
                    i += 1;
                    continue;
                }
                // a `}` with an open pattern closes the match itself
                // (trailing comma / empty arm) — handled by block close below
            }
        }

        // ---------- inside a MatchBody: non-braced arm body ----------
        {
            let last = stack.len() - 1;
            if stack[last].kind == BlockKind::MatchBody
                && !stack[last].arm_pattern
                && !cond_mode
                && kind == Kind::Punct
            {
                if text == "(" || text == "[" {
                    stack[last].expr_depth += 1;
                } else if text == ")" || text == "]" {
                    stack[last].expr_depth -= 1;
                } else if text == "," && stack[last].expr_depth == 0 {
                    stack[last].arm_pattern = true;
                    stack[last].cur_pattern.clear();
                    stmt.reset();
                    i += 1;
                    continue;
                }
            }
        }

        // ---------- block close ----------
        if kind == Kind::Punct && text == "}" && !cond_mode {
            if stack.len() > 1 {
                let blk = stack.pop().expect("stack always has a root block");
                if blk.kind == BlockKind::MatchBody
                    && blk.is_roundkind
                    && blk.wildcard_line > 0
                    && !blk.cfg_test
                {
                    push(
                        findings,
                        "R4",
                        path,
                        blk.wildcard_line,
                        "wildcard `_` arm in a RoundKind match defeats cross-file \
                         exhaustiveness — write every variant out"
                            .to_string(),
                    );
                }
                let last = stack.len() - 1;
                if stack[last].kind == BlockKind::MatchBody {
                    // a braced arm body just closed: next tokens are the
                    // following arm's pattern
                    stack[last].arm_pattern = true;
                    stack[last].cur_pattern.clear();
                    stack[last].pat_line = line;
                }
                let was_rank_if = blk.rank_cond && !stack[last].rank_cond;
                if was_rank_if
                    && t_kind(&toks, i + 1) == Kind::Ident
                    && t_text(&toks, i + 1) == "else"
                {
                    pending_else_rank = true;
                }
            }
            stmt.reset();
            i += 1;
            continue;
        }

        // ---------- condition-collection mode ----------
        if cond_mode {
            if kind == Kind::Punct {
                match text {
                    "(" | "[" => cond_depth += 1,
                    ")" | "]" => cond_depth -= 1,
                    "{" => {
                        if cond_depth == 0 {
                            // condition ends; re-handle `{` as the body block
                            cond_mode = false;
                            pending_cond = Some((cond_kind, cond_has_rank));
                            continue;
                        }
                        cond_depth += 1;
                    }
                    "}" => cond_depth -= 1,
                    _ => {}
                }
            } else if kind == Kind::Ident && text == "rank" {
                cond_has_rank = true;
            }
            // no continue: call rules still apply inside conditions
        }

        // ---------- statement tracking ----------
        if kind == Kind::Punct && text == ";" {
            end_stmt(&mut stack, &mut stmt);
            i += 1;
            continue;
        }
        if kind == Kind::Punct && text == "=>" {
            stmt.reset();
            i += 1;
            continue;
        }
        if stmt.first.len() < 3 {
            stmt.first.push(text.to_string());
            if stmt.first.len() == 1 && stmt.first[0] == "let" {
                stmt.is_let = true;
                stmt.line = line;
            }
        }
        if stmt.is_let && stmt.bind.is_none() && kind == Kind::Ident && text != "let" && text != "mut"
        {
            stmt.bind = Some(text.to_string());
        }
        if kind == Kind::Ident
            && text == "lock"
            && t_text(&toks, i.wrapping_sub(1)) == "."
            && t_text(&toks, i + 1) == "("
        {
            stmt.has_lock = true;
        }

        // ---------- keywords starting control flow / items ----------
        if kind == Kind::Ident && !cond_mode {
            if text == "fn" {
                if t_kind(&toks, i + 1) == Kind::Ident {
                    sig_mode = true;
                    sig_name = t_text(&toks, i + 1).to_string();
                    sig_paren = 0;
                    sig_angle = 0;
                    sig_ret_mode = false;
                    sig_in_where = false;
                    sig_returns_result = false;
                    i += 2;
                    continue;
                }
            } else if text == "if" || text == "while" || text == "match" {
                cond_mode = true;
                cond_kind = if text == "match" {
                    BlockKind::MatchBody
                } else {
                    BlockKind::Other
                };
                cond_depth = 0;
                cond_has_rank = false;
                i += 1;
                continue;
            } else if text == "enum"
                && t_text(&toks, i + 1) == "RoundKind"
                && t_text(&toks, i + 2) == "{"
                && !stack[stack.len() - 1].cfg_test
            {
                r4.enum_file = Some(path.to_string());
                r4.enum_line = line;
                let mut j = i + 3;
                let mut depth = 1i32;
                let mut expecting = true;
                while j < n && depth > 0 {
                    let tx = t_text(&toks, j);
                    let tk = t_kind(&toks, j);
                    if tk == Kind::Punct && (tx == "{" || tx == "(" || tx == "[") {
                        depth += 1;
                    } else if tk == Kind::Punct && (tx == "}" || tx == ")" || tx == "]") {
                        depth -= 1;
                    } else if depth == 1 && tk == Kind::Punct && tx == "," {
                        expecting = true;
                    } else if depth == 1 && tk == Kind::Punct && tx == "#" {
                        // variant attribute: skip the bracketed group
                        if t_text(&toks, j + 1) == "[" {
                            let mut d2 = 0i32;
                            j += 1;
                            while j < n {
                                let t2 = t_text(&toks, j);
                                if t2 == "[" {
                                    d2 += 1;
                                } else if t2 == "]" {
                                    d2 -= 1;
                                    if d2 == 0 {
                                        break;
                                    }
                                }
                                j += 1;
                            }
                        }
                    } else if depth == 1 && tk == Kind::Ident && expecting {
                        r4.variants.push(tx.to_string());
                        expecting = false;
                    }
                    j += 1;
                }
                i = j;
                continue;
            } else if text == "const"
                && (t_text(&toks, i + 1) == "COUNT" || t_text(&toks, i + 1) == "ALL")
                && !stack[stack.len() - 1].cfg_test
            {
                let cname = t_text(&toks, i + 1).to_string();
                let mut j = i + 2;
                let mut depth = 0i32;
                let mut refs: BTreeSet<String> = BTreeSet::new();
                let mut num: Option<String> = None;
                while j < n {
                    let tx = t_text(&toks, j);
                    let tk = t_kind(&toks, j);
                    if tk == Kind::Punct && (tx == "(" || tx == "[" || tx == "{") {
                        depth += 1;
                    } else if tk == Kind::Punct && (tx == ")" || tx == "]" || tx == "}") {
                        depth -= 1;
                    } else if tk == Kind::Punct && tx == ";" && depth == 0 {
                        // the `;` inside `[RoundKind; COUNT]` sits at depth 1
                        // and must not end the scan
                        break;
                    } else if tk == Kind::Ident
                        && tx == "RoundKind"
                        && t_text(&toks, j + 1) == "::"
                        && t_kind(&toks, j + 2) == Kind::Ident
                    {
                        refs.insert(t_text(&toks, j + 2).to_string());
                        j += 2;
                    } else if tk == Kind::Num && num.is_none() {
                        num = Some(tx.to_string());
                    }
                    j += 1;
                }
                if cname == "COUNT" && r4.count_decl.is_none() {
                    if let Some(nm) = &num {
                        if let Some(v) = parse_int(nm) {
                            r4.count_decl = Some((path.to_string(), line, v));
                        }
                    }
                }
                if cname == "ALL" && !refs.is_empty() && r4.all_refs.is_none() {
                    r4.all_refs = Some((path.to_string(), line, refs));
                }
                i = j;
                continue;
            }
        }

        // ---------- call-site rules ----------
        if kind == Kind::Ident && !stack[stack.len() - 1].cfg_test {
            let prev = t_text(&toks, i.wrapping_sub(1)).to_string();
            let nxt = t_text(&toks, i + 1).to_string();

            // R2: panic-freedom in dist/ library paths
            if in_dist {
                if (text == "unwrap" || text == "expect") && prev == "." && nxt == "(" {
                    push(
                        findings,
                        "R2",
                        path,
                        line,
                        format!(
                            "`.{text}()` in dist/ library code — propagate a CommError \
                             (or add a justified spmd-lint allow)"
                        ),
                    );
                } else if PANIC_MACROS.contains(&text) && nxt == "!" {
                    push(
                        findings,
                        "R2",
                        path,
                        line,
                        format!(
                            "`{text}!` in dist/ library code — return Err(CommError) so \
                             peers see PeerLost, not a hang"
                        ),
                    );
                }
            }

            // R6: sampler-thread code must not switch planes
            if in_prefetch {
                if text == "plane" && prev == "." && nxt == "(" {
                    push(
                        findings,
                        "R6",
                        path,
                        line,
                        "`.plane()` in sampler-thread code — the sampler owns exactly \
                         the one plane handle it was given; deriving another would let \
                         its rounds interleave with the trainer's"
                            .to_string(),
                    );
                } else if text == "Plane"
                    && nxt == "::"
                    && t_text(&toks, i + 2) == "Gradient"
                {
                    push(
                        findings,
                        "R6",
                        path,
                        line,
                        "`Plane::Gradient` in sampler-thread code — the gradient plane \
                         belongs to the trainer thread"
                            .to_string(),
                    );
                }
            }

            // R5: no transport send/flush while a MutexGuard is live
            if in_dist && SEND_METHODS.contains(&text) && prev == "." && nxt == "(" {
                let live: Vec<(String, u32)> = stack
                    .iter()
                    .flat_map(|b| b.guards.iter().cloned())
                    .collect();
                if let Some((gname, gline)) = live.last() {
                    push(
                        findings,
                        "R5",
                        path,
                        line,
                        format!(
                            "`.{text}()` while MutexGuard `{gname}` (line {gline}) is \
                             live — drop the guard before touching the transport"
                        ),
                    );
                } else if stmt.has_lock {
                    push(
                        findings,
                        "R5",
                        path,
                        line,
                        format!(
                            "`.{text}()` in the same statement as a `.lock()` temporary \
                             — the guard is live across the call"
                        ),
                    );
                }
            }

            // drop(guard) releases an R5 guard
            if text == "drop"
                && nxt == "("
                && t_kind(&toks, i + 2) == Kind::Ident
                && t_text(&toks, i + 3) == ")"
            {
                let victim = t_text(&toks, i + 2).to_string();
                for blk in stack.iter_mut() {
                    blk.guards.retain(|g| g.0 != victim);
                }
            }

            // collective calls: R1 + R3
            if is_collective(text) && prev != "fn" {
                if let Some(cp) = call_paren_index(&toks, i) {
                    if stack[stack.len() - 1].rank_cond || (cond_mode && cond_has_rank) {
                        push(
                            findings,
                            "R1",
                            path,
                            line,
                            format!(
                                "collective `{text}` under rank-conditional control flow \
                                 — every rank must reach every collective in the same \
                                 order"
                            ),
                        );
                    }
                    let close = find_close_paren(&toks, cp);
                    if t_text(&toks, close + 1) == "."
                        && t_text(&toks, close + 2) == "ok"
                        && t_text(&toks, close + 3) == "("
                    {
                        push(
                            findings,
                            "R3",
                            path,
                            line,
                            format!(
                                "result of collective `{text}` discarded via `.ok()` — a \
                                 swallowed CommError desynchronizes the world"
                            ),
                        );
                    }
                    if stmt.first.len() >= 3
                        && stmt.first[0] == "let"
                        && stmt.first[1] == "_"
                        && stmt.first[2] == "="
                    {
                        push(
                            findings,
                            "R3",
                            path,
                            line,
                            format!(
                                "result of collective `{text}` discarded via `let _ =` — \
                                 propagate the CommError"
                            ),
                        );
                    }
                    // the enclosing fn must return Result (closures exempt)
                    let mut encl: Option<&Block> = None;
                    for blk in stack.iter().rev() {
                        if blk.kind == BlockKind::Fn || blk.kind == BlockKind::Closure {
                            encl = Some(blk);
                            break;
                        }
                    }
                    if let Some(e) = encl {
                        if e.kind == BlockKind::Fn && !e.returns_result {
                            push(
                                findings,
                                "R3",
                                path,
                                line,
                                format!(
                                    "fn `{}` calls collective `{text}` but does not \
                                     return Result — fabric errors must propagate",
                                    e.fn_name
                                ),
                            );
                        }
                    }
                }
            }
        }

        i += 1;
    }
}

fn finalize_r4(r4: &R4State, findings: &mut Vec<Finding>) {
    if r4.variants.is_empty() {
        return;
    }
    let vs = &r4.variants;
    if let Some((f, ln, val)) = &r4.count_decl {
        if *val != vs.len() as u64 {
            push(
                findings,
                "R4",
                f,
                *ln,
                format!(
                    "RoundKind::COUNT is {val} but the enum has {} variants",
                    vs.len()
                ),
            );
        }
    }
    if let Some((f, ln, refs)) = &r4.all_refs {
        for v in vs {
            if !refs.contains(v) {
                push(
                    findings,
                    "R4",
                    f,
                    *ln,
                    format!(
                        "RoundKind::{v} is missing from the ALL array — encode-side \
                         iteration will skip it"
                    ),
                );
            }
        }
    }
    for v in vs {
        if !r4.matched.contains(v) {
            let ef = r4.enum_file.clone().unwrap_or_default();
            push(
                findings,
                "R4",
                &ef,
                r4.enum_line,
                format!(
                    "RoundKind::{v} appears in no match arm — decode-side dispatch does \
                     not cover it"
                ),
            );
        }
    }
}

/// Lint a set of `(path, source)` pairs as one unit (R4 is cross-file).
/// Returns findings sorted by `(file, line, rule)`, with suppressed findings
/// removed.
pub fn lint_sources(files: &[(String, String)]) -> Vec<Finding> {
    let mut findings: Vec<Finding> = Vec::new();
    let mut r4 = R4State::default();
    let mut suppress: BTreeMap<String, BTreeSet<(u32, String)>> = BTreeMap::new();
    for (path, src) in files {
        let sup = parse_allows(path, src, &mut findings);
        suppress.insert(path.clone(), sup);
        analyze_file(path, src, &mut r4, &mut findings);
    }
    finalize_r4(&r4, &mut findings);
    let mut out: Vec<Finding> = findings
        .into_iter()
        .filter(|f| match suppress.get(&f.file) {
            Some(sup) => {
                !sup.contains(&(f.line, f.rule.clone()))
                    && !sup.contains(&(f.line.saturating_sub(1), f.rule.clone()))
            }
            None => true,
        })
        .collect();
    out.sort_by(|a, b| {
        (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule))
    });
    out
}
