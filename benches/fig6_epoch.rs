//! Bench: paper Fig 6 — distributed epoch time for {vanilla, hybrid,
//! hybrid+fused} across worker counts on products-sim and
//! papers100m-sim, under the modeled 200 Gb/s InfiniBand fabric.
//!
//!   cargo bench --bench fig6_epoch
//!   FIG6_FULL=1 cargo bench --bench fig6_epoch    (bigger graphs + 8 workers)
//!
//! Also prints Table-1/Fig-4 context rows (dataset stats + storage
//! breakdown) so one bench run regenerates every table/figure's numbers.

use fastsample::coordinator::experiments::{fig4, fig6, rounds_report, table1, Fig6Opts};

fn main() -> anyhow::Result<()> {
    if !fastsample::config::artifacts_available() {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let full = std::env::var("FIG6_FULL").is_ok();

    // Context: Table 1 + Fig 4 (cheap, metadata + generated graphs).
    println!("{}", table1(0.01, 0.001, 7)?);
    println!("{}", fig4(0.01, 0.001, 7)?);

    let opts = if full {
        Fig6Opts {
            runs: vec![
                ("products-sim:0.05".into(), "fig6_products".into()),
                ("papers100m-sim:0.005".into(), "fig6_papers".into()),
            ],
            workers: vec![4, 8],
            epochs: 2,
            max_batches: Some(8),
            ..Default::default()
        }
    } else {
        Fig6Opts {
            runs: vec![
                ("products-sim:0.02".into(), "fig6_products_small".into()),
                ("papers100m-sim:0.002".into(), "fig6_papers_small".into()),
            ],
            workers: vec![4, 8],
            epochs: 1,
            max_batches: Some(6),
            ..Default::default()
        }
    };
    println!("{}", fig6(&opts)?);

    // A3 rounds accounting rides along (cheap, quickstart-sized).
    println!("{}", rounds_report(4, 7, &fastsample::dist::TransportConfig::Inproc)?);
    Ok(())
}
