//! Microbenchmarks of the L3 hot paths feeding the figure-level numbers:
//! per-level sampling kernels, the relabel/intern pass, the RNG, the
//! partitioner, and the all-reduce collective. These are the profile
//! targets of EXPERIMENTS.md §Perf.
//!
//!   cargo bench --bench kernels_micro
//!
//! Besides the printed table, results are dumped as machine-readable JSON
//! to `BENCH_dist.json` (override the path with `BENCH_JSON=...`), giving
//! later PRs a perf trajectory to diff against.

use std::collections::BTreeMap;

use fastsample::dist::{run_workers, NetworkModel, RoundKind};
use fastsample::graph::generator::{planted_communities, rmat};
use fastsample::partition::{partition_graph, PartitionConfig};
use fastsample::sampling::rng::RngKey;
use fastsample::sampling::{
    sample_level_baseline, sample_level_fused, SamplerWorkspace,
};
use fastsample::util::bench::{header, Bencher, Stats};
use fastsample::util::json::Json;

fn main() {
    let bench = Bencher::default();
    let mut all: Vec<Stats> = Vec::new();
    println!("{}", header());

    // ---- Per-level kernels on a skewed RMAT graph (1M edges).
    let g = rmat(1 << 17, 1 << 20, (0.57, 0.19, 0.19, 0.05), RngKey::new(1));
    let seeds: Vec<u32> = (0..8192u32).map(|i| i * 13 % (1 << 17)).collect();
    // Dedup seeds (sampling requires unique seeds).
    let seeds = {
        let mut s = seeds;
        s.sort_unstable();
        s.dedup();
        s
    };
    for fanout in [5usize, 15, 30] {
        let mut ws = SamplerWorkspace::new();
        let key = RngKey::new(2);
        let mut i = 0u64;
        let s = bench.run(&format!("level/baseline fanout={fanout}"), || {
            i += 1;
            sample_level_baseline(&g, &seeds, fanout, key.fold(i), &mut ws)
        });
        println!("{}", s.row());
        all.push(s);
        let mut ws = SamplerWorkspace::new();
        let mut j = 0u64;
        let s = bench.run(&format!("level/fused    fanout={fanout}"), || {
            j += 1;
            sample_level_fused(&g, &seeds, fanout, key.fold(j), &mut ws)
        });
        println!("{}", s.row());
        all.push(s);
    }

    // ---- Relabel/intern pass in isolation.
    {
        let mut ws = SamplerWorkspace::new();
        let ids: Vec<u32> = (0..100_000u32).map(|i| i.wrapping_mul(2654435761) >> 15).collect();
        let s = bench.run("workspace/intern 100k ids", || {
            ws.begin(1 << 17);
            let mut order = Vec::with_capacity(ids.len());
            for &v in &ids {
                std::hint::black_box(ws.intern(v, &mut order));
            }
            order.len()
        });
        println!("{}", s.row());
        all.push(s);
    }

    // ---- RNG throughput.
    {
        let key = RngKey::new(3);
        let s = bench.run("rng/sample_distinct 30-of-300 x1k", || {
            let mut out = Vec::new();
            let mut acc = 0usize;
            for i in 0..1000 {
                let mut st = key.stream(i);
                st.sample_distinct(300, 30, &mut out);
                acc += out[0];
            }
            acc
        });
        println!("{}", s.row());
        all.push(s);
    }

    // ---- Partitioner end to end (64k nodes).
    {
        let (pg, _) = planted_communities(65_536, 8, 12, 0.9, RngKey::new(4));
        let train: Vec<u32> = (0..65_536u32).step_by(11).collect();
        let slow = Bencher {
            budget: std::time::Duration::from_secs(6),
            min_iters: 3,
            ..Default::default()
        };
        let s = slow.run("partition/metis-like 64k x8", || {
            partition_graph(&pg, &train, &PartitionConfig::new(8))
        });
        println!("{}", s.row());
        all.push(s);
    }

    // ---- All-reduce collective (1M floats, 4 workers).
    {
        let slow = Bencher {
            budget: std::time::Duration::from_secs(4),
            min_iters: 3,
            ..Default::default()
        };
        let s = slow.run("comm/all_reduce 1M f32 x4 workers", || {
            run_workers(4, NetworkModel::free(), |rank, comm| {
                let mut data = vec![rank as f32; 1 << 20];
                comm.all_reduce_mean_f32(RoundKind::GradSync, &mut data);
                data[0]
            })
        });
        println!("{}", s.row());
        all.push(s);
    }

    // ---- Machine-readable record for the perf trajectory.
    let path = std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_dist.json".into());
    let doc = Json::Obj(BTreeMap::from([
        ("schema".to_string(), Json::Str("fastsample-bench-v1".into())),
        ("bench".to_string(), Json::Str("kernels_micro".into())),
        ("status".to_string(), Json::Str("measured".into())),
        (
            "threads".to_string(),
            Json::Num(fastsample::util::par::num_threads() as f64),
        ),
        ("results".to_string(), Json::Arr(all.iter().map(stats_json).collect())),
    ]));
    match std::fs::write(&path, doc.dump() + "\n") {
        Ok(()) => println!("\nwrote {} results to {path}", all.len()),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn stats_json(s: &Stats) -> Json {
    Json::Obj(BTreeMap::from([
        ("name".to_string(), Json::Str(s.name.clone())),
        ("iters".to_string(), Json::Num(s.iters as f64)),
        ("mean_s".to_string(), Json::Num(s.mean)),
        ("std_s".to_string(), Json::Num(s.std)),
        ("min_s".to_string(), Json::Num(s.min)),
        ("p50_s".to_string(), Json::Num(s.p50)),
        ("p95_s".to_string(), Json::Num(s.p95)),
    ]))
}
