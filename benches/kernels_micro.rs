//! Microbenchmarks of the L3 hot paths feeding the figure-level numbers:
//! per-level sampling kernels, the relabel/intern pass, the RNG, the
//! partitioner, and the all-reduce collective. These are the profile
//! targets of EXPERIMENTS.md §Perf.
//!
//!   cargo bench --bench kernels_micro
//!
//! Besides the printed table, results are dumped as machine-readable JSON
//! to `BENCH_dist.json` (override the path with `BENCH_JSON=...`), giving
//! later PRs a perf trajectory to diff against. Set
//! `FASTSAMPLE_BENCH_QUICK=1` for the CI smoke mode: same cases at ~1/8
//! scale with short budgets, so the bench targets and the JSON
//! regeneration path stay exercised on every push.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::Duration;

use fastsample::dist::{
    fetch_features, run_workers, sample_mfgs_distributed_wire, CachePolicy, NetworkModel, Plane,
    RoundKind, SamplingWire,
};
use fastsample::graph::generator::{make_dataset, planted_communities, rmat, DatasetParams};
use fastsample::partition::{build_shards, partition_graph, PartitionConfig, ReplicationPolicy};
use fastsample::sampling::rng::RngKey;
use fastsample::sampling::{
    sample_level_baseline, sample_level_fused, KernelKind, MinibatchSchedule, SamplerWorkspace,
};
use fastsample::train::prefetch::{sampler_epochs, Produced, ProducerPlan};
use fastsample::util::bench::{header, Bencher, Stats};
use fastsample::util::json::Json;

fn main() {
    // Value-checked, not presence-checked: FASTSAMPLE_BENCH_QUICK=0 (or
    // empty) must still run the full-scale trajectory baseline.
    let quick = std::env::var("FASTSAMPLE_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let bench = if quick {
        Bencher {
            warmup: Duration::from_millis(20),
            budget: Duration::from_millis(200),
            min_iters: 2,
            ..Default::default()
        }
    } else {
        Bencher::default()
    };
    let mut all: Vec<Stats> = Vec::new();
    if quick {
        println!("(quick mode: reduced sizes/budgets — trajectory numbers come from full runs)");
    }
    println!("{}", header());

    // ---- Per-level kernels on a skewed RMAT graph (1M edges; 128K quick).
    let (log_n, log_m) = if quick { (14, 17) } else { (17, 20) };
    let g = rmat(1 << log_n, 1 << log_m, (0.57, 0.19, 0.19, 0.05), RngKey::new(1));
    let seeds: Vec<u32> = (0..8192u32).map(|i| i * 13 % (1u32 << log_n)).collect();
    // Dedup seeds (sampling requires unique seeds).
    let seeds = {
        let mut s = seeds;
        s.sort_unstable();
        s.dedup();
        s
    };
    for fanout in [5usize, 15, 30] {
        let mut ws = SamplerWorkspace::new();
        let key = RngKey::new(2);
        let mut i = 0u64;
        let s = bench.run(&format!("level/baseline fanout={fanout}"), || {
            i += 1;
            sample_level_baseline(&g, &seeds, fanout, key.fold(i), &mut ws)
        });
        println!("{}", s.row());
        all.push(s);
        let mut ws = SamplerWorkspace::new();
        let mut j = 0u64;
        let s = bench.run(&format!("level/fused    fanout={fanout}"), || {
            j += 1;
            sample_level_fused(&g, &seeds, fanout, key.fold(j), &mut ws)
        });
        println!("{}", s.row());
        all.push(s);
    }

    // ---- Relabel/intern pass in isolation.
    {
        let mut ws = SamplerWorkspace::new();
        let ids: Vec<u32> = (0..100_000u32).map(|i| i.wrapping_mul(2654435761) >> 15).collect();
        let s = bench.run("workspace/intern 100k ids", || {
            ws.begin(1 << 17);
            let mut order = Vec::with_capacity(ids.len());
            for &v in &ids {
                std::hint::black_box(ws.intern(v, &mut order));
            }
            order.len()
        });
        println!("{}", s.row());
        all.push(s);
    }

    // ---- RNG throughput.
    {
        let key = RngKey::new(3);
        let s = bench.run("rng/sample_distinct 30-of-300 x1k", || {
            let mut out = Vec::new();
            let mut acc = 0usize;
            for i in 0..1000 {
                let mut st = key.stream(i);
                st.sample_distinct(300, 30, &mut out);
                acc += out[0];
            }
            acc
        });
        println!("{}", s.row());
        all.push(s);
    }

    // ---- Partitioner end to end (64k nodes; 8k quick).
    let part_n: usize = if quick { 8_192 } else { 65_536 };
    {
        let (pg, _) = planted_communities(part_n, 8, 12, 0.9, RngKey::new(4));
        let train: Vec<u32> = (0..part_n as u32).step_by(11).collect();
        let slow = Bencher {
            budget: Duration::from_secs(if quick { 1 } else { 6 }),
            min_iters: 3,
            ..Default::default()
        };
        let s = slow.run(&format!("partition/metis-like {}k x8", part_n / 1024), || {
            partition_graph(&pg, &train, &PartitionConfig::new(8))
        });
        println!("{}", s.row());
        all.push(s);
    }

    // ---- Budgeted halo construction (the replication spectrum's setup
    // cost): build_shards at three budget points over one partition book.
    {
        let n = if quick { 4_096 } else { 32_768 };
        let d = make_dataset(&DatasetParams {
            name: "bench-halo".into(),
            num_nodes: n,
            avg_degree: 12,
            feat_dim: 8,
            num_classes: 4,
            labeled_frac: 0.1,
            p_intra: 0.9,
            noise: 0.2,
            seed: 9,
        });
        let book = std::sync::Arc::new(partition_graph(
            &d.graph,
            &d.train_ids,
            &PartitionConfig::new(8),
        ));
        let halo_max = book
            .halo_profile(&d.graph)
            .iter()
            .map(|h| h.halo_bytes)
            .max()
            .unwrap_or(0)
            .max(64);
        for (tag, policy) in [
            ("budget=0", ReplicationPolicy::vanilla()),
            ("budget=halo/2", ReplicationPolicy::budgeted(halo_max / 2)),
            ("budget=inf", ReplicationPolicy::hybrid()),
        ] {
            let s = bench.run(&format!("partition/build_shards {}k x8 {tag}", n / 1024), || {
                build_shards(&d, &book, &policy)
            });
            println!("{}", s.row());
            all.push(s);
        }
    }

    // ---- Distributed sampling across the wire × cache grid (vanilla
    // replication, 4 workers, 4 minibatches per run so the cached arms
    // actually warm up and later batches sample cached rows locally —
    // the effect the `cache-decay` report measures). Scalar-vs-bulk at
    // the same cache point isolates the columnar kernel's serve/decode
    // speedup; the sampled MFGs are bit-identical across wires.
    {
        let n = if quick { 2_048 } else { 16_384 };
        let d = make_dataset(&DatasetParams {
            name: "bench-dist-cache".into(),
            num_nodes: n,
            avg_degree: 10,
            feat_dim: 4,
            num_classes: 4,
            labeled_frac: 0.2,
            p_intra: 0.7, // plenty of cross-partition frontier
            noise: 0.2,
            seed: 17,
        });
        let book = std::sync::Arc::new(partition_graph(
            &d.graph,
            &d.train_ids,
            &PartitionConfig::new(4),
        ));
        let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
        let fanouts = [10usize, 5];
        let key = RngKey::new(23);
        for (wire_tag, wire) in
            [("scalar", SamplingWire::Scalar), ("bulk", SamplingWire::Bulk)]
        {
            for (tag, cache_bytes) in [("uncached", 0u64), ("cache=1m", 1 << 20)] {
                let shards_ref = &shards;
                let s = bench.run(
                    &format!("dist/sample_mfgs {}k x4 {wire_tag} {tag}", n / 1024),
                    || {
                        run_workers(4, NetworkModel::free(), move |rank, comm| {
                            let shard = &shards_ref[rank];
                            let mut view = shard.topology.clone();
                            if cache_bytes > 0 {
                                view.enable_cache(cache_bytes, CachePolicy::Clock);
                            }
                            let seeds: Vec<u32> =
                                shard.train_local.iter().copied().take(256).collect();
                            let mut ws = SamplerWorkspace::new();
                            let mut edges = 0usize;
                            for b in 0..4u64 {
                                let mfgs = sample_mfgs_distributed_wire(
                                    comm,
                                    shard,
                                    &mut view,
                                    &seeds,
                                    &fanouts,
                                    key.fold(b),
                                    &mut ws,
                                    KernelKind::Fused,
                                    wire,
                                )
                                .unwrap();
                                edges += mfgs.iter().map(|m| m.num_edges()).sum::<usize>();
                            }
                            edges
                        })
                    },
                );
                println!("{}", s.row());
                all.push(s);
            }
        }
    }

    // ---- Serial vs pipelined epoch (the `--pipeline` overlap): per
    // batch, distributed sampling + feature fetch plus a deterministic
    // f32 "train step" over the fetched rows. The pipelined arm runs the
    // sampler on its own thread over the Sampling plane (the production
    // `sampler_epochs` producer, depth-1 channel) so batch t+1's
    // sampling + fetch overlaps batch t's compute; the serial arm runs
    // the identical phase sequence inline. Bit-equality of the two modes
    // is pinned by the equivalence suites — these rows pin the
    // wall-clock direction (pipelined ≤ serial).
    {
        let n = if quick { 2_048 } else { 16_384 };
        let batch = if quick { 16 } else { 64 };
        let batches = 4usize;
        let d = make_dataset(&DatasetParams {
            name: "bench-pipe".into(),
            num_nodes: n,
            avg_degree: 10,
            feat_dim: 4,
            num_classes: 4,
            labeled_frac: 0.2,
            p_intra: 0.7,
            noise: 0.2,
            seed: 29,
        });
        let book = std::sync::Arc::new(partition_graph(
            &d.graph,
            &d.train_ids,
            &PartitionConfig::new(4),
        ));
        let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
        let fanouts = vec![10usize, 5];
        let key = RngKey::new(31);

        /// Deterministic consumer-side compute: a dense mul-add sweep
        /// over the fetched feature rows, sized to take about as long as
        /// one batch's sampling + fetch so the overlap is visible.
        fn train_step(feats: &[f32]) -> f32 {
            let mut acc = 0.0f32;
            for _ in 0..64 {
                for &v in feats {
                    acc = acc.mul_add(0.999_9, v);
                }
            }
            acc
        }

        for pipelined in [false, true] {
            let shards_ref = &shards;
            let fan = &fanouts;
            let tag = if pipelined { "pipelined" } else { "serial" };
            let s = bench.run(&format!("pipeline/epoch {}k x4 {tag}", n / 1024), || {
                run_workers(4, NetworkModel::free(), move |rank, comm| {
                    let shard = &shards_ref[rank];
                    let mut view = shard.topology.clone();
                    let mut ws = SamplerWorkspace::new();
                    let mut scomm = comm.plane(Plane::Sampling);
                    let mut acc = 0.0f32;
                    if pipelined {
                        let plan = ProducerPlan {
                            key,
                            epochs: 1,
                            batches,
                            batch,
                            kernel: KernelKind::Fused,
                            wire: SamplingWire::Scalar,
                        };
                        let (items_tx, items_rx) = mpsc::sync_channel::<Produced>(1);
                        let (go_tx, go_rx) = mpsc::channel::<Vec<usize>>();
                        std::thread::scope(|scope| {
                            let scomm = &mut scomm;
                            let view = &mut view;
                            let ws = &mut ws;
                            let plan = &plan;
                            scope.spawn(move || {
                                sampler_epochs(
                                    scomm, shard, view, ws, None, plan, &items_tx, &go_rx,
                                )
                                .unwrap();
                            });
                            go_tx.send(fan.clone()).unwrap();
                            for _ in 0..batches {
                                let Ok(Produced::Batch { feats, .. }) = items_rx.recv() else {
                                    panic!("prefetcher stopped early");
                                };
                                acc += train_step(&feats);
                            }
                            match items_rx.recv() {
                                Ok(Produced::EpochEnd { .. }) => {}
                                other => panic!("expected epoch end, got {other:?}"),
                            }
                        });
                    } else {
                        let schedule =
                            MinibatchSchedule::new(&shard.train_local, batch, key.fold(0));
                        for b in 0..batches {
                            let seeds = schedule.batch(b).to_vec();
                            let mfgs = sample_mfgs_distributed_wire(
                                &mut scomm,
                                shard,
                                &mut view,
                                &seeds,
                                fan,
                                key.fold(0).fold(b as u64 + 1),
                                &mut ws,
                                KernelKind::Fused,
                                SamplingWire::Scalar,
                            )
                            .unwrap();
                            let mut feats = Vec::new();
                            fetch_features(&mut scomm, shard, &mfgs[0].src_nodes, None, &mut feats)
                                .unwrap();
                            acc += train_step(&feats);
                        }
                    }
                    acc
                })
            });
            println!("{}", s.row());
            all.push(s);
        }
    }

    // ---- All-reduce collective (1M floats, 4 workers; 64k quick).
    {
        let words: usize = if quick { 1 << 16 } else { 1 << 20 };
        let slow = Bencher {
            budget: Duration::from_secs(if quick { 1 } else { 4 }),
            min_iters: 3,
            ..Default::default()
        };
        let s = slow.run(
            &format!("comm/all_reduce {}k f32 x4 workers", words >> 10),
            || {
                run_workers(4, NetworkModel::free(), |rank, comm| {
                    let mut data = vec![rank as f32; words];
                    comm.all_reduce_mean_f32(RoundKind::GradSync, &mut data).unwrap();
                    data[0]
                })
            },
        );
        println!("{}", s.row());
        all.push(s);
    }

    // ---- Machine-readable record for the perf trajectory.
    let path = std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_dist.json".into());
    let doc = Json::Obj(BTreeMap::from([
        ("schema".to_string(), Json::Str("fastsample-bench-v1".into())),
        ("bench".to_string(), Json::Str("kernels_micro".into())),
        ("status".to_string(), Json::Str("measured".into())),
        // Quick-mode records exercise the pipeline but are not trajectory
        // baselines; diff tooling should prefer quick=false records.
        ("quick".to_string(), Json::Bool(quick)),
        (
            "threads".to_string(),
            Json::Num(fastsample::util::par::num_threads() as f64),
        ),
        ("results".to_string(), Json::Arr(all.iter().map(stats_json).collect())),
    ]));
    match std::fs::write(&path, doc.dump() + "\n") {
        Ok(()) => println!("\nwrote {} results to {path}", all.len()),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn stats_json(s: &Stats) -> Json {
    Json::Obj(BTreeMap::from([
        ("name".to_string(), Json::Str(s.name.clone())),
        ("iters".to_string(), Json::Num(s.iters as f64)),
        ("mean_s".to_string(), Json::Num(s.mean)),
        ("std_s".to_string(), Json::Num(s.std)),
        ("min_s".to_string(), Json::Num(s.min)),
        ("p50_s".to_string(), Json::Num(s.p50)),
        ("p95_s".to_string(), Json::Num(s.p95)),
    ]))
}
