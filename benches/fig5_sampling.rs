//! Bench: paper Fig 5 — fused-kernel speedup over the DGL-style baseline,
//! swept over batch sizes and fanout tuples on papers100m-sim.
//!
//!   cargo bench --bench fig5_sampling
//!   FIG5_SCALE=0.005 FIG5_FULL=1 cargo bench --bench fig5_sampling
//!
//! Prints the same two panels the paper plots: sampling-only speedup and
//! overall (sampling + training) speedup.

use fastsample::coordinator::experiments::{fig5_e2e, fig5_sampling, Fig5Opts};

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::var("FIG5_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.002);
    let full = std::env::var("FIG5_FULL").is_ok();

    let mut opts = Fig5Opts {
        dataset_spec: format!("papers100m-sim:{scale}"),
        seed: 7,
        ..Default::default()
    };
    if !full {
        opts.batch_sizes = vec![1024, 2048, 4096];
        opts.fanout_sets =
            vec![vec![5, 5, 5], vec![10, 10, 10], vec![15, 10, 5], vec![20, 15, 10]];
        opts.iters = 5;
    }

    println!("{}", fig5_sampling(&opts)?);

    // Bottom panel needs the fig5_* AOT variants; skip cleanly otherwise.
    if fastsample::config::artifacts_available() {
        opts.iters = 3;
        println!("{}", fig5_e2e(&opts)?);
    } else {
        println!("(skipping end-to-end panel: run `make artifacts`)");
    }
    Ok(())
}
