//! Multi-process rendezvous: N **real OS processes** (re-exec'd children
//! of this test binary) rendezvous over `TcpMesh::connect` and must be
//! bit-equal to the in-process `ChannelMesh` harness.
//!
//! * `spawned_worker_child_entry` is the child role: inert under a
//!   normal test run, but when the parent re-execs this binary with the
//!   `FASTSAMPLE_TEST_CHILD_*` environment set, it runs one rank of the
//!   workload through `run_worker_process` and writes its full report
//!   (digest curve, seeds, MFGs, per-process counters — all in exact
//!   textual form, f32 by bit pattern) to a file.
//! * The parent spawns 4 children, computes the same per-rank reports
//!   over the in-process channel mesh, and compares **strings**: equal
//!   encodings ⇒ bit-identical MFGs and digest curves. Counters are
//!   compared by their multi-process semantics: rank 0 carries the
//!   global round counts, and per-rank bytes sum to the in-process
//!   totals.
//! * A rank that exits early must surface as `CommError::PeerLost` in
//!   every survivor — no hang — bounded by a hard parent-side deadline.
//! * Children running `--pipeline on` (the double-buffered MFG
//!   prefetcher) stay bit-equal to the serial in-process run.
//! * With AOT artifacts present, the same harness runs real training
//!   (`train_rank`) and pins the loss curve (skips politely otherwise,
//!   like `train_e2e`).

use std::fmt::Write as _;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastsample::dist::{
    run_worker_process, run_workers_with, Counters, NetworkModel, RendezvousConfig, RoundKind,
};
use fastsample::graph::generator::{make_dataset, DatasetParams};
use fastsample::graph::Dataset;
use fastsample::train::{sample_rank, train_distributed, train_rank, SampleRankReport, TrainConfig};

const WORLD: usize = 4;
const BATCH: usize = 8;
const FANOUTS: [usize; 2] = [3, 2];

fn sample_dataset() -> Dataset {
    make_dataset(&DatasetParams {
        name: "process-rendezvous".into(),
        num_nodes: 500,
        avg_degree: 8,
        feat_dim: 5,
        num_classes: 4,
        labeled_frac: 0.3,
        p_intra: 0.8,
        noise: 0.2,
        seed: 41,
    })
}

/// The sample-task config every rank (thread or process) runs with.
fn task_config(world: usize, epochs: usize, max_batches: usize, pipeline: bool) -> TrainConfig {
    let mut cfg = TrainConfig::mode("quickstart", "vanilla", world).unwrap();
    cfg.epochs = epochs;
    cfg.max_batches = Some(max_batches);
    cfg.net = NetworkModel::free();
    cfg.seed = 7;
    cfg.verbose = false;
    cfg.pipeline = pipeline;
    cfg
}

fn quick_rdv() -> RendezvousConfig {
    RendezvousConfig {
        timeout: Duration::from_secs(60),
        retry_initial: Duration::from_millis(5),
        retry_max: Duration::from_millis(100),
        bind: None,
    }
}

/// Exact textual encoding of a rank's report: first the counter lines
/// (per-process semantics), then the bit-exact body (digest curve as f32
/// bit patterns, seeds, every MFG's arrays).
fn encode_report(r: &SampleRankReport) -> String {
    let mut s = String::new();
    write!(s, "rounds").unwrap();
    for k in RoundKind::ALL {
        write!(s, " {}", r.comm_total.rounds_of(k)).unwrap();
    }
    writeln!(s).unwrap();
    write!(s, "bytes").unwrap();
    for k in RoundKind::ALL {
        write!(s, " {}", r.comm_total.bytes_of(k)).unwrap();
    }
    writeln!(s).unwrap();
    s.push_str(&encode_body(r));
    s
}

/// The counter-free part of the encoding (identical between process
/// layouts; the counters are compared by their own rules).
fn encode_body(r: &SampleRankReport) -> String {
    let mut s = String::new();
    write!(s, "curve").unwrap();
    for v in &r.curve {
        write!(s, " {:08x}", v.to_bits()).unwrap();
    }
    writeln!(s).unwrap();
    write!(s, "seeds").unwrap();
    for v in &r.seeds {
        write!(s, " {v}").unwrap();
    }
    writeln!(s).unwrap();
    for (step, mfgs) in r.mfgs.iter().enumerate() {
        for (li, m) in mfgs.iter().enumerate() {
            write!(s, "mfg {step} {li} ndst {} indptr", m.n_dst).unwrap();
            for v in &m.indptr {
                write!(s, " {v}").unwrap();
            }
            write!(s, " indices").unwrap();
            for v in &m.indices {
                write!(s, " {v}").unwrap();
            }
            write!(s, " src").unwrap();
            for v in &m.src_nodes {
                write!(s, " {v}").unwrap();
            }
            writeln!(s).unwrap();
        }
    }
    s
}

/// Parse one `rounds ...` / `bytes ...` counter line back into numbers.
fn parse_counter_line(line: &str, tag: &str) -> Vec<u64> {
    let mut it = line.split_whitespace();
    assert_eq!(it.next(), Some(tag), "bad counter line {line:?}");
    it.map(|t| t.parse().unwrap()).collect()
}

// ---------------------------------------------------------------------------
// The child role (inert unless the parent set the environment)
// ---------------------------------------------------------------------------

#[test]
fn spawned_worker_child_entry() {
    let Ok(rank) = std::env::var("FASTSAMPLE_TEST_CHILD_RANK") else {
        return; // normal test run: nothing to do
    };
    let rank: usize = rank.parse().unwrap();
    let peers: Vec<String> = std::env::var("FASTSAMPLE_TEST_CHILD_PEERS")
        .unwrap()
        .split(',')
        .map(String::from)
        .collect();
    let out_path = std::env::var("FASTSAMPLE_TEST_CHILD_OUT").unwrap();
    let epochs: usize = std::env::var("FASTSAMPLE_TEST_CHILD_EPOCHS").unwrap().parse().unwrap();
    let steps: usize = std::env::var("FASTSAMPLE_TEST_CHILD_STEPS").unwrap().parse().unwrap();
    let task = std::env::var("FASTSAMPLE_TEST_CHILD_TASK").unwrap_or_else(|_| "sample".into());
    let pipeline = std::env::var("FASTSAMPLE_TEST_CHILD_PIPELINE")
        .map(|v| v == "on")
        .unwrap_or(false);
    let counters = Arc::new(Counters::default());

    let body = if task == "train" {
        let artifacts =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let d = fastsample::graph::datasets::quickstart(1);
        let mut cfg = TrainConfig::mode("quickstart", "vanilla", peers.len()).unwrap();
        cfg.epochs = epochs;
        cfg.max_batches = Some(steps);
        cfg.net = NetworkModel::free();
        cfg.seed = 3;
        cfg.verbose = false;
        let result = run_worker_process(
            rank,
            &peers,
            &quick_rdv(),
            None,
            NetworkModel::free(),
            counters,
            |rank, comm| train_rank(&d, &artifacts, &cfg, rank, comm),
        )
        .expect("rendezvous failed");
        match result {
            Ok(r) => {
                let mut s = String::new();
                write!(s, "loss").unwrap();
                for v in &r.loss_curve {
                    write!(s, " {:08x}", v.to_bits()).unwrap();
                }
                writeln!(s).unwrap();
                s
            }
            Err(e) => format!("ERROR {e:#}\n"),
        }
    } else {
        let d = sample_dataset();
        let cfg = task_config(peers.len(), epochs, steps, pipeline);
        let result = run_worker_process(
            rank,
            &peers,
            &quick_rdv(),
            None,
            NetworkModel::free(),
            counters,
            |rank, comm| sample_rank(&d, &cfg, BATCH, &FANOUTS, true, rank, comm),
        )
        .expect("rendezvous failed");
        match result {
            Ok(r) => encode_report(&r),
            Err(e) => format!("ERROR {e:#}\n"),
        }
    };
    std::fs::write(&out_path, body).unwrap();
}

// ---------------------------------------------------------------------------
// The parent side
// ---------------------------------------------------------------------------

/// Reserve `n` distinct loopback ports (bind-then-drop; the dial retries
/// of the rendezvous absorb start-order races).
fn free_peer_csv(n: usize) -> String {
    let listeners: Vec<std::net::TcpListener> =
        (0..n).map(|_| std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap()).collect();
    listeners
        .iter()
        .map(|l| format!("127.0.0.1:{}", l.local_addr().unwrap().port()))
        .collect::<Vec<_>>()
        .join(",")
}

struct ChildSpec {
    rank: usize,
    steps: usize,
    epochs: usize,
    task: &'static str,
    pipeline: bool,
}

/// Re-exec this test binary as one worker child, filtered down to the
/// child entry test.
fn spawn_child(spec: &ChildSpec, peers_csv: &str, out: &PathBuf) -> Child {
    Command::new(std::env::current_exe().unwrap())
        .args(["spawned_worker_child_entry", "--exact", "--nocapture", "--test-threads=1"])
        .env("FASTSAMPLE_TEST_CHILD_RANK", spec.rank.to_string())
        .env("FASTSAMPLE_TEST_CHILD_PEERS", peers_csv)
        .env("FASTSAMPLE_TEST_CHILD_OUT", out)
        .env("FASTSAMPLE_TEST_CHILD_EPOCHS", spec.epochs.to_string())
        .env("FASTSAMPLE_TEST_CHILD_STEPS", spec.steps.to_string())
        .env("FASTSAMPLE_TEST_CHILD_TASK", spec.task)
        .env("FASTSAMPLE_TEST_CHILD_PIPELINE", if spec.pipeline { "on" } else { "off" })
        .stdout(Stdio::null())
        .spawn()
        .expect("spawn child worker process")
}

/// Wait for every child under one hard deadline; a child that neither
/// exits nor fails within it is a hang (kill them all, fail the test).
fn join_children(mut children: Vec<(usize, Child)>, secs: u64) {
    let t0 = Instant::now();
    while !children.is_empty() {
        let mut still = Vec::new();
        for (rank, mut c) in children {
            match c.try_wait().unwrap() {
                Some(status) => {
                    assert!(status.success(), "child rank {rank} exited with {status}")
                }
                None => still.push((rank, c)),
            }
        }
        children = still;
        if children.is_empty() {
            break;
        }
        if t0.elapsed() > Duration::from_secs(secs) {
            let hung: Vec<usize> = children.iter().map(|(r, _)| *r).collect();
            for (_, c) in &mut children {
                let _ = c.kill();
            }
            panic!("child ranks {hung:?} did not exit within {secs}s — multi-process hang");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn out_path(test: &str, rank: usize) -> PathBuf {
    std::env::temp_dir().join(format!(
        "fastsample-{test}-{}-rank{rank}.txt",
        std::process::id()
    ))
}

/// The tentpole acceptance test: 4 separate OS processes produce
/// bit-identical MFGs and digest curves to the in-process channel mesh,
/// and their per-process counters recombine into the in-process totals.
#[test]
fn four_child_processes_match_the_in_process_channel_mesh() {
    let peers = free_peer_csv(WORLD);
    let mut children = Vec::new();
    let mut outs = Vec::new();
    for rank in 0..WORLD {
        let out = out_path("match", rank);
        let _ = std::fs::remove_file(&out);
        let spec = ChildSpec { rank, steps: 2, epochs: 2, task: "sample", pipeline: false };
        children.push((rank, spawn_child(&spec, &peers, &out)));
        outs.push(out);
    }
    join_children(children, 180);

    // Ground truth: the same per-rank workload over the in-process
    // channel mesh (shared counters — snapshot after all threads join).
    let d = sample_dataset();
    let cfg = task_config(WORLD, 2, 2, false);
    let counters = Arc::new(Counters::default());
    let d_ref = &d;
    let cfg_ref = &cfg;
    let expected = run_workers_with(
        WORLD,
        NetworkModel::free(),
        Arc::clone(&counters),
        move |rank, comm| sample_rank(d_ref, cfg_ref, BATCH, &FANOUTS, true, rank, comm).unwrap(),
    );
    let global = counters.snapshot();

    let mut byte_sums = vec![0u64; RoundKind::COUNT];
    for (rank, out) in outs.iter().enumerate() {
        let text = std::fs::read_to_string(out)
            .unwrap_or_else(|e| panic!("child rank {rank} wrote no report: {e}"));
        let mut lines = text.lines();
        let rounds = parse_counter_line(lines.next().unwrap(), "rounds");
        let bytes = parse_counter_line(lines.next().unwrap(), "bytes");
        // Rank 0 increments the global round counters; other ranks none.
        for k in RoundKind::ALL {
            let want = if rank == 0 { global.rounds_of(k) } else { 0 };
            assert_eq!(rounds[k.index()], want, "rank {rank} {} rounds", k.name());
            byte_sums[k.index()] += bytes[k.index()];
        }
        // Body: bit-identical to the in-process rank.
        let body: String = lines.map(|l| format!("{l}\n")).collect();
        assert_eq!(
            body,
            encode_body(&expected[rank]),
            "rank {rank}: multi-process run diverged from the channel mesh"
        );
        let _ = std::fs::remove_file(out);
    }
    // Per-process byte counters sum to the fabric-global totals.
    for k in RoundKind::ALL {
        assert_eq!(byte_sums[k.index()], global.bytes_of(k), "{} bytes", k.name());
    }
    // The digest curves are identical across ranks by construction.
    for r in &expected {
        assert_eq!(r.curve, expected[0].curve);
    }
    assert!(global.total_bytes() > 0, "workload moved no data — test too weak");
}

/// The pipelined prefetcher across real OS processes: 4 children running
/// `--pipeline on` must be bit-identical to the SERIAL in-process channel
/// mesh — one comparison pinning the process layout and the pipeline
/// mode at the same time.
#[test]
fn pipelined_child_processes_match_the_serial_in_process_mesh() {
    let peers = free_peer_csv(WORLD);
    let mut children = Vec::new();
    let mut outs = Vec::new();
    for rank in 0..WORLD {
        let out = out_path("pipe", rank);
        let _ = std::fs::remove_file(&out);
        let spec = ChildSpec { rank, steps: 2, epochs: 2, task: "sample", pipeline: true };
        children.push((rank, spawn_child(&spec, &peers, &out)));
        outs.push(out);
    }
    join_children(children, 180);

    let d = sample_dataset();
    let cfg = task_config(WORLD, 2, 2, false); // serial phases: the ground truth
    let d_ref = &d;
    let cfg_ref = &cfg;
    let expected = run_workers_with(
        WORLD,
        NetworkModel::free(),
        Arc::new(Counters::default()),
        move |rank, comm| sample_rank(d_ref, cfg_ref, BATCH, &FANOUTS, true, rank, comm).unwrap(),
    );
    for (rank, out) in outs.iter().enumerate() {
        let text = std::fs::read_to_string(out)
            .unwrap_or_else(|e| panic!("child rank {rank} wrote no report: {e}"));
        // Skip the two counter lines; the body must be bit-identical.
        let body: String = text.lines().skip(2).map(|l| format!("{l}\n")).collect();
        assert_eq!(
            body,
            encode_body(&expected[rank]),
            "rank {rank}: pipelined multi-process run diverged from the serial mesh"
        );
        let _ = std::fs::remove_file(out);
    }
}

/// A rank that finishes early and exits (its process gone, sockets
/// closed by the OS) must surface as a clean `CommError` in every
/// survivor — no hang — well within the deadline.
#[test]
fn early_exiting_rank_surfaces_comm_error_in_survivors_without_hanging() {
    let peers = free_peer_csv(WORLD);
    let mut children = Vec::new();
    let mut outs = Vec::new();
    for rank in 0..WORLD {
        let out = out_path("die", rank);
        let _ = std::fs::remove_file(&out);
        // Rank 1 caps itself at 1 step and exits; the others expect 3.
        let steps = if rank == 1 { 1 } else { 3 };
        let spec = ChildSpec { rank, steps, epochs: 1, task: "sample", pipeline: false };
        children.push((rank, spawn_child(&spec, &peers, &out)));
        outs.push(out);
    }
    join_children(children, 180);
    for (rank, out) in outs.iter().enumerate() {
        let text = std::fs::read_to_string(out)
            .unwrap_or_else(|e| panic!("child rank {rank} wrote no report: {e}"));
        if rank == 1 {
            assert!(
                text.starts_with("rounds"),
                "rank 1 (the early exiter) should have finished cleanly: {text:?}"
            );
        } else {
            assert!(
                text.starts_with("ERROR") && text.contains("exited mid-collective"),
                "rank {rank} should have seen PeerLost, got: {text:?}"
            );
        }
        let _ = std::fs::remove_file(out);
    }
}

/// Full training across processes (needs the AOT artifacts — skips
/// politely without them): the 4-process loss curve is bit-identical to
/// the in-process `train_distributed` run.
#[test]
fn multi_process_loss_curve_matches_in_process_training() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    let peers = free_peer_csv(WORLD);
    let mut children = Vec::new();
    let mut outs = Vec::new();
    for rank in 0..WORLD {
        let out = out_path("train", rank);
        let _ = std::fs::remove_file(&out);
        let spec = ChildSpec { rank, steps: 2, epochs: 2, task: "train", pipeline: false };
        children.push((rank, spawn_child(&spec, &peers, &out)));
        outs.push(out);
    }
    join_children(children, 300);

    let d = fastsample::graph::datasets::quickstart(1);
    let mut cfg = TrainConfig::mode("quickstart", "vanilla", WORLD).unwrap();
    cfg.epochs = 2;
    cfg.max_batches = Some(2);
    cfg.net = NetworkModel::free();
    cfg.seed = 3;
    let report = train_distributed(&d, &artifacts, &cfg).unwrap();
    let mut want = String::from("loss");
    for v in &report.loss_curve {
        write!(want, " {:08x}", v.to_bits()).unwrap();
    }
    want.push('\n');

    let rank0 = std::fs::read_to_string(&outs[0]).unwrap();
    assert_eq!(rank0, want, "multi-process loss curve diverged");
    for out in &outs {
        let text = std::fs::read_to_string(out).unwrap();
        assert!(text.starts_with("loss"), "a rank failed: {text:?}");
        let _ = std::fs::remove_file(out);
    }
}
