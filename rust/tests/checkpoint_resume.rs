//! Checkpoint/resume: a killed run must be recoverable **bit-identically**.
//!
//! * In-process grid over `{serial, +pipe} × {scalar, bulk}` (plus a
//!   budgeted + adjacency-cache arm): run 2 of 3 epochs with
//!   `--checkpoint-dir`, start a fresh world with `--resume`, and the
//!   stitched digest curve / step / edge counts must equal an
//!   uninterrupted 3-epoch run bit for bit.
//! * Typed-error paths: mismatched fingerprint, ranks with no
//!   checkpoints, and a corrupted binary all surface as
//!   [`CheckpointError`] variants — never a silent divergence or a hang.
//! * The re-exec harness (pattern of `process_rendezvous.rs`): 4 real OS
//!   processes checkpoint every epoch; rank 3 is configured to exit
//!   after epoch 1 (a "kill" — its sockets close and the survivors die
//!   mid-epoch-2 with `PeerLost`); a full relaunch with `--resume`
//!   continues from the epoch every rank holds and the final curve is
//!   bit-identical to a run that was never killed. Same grid of modes.
//! * With AOT artifacts present, the same interrupt/resume cycle runs
//!   real training (Adam state, params, loss curve) — skips politely
//!   otherwise, like `train_e2e`.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastsample::dist::{
    run_worker_process, run_workers_with, Counters, NetworkModel, RendezvousConfig,
};
use fastsample::graph::generator::{make_dataset, DatasetParams};
use fastsample::graph::Dataset;
use fastsample::train::{sample_rank, CheckpointError, SampleRankReport, TrainConfig};

const WORLD: usize = 4;
const BATCH: usize = 8;
const FANOUTS: [usize; 2] = [3, 2];
const STEPS: usize = 2;
const EPOCHS: usize = 3;

/// The mode grid the resume guarantee is pinned over. The cache arm uses
/// a byte budget small enough to leave remote misses (so the adjacency
/// cache actually fills and rides the checkpoint) and a cache large
/// enough to never evict (restored resident rows then reproduce traffic
/// exactly; CLOCK reference bits are not checkpointed).
const GRID: [(&str, &str, bool); 6] = [
    ("serial-bulk", "vanilla+wire:bulk", false),
    ("serial-scalar", "vanilla+wire:scalar", false),
    ("pipe-bulk", "vanilla+wire:bulk", true),
    ("pipe-scalar", "vanilla+wire:scalar", true),
    ("serial-cache", "budget:4k+cache:64k", false),
    ("pipe-cache", "budget:4k+cache:64k", true),
];

fn sample_dataset() -> Dataset {
    make_dataset(&DatasetParams {
        name: "checkpoint-resume".into(),
        num_nodes: 500,
        avg_degree: 8,
        feat_dim: 5,
        num_classes: 4,
        labeled_frac: 0.3,
        p_intra: 0.8,
        noise: 0.2,
        seed: 41,
    })
}

fn task_config(mode: &str, pipeline: bool, epochs: usize) -> TrainConfig {
    let mut cfg = TrainConfig::mode("quickstart", mode, WORLD).unwrap();
    cfg.epochs = epochs;
    cfg.max_batches = Some(STEPS);
    cfg.net = NetworkModel::free();
    cfg.seed = 7;
    cfg.verbose = false;
    cfg.pipeline = pipeline;
    cfg.checkpoint_every = 1;
    cfg
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fastsample-ckpt-resume-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Run the sample task on every rank of an in-process world, panicking
/// on any rank error (the happy-path helper).
fn run_sample(d: &Dataset, cfg: &TrainConfig) -> Vec<SampleRankReport> {
    run_workers_with(
        WORLD,
        NetworkModel::free(),
        Arc::new(Counters::default()),
        move |rank, comm| sample_rank(d, cfg, BATCH, &FANOUTS, false, rank, comm).unwrap(),
    )
}

/// Same, but returning each rank's `Result` (the error-path helper).
fn try_sample(d: &Dataset, cfg: &TrainConfig) -> Vec<anyhow::Result<SampleRankReport>> {
    run_workers_with(WORLD, NetworkModel::free(), Arc::new(Counters::default()), {
        move |rank, comm| sample_rank(d, cfg, BATCH, &FANOUTS, false, rank, comm)
    })
}

fn curve_bits(curve: &[f32]) -> Vec<u32> {
    curve.iter().map(|v| v.to_bits()).collect()
}

// ---------------------------------------------------------------------------
// In-process: resume equality over the whole mode grid
// ---------------------------------------------------------------------------

#[test]
fn resume_continues_bit_identically_across_modes_and_wires() {
    let d = sample_dataset();
    for (tag, mode, pipeline) in GRID {
        // Ground truth: the same world, never interrupted.
        let full = run_sample(&d, &task_config(mode, pipeline, EPOCHS));

        // Interrupted: 2 epochs with checkpointing, then a fresh world
        // resumes to the full epoch count from the same directory.
        let dir = fresh_dir(tag);
        let mut cfg = task_config(mode, pipeline, 2);
        cfg.checkpoint_dir = Some(dir.clone());
        let partial = run_sample(&d, &cfg);
        let mut cfg = task_config(mode, pipeline, EPOCHS);
        cfg.checkpoint_dir = Some(dir.clone());
        cfg.resume = true;
        let resumed = run_sample(&d, &cfg);

        for rank in 0..WORLD {
            assert_eq!(
                curve_bits(&resumed[rank].curve),
                curve_bits(&full[rank].curve),
                "{tag} rank {rank}: stitched digest curve diverged"
            );
            assert_eq!(resumed[rank].steps, full[rank].steps, "{tag} rank {rank} steps");
            assert_eq!(
                resumed[rank].sampled_edges, full[rank].sampled_edges,
                "{tag} rank {rank} sampled edges"
            );
            // The restored prefix really is the partial run's curve.
            assert_eq!(
                curve_bits(&partial[rank].curve),
                curve_bits(&full[rank].curve[..partial[rank].curve.len()]),
                "{tag} rank {rank}: partial run is not a prefix of the full run"
            );
        }
        // Serial vanilla arms: the per-epoch fenced counter deltas and
        // the restored cumulative counters must also stitch exactly
        // (pipelined/cache checkpoints are covered by the curve and by
        // the resident-set parity test below — a restored cache changes
        // which rounds miss, so counter stitching is a vanilla-only
        // guarantee).
        if !pipeline && !mode.contains("cache") {
            for rank in 0..WORLD {
                assert_eq!(
                    resumed[rank].epoch_deltas, full[rank].epoch_deltas,
                    "{tag} rank {rank}: per-epoch comm deltas diverged across resume"
                );
                assert_eq!(
                    resumed[rank].comm_total, full[rank].comm_total,
                    "{tag} rank {rank}: cumulative counters diverged across resume"
                );
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// The resident-set handoff regression: a pipelined checkpoint used to
/// write an empty adjacency-cache section (the sampler thread owns the
/// view), so a resumed `+pipe` run re-warmed from cold. The sampler now
/// hands its resident set back through the `EpochEnd` fence marker —
/// serial and pipelined checkpoints of the same run must carry the
/// identical, non-empty resident set on every rank.
#[test]
fn pipelined_checkpoint_carries_the_same_resident_set_as_serial() {
    use fastsample::train::{load_checkpoint, Fingerprint};
    let d = sample_dataset();
    let mode = "budget:4k+cache:64k";
    let dirs: Vec<PathBuf> = [false, true]
        .iter()
        .map(|&pipeline| {
            let dir = fresh_dir(if pipeline { "resident-pipe" } else { "resident-serial" });
            let mut cfg = task_config(mode, pipeline, 2);
            cfg.checkpoint_dir = Some(dir.clone());
            run_sample(&d, &cfg);
            dir
        })
        .collect();
    for rank in 0..WORLD {
        let states: Vec<_> = [false, true]
            .iter()
            .zip(&dirs)
            .map(|(&pipeline, dir)| {
                // The fingerprint records the pipeline flag, so each
                // mode's checkpoint is loaded under its own.
                let mut cfg = task_config(mode, pipeline, 2);
                cfg.checkpoint_dir = Some(dir.clone());
                let fp = Fingerprint::new("sample", &d.name, &cfg, Some((BATCH, &FANOUTS)));
                load_checkpoint(dir, &fp, rank, 2)
                    .unwrap_or_else(|e| panic!("pipeline={pipeline} rank {rank}: {e}"))
            })
            .collect();
        assert!(
            !states[0].cache_rows.is_empty(),
            "rank {rank}: the 4k-budget run should leave remote misses that fill the cache"
        );
        assert_eq!(
            states[0].cache_rows, states[1].cache_rows,
            "rank {rank}: pipelined checkpoint carries a different resident set than serial"
        );
    }
    for dir in dirs {
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn resume_with_no_checkpoints_is_a_fresh_start() {
    let d = sample_dataset();
    let full = run_sample(&d, &task_config("vanilla", false, EPOCHS));
    let dir = fresh_dir("fresh-start");
    let mut cfg = task_config("vanilla", false, EPOCHS);
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.resume = true; // nothing to resume from — must run from epoch 0
    let resumed = run_sample(&d, &cfg);
    for rank in 0..WORLD {
        assert_eq!(curve_bits(&resumed[rank].curve), curve_bits(&full[rank].curve));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_falls_back_to_the_newest_epoch_every_rank_holds() {
    let d = sample_dataset();
    let full = run_sample(&d, &task_config("vanilla", false, EPOCHS));
    let dir = fresh_dir("fallback");
    let mut cfg = task_config("vanilla", false, 2);
    cfg.checkpoint_dir = Some(dir.clone());
    run_sample(&d, &cfg);
    // Rank 2 "lost" its epoch-2 checkpoint (kill between the bin and
    // manifest renames): the world must agree on epoch 1 and still
    // finish bit-identically.
    std::fs::remove_file(dir.join("ckpt-000002").join("rank2.json")).unwrap();
    let mut cfg = task_config("vanilla", false, EPOCHS);
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.resume = true;
    let resumed = run_sample(&d, &cfg);
    for rank in 0..WORLD {
        assert_eq!(
            curve_bits(&resumed[rank].curve),
            curve_bits(&full[rank].curve),
            "rank {rank}: fallback-to-epoch-1 resume diverged"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// In-process: typed error paths
// ---------------------------------------------------------------------------

#[test]
fn resume_refuses_a_mismatched_config_with_a_typed_error() {
    let d = sample_dataset();
    let dir = fresh_dir("mismatch");
    let mut cfg = task_config("vanilla", false, 2);
    cfg.checkpoint_dir = Some(dir.clone());
    run_sample(&d, &cfg);
    // Same directory, different seed: every rank must refuse, naming
    // the field — never silently diverge.
    let mut cfg = task_config("vanilla", false, EPOCHS);
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.resume = true;
    cfg.seed = 8;
    for (rank, r) in try_sample(&d, &cfg).into_iter().enumerate() {
        let e = r.expect_err("resume under a different seed must fail");
        match e.downcast_ref::<CheckpointError>() {
            Some(CheckpointError::FingerprintMismatch { field, .. }) => {
                assert_eq!(field, "seed", "rank {rank}")
            }
            other => panic!("rank {rank}: wanted FingerprintMismatch, got {other:?} ({e:#})"),
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ranks_without_checkpoints_surface_rank_disagreement() {
    let d = sample_dataset();
    let dir = fresh_dir("disagreement");
    let mut cfg = task_config("vanilla", false, 2);
    cfg.checkpoint_dir = Some(dir.clone());
    run_sample(&d, &cfg);
    // Rank 2 has no checkpoints at all (e.g. a wrong --checkpoint-dir on
    // one machine): a partial restore would desynchronize, so every rank
    // gets the typed refusal.
    for epoch in ["ckpt-000001", "ckpt-000002"] {
        std::fs::remove_file(dir.join(epoch).join("rank2.json")).unwrap();
    }
    let mut cfg = task_config("vanilla", false, EPOCHS);
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.resume = true;
    for (rank, r) in try_sample(&d, &cfg).into_iter().enumerate() {
        let e = r.expect_err("resume with a checkpoint-less rank must fail");
        match e.downcast_ref::<CheckpointError>() {
            Some(CheckpointError::RankDisagreement { .. }) => {}
            other => panic!("rank {rank}: wanted RankDisagreement, got {other:?} ({e:#})"),
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn a_corrupted_checkpoint_is_a_typed_error_not_a_silent_restore() {
    let d = sample_dataset();
    let dir = fresh_dir("corrupt");
    let mut cfg = task_config("vanilla", false, 2);
    cfg.checkpoint_dir = Some(dir.clone());
    run_sample(&d, &cfg);
    // Flip one byte in rank 1's newest binary. Rank 1 must fail with
    // Corrupt; the other ranks see its departure as a fabric error (the
    // documented never-hang contract), not a partial restore.
    let bpath = dir.join("ckpt-000002").join("rank1.bin");
    let mut bytes = std::fs::read(&bpath).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&bpath, &bytes).unwrap();
    let mut cfg = task_config("vanilla", false, EPOCHS);
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.resume = true;
    let results = try_sample(&d, &cfg);
    let e = results[1].as_ref().expect_err("rank 1 read a corrupted checkpoint");
    match e.downcast_ref::<CheckpointError>() {
        Some(CheckpointError::Corrupt { detail, .. }) => {
            assert!(detail.contains("checksum"), "{detail}")
        }
        other => panic!("wanted Corrupt, got {other:?} ({e:#})"),
    }
    for (rank, r) in results.iter().enumerate() {
        assert!(r.is_err(), "rank {rank} should not have proceeded");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// The child role of the re-exec kill/resume harness (inert unless the
// parent set the environment; see process_rendezvous.rs for the pattern)
// ---------------------------------------------------------------------------

fn quick_rdv() -> RendezvousConfig {
    RendezvousConfig {
        timeout: Duration::from_secs(60),
        retry_initial: Duration::from_millis(5),
        retry_max: Duration::from_millis(100),
        bind: None,
    }
}

/// Exact textual encoding of what the resume guarantee pins per rank.
fn encode_outcome(r: &SampleRankReport) -> String {
    let mut s = String::new();
    write!(s, "curve").unwrap();
    for v in &r.curve {
        write!(s, " {:08x}", v.to_bits()).unwrap();
    }
    writeln!(s).unwrap();
    writeln!(s, "steps {}", r.steps).unwrap();
    writeln!(s, "edges {}", r.sampled_edges).unwrap();
    s
}

#[test]
fn checkpoint_child_entry() {
    let Ok(rank) = std::env::var("FASTSAMPLE_CKPT_CHILD_RANK") else {
        return; // normal test run: nothing to do
    };
    let rank: usize = rank.parse().unwrap();
    let peers: Vec<String> = std::env::var("FASTSAMPLE_CKPT_CHILD_PEERS")
        .unwrap()
        .split(',')
        .map(String::from)
        .collect();
    let out_path = std::env::var("FASTSAMPLE_CKPT_CHILD_OUT").unwrap();
    let epochs: usize = std::env::var("FASTSAMPLE_CKPT_CHILD_EPOCHS").unwrap().parse().unwrap();
    let mode = std::env::var("FASTSAMPLE_CKPT_CHILD_MODE").unwrap();
    let pipeline = std::env::var("FASTSAMPLE_CKPT_CHILD_PIPELINE")
        .map(|v| v == "on")
        .unwrap_or(false);
    let ckpt_dir = PathBuf::from(std::env::var("FASTSAMPLE_CKPT_CHILD_DIR").unwrap());
    let resume = std::env::var("FASTSAMPLE_CKPT_CHILD_RESUME").map(|v| v == "1").unwrap_or(false);

    let d = sample_dataset();
    let mut cfg = task_config(&mode, pipeline, epochs);
    cfg.workers = peers.len();
    cfg.checkpoint_dir = Some(ckpt_dir);
    cfg.resume = resume;
    let result = run_worker_process(
        rank,
        &peers,
        &quick_rdv(),
        None,
        NetworkModel::free(),
        Arc::new(Counters::default()),
        |rank, comm| sample_rank(&d, &cfg, BATCH, &FANOUTS, false, rank, comm),
    )
    .expect("rendezvous failed");
    let body = match result {
        Ok(r) => encode_outcome(&r),
        Err(e) => format!("ERROR {e:#}\n"),
    };
    std::fs::write(&out_path, body).unwrap();
}

// ---------------------------------------------------------------------------
// The parent side of the kill/resume harness
// ---------------------------------------------------------------------------

fn free_peer_csv(n: usize) -> String {
    let listeners: Vec<std::net::TcpListener> =
        (0..n).map(|_| std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap()).collect();
    listeners
        .iter()
        .map(|l| format!("127.0.0.1:{}", l.local_addr().unwrap().port()))
        .collect::<Vec<_>>()
        .join(",")
}

struct ChildSpec<'a> {
    rank: usize,
    epochs: usize,
    mode: &'a str,
    pipeline: bool,
    dir: &'a Path,
    resume: bool,
}

fn spawn_child(spec: &ChildSpec, peers_csv: &str, out: &PathBuf) -> Child {
    Command::new(std::env::current_exe().unwrap())
        .args(["checkpoint_child_entry", "--exact", "--nocapture", "--test-threads=1"])
        .env("FASTSAMPLE_CKPT_CHILD_RANK", spec.rank.to_string())
        .env("FASTSAMPLE_CKPT_CHILD_PEERS", peers_csv)
        .env("FASTSAMPLE_CKPT_CHILD_OUT", out)
        .env("FASTSAMPLE_CKPT_CHILD_EPOCHS", spec.epochs.to_string())
        .env("FASTSAMPLE_CKPT_CHILD_MODE", spec.mode)
        .env("FASTSAMPLE_CKPT_CHILD_PIPELINE", if spec.pipeline { "on" } else { "off" })
        .env("FASTSAMPLE_CKPT_CHILD_DIR", spec.dir)
        .env("FASTSAMPLE_CKPT_CHILD_RESUME", if spec.resume { "1" } else { "0" })
        .stdout(Stdio::null())
        .spawn()
        .expect("spawn child worker process")
}

/// Wait for every child under one hard deadline. Children report fabric
/// errors in their out files and still exit 0, so success is asserted
/// here exactly as in `process_rendezvous.rs`.
fn join_children(mut children: Vec<(usize, Child)>, secs: u64) {
    let t0 = Instant::now();
    while !children.is_empty() {
        let mut still = Vec::new();
        for (rank, mut c) in children {
            match c.try_wait().unwrap() {
                Some(status) => {
                    assert!(status.success(), "child rank {rank} exited with {status}")
                }
                None => still.push((rank, c)),
            }
        }
        children = still;
        if children.is_empty() {
            break;
        }
        if t0.elapsed() > Duration::from_secs(secs) {
            let hung: Vec<usize> = children.iter().map(|(r, _)| *r).collect();
            for (_, c) in &mut children {
                let _ = c.kill();
            }
            panic!("child ranks {hung:?} did not exit within {secs}s — multi-process hang");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn out_path(test: &str, phase: &str, rank: usize) -> PathBuf {
    std::env::temp_dir().join(format!(
        "fastsample-ckptkill-{test}-{phase}-{}-rank{rank}.txt",
        std::process::id()
    ))
}

/// The tentpole acceptance test. For every grid mode: 4 real OS
/// processes train with per-epoch checkpoints; rank 3 "dies" after
/// epoch 1 (clean process exit — the survivors fail mid-epoch-2 with a
/// fabric error, exactly a kill's signature); a full relaunch with
/// `--resume` agrees on epoch 1 and the stitched digest curve is
/// bit-identical to an uninterrupted in-process reference.
#[test]
fn killed_multi_process_run_resumes_bit_identically() {
    let d = sample_dataset();
    for (tag, mode, pipeline) in GRID {
        let full = run_sample(&d, &task_config(mode, pipeline, EPOCHS));
        let dir = fresh_dir(&format!("kill-{tag}"));

        // Phase 1: the interrupted run. Rank 3 stops after epoch 1.
        let peers = free_peer_csv(WORLD);
        let mut children = Vec::new();
        let mut outs = Vec::new();
        for rank in 0..WORLD {
            let out = out_path(tag, "kill", rank);
            let _ = std::fs::remove_file(&out);
            let epochs = if rank == 3 { 1 } else { EPOCHS };
            let spec = ChildSpec { rank, epochs, mode, pipeline, dir: &dir, resume: false };
            children.push((rank, spawn_child(&spec, &peers, &out)));
            outs.push(out);
        }
        join_children(children, 300);
        for (rank, out) in outs.iter().enumerate() {
            let text = std::fs::read_to_string(out)
                .unwrap_or_else(|e| panic!("{tag}: child rank {rank} wrote no report: {e}"));
            if rank == 3 {
                assert!(text.starts_with("curve"), "{tag}: rank 3 should exit cleanly: {text:?}");
            } else {
                assert!(
                    text.starts_with("ERROR"),
                    "{tag}: rank {rank} should have died mid-epoch-2: {text:?}"
                );
            }
            let _ = std::fs::remove_file(out);
        }
        // Every rank fenced epoch 1 before the kill, so every rank's
        // epoch-1 checkpoint must be complete on disk.
        for rank in 0..WORLD {
            assert!(
                dir.join("ckpt-000001").join(format!("rank{rank}.json")).exists(),
                "{tag}: rank {rank} has no complete epoch-1 checkpoint"
            );
        }

        // Phase 2: full relaunch with --resume (fresh ports, fresh
        // processes — exactly an operator's relaunch after a crash).
        let peers = free_peer_csv(WORLD);
        let mut children = Vec::new();
        let mut outs = Vec::new();
        for rank in 0..WORLD {
            let out = out_path(tag, "resume", rank);
            let _ = std::fs::remove_file(&out);
            let spec =
                ChildSpec { rank, epochs: EPOCHS, mode, pipeline, dir: &dir, resume: true };
            children.push((rank, spawn_child(&spec, &peers, &out)));
            outs.push(out);
        }
        join_children(children, 300);
        for (rank, out) in outs.iter().enumerate() {
            let text = std::fs::read_to_string(out)
                .unwrap_or_else(|e| panic!("{tag}: resumed rank {rank} wrote no report: {e}"));
            assert_eq!(
                text,
                encode_outcome(&full[rank]),
                "{tag} rank {rank}: resumed multi-process run diverged from the \
                 uninterrupted reference"
            );
            let _ = std::fs::remove_file(out);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// ---------------------------------------------------------------------------
// Real training (artifacts-gated, like train_e2e)
// ---------------------------------------------------------------------------

/// Interrupt/resume through real training: parameters, Adam moments, and
/// the loss curve all ride the checkpoint, and the stitched loss curve
/// is bit-identical — serial and pipelined.
#[test]
fn training_resume_is_bit_identical_when_artifacts_exist() {
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    let d = fastsample::graph::datasets::quickstart(1);
    for pipeline in [false, true] {
        let mut cfg = TrainConfig::mode("quickstart", "vanilla", WORLD).unwrap();
        cfg.epochs = EPOCHS;
        cfg.max_batches = Some(STEPS);
        cfg.net = NetworkModel::free();
        cfg.seed = 3;
        cfg.pipeline = pipeline;
        let full = fastsample::train::train_distributed(&d, &artifacts, &cfg).unwrap();

        let dir = fresh_dir(if pipeline { "train-pipe" } else { "train-serial" });
        let mut interrupted = cfg.clone();
        interrupted.epochs = 2;
        interrupted.checkpoint_dir = Some(dir.clone());
        fastsample::train::train_distributed(&d, &artifacts, &interrupted).unwrap();

        let mut resumed_cfg = cfg.clone();
        resumed_cfg.checkpoint_dir = Some(dir.clone());
        resumed_cfg.resume = true;
        let resumed = fastsample::train::train_distributed(&d, &artifacts, &resumed_cfg).unwrap();

        assert_eq!(
            curve_bits(&resumed.loss_curve),
            curve_bits(&full.loss_curve),
            "pipeline={pipeline}: stitched loss curve diverged across resume"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
