//! Serve mode: the request-level guarantees, pinned end to end.
//!
//! * **Bit-identity grid**: a served embedding for node v equals the
//!   single-machine forward pass (`sample_mfgs` + `propagate_mean` under
//!   the same serve key) bit for bit, across {scalar, bulk} sampling
//!   wire × {inproc, tcp} transport × {budget:0, budget:4k, full
//!   replication} policy — the same grid the training equivalence
//!   suites pin, now observed through the client socket.
//! * **Coalescing correctness**: concurrent clients with interleaved,
//!   overlapping requests each get their own per-request-correct rows —
//!   no cross-batch contamination (per-node sampling keys make batch
//!   composition irrelevant).
//! * **Fault seams**: a mid-query peer kill surfaces a typed `PeerLost`
//!   to the in-flight client and a typed `CommError` on every surviving
//!   rank under a hard deadline — never a hang; a client that
//!   disconnects mid-request must not wedge the serving loop.

use std::io::Write as _;
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use fastsample::dist::{
    query_once, request_shutdown, run_workers_on, run_workers_with, AddrSlot, CommError,
    Counters, NetworkModel, ServeErrorKind, ServeOp, ServeRequest, TransportConfig,
};
use fastsample::graph::generator::{make_dataset, DatasetParams};
use fastsample::graph::{Dataset, NodeId};
use fastsample::sampling::{sample_mfgs, KernelKind, SamplerWorkspace};
use fastsample::train::{
    propagate_mean, serve_key, serve_rank, ServeConfig, ServeReport, TrainConfig,
};

const WORLD: usize = 4;
const FANOUTS: [usize; 2] = [3, 2];
const SEED: u64 = 11;

fn serve_dataset() -> Dataset {
    make_dataset(&DatasetParams {
        name: "serve-equivalence".into(),
        num_nodes: 300,
        avg_degree: 7,
        feat_dim: 4,
        num_classes: 3,
        labeled_frac: 0.3,
        p_intra: 0.8,
        noise: 0.2,
        seed: 43,
    })
}

fn task_config(mode: &str, world: usize) -> TrainConfig {
    let mut cfg = TrainConfig::mode("quickstart", mode, world).unwrap();
    cfg.net = NetworkModel::free();
    cfg.seed = SEED;
    cfg.verbose = false;
    cfg
}

/// The single-machine reference: dedup exactly as the frontend does,
/// sample under the serve key, mean-propagate, re-expand per requested
/// node (duplicates answered per occurrence).
fn reference_rows(d: &Dataset, nodes: &[NodeId], fanouts: &[usize], seed: u64) -> Vec<f32> {
    let mut batch: Vec<NodeId> = Vec::new();
    for &v in nodes {
        if !batch.contains(&v) {
            batch.push(v);
        }
    }
    let mut ws = SamplerWorkspace::new();
    let mfgs = sample_mfgs(&d.graph, &batch, fanouts, serve_key(seed), &mut ws, KernelKind::Fused);
    let dim = d.feat_dim;
    let mut feats = Vec::with_capacity(mfgs[0].src_nodes.len() * dim);
    for &s in &mfgs[0].src_nodes {
        feats.extend_from_slice(d.feat(s));
    }
    let rows = propagate_mean(&mfgs, &feats, dim);
    let mut out = Vec::with_capacity(nodes.len() * dim);
    for &v in nodes {
        let i = batch.iter().position(|&b| b == v).unwrap();
        out.extend_from_slice(&rows[i * dim..(i + 1) * dim]);
    }
    out
}

fn bits(rows: &[f32]) -> Vec<u32> {
    rows.iter().map(|v| v.to_bits()).collect()
}

fn wait_addr(slot: &AddrSlot) -> String {
    slot.wait(Duration::from_secs(30)).expect("frontend never published its address").to_string()
}

fn base_scfg(slot: &Arc<AddrSlot>) -> ServeConfig {
    let mut scfg = ServeConfig::new(FANOUTS.to_vec());
    scfg.ready = Some(Arc::clone(slot));
    scfg
}

/// Serve one query and assert its rows equal the reference bit for bit.
fn query_and_check(d: &Dataset, addr: &str, id: u64, nodes: &[NodeId], tag: &str) {
    let reply = query_once(addr, id, nodes).unwrap_or_else(|e| panic!("{tag}: query {id}: {e}"));
    assert_eq!(reply.id, id, "{tag}: reply correlated to the wrong request");
    let emb = reply.body.unwrap_or_else(|e| panic!("{tag}: query {id} rejected: {e}"));
    assert_eq!(emb.dim, d.feat_dim, "{tag}: wrong row width");
    assert_eq!(emb.num_rows(), nodes.len(), "{tag}: wrong row count");
    assert_eq!(
        bits(&emb.rows),
        bits(&reference_rows(d, nodes, &FANOUTS, SEED)),
        "{tag}: served rows diverged from the single-machine reference"
    );
}

/// One serve world: spin up `WORLD` ranks, run `client` against the
/// published address, and return (per-rank results, client output).
fn run_serve_world<T: Send>(
    d: &Dataset,
    cfg: &TrainConfig,
    transport: &TransportConfig,
    client: impl FnOnce(String) -> T + Send,
) -> (Vec<anyhow::Result<ServeReport>>, T) {
    let slot = Arc::new(AddrSlot::default());
    let scfg = base_scfg(&slot);
    std::thread::scope(|s| {
        let client = s.spawn({
            let slot = Arc::clone(&slot);
            move || client(wait_addr(&slot))
        });
        let results = run_workers_on(
            transport,
            WORLD,
            NetworkModel::free(),
            Arc::new(Counters::default()),
            |rank, comm| serve_rank(d, &fastsample::config::artifacts_dir(), cfg, &scfg, rank, comm),
        )
        .expect("transport mesh failed to connect");
        (results, client.join().expect("client thread panicked"))
    })
}

// ---------------------------------------------------------------------------
// The bit-identity grid
// ---------------------------------------------------------------------------

fn run_grid(transport: &TransportConfig, transport_tag: &str) {
    let d = serve_dataset();
    for policy in ["vanilla", "budget:4k", "hybrid"] {
        for wire in ["wire:scalar", "wire:bulk"] {
            let tag = format!("{transport_tag}/{policy}+{wire}");
            let cfg = task_config(&format!("{policy}+{wire}"), WORLD);
            let (results, ()) = run_serve_world(&d, &cfg, transport, |addr| {
                query_and_check(&d, &addr, 1, &[0, 5, 9], &tag);
                // Duplicates in one request are answered per occurrence.
                query_and_check(&d, &addr, 2, &[7, 7, 2], &tag);
                query_and_check(&d, &addr, 3, &[299], &tag);
                let ack = request_shutdown(&addr).unwrap();
                assert!(ack.body.is_ok(), "{tag}: shutdown not acked");
            });
            let mut batch_counts = Vec::new();
            for (rank, r) in results.into_iter().enumerate() {
                let report = r.unwrap_or_else(|e| panic!("{tag}: rank {rank} failed: {e:#}"));
                batch_counts.push(report.batches);
                if rank == 0 {
                    assert_eq!(report.requests, 3, "{tag}: frontend request count");
                    assert_eq!(report.rejected, 0, "{tag}: nothing should be load-shed");
                    assert_eq!(report.latency.len(), 3, "{tag}: one latency sample per request");
                    assert!(report.latency.summary().contains("p50="), "{tag}: report summary");
                }
            }
            assert!(
                batch_counts.iter().all(|&b| b == batch_counts[0]),
                "{tag}: ranks disagree on the collective batch count: {batch_counts:?}"
            );
        }
    }
}

#[test]
fn served_rows_match_the_single_machine_reference_inproc() {
    run_grid(&TransportConfig::Inproc, "inproc");
}

#[test]
fn served_rows_match_the_single_machine_reference_over_tcp() {
    run_grid(&TransportConfig::Tcp { base_port: 0 }, "tcp");
}

// ---------------------------------------------------------------------------
// Coalescing: concurrent clients, per-request correctness
// ---------------------------------------------------------------------------

#[test]
fn concurrent_clients_get_per_request_correct_answers() {
    let d = serve_dataset();
    let cfg = task_config("budget:4k+wire:bulk", WORLD);
    let slot = Arc::new(AddrSlot::default());
    // A wide coalescing window and batch so interleaved requests really
    // do share collective batches.
    let mut scfg = base_scfg(&slot);
    scfg.max_wait = Duration::from_millis(50);
    scfg.max_batch = 64;
    scfg.max_inflight = 16;

    const CLIENTS: u64 = 6;
    const QUERIES_PER_CLIENT: u64 = 3;
    std::thread::scope(|s| {
        let clients: Vec<_> = (0..CLIENTS)
            .map(|c| {
                s.spawn({
                    let d = &d;
                    let slot = Arc::clone(&slot);
                    move || {
                        let addr = wait_addr(&slot);
                        for q in 0..QUERIES_PER_CLIENT {
                            // Overlapping node sets across clients, distinct
                            // per (client, query): contamination would hand
                            // one client another's rows.
                            let nodes: Vec<NodeId> =
                                vec![(c * 7 % 300) as NodeId, (c * 13 + q * 31 + 1) as NodeId % 300, (q * 97 + 5) as NodeId % 300];
                            query_and_check(d, &addr, c * 100 + q, &nodes, &format!("client {c}"));
                        }
                    }
                })
            })
            .collect();
        // The closer joins every client, then asks the mesh to stop —
        // it must run off this thread, which is about to block in
        // `run_workers_with` until that very shutdown lands.
        let closer = s.spawn({
            let slot = Arc::clone(&slot);
            move || {
                for c in clients {
                    c.join().expect("client thread panicked");
                }
                let addr = wait_addr(&slot);
                let ack = request_shutdown(&addr).expect("shutdown send failed");
                assert!(ack.body.is_ok(), "shutdown not acked");
            }
        });
        let results = run_workers_with(
            WORLD,
            NetworkModel::free(),
            Arc::new(Counters::default()),
            |rank, comm| {
                serve_rank(&d, &fastsample::config::artifacts_dir(), &cfg, &scfg, rank, comm)
            },
        );
        closer.join().expect("closer thread panicked");
        for (rank, r) in results.into_iter().enumerate() {
            let report = r.unwrap_or_else(|e| panic!("rank {rank} failed: {e:#}"));
            if rank == 0 {
                assert_eq!(report.requests, CLIENTS * QUERIES_PER_CLIENT);
                assert_eq!(report.latency.len() as u64, CLIENTS * QUERIES_PER_CLIENT);
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Fault seams
// ---------------------------------------------------------------------------

/// A peer dying between batches: the survivors' next collective gets a
/// typed `PeerLost`, the in-flight client gets a typed `peer-lost`
/// reply, and everything returns under a hard deadline — never a hang.
#[test]
fn mid_query_peer_kill_surfaces_typed_errors_and_never_hangs() {
    const KILL_WORLD: usize = 3;
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let d = serve_dataset();
        let cfg = task_config("budget:4k+wire:bulk", KILL_WORLD);
        let slot = Arc::new(AddrSlot::default());
        let mut scfg = base_scfg(&slot);
        // This test pins the *in-flight* seam: query 2 must be the thing
        // that trips over the dead rank. A long heartbeat keeps the idle
        // liveness round (pinned by the idle-kill test below) from
        // winning that race and tearing the mesh down first.
        scfg.idle_heartbeat = Duration::from_secs(10);
        let out = std::thread::scope(|s| {
            let client = s.spawn({
                let d = &d;
                let slot = Arc::clone(&slot);
                move || {
                    let addr = wait_addr(&slot);
                    // Batch 1 is served by the full mesh.
                    query_and_check(d, &addr, 1, &[1, 2], "pre-kill");
                    // Rank 2 has left; the next query's collective fails.
                    let reply = query_once(&addr, 2, &[3]).expect("reply channel broken");
                    reply.body.expect_err("query after the kill must be refused")
                }
            });
            let results = run_workers_with(
                KILL_WORLD,
                NetworkModel::free(),
                Arc::new(Counters::default()),
                |rank, comm| {
                    let mut scfg = scfg.clone();
                    if rank == 2 {
                        // The simulated kill: serve one batch, leave.
                        scfg.max_batches = Some(1);
                    }
                    serve_rank(&d, &fastsample::config::artifacts_dir(), &cfg, &scfg, rank, comm)
                },
            );
            (results, client.join().expect("client thread panicked"))
        });
        let _ = tx.send(out);
    });
    // The hard deadline: a wedged mesh fails here, not in CI's timeout.
    let (results, client_err) = rx
        .recv_timeout(Duration::from_secs(120))
        .expect("serve world hung after a peer kill");

    assert_eq!(
        client_err.kind,
        ServeErrorKind::PeerLost,
        "in-flight client should see the typed peer loss: {client_err}"
    );
    // The killed rank exited cleanly; every survivor holds a typed
    // fabric error naming the loss.
    assert!(results[2].is_ok(), "the capped rank leaves cleanly");
    for (rank, r) in results.iter().enumerate().take(2) {
        let e = r.as_ref().expect_err("survivors must fail, not hang");
        match e.downcast_ref::<CommError>() {
            Some(CommError::PeerLost { .. }) => {}
            other => panic!("rank {rank}: wanted PeerLost, got {other:?} ({e:#})"),
        }
    }
}

/// A peer dying while the mesh is completely idle (no client traffic at
/// all): the frontend's idle heartbeat — an empty collective round every
/// `idle_heartbeat` — detects the loss, so every survivor exits with a
/// typed `PeerLost` under a hard deadline instead of hanging in a
/// collective until the next query happens to arrive.
#[test]
fn peer_kill_while_idle_is_detected_by_the_heartbeat() {
    const KILL_WORLD: usize = 3;
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let d = serve_dataset();
        let cfg = task_config("budget:4k+wire:bulk", KILL_WORLD);
        let slot = Arc::new(AddrSlot::default());
        let mut scfg = base_scfg(&slot);
        scfg.idle_heartbeat = Duration::from_millis(50);
        let results = run_workers_with(
            KILL_WORLD,
            NetworkModel::free(),
            Arc::new(Counters::default()),
            |rank, comm| {
                let mut scfg = scfg.clone();
                if rank == 2 {
                    // The simulated kill: leave before serving anything —
                    // no client ever queries, so only a heartbeat can
                    // notice.
                    scfg.max_batches = Some(0);
                }
                serve_rank(&d, &fastsample::config::artifacts_dir(), &cfg, &scfg, rank, comm)
            },
        );
        let _ = tx.send(results);
    });
    // The hard deadline: without the heartbeat the survivors block in
    // their collectives forever and this recv times out.
    let results = rx
        .recv_timeout(Duration::from_secs(120))
        .expect("idle serve mesh hung after a peer kill");
    assert!(results[2].is_ok(), "the capped rank leaves cleanly");
    for (rank, r) in results.iter().enumerate().take(2) {
        let e = r.as_ref().expect_err("survivors must fail, not hang");
        match e.downcast_ref::<CommError>() {
            Some(CommError::PeerLost { .. }) => {}
            other => panic!("rank {rank}: wanted PeerLost, got {other:?} ({e:#})"),
        }
    }
}

/// A client that sends a request and vanishes without reading the reply
/// must not wedge the loop: the write failure is the client's problem,
/// the next client is served normally.
#[test]
fn client_disconnect_mid_request_does_not_wedge_serving() {
    let d = serve_dataset();
    let cfg = task_config("vanilla+wire:bulk", WORLD);
    let transport = TransportConfig::Inproc;
    let (results, ()) = run_serve_world(&d, &cfg, &transport, |addr| {
        {
            let mut s = TcpStream::connect(&addr).expect("connect");
            let mut buf = Vec::new();
            ServeRequest { id: 9, op: ServeOp::Query(vec![1, 2, 3]) }.encode_to(&mut buf);
            s.write_all(&buf).expect("send");
            // Vanish: the reply write will fail; nobody must care.
        }
        query_and_check(&d, &addr, 10, &[4, 6], "post-disconnect");
        let ack = request_shutdown(&addr).unwrap();
        assert!(ack.body.is_ok(), "shutdown not acked");
    });
    for (rank, r) in results.into_iter().enumerate() {
        r.unwrap_or_else(|e| panic!("rank {rank} failed: {e:#}"));
    }
}
