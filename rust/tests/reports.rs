//! Integration: the experiment regenerators produce well-formed reports
//! with the paper's invariants visible in the text (cheap configs).

use fastsample::coordinator::experiments as exp;

fn artifacts_available() -> bool {
    fastsample::config::artifacts_available()
}

#[test]
fn table1_contains_published_and_sim_rows() {
    let t = exp::table1(0.002, 0.0005, 1).unwrap();
    assert!(t.contains("ogbn-products"));
    assert!(t.contains("124000000"));
    assert!(t.contains("ogbn-papers100M"));
    assert!(t.contains("products-sim"));
    assert!(t.contains("papers100m-sim"));
}

#[test]
fn fig4_shows_topology_fraction_claim() {
    let t = exp::fig4(0.002, 0.0005, 1).unwrap();
    assert!(t.contains("MAG240M"));
    assert!(t.contains("IGBH-full"));
    // The paper's point: MAG240M topology ~2.3% of total storage.
    assert!(t.contains("2.31%"), "{t}");
    assert!(t.contains("1.62%"), "{t}");
}

#[test]
fn fig5_sampling_reports_speedups_ge_one_mostly() {
    let opts = exp::Fig5Opts {
        dataset_spec: "quickstart".into(),
        batch_sizes: vec![128, 256],
        fanout_sets: vec![vec![5, 5], vec![10, 10]],
        iters: 3,
        e2e: false,
        seed: 2,
    };
    let t = exp::fig5_sampling(&opts).unwrap();
    assert!(t.contains("speedup"));
    // Every configured row is present.
    assert_eq!(t.matches("\n[").count(), 4, "{t}");
}

#[test]
fn partition_memory_reports_the_spectrum() {
    let t = exp::partition_memory("quickstart", 4, 3).unwrap();
    assert!(t.contains("vanilla"));
    assert!(t.contains("hybrid"));
    assert!(t.contains("budget:"), "{t}");
    assert!(t.contains("halo:1"), "{t}");
    assert!(t.contains("edge-cut fraction"));
}

#[test]
fn replication_frontier_curve_holds_its_contract() {
    // The regenerator enforces monotone rounds and the analytic
    // endpoints internally (ensure! on failure), so a successful run IS
    // the acceptance check; the text assertions pin the printed summary.
    let t = exp::replication_frontier("quickstart", 4, 3).unwrap();
    assert!(t.contains("vanilla"));
    assert!(t.contains("hybrid"));
    assert!(t.contains("(analytic 2L+1 = 7)"), "{t}");
    assert!(t.contains("(analytic 3)"), "{t}");
    assert!(t.contains("monotone"), "{t}");
}

#[test]
fn cache_decay_report_holds_its_contract() {
    // The regenerator enforces the decay invariants internally (cache
    // off ⇒ flat per-epoch request bytes; cache on ⇒ non-increasing;
    // unbounded cache ⇒ zero traffic after epoch 0), so a successful run
    // IS the acceptance check; the text assertions pin the summary.
    let t = exp::cache_decay("quickstart", 4, 3, &fastsample::dist::TransportConfig::Inproc)
        .unwrap();
    assert!(t.contains("cache:0 (off)"), "{t}");
    assert!(t.contains("cache:inf static"), "{t}");
    assert!(t.contains("cache:inf clock"), "{t}");
    assert!(t.contains("non-increasing"), "{t}");
    assert!(t.contains("contract held"), "{t}");
    assert!(t.contains("inproc transport"), "{t}");
}

#[test]
fn cache_decay_report_holds_over_tcp_too() {
    // Same contract, counters tallied from frames serialized to real
    // loopback sockets — the decay curve is a wire-measured quantity.
    let t = exp::cache_decay(
        "quickstart",
        3,
        3,
        &fastsample::dist::TransportConfig::Tcp { base_port: 0 },
    )
    .unwrap();
    assert!(t.contains("contract held"), "{t}");
    assert!(t.contains("tcp:0 transport"), "{t}");
}

#[test]
fn rounds_report_shows_the_2l_to_2_reduction() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let t = exp::rounds_report(3, 5, &fastsample::dist::TransportConfig::Inproc).unwrap();
    assert!(t.contains("mode: vanilla"));
    assert!(t.contains("mode: hybrid"));
    // Vanilla: 4 sampling rounds per batch (L=3); hybrid: 0.
    assert!(t.contains("sampling rounds/batch: 4"), "{t}");
    assert!(t.contains("sampling rounds/batch: 0"), "{t}");
}

#[test]
fn e2e_run_emits_loss_curve() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let d = fastsample::graph::datasets::quickstart(6);
    let t = exp::e2e_run(&d, "quickstart", "hybrid+fused", 2, 2, 6).unwrap();
    assert!(t.contains("loss curve"));
    assert!(t.contains("epoch"));
}
