//! Integration: the paper's "mathematically equivalent" claim, pinned to
//! bit-equality — across the whole replication-budget spectrum.
//!
//! * Distributed sampling at **every** budget point (vanilla, byte
//!   budgets, hop-bounded halos, full replication) must produce exactly
//!   the MFGs that single-machine fused sampling produces with the same
//!   key.
//! * Sampling rounds are data-dependent and monotone in the budget:
//!   budget 0 pays the paper's 2(L−1), the complete 1-hop halo clears
//!   the first exchange, full replication pays zero.
//! * The partitioned feature store must return exactly the dataset rows,
//!   with and without a cache.
//! * The dynamic remote-adjacency cache preserves bit-equality at every
//!   (budget, capacity, policy) point, and decays `SampleRequest`
//!   traffic to zero across epochs once the miss set goes resident.
//! * The bulk (columnar) and scalar (run-length) miss-response wires are
//!   bit-identical in content at every (budget, cache) point — same
//!   MFGs, rounds, and request bytes; bulk response bytes never exceed
//!   scalar's — and malformed bulk frames fail the round as
//!   `CommError::Malformed` instead of panicking or hanging.
//! * The double-buffered MFG prefetcher (`--pipeline on`) is bit-exact
//!   against the serial phases at every {policy × cache × wire} grid
//!   point, including the multi-epoch adjacency-cache decay trajectory
//!   (pinned per epoch by the fenced counter deltas).

use std::sync::Arc;

use fastsample::dist::{
    fetch_features, run_workers_with, sample_mfgs_distributed, sample_mfgs_distributed_wire,
    CachePolicy, CommError, CommStats, Counters, FeatureCache, NetworkModel, RoundKind,
    SamplingWire,
};
use fastsample::graph::generator::{make_dataset, DatasetParams};
use fastsample::graph::{Dataset, NodeId};
use fastsample::partition::{
    build_shards, partition_graph, PartitionConfig, ReplicationPolicy, WorkerShard,
};
use fastsample::sampling::rng::RngKey;
use fastsample::sampling::{sample_mfgs, KernelKind, Mfg, SamplerWorkspace};
use fastsample::train::{sample_rank, SampleRankReport, TrainConfig};

fn dataset() -> Dataset {
    make_dataset(&DatasetParams {
        name: "dist-eq".into(),
        num_nodes: 1200,
        avg_degree: 12,
        feat_dim: 7,
        num_classes: 5,
        labeled_frac: 0.2,
        p_intra: 0.85,
        noise: 0.3,
        seed: 77,
    })
}

/// Seeds per worker: its own labeled nodes (as in training).
fn worker_seeds(d: &Dataset, book: &fastsample::partition::PartitionBook, part: usize, n: usize) -> Vec<NodeId> {
    d.train_ids.iter().copied().filter(|&v| book.part_of(v) == part).take(n).collect()
}

/// Run 4 workers sampling one minibatch each under `policy`; assert
/// bit-equality with the single-machine sampler on every rank and return
/// the fabric's sampling-round count.
fn run_policy(d: &Dataset, policy: ReplicationPolicy, fanouts: &[usize], key: RngKey) -> u64 {
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(4)));
    let shards = build_shards(d, &book, &policy);
    let counters = Arc::new(Counters::default());
    let shards_ref = &shards;
    let book_ref = &book;
    let results = run_workers_with(4, NetworkModel::free(), Arc::clone(&counters), {
        move |rank, comm| {
            let shard = &shards_ref[rank];
            let seeds = worker_seeds(d, book_ref, rank, 16);
            let mut ws = SamplerWorkspace::new();
            let mut view = shard.topology.clone();
            let mfgs = sample_mfgs_distributed(
                comm, shard, &mut view, &seeds, fanouts, key, &mut ws, KernelKind::Fused,
            )
            .unwrap();
            (seeds, mfgs)
        }
    });
    let mut ws = SamplerWorkspace::new();
    for (seeds, mfgs) in &results {
        let expect = sample_mfgs(&d.graph, seeds, fanouts, key, &mut ws, KernelKind::Fused);
        assert_eq!(mfgs, &expect, "{policy:?} != single-machine");
    }
    counters.snapshot().sampling_rounds()
}

#[test]
fn vanilla_distributed_equals_single_machine_fused() {
    let d = dataset();
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(4)));
    let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
    let fanouts = [4usize, 3, 3];
    let key = RngKey::new(123);

    let counters = Arc::new(Counters::default());
    let shards_ref = &shards;
    let d_ref = &d;
    let book_ref = &book;
    let results = run_workers_with(4, NetworkModel::free(), Arc::clone(&counters), {
        move |rank, comm| {
            let shard = &shards_ref[rank];
            let seeds = worker_seeds(d_ref, book_ref, rank, 16);
            let mut ws = SamplerWorkspace::new();
            let mut view = shard.topology.clone();
            let mfgs = sample_mfgs_distributed(
                comm, shard, &mut view, &seeds, &fanouts, key, &mut ws, KernelKind::Fused,
            )
            .unwrap();
            (seeds, mfgs)
        }
    });

    // Ground truth: single-machine sampling on the full graph.
    let mut ws = SamplerWorkspace::new();
    for (seeds, mfgs) in &results {
        let expect = sample_mfgs(&d.graph, seeds, &fanouts, key, &mut ws, KernelKind::Fused);
        assert_eq!(mfgs, &expect, "distributed vanilla != local fused");
        for (li, m) in mfgs.iter().enumerate() {
            let layer = li + 1;
            let fanout = fanouts[fanouts.len() - layer];
            let dst: Vec<NodeId> = m.src_nodes[..m.n_dst].to_vec();
            m.validate(&dst, fanout).unwrap();
        }
    }

    // Round accounting: L=3 → 2(L−1) = 4 sampling rounds per minibatch
    // (every non-seed level has cross-partition misses on this graph).
    let s = counters.snapshot();
    assert_eq!(s.rounds_of(RoundKind::SampleRequest), 2);
    assert_eq!(s.rounds_of(RoundKind::SampleResponse), 2);
    assert_eq!(s.sampling_rounds(), 4);
}

#[test]
fn vanilla_baseline_assembly_matches_fused_assembly() {
    let d = dataset();
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(3)));
    let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
    let fanouts = [5usize, 4];
    let key = RngKey::new(9);
    let shards_ref = &shards;
    let d_ref = &d;
    let book_ref = &book;
    let results = run_workers_with(3, NetworkModel::free(), Arc::new(Counters::default()), {
        move |rank, comm| {
            let shard = &shards_ref[rank];
            let seeds = worker_seeds(d_ref, book_ref, rank, 12);
            let mut ws = SamplerWorkspace::new();
            let mut view = shard.topology.clone();
            let a = sample_mfgs_distributed(
                comm, shard, &mut view, &seeds, &fanouts, key, &mut ws, KernelKind::Fused,
            )
            .unwrap();
            let b = sample_mfgs_distributed(
                comm, shard, &mut view, &seeds, &fanouts, key, &mut ws, KernelKind::Baseline,
            )
            .unwrap();
            (a, b)
        }
    });
    for (a, b) in results {
        assert_eq!(a, b);
    }
}

#[test]
fn full_replication_needs_zero_sampling_rounds_and_matches_vanilla() {
    let d = dataset();
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(4)));
    let hybrid = build_shards(&d, &book, &ReplicationPolicy::hybrid());
    let fanouts = [4usize, 3, 3];
    let key = RngKey::new(123);

    let counters = Arc::new(Counters::default());
    let hybrid_ref = &hybrid;
    let d_ref = &d;
    let book_ref = &book;
    let results = run_workers_with(4, NetworkModel::free(), Arc::clone(&counters), {
        move |rank, comm| {
            let shard = &hybrid_ref[rank];
            let seeds = worker_seeds(d_ref, book_ref, rank, 16);
            let mut ws = SamplerWorkspace::new();
            let mut view = shard.topology.clone();
            sample_mfgs_distributed(
                comm, shard, &mut view, &seeds, &fanouts, key, &mut ws, KernelKind::Fused,
            )
            .unwrap()
        }
    });

    // Full replication is mathematically identical to single-machine.
    let mut ws = SamplerWorkspace::new();
    for (rank, mfgs) in results.iter().enumerate() {
        let seeds = worker_seeds(&d, &book, rank, 16);
        let expect = sample_mfgs(&d.graph, &seeds, &fanouts, key, &mut ws, KernelKind::Fused);
        assert_eq!(mfgs, &expect);
    }

    // The headline: zero sampling communication under full replication.
    let s = counters.snapshot();
    assert_eq!(s.sampling_rounds(), 0);
    assert_eq!(s.total_bytes(), 0);
}

/// The tentpole acceptance test: sweep the budget spectrum. Every point
/// is bit-identical to single-machine sampling; rounds fall monotonically
/// from the vanilla endpoint (2(L−1)) to the hybrid endpoint (0); the
/// 1-hop halo pays strictly fewer rounds than vanilla at strictly less
/// adjacency memory than hybrid.
#[test]
fn replication_spectrum_is_bit_identical_with_monotone_rounds() {
    let d = dataset();
    let fanouts = [4usize, 3, 3]; // L = 3
    let key = RngKey::new(123);
    let policies = [
        ReplicationPolicy::vanilla(),
        ReplicationPolicy::budgeted(4 * 1024),
        ReplicationPolicy::halo(1),
        ReplicationPolicy::hybrid(),
    ];
    let rounds: Vec<u64> =
        policies.iter().map(|&p| run_policy(&d, p, &fanouts, key)).collect();

    // Endpoints are the analytic scheme constants.
    assert_eq!(rounds[0], 4, "vanilla endpoint: 2(L-1)");
    assert_eq!(rounds[3], 0, "hybrid endpoint");
    // Monotone non-increasing along the sweep.
    for w in rounds.windows(2) {
        assert!(w[1] <= w[0], "rounds not monotone: {rounds:?}");
    }
    // The complete 1-hop halo clears exactly the first exchange of the
    // minibatch: levels 2..L still pay, level 1 never does.
    assert_eq!(rounds[2], 2, "1-hop halo should pay 2(L-2) rounds");
    assert!(rounds[2] < rounds[0], "mid-point must beat vanilla");

    // Memory: the mid-points sit strictly between the endpoints.
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(4)));
    let mems: Vec<usize> = policies
        .iter()
        .map(|p| {
            build_shards(&d, &book, p)
                .iter()
                .map(|s| s.topology.storage_bytes())
                .max()
                .unwrap()
        })
        .collect();
    assert!(mems[0] < mems[1] && mems[1] < mems[3], "budgeted memory out of order: {mems:?}");
    assert!(mems[0] < mems[2] && mems[2] < mems[3], "halo memory out of order: {mems:?}");
}

/// The adjacency-cache acceptance sweep: every (replication budget,
/// cache capacity, cache policy) point — including capacity 0 (must
/// behave exactly like the uncached runtime) and a capacity larger than
/// the whole miss set — stays bit-identical to single-machine sampling
/// across several minibatches, while rounds never exceed the uncached
/// baseline's.
#[test]
fn adjacency_cache_spectrum_is_bit_identical() {
    let d = dataset();
    let fanouts = [4usize, 3];
    let key = RngKey::new(4242);
    let batches = 3u64;
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(4)));

    for policy in [ReplicationPolicy::vanilla(), ReplicationPolicy::budgeted(4 * 1024)] {
        let shards = build_shards(&d, &book, &policy);
        let mut uncached_rounds = None;
        for cache_bytes in [0u64, 600, u64::MAX >> 1] {
            for cache_policy in [CachePolicy::StaticDegree, CachePolicy::Clock] {
                let counters = Arc::new(Counters::default());
                let shards_ref = &shards;
                let d_ref = &d;
                let book_ref = &book;
                let results =
                    run_workers_with(4, NetworkModel::free(), Arc::clone(&counters), {
                        move |rank, comm| {
                            let shard = &shards_ref[rank];
                            let seeds = worker_seeds(d_ref, book_ref, rank, 16);
                            let mut ws = SamplerWorkspace::new();
                            let mut view = shard.topology.clone();
                            if cache_bytes > 0 {
                                view.enable_cache(cache_bytes, cache_policy);
                            }
                            let per_batch: Vec<_> = (0..batches)
                                .map(|b| {
                                    sample_mfgs_distributed(
                                        comm,
                                        shard,
                                        &mut view,
                                        &seeds,
                                        &fanouts,
                                        key.fold(b),
                                        &mut ws,
                                        KernelKind::Fused,
                                    )
                                    .unwrap()
                                })
                                .collect();
                            (seeds, per_batch)
                        }
                    });
                let mut ws = SamplerWorkspace::new();
                for (seeds, per_batch) in &results {
                    for (b, mfgs) in per_batch.iter().enumerate() {
                        let expect = sample_mfgs(
                            &d.graph,
                            seeds,
                            &fanouts,
                            key.fold(b as u64),
                            &mut ws,
                            KernelKind::Fused,
                        );
                        assert_eq!(
                            mfgs, &expect,
                            "{policy:?} cache {cache_bytes}B {cache_policy:?} batch {b} \
                             diverged from single-machine"
                        );
                    }
                }
                let rounds = counters.snapshot().sampling_rounds();
                let baseline = *uncached_rounds.get_or_insert(rounds);
                if cache_bytes == 0 {
                    assert_eq!(
                        rounds, baseline,
                        "capacity 0 must behave exactly like the uncached runtime"
                    );
                } else {
                    assert!(
                        rounds <= baseline,
                        "{policy:?} cache {cache_bytes}B {cache_policy:?}: \
                         caching increased rounds ({rounds} > {baseline})"
                    );
                }
            }
        }
    }
}

/// The decay regression: a second epoch over the *same* seeds issues
/// strictly fewer `SampleRequest` bytes than the first, and with a cache
/// larger than the miss set the second epoch issues none at all — every
/// exchange is cleared by the round-skip vote.
#[test]
fn adjacency_cache_decays_request_traffic_across_epochs() {
    let d = dataset();
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(4)));
    let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
    let fanouts = [4usize, 3, 3];
    let key = RngKey::new(321);
    let counters = Arc::new(Counters::default());
    let shards_ref = &shards;
    let d_ref = &d;
    let book_ref = &book;
    let results = run_workers_with(4, NetworkModel::free(), Arc::clone(&counters), {
        move |rank, comm| {
            let shard = &shards_ref[rank];
            let seeds = worker_seeds(d_ref, book_ref, rank, 16);
            let mut ws = SamplerWorkspace::new();
            let mut view = shard.topology.clone();
            view.enable_cache(u64::MAX >> 1, CachePolicy::StaticDegree);
            // Barrier-fenced epoch marks (`Comm::fenced_snapshot`): the
            // counters are fabric-global, so no rank may charge an
            // epoch's bytes before every rank has marked the boundary.
            let mut marks = Vec::new();
            let mut epochs = Vec::new();
            for _e in 0..2 {
                marks.push(comm.fenced_snapshot().unwrap());
                epochs.push(
                    sample_mfgs_distributed(
                        comm, shard, &mut view, &seeds, &fanouts, key, &mut ws,
                        KernelKind::Fused,
                    )
                    .unwrap(),
                );
            }
            marks.push(comm.fenced_snapshot().unwrap());
            let deltas: Vec<CommStats> =
                marks.windows(2).map(|w| w[1].diff(&w[0])).collect();
            (seeds, epochs, deltas)
        }
    });
    let mut ws = SamplerWorkspace::new();
    for (seeds, epochs, deltas) in &results {
        let expect = sample_mfgs(&d.graph, seeds, &fanouts, key, &mut ws, KernelKind::Fused);
        let (e1, s1) = (&epochs[0], &deltas[0]);
        let (e2, s2) = (&epochs[1], &deltas[1]);
        assert_eq!(e1, &expect, "cold epoch diverged from single-machine");
        assert_eq!(e2, &expect, "warm epoch diverged from single-machine");
        let b1 = s1.bytes_of(RoundKind::SampleRequest);
        let b2 = s2.bytes_of(RoundKind::SampleRequest);
        assert!(b1 > 0, "cold epoch should pay request bytes on this graph");
        assert!(b2 < b1, "warm epoch must issue strictly fewer request bytes");
        assert_eq!(b2, 0, "cache larger than the miss set should absorb everything");
        assert_eq!(s2.sampling_rounds(), 0, "warm epoch should vote every exchange away");
    }
}

/// One pipelined-vs-serial cell: the full per-rank `sample_rank` reports
/// (digest curve, MFGs, seeds, per-epoch fenced deltas, counter totals)
/// under `mode` over the in-process mesh.
fn run_pipeline_cell(d: &Dataset, mode: &str, pipeline: bool) -> Vec<SampleRankReport> {
    let mut cfg = TrainConfig::mode("quickstart", mode, 4).unwrap();
    cfg.epochs = 2;
    cfg.max_batches = Some(2);
    cfg.net = NetworkModel::free();
    cfg.seed = 5;
    cfg.verbose = false;
    cfg.pipeline = pipeline;
    let cfg_ref = &cfg;
    run_workers_with(4, NetworkModel::free(), Arc::new(Counters::default()), {
        move |rank, comm| sample_rank(d, cfg_ref, 12, &[4, 3], true, rank, comm).unwrap()
    })
}

/// The prefetcher acceptance grid: at every {replication policy ×
/// adjacency cache × sampling wire} point, `--pipeline on` produces
/// reports bit-identical to the serial phases — the digest curve plays
/// the loss curve's role, the retained MFGs pin the sampled stream, and
/// the fenced per-epoch deltas pin the wire traffic epoch by epoch.
#[test]
fn pipeline_on_off_is_bit_identical_across_the_grid() {
    let d = dataset();
    for policy in ["vanilla", "budget:4k", "hybrid"] {
        for cache in ["", "+cache:16k"] {
            for wire in ["+wire:scalar", "+wire:bulk"] {
                let mode = format!("{policy}{cache}{wire}");
                let serial = run_pipeline_cell(&d, &mode, false);
                let piped = run_pipeline_cell(&d, &mode, true);
                assert_eq!(serial, piped, "{mode}: --pipeline on diverged from serial");
                assert!(!piped[0].curve.is_empty(), "{mode}: ran no steps — test too weak");
            }
        }
    }
}

/// The decay-over-pipeline pin: with an adjacency cache larger than the
/// miss set, the per-epoch fenced deltas show `SampleRequest` traffic
/// decaying across epochs — and the whole trajectory is bit-identical
/// under `--pipeline on|off`, because cache inserts and RNG cursors
/// live on the sampler thread in both modes.
#[test]
fn cache_decay_trajectory_is_pipeline_invariant() {
    let d = dataset();
    let run = |pipeline: bool| -> Vec<SampleRankReport> {
        let mut cfg = TrainConfig::mode("quickstart", "vanilla+cache:inf", 4).unwrap();
        cfg.epochs = 3;
        cfg.max_batches = Some(3);
        cfg.net = NetworkModel::free();
        cfg.seed = 17;
        cfg.verbose = false;
        cfg.pipeline = pipeline;
        let d_ref = &d;
        let cfg_ref = &cfg;
        run_workers_with(4, NetworkModel::free(), Arc::new(Counters::default()), {
            move |rank, comm| sample_rank(d_ref, cfg_ref, 12, &[4, 3], true, rank, comm).unwrap()
        })
    };
    let serial = run(false);
    let piped = run(true);
    assert_eq!(serial, piped, "decay trajectory diverged under --pipeline on");
    for r in &serial {
        let req: Vec<u64> =
            r.epoch_deltas.iter().map(|s| s.bytes_of(RoundKind::SampleRequest)).collect();
        assert_eq!(req.len(), 3, "one fenced delta per epoch");
        assert!(req[0] > 0, "cold epoch should pay request bytes on this graph");
        assert!(
            req[2] < req[0],
            "unbounded cache must decay request traffic across epochs: {req:?}"
        );
    }
}

/// Run 4 workers sampling 3 minibatches each over an explicit wire
/// format, returning every rank's (seeds, per-batch MFGs) plus the
/// fabric's counter snapshot.
fn run_wire(
    d: &Dataset,
    book: &Arc<fastsample::partition::PartitionBook>,
    shards: &[WorkerShard],
    cache: (u64, CachePolicy),
    wire: SamplingWire,
    fanouts: &[usize],
    key: RngKey,
) -> (Vec<(Vec<NodeId>, Vec<Vec<Mfg>>)>, CommStats) {
    let (cache_bytes, cache_policy) = cache;
    let counters = Arc::new(Counters::default());
    let results = run_workers_with(4, NetworkModel::free(), Arc::clone(&counters), {
        move |rank, comm| {
            let shard = &shards[rank];
            let seeds = worker_seeds(d, book, rank, 16);
            let mut ws = SamplerWorkspace::new();
            let mut view = shard.topology.clone();
            if cache_bytes > 0 {
                view.enable_cache(cache_bytes, cache_policy);
            }
            let per_batch: Vec<Vec<Mfg>> = (0..3u64)
                .map(|b| {
                    sample_mfgs_distributed_wire(
                        comm,
                        shard,
                        &mut view,
                        &seeds,
                        fanouts,
                        key.fold(b),
                        &mut ws,
                        KernelKind::Fused,
                        wire,
                    )
                    .unwrap()
                })
                .collect();
            (seeds, per_batch)
        }
    });
    (results, counters.snapshot())
}

/// The bulk-kernel acceptance sweep: at every (replication budget, cache
/// capacity, cache policy) point, the columnar bulk wire and the scalar
/// run-length wire produce bit-identical MFGs (both equal to
/// single-machine sampling), identical measured rounds and request
/// bytes (the multi-batch runs pin identical cache-state evolution too),
/// and bulk response bytes never exceed scalar's — exactly equal with
/// the cache off, where the two encodings are the same size by
/// construction.
#[test]
fn bulk_and_scalar_wires_are_bit_identical_across_the_spectrum() {
    let d = dataset();
    let fanouts = [4usize, 3];
    let key = RngKey::new(2024);
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(4)));
    for policy in [
        ReplicationPolicy::vanilla(),
        ReplicationPolicy::budgeted(4 * 1024),
        ReplicationPolicy::halo(1),
        ReplicationPolicy::hybrid(),
    ] {
        let shards = build_shards(&d, &book, &policy);
        for cache in [
            (0u64, CachePolicy::Clock),
            (600, CachePolicy::Clock),
            (600, CachePolicy::StaticDegree),
            (u64::MAX >> 1, CachePolicy::Clock),
        ] {
            let (scalar, s_stats) =
                run_wire(&d, &book, &shards, cache, SamplingWire::Scalar, &fanouts, key);
            let (bulk, b_stats) =
                run_wire(&d, &book, &shards, cache, SamplingWire::Bulk, &fanouts, key);
            let tag = format!("{policy:?} cache {cache:?}");
            assert_eq!(scalar, bulk, "{tag}: wires diverged");
            let mut ws = SamplerWorkspace::new();
            for (seeds, per_batch) in &bulk {
                for (b, mfgs) in per_batch.iter().enumerate() {
                    let expect = sample_mfgs(
                        &d.graph,
                        seeds,
                        &fanouts,
                        key.fold(b as u64),
                        &mut ws,
                        KernelKind::Fused,
                    );
                    assert_eq!(mfgs, &expect, "{tag} batch {b}: != single-machine");
                }
            }
            // The wire choice must not change what the fabric *did* —
            // only how response payloads were laid out.
            assert_eq!(
                s_stats.sampling_rounds(),
                b_stats.sampling_rounds(),
                "{tag}: rounds diverged"
            );
            assert_eq!(
                s_stats.bytes_of(RoundKind::SampleRequest),
                b_stats.bytes_of(RoundKind::SampleRequest),
                "{tag}: request bytes diverged"
            );
            let sb = s_stats.bytes_of(RoundKind::SampleResponse);
            let bb = b_stats.bytes_of(RoundKind::SampleResponse);
            assert!(bb <= sb, "{tag}: bulk responses larger than scalar ({bb} > {sb})");
            if cache.0 == 0 {
                assert_eq!(bb, sb, "{tag}: uncached encodings must be the same size");
            }
        }
    }
}

/// Malformed bulk responses must surface as `CommError::Malformed` —
/// naming the offending peer — never as a panic or a hang. Rank 1 plays
/// a byzantine owner: it mimics the level's round sequence by hand
/// (vote, request exchange, response exchange) but answers rank 0's
/// misses with a corrupted columnar frame.
#[test]
fn malformed_bulk_responses_fail_the_round_cleanly() {
    type ReplyFn = fn(usize, usize) -> Vec<NodeId>; // (n_requests, fanout)
    let cases: [(&str, ReplyFn, &str); 5] = [
        ("truncated counts block", |_n, _f| Vec::new(), "truncated counts block"),
        (
            "blob shorter than prefix sum",
            |n, f| vec![f as NodeId; n],
            "ids blob shorter than its prefix sum",
        ),
        (
            "cache flags on an uncached round",
            |n, _f| {
                let mut r = vec![0 as NodeId; n];
                r[0] = 1 << 31; // ROW_FLAG with limit == 0
                r
            },
            "on an uncached round",
        ),
        (
            "count exceeds fanout",
            |n, f| {
                let mut r = vec![0 as NodeId; n];
                r[0] = f as NodeId + 1;
                r
            },
            "exceeds fanout",
        ),
        (
            "trailing words",
            |n, _f| vec![0 as NodeId; n + 1],
            "ordering invariant violated",
        ),
    ];
    for (name, make_reply, expect) in cases {
        let err = run_byzantine_owner(SamplingWire::Bulk, make_reply);
        match &err {
            CommError::Malformed { src, detail } => {
                assert_eq!(*src, 1, "{name}: wrong peer blamed");
                assert!(
                    detail.contains(expect),
                    "{name}: detail {detail:?} missing {expect:?}"
                );
            }
            other => panic!("{name}: expected Malformed, got {other:?}"),
        }
    }
    // The scalar decode rejects truncation the same way.
    let err = run_byzantine_owner(SamplingWire::Scalar, |_n, f| vec![f as NodeId]);
    match &err {
        CommError::Malformed { src, .. } => assert_eq!(*src, 1),
        other => panic!("scalar truncation: expected Malformed, got {other:?}"),
    }
}

/// 2-rank harness for the byzantine-owner tests: rank 0 runs the real
/// sampler (uncached, one level, every seed owned by rank 1, so all
/// misses route there); rank 1 replays the identical round sequence but
/// substitutes `make_reply(n, fanout)` for its response payload. Returns
/// rank 0's sampling error.
fn run_byzantine_owner(
    wire: SamplingWire,
    make_reply: fn(usize, usize) -> Vec<NodeId>,
) -> CommError {
    const FANOUT: usize = 3;
    let d = dataset();
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(2)));
    let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
    let key = RngKey::new(99);
    let shards_ref = &shards;
    let d_ref = &d;
    let book_ref = &book;
    let mut results = run_workers_with(2, NetworkModel::free(), Arc::new(Counters::default()), {
        move |rank, comm| {
            if rank == 0 {
                // Seeds owned by rank 1: every one is a level-0 miss.
                let seeds = worker_seeds(d_ref, book_ref, 1, 6);
                assert!(!seeds.is_empty(), "dataset has no rank-1 labeled nodes");
                let mut ws = SamplerWorkspace::new();
                let mut view = shards_ref[0].topology.clone();
                sample_mfgs_distributed_wire(
                    comm,
                    &shards_ref[0],
                    &mut view,
                    &seeds,
                    &[FANOUT],
                    key,
                    &mut ws,
                    KernelKind::Fused,
                    wire,
                )
                .map(|_| ())
            } else {
                // The byzantine owner: same vote + two data rounds, bad
                // payload. (Its own calls must all succeed — the
                // corruption is semantic, not a fabric failure.)
                let all_zero = comm.all_zero_u64(0).unwrap();
                assert!(!all_zero, "rank 0 must have misses");
                let granted: Vec<Vec<NodeId>> = comm
                    .exchange(RoundKind::SampleRequest, vec![Vec::new(), Vec::new()])
                    .unwrap();
                let n = granted[0].len();
                assert!(n > 0, "rank 0's misses should all route to rank 1");
                let reply = make_reply(n, FANOUT);
                comm.exchange(RoundKind::SampleResponse, vec![reply, Vec::new()]).unwrap();
                Ok(())
            }
        }
    });
    assert_eq!(results[1], Ok(()), "the byzantine rank itself must not fail");
    results
        .swap_remove(0)
        .expect_err("rank 0 must reject the corrupted response")
}

#[test]
fn feature_store_returns_exact_rows() {
    let d = dataset();
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(4)));
    let shards = build_shards(&d, &book, &ReplicationPolicy::hybrid());
    let counters = Arc::new(Counters::default());
    let shards_ref = &shards;
    let d_ref = &d;
    let results = run_workers_with(4, NetworkModel::free(), Arc::clone(&counters), {
        move |rank, comm| {
            let shard = &shards_ref[rank];
            // Mix of local and remote nodes, some repeated.
            let nodes: Vec<NodeId> = (0..200)
                .map(|i| ((i * 37 + rank * 311) % d_ref.num_nodes()) as NodeId)
                .collect();
            let mut out = Vec::new();
            let stats = fetch_features(comm, shard, &nodes, None, &mut out).unwrap();
            (nodes, out, stats)
        }
    });
    for (nodes, out, stats) in &results {
        assert_eq!(out.len(), nodes.len() * d.feat_dim);
        for (i, &v) in nodes.iter().enumerate() {
            assert_eq!(
                &out[i * d.feat_dim..(i + 1) * d.feat_dim],
                d.feat(v),
                "row mismatch at node {v}"
            );
        }
        assert_eq!(stats.local_rows + stats.remote_rows, nodes.len());
        assert!(stats.remote_rows > 0, "test should exercise remote rows");
    }
    // Exactly 2 feature rounds regardless of worker count.
    let s = counters.snapshot();
    assert_eq!(s.rounds_of(RoundKind::FeatureRequest), 1);
    assert_eq!(s.rounds_of(RoundKind::FeatureResponse), 1);
}

#[test]
fn feature_cache_cuts_traffic_without_changing_rows() {
    let d = dataset();
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(4)));
    let shards = build_shards(&d, &book, &ReplicationPolicy::hybrid());
    let shards_ref = &shards;
    let d_ref = &d;
    let results = run_workers_with(4, NetworkModel::free(), Arc::new(Counters::default()), {
        move |rank, comm| {
            let shard = &shards_ref[rank];
            let mut cache = FeatureCache::new(CachePolicy::Clock, 256, d_ref.feat_dim);
            let nodes: Vec<NodeId> = (0..150)
                .map(|i| ((i * 13 + rank * 101) % d_ref.num_nodes()) as NodeId)
                .collect();
            let mut out1 = Vec::new();
            let s1 = fetch_features(comm, shard, &nodes, Some(&mut cache), &mut out1).unwrap();
            // Second fetch of the same nodes: remote rows now cached.
            let mut out2 = Vec::new();
            let s2 = fetch_features(comm, shard, &nodes, Some(&mut cache), &mut out2).unwrap();
            (nodes, out1, out2, s1, s2)
        }
    });
    for (nodes, out1, out2, s1, s2) in &results {
        assert_eq!(out1, out2);
        for (i, &v) in nodes.iter().enumerate() {
            assert_eq!(&out1[i * d.feat_dim..(i + 1) * d.feat_dim], d.feat(v));
        }
        assert_eq!(s1.cache_hits, 0);
        assert!(s2.cache_hits > 0);
        assert!(s2.bytes_in < s1.bytes_in, "cache must cut feature traffic");
    }
}
