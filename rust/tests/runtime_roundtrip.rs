//! Integration: the python-AOT → rust-PJRT round trip on the real
//! `quickstart` artifact. Requires `make artifacts` (skips with a clear
//! message otherwise, so `cargo test` works on a fresh checkout).

use fastsample::runtime::{Engine, HostTensor, Manifest, ModelRuntime, PaddedBatch};
use fastsample::sampling::rng::RngKey;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// Build a random-but-valid padded batch for a variant.
fn random_batch(rt: &ModelRuntime, seed: u64) -> PaddedBatch {
    let v = &rt.variant;
    let key = RngKey::new(seed);
    let mut s = key.stream(0);
    let feats: Vec<f32> =
        (0..v.caps[0] * v.feat_dim).map(|_| s.next_range_f32(-1.0, 1.0)).collect();
    let mut levels = Vec::new();
    for l in 1..=v.layers() {
        let k = v.fanout_at_layer(l);
        let n_dst = v.caps[l];
        let n_src = v.caps[l - 1];
        let idx: Vec<i32> = (0..n_dst * k).map(|_| s.next_below(n_src) as i32).collect();
        let cnt: Vec<i32> = (0..n_dst).map(|_| s.next_below(k + 1) as i32).collect();
        levels.push((
            HostTensor::i32(idx, &[n_dst, k]),
            HostTensor::i32(cnt, &[n_dst]),
        ));
    }
    let labels: Vec<i32> = (0..v.batch).map(|_| s.next_below(v.classes) as i32).collect();
    PaddedBatch {
        feats: HostTensor::f32(feats, &[v.caps[0], v.feat_dim]),
        levels,
        labels,
        label_mask: vec![1.0; v.batch],
    }
}

#[test]
fn quickstart_train_and_eval_execute() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(&engine, &manifest, "quickstart").unwrap();

    let params = rt.init_params(0);
    assert_eq!(params.len(), rt.variant.params.len());

    let batch = random_batch(&rt, 1);
    let out = rt.train_step(&params, &batch, 0).unwrap();
    assert!(out.loss.is_finite(), "loss {}", out.loss);
    // Random logits + 8 classes → loss near ln(8).
    assert!((0.5..6.0).contains(&out.loss), "loss {}", out.loss);
    assert_eq!(out.grads.len(), params.len());
    for (g, p) in out.grads.iter().zip(&params) {
        assert_eq!(g.shape(), p.shape());
        assert!(g.as_f32().unwrap().iter().all(|x| x.is_finite()));
    }
    // Grads must not be identically zero (the model is differentiable).
    let total: f32 = out
        .grads
        .iter()
        .map(|g| g.as_f32().unwrap().iter().map(|x| x.abs()).sum::<f32>())
        .sum();
    assert!(total > 0.0);

    let eval = rt.eval_step(&params, &batch).unwrap();
    assert_eq!(eval.logits.shape(), &[rt.variant.batch, rt.variant.classes]);
}

#[test]
fn train_step_is_deterministic_given_seed() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(&engine, &manifest, "quickstart").unwrap();
    let params = rt.init_params(3);
    let batch = random_batch(&rt, 4);
    let a = rt.train_step(&params, &batch, 7).unwrap();
    let b = rt.train_step(&params, &batch, 7).unwrap();
    assert_eq!(a.loss, b.loss);
    for (x, y) in a.grads.iter().zip(&b.grads) {
        assert_eq!(x, y);
    }
    // Different dropout seed → different loss (dropout is live).
    let c = rt.train_step(&params, &batch, 8).unwrap();
    assert_ne!(a.loss, c.loss);
}

#[test]
fn sgd_on_executable_reduces_loss() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(&engine, &manifest, "quickstart").unwrap();
    let mut params = rt.init_params(5);
    let batch = random_batch(&rt, 6);
    let first = rt.train_step(&params, &batch, 0).unwrap().loss;
    let mut last = first;
    for step in 0..30 {
        let out = rt.train_step(&params, &batch, step).unwrap();
        last = out.loss;
        for (p, g) in params.iter_mut().zip(&out.grads) {
            if let (HostTensor::F32 { data: pd, .. }, HostTensor::F32 { data: gd, .. }) =
                (p, g)
            {
                for (x, dx) in pd.iter_mut().zip(gd) {
                    *x -= 0.2 * dx;
                }
            }
        }
    }
    assert!(
        last < 0.8 * first,
        "loss failed to decrease on fixed batch: {first} -> {last}"
    );
}
