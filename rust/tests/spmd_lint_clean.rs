//! Tier-1 gate: the shipped tree honors the SPMD fabric contract.
//!
//! `spmd-lint` walks every source file and reports R1-R6 violations
//! (rank-divergent collectives, panics in dist/, dropped fabric errors,
//! RoundKind coverage holes, sends under a held lock, plane switches in
//! sampler-thread code). The tree ships at
//! ZERO findings — if this test fails, fix the code or add a justified
//! `// spmd-lint: allow(<rule>) — <why>` at the site, never here.

use std::path::Path;

#[test]
fn tree_has_zero_spmd_lint_findings() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let findings = spmd_lint::lint_tree(&root).expect("rust/src is readable");
    assert!(
        findings.is_empty(),
        "spmd-lint found {} violation(s):\n{}",
        findings.len(),
        spmd_lint::render_human(&findings)
    );
}
