//! Transport equivalence + fault injection: the proof that the socket
//! transport is a drop-in for the in-process channel mesh.
//!
//! * **Equivalence** — for every replication/cache arm (vanilla,
//!   `budget:<bytes>`, hybrid, `+cache:`), the same seeded run over
//!   [`ChannelMesh`] and [`TcpMesh`] on loopback produces bit-identical
//!   MFGs (and, with AOT artifacts present, bit-identical loss curves)
//!   and **identical** `CommStats` — round counts and byte counts both,
//!   because both transports serialize payloads through the same wire
//!   encoding.
//! * **Accounting** — `CommStats` byte counters equal the sum of framed
//!   payload lengths actually handed to the transport (verified by a
//!   counting wrapper under the real mesh).
//! * **Pipelining** — `--pipeline on` (the double-buffered MFG
//!   prefetcher on the Sampling plane) is bit-identical to the serial
//!   phases on both transports: same digest curve, MFGs, seeds,
//!   per-epoch fenced deltas, and counter totals.
//! * **Fault injection** — a [`FlakyTransport`] wrapper (deterministic
//!   seeded delays; short writes via `TcpMesh::set_max_chunk`) must not
//!   change a single bit; a peer dropping mid-round must surface as a
//!   clean `CommError::PeerLost` naming a peer on every survivor — no
//!   deadlock, no panic (bounded by an explicit test deadline) — and a
//!   mid-epoch death must poison BOTH communication planes promptly.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use fastsample::dist::{
    fetch_features, run_workers_on, run_workers_over, sample_mfgs_distributed,
    sample_mfgs_distributed_wire, CachePolicy, CommError, CommStats, Counters, Frame,
    NetworkModel, Plane, RoundKind, SamplingWire, TcpMesh, Transport, TransportConfig,
};
use fastsample::graph::generator::{make_dataset, DatasetParams};
use fastsample::graph::{Dataset, NodeId};
use fastsample::partition::{
    build_shards, partition_graph, PartitionBook, PartitionConfig, ReplicationPolicy,
};
use fastsample::sampling::rng::{RngKey, RngStream};
use fastsample::sampling::{sample_mfgs, KernelKind, Mfg, SamplerWorkspace};
use fastsample::train::{sample_rank, train_distributed, SampleRankReport, TrainConfig};

const WORKERS: usize = 3;
const BATCHES: u64 = 3;
const FANOUTS: [usize; 2] = [4, 3];

fn dataset() -> Dataset {
    make_dataset(&DatasetParams {
        name: "transport-eq".into(),
        num_nodes: 600,
        avg_degree: 9,
        feat_dim: 5,
        num_classes: 4,
        labeled_frac: 0.25,
        p_intra: 0.8,
        noise: 0.25,
        seed: 99,
    })
}

fn worker_seeds(d: &Dataset, book: &PartitionBook, part: usize, n: usize) -> Vec<NodeId> {
    d.train_ids.iter().copied().filter(|&v| book.part_of(v) == part).take(n).collect()
}

/// The replication/cache arms the transports must agree on.
fn arms() -> Vec<(&'static str, ReplicationPolicy, u64)> {
    vec![
        ("vanilla", ReplicationPolicy::vanilla(), 0),
        ("budget:4k", ReplicationPolicy::budgeted(4 * 1024), 0),
        ("hybrid", ReplicationPolicy::hybrid(), 0),
        ("vanilla+cache:32k", ReplicationPolicy::vanilla(), 32 << 10),
    ]
}

/// One arm's training-shaped workload (sampling + feature exchange +
/// grad sync per batch) over the given transport: per-rank results plus
/// the fabric's counter snapshot.
#[allow(clippy::type_complexity)]
fn run_arm(
    d: &Dataset,
    book: &Arc<PartitionBook>,
    policy: &ReplicationPolicy,
    cache_bytes: u64,
    config: &TransportConfig,
    wire: SamplingWire,
) -> (Vec<(Vec<NodeId>, Vec<Vec<Mfg>>, Vec<f32>)>, CommStats) {
    let shards = build_shards(d, book, policy);
    let counters = Arc::new(Counters::default());
    let key = RngKey::new(2024);
    let shards_ref = &shards;
    let d_ref = d;
    let book_ref = book;
    let results = run_workers_on(
        config,
        WORKERS,
        NetworkModel::free(),
        Arc::clone(&counters),
        move |rank, comm| {
            let shard = &shards_ref[rank];
            let seeds = worker_seeds(d_ref, book_ref, rank, 12);
            let mut ws = SamplerWorkspace::new();
            let mut view = shard.topology.clone();
            if cache_bytes > 0 && !shard.policy.is_full() {
                view.enable_cache(cache_bytes, CachePolicy::Clock);
            }
            let mut feat = Vec::new();
            let per_batch: Vec<Vec<Mfg>> = (0..BATCHES)
                .map(|b| {
                    let mfgs = sample_mfgs_distributed_wire(
                        comm,
                        shard,
                        &mut view,
                        &seeds,
                        &FANOUTS,
                        key.fold(b),
                        &mut ws,
                        KernelKind::Fused,
                        wire,
                    )
                    .unwrap();
                    fetch_features(comm, shard, &mfgs[0].src_nodes, None, &mut feat).unwrap();
                    let mut grad = vec![rank as f32 + 0.5; 16];
                    comm.all_reduce_mean_f32(RoundKind::GradSync, &mut grad).unwrap();
                    mfgs
                })
                .collect();
            (seeds, per_batch, feat)
        },
    )
    .expect("transport setup");
    (results, counters.snapshot())
}

/// The tentpole acceptance test: every arm is bit-identical (MFGs) and
/// counter-identical (rounds AND bytes) between the channel mesh and
/// loopback TCP, and both match single-machine sampling.
#[test]
fn transports_are_bit_identical_and_round_identical_on_every_arm() {
    let d = dataset();
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(WORKERS)));
    let key = RngKey::new(2024);
    for (label, policy, cache_bytes) in arms() {
        let (inproc, s_inproc) = run_arm(
            &d,
            &book,
            &policy,
            cache_bytes,
            &TransportConfig::Inproc,
            SamplingWire::default(),
        );
        let (tcp, s_tcp) = run_arm(
            &d,
            &book,
            &policy,
            cache_bytes,
            &TransportConfig::Tcp { base_port: 0 },
            SamplingWire::default(),
        );

        assert_eq!(inproc, tcp, "{label}: per-rank results diverged across transports");
        assert_eq!(
            s_inproc, s_tcp,
            "{label}: round/byte counters diverged across transports"
        );

        // And both equal single-machine ground truth.
        let mut ws = SamplerWorkspace::new();
        for (seeds, per_batch, _) in &inproc {
            for (b, mfgs) in per_batch.iter().enumerate() {
                let expect = sample_mfgs(
                    &d.graph,
                    seeds,
                    &FANOUTS,
                    key.fold(b as u64),
                    &mut ws,
                    KernelKind::Fused,
                );
                assert_eq!(mfgs, &expect, "{label} batch {b} != single-machine");
            }
        }

        // Sanity on the round structure per arm: hybrid pays zero;
        // vanilla pays 2(L−1) = 2 per batch on this graph (level 0 seeds
        // are local, level 1 always has cross-partition misses).
        if policy.is_full() {
            assert_eq!(s_tcp.sampling_rounds(), 0, "{label}: hybrid must pay zero");
        } else if label == "vanilla" {
            assert_eq!(s_tcp.sampling_rounds(), 2 * BATCHES, "{label}");
        }
    }
}

/// The sampling-wire grid over both transports: scalar and bulk produce
/// bit-identical per-rank results on the channel mesh AND over loopback
/// TCP; counters for a given wire are transport-invariant; and bulk
/// response bytes never exceed scalar's on either transport (this arm
/// runs cache-on, where bulk saves a word per `NO_ROW`/elided entry —
/// the exact per-entry savings are pinned by the elision unit test in
/// `dist::sampling`).
#[test]
fn wire_formats_match_across_transports() {
    let d = dataset();
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(WORKERS)));
    let policy = ReplicationPolicy::vanilla();
    let cache_bytes = 32 << 10;
    let mut results = Vec::new();
    let mut stats = Vec::new();
    for config in [TransportConfig::Inproc, TransportConfig::Tcp { base_port: 0 }] {
        for wire in [SamplingWire::Scalar, SamplingWire::Bulk] {
            let (r, s) = run_arm(&d, &book, &policy, cache_bytes, &config, wire);
            results.push(r);
            stats.push((config.clone(), wire, s));
        }
    }
    // All four (transport, wire) cells are bit-identical in content.
    for (cell, r) in results.iter().enumerate().skip(1) {
        assert_eq!(&results[0], r, "cell {cell} diverged from inproc+scalar");
    }
    // A wire's counters are transport-invariant (inproc cells 0/1 pair
    // with tcp cells 2/3)...
    assert_eq!(stats[0].2, stats[2].2, "scalar counters diverged across transports");
    assert_eq!(stats[1].2, stats[3].2, "bulk counters diverged across transports");
    // ...and within each transport, requests match while bulk responses
    // never exceed scalar's (each `NO_ROW`/elided entry saves a word).
    for pair in stats.chunks(2) {
        let (scalar, bulk) = (&pair[0].2, &pair[1].2);
        assert_eq!(
            scalar.bytes_of(RoundKind::SampleRequest),
            bulk.bytes_of(RoundKind::SampleRequest),
            "request bytes must be wire-invariant"
        );
        assert!(
            bulk.bytes_of(RoundKind::SampleResponse)
                <= scalar.bytes_of(RoundKind::SampleResponse),
            "bulk responses must never be larger than scalar"
        );
    }
}

/// Loss-curve equivalence (the full trainer, AOT artifacts required —
/// skips politely without them, like `train_e2e`): per arm, inproc and
/// tcp runs produce bit-identical loss curves and identical comm totals.
#[test]
fn loss_curves_are_bit_identical_across_transports() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    let d = fastsample::graph::datasets::quickstart(1);
    for mode in ["vanilla", "budget:16k", "hybrid", "vanilla+cache:8k"] {
        let run = |transport: TransportConfig| {
            let mut cfg = TrainConfig::mode("quickstart", mode, 4).unwrap();
            cfg.epochs = 2;
            cfg.max_batches = Some(3);
            cfg.net = NetworkModel::free();
            cfg.transport = transport;
            train_distributed(&d, &artifacts, &cfg).unwrap()
        };
        let a = run(TransportConfig::Inproc);
        let b = run(TransportConfig::Tcp { base_port: 0 });
        assert!(!a.loss_curve.is_empty());
        assert_eq!(a.loss_curve, b.loss_curve, "{mode}: loss curves diverged");
        assert_eq!(a.comm_total, b.comm_total, "{mode}: comm totals diverged");
    }
}

/// The prefetcher arm: `--pipeline on` (a sampler thread producing
/// minibatch t+1 into a depth-1 channel on the Sampling plane while the
/// trainer consumes t) is bit-identical to the serial phases — same
/// digest curve, MFGs, seeds, per-epoch fenced deltas, and counter
/// totals — on the channel mesh AND over loopback TCP, and all four
/// (transport, pipeline) cells agree with each other.
#[test]
fn pipelined_sampling_is_bit_identical_on_both_transports() {
    let d = dataset();
    let run = |config: &TransportConfig, pipeline: bool| -> Vec<SampleRankReport> {
        let mut cfg = TrainConfig::mode("quickstart", "vanilla+cache:16k", WORKERS).unwrap();
        cfg.epochs = 2;
        cfg.max_batches = Some(3);
        cfg.net = NetworkModel::free();
        cfg.seed = 11;
        cfg.verbose = false;
        cfg.pipeline = pipeline;
        let d_ref = &d;
        let cfg_ref = &cfg;
        run_workers_on(
            config,
            WORKERS,
            NetworkModel::free(),
            Arc::new(Counters::default()),
            move |rank, comm| sample_rank(d_ref, cfg_ref, 8, &FANOUTS, true, rank, comm).unwrap(),
        )
        .expect("transport setup")
    };
    let mut baseline: Option<Vec<SampleRankReport>> = None;
    for config in [TransportConfig::Inproc, TransportConfig::Tcp { base_port: 0 }] {
        let serial = run(&config, false);
        let piped = run(&config, true);
        assert_eq!(serial, piped, "{config}: --pipeline on diverged from the serial phases");
        assert_eq!(piped[0].epoch_deltas.len(), 2, "{config}: one fenced delta per epoch");
        assert!(!piped[0].curve.is_empty(), "{config}: workload ran no steps — test too weak");
        match &baseline {
            None => baseline = Some(serial),
            Some(b) => assert_eq!(b, &serial, "{config}: diverged from the inproc baseline"),
        }
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// Test wrapper around any transport: deterministic seeded delays before
/// every send/recv (so frame arrivals interleave differently from the
/// lockstep schedule) and an exact count of data-round payload bytes
/// handed to the wire (for the accounting assertion). The jitter stream
/// sits behind a mutex because the `&self` transport contract lets both
/// plane owners call in concurrently.
struct FlakyTransport {
    inner: Box<dyn Transport>,
    rng: Mutex<RngStream>,
    delay_max_us: usize,
    data_bytes: Arc<AtomicU64>,
}

impl FlakyTransport {
    fn new(inner: Box<dyn Transport>, seed: u64, delay_max_us: usize) -> Self {
        let rank = inner.rank() as u64;
        FlakyTransport {
            inner,
            rng: Mutex::new(RngKey::new(seed).fold(rank).stream(0)),
            delay_max_us,
            data_bytes: Arc::new(AtomicU64::new(0)),
        }
    }

    fn jitter(&self) {
        if self.delay_max_us > 0 {
            let us = self.rng.lock().unwrap().next_below(self.delay_max_us) as u64;
            if us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(us));
            }
        }
    }
}

impl Transport for FlakyTransport {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn world(&self) -> usize {
        self.inner.world()
    }

    fn send(&self, dst: usize, frame: Frame) -> Result<(), CommError> {
        if (frame.kind as usize) < RoundKind::COUNT {
            self.data_bytes.fetch_add(frame.payload.len() as u64, Ordering::Relaxed);
        }
        self.jitter();
        self.inner.send(dst, frame)
    }

    fn flush(&self) -> Result<(), CommError> {
        self.inner.flush()
    }

    fn recv(&self, src: usize) -> Result<Frame, CommError> {
        self.jitter();
        self.inner.recv(src)
    }

    fn name(&self) -> &'static str {
        "flaky"
    }

    fn shutdown(&self) {
        self.inner.shutdown()
    }
}

/// Bound a fault scenario with a hard deadline: if the workers deadlock,
/// the test fails with a message instead of hanging the suite.
fn with_deadline<R: Send + 'static>(secs: u64, f: impl FnOnce() -> R + Send + 'static) -> R {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(std::time::Duration::from_secs(secs)) {
        Ok(r) => r,
        Err(_) => panic!("fault-injection scenario did not complete within {secs}s — deadlock"),
    }
}

/// Seeded delays + short writes (7-byte chunks with eager flushes, so
/// every frame crosses the wire fragmented) must not change a bit, and
/// the byte counters must equal the framed payload bytes exactly.
#[test]
fn flaky_tcp_with_short_writes_is_still_bit_exact_and_counted() {
    with_deadline(120, || {
        let d = dataset();
        let book =
            Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(WORKERS)));
        let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
        let counters = Arc::new(Counters::default());
        let key = RngKey::new(2024);

        let meshes = TcpMesh::loopback(WORKERS, 0).unwrap();
        for m in &meshes {
            m.set_max_chunk(7); // short writes: frames fragment on the wire
        }
        let mut wire_counts = Vec::new();
        let transports: Vec<Box<dyn Transport>> = meshes
            .into_iter()
            .map(|m| {
                let t = FlakyTransport::new(Box::new(m), 0xF1A2, 120);
                wire_counts.push(Arc::clone(&t.data_bytes));
                Box::new(t) as Box<dyn Transport>
            })
            .collect();

        let shards_ref = &shards;
        let d_ref = &d;
        let book_ref = &book;
        let results = run_workers_over(
            transports,
            NetworkModel::free(),
            Arc::clone(&counters),
            move |rank, comm| {
                let shard = &shards_ref[rank];
                let seeds = worker_seeds(d_ref, book_ref, rank, 12);
                let mut ws = SamplerWorkspace::new();
                let mut view = shard.topology.clone();
                let mut feat = Vec::new();
                let per_batch: Vec<Vec<Mfg>> = (0..BATCHES)
                    .map(|b| {
                        let mfgs = sample_mfgs_distributed(
                            comm,
                            shard,
                            &mut view,
                            &seeds,
                            &FANOUTS,
                            key.fold(b),
                            &mut ws,
                            KernelKind::Fused,
                        )
                        .unwrap();
                        fetch_features(comm, shard, &mfgs[0].src_nodes, None, &mut feat)
                            .unwrap();
                        let mut grad = vec![rank as f32; 8];
                        comm.all_reduce_mean_f32(RoundKind::GradSync, &mut grad).unwrap();
                        mfgs
                    })
                    .collect();
                (seeds, per_batch)
            },
        );

        // Bit-exactness under fragmentation + jitter.
        let mut ws = SamplerWorkspace::new();
        for (seeds, per_batch) in &results {
            for (b, mfgs) in per_batch.iter().enumerate() {
                let expect = sample_mfgs(
                    &d.graph,
                    seeds,
                    &FANOUTS,
                    key.fold(b as u64),
                    &mut ws,
                    KernelKind::Fused,
                );
                assert_eq!(mfgs, &expect, "short writes corrupted batch {b}");
            }
        }

        // CommStats bytes == sum of framed data payload lengths, exactly.
        let framed: u64 = wire_counts.iter().map(|c| c.load(Ordering::Relaxed)).sum();
        assert_eq!(
            counters.snapshot().total_bytes(),
            framed,
            "byte counters are not measuring the framed wire payloads"
        );
        assert!(framed > 0, "workload moved no data — test too weak");
    });
}

/// The same framed-bytes accounting identity over the channel mesh: the
/// counters measure serialized payloads on every transport.
#[test]
fn comm_bytes_match_framed_payloads_on_the_channel_mesh() {
    let d = dataset();
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(WORKERS)));
    let counters = Arc::new(Counters::default());
    let mut wire_counts = Vec::new();
    let transports: Vec<Box<dyn Transport>> = TransportConfig::Inproc
        .build_mesh(WORKERS)
        .unwrap()
        .into_iter()
        .map(|m| {
            let t = FlakyTransport::new(m, 0xC0DE, 0); // count only, no delays
            wire_counts.push(Arc::clone(&t.data_bytes));
            Box::new(t) as Box<dyn Transport>
        })
        .collect();
    let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
    let key = RngKey::new(7);
    let shards_ref = &shards;
    let d_ref = &d;
    let book_ref = &book;
    run_workers_over(transports, NetworkModel::free(), Arc::clone(&counters), {
        move |rank, comm| {
            let shard = &shards_ref[rank];
            let seeds = worker_seeds(d_ref, book_ref, rank, 10);
            let mut ws = SamplerWorkspace::new();
            let mut view = shard.topology.clone();
            let mut feat = Vec::new();
            let mfgs = sample_mfgs_distributed(
                comm,
                shard,
                &mut view,
                &seeds,
                &FANOUTS,
                key,
                &mut ws,
                KernelKind::Fused,
            )
            .unwrap();
            fetch_features(comm, shard, &mfgs[0].src_nodes, None, &mut feat).unwrap();
        }
    });
    let framed: u64 = wire_counts.iter().map(|c| c.load(Ordering::Relaxed)).sum();
    assert_eq!(counters.snapshot().total_bytes(), framed);
    assert!(framed > 0);
}

/// One peer drops mid-run: every survivor's next round fails with a
/// clean `CommError::PeerLost` naming a peer — no deadlock, no panic —
/// on both transports. The rank whose receive order reaches the dead
/// peer first must name it precisely.
#[test]
fn mid_round_peer_drop_fails_cleanly_on_both_transports() {
    for config in [TransportConfig::Inproc, TransportConfig::Tcp { base_port: 0 }] {
        let results = with_deadline(60, move || {
            let counters = Arc::new(Counters::default());
            run_workers_on(&config, 3, NetworkModel::free(), counters, |rank, comm| {
                let boxes = |v: u32| (0..3).map(|_| vec![v]).collect::<Vec<Vec<u32>>>();
                // Round 1: everyone healthy.
                comm.exchange(RoundKind::SampleRequest, boxes(1)).unwrap();
                if rank == 1 {
                    return None; // rank 1 dies here; its links close on drop
                }
                // Round 2: survivors must fail cleanly, not hang.
                Some(comm.exchange(RoundKind::SampleRequest, boxes(2)))
            })
            .unwrap()
        });
        assert!(results[1].is_none(), "{config}: the dropped rank should have exited");
        for rank in [0usize, 2] {
            match &results[rank] {
                Some(Err(CommError::PeerLost { rank: lost })) => {
                    assert_ne!(*lost, rank, "{config}: rank {rank} lost itself?");
                }
                other => panic!(
                    "{config}: rank {rank} expected Err(PeerLost), got {other:?}"
                ),
            }
        }
        // Rank 0 receives from rank 1 before rank 2, and rank 1's death
        // is the only fault — rank 0 must name it exactly.
        assert_eq!(
            results[0],
            Some(Err(CommError::PeerLost { rank: 1 })),
            "{config}: rank 0 did not name the dead peer"
        );
    }
}

/// A peer dying mid-epoch must poison BOTH communication planes of every
/// survivor: the sampler thread's Sampling-plane round and the trainer's
/// Gradient-plane round each surface a typed `CommError::PeerLost` — no
/// deadlock, no panic — on both transports, under a hard deadline.
#[test]
fn peer_death_surfaces_on_both_planes_of_every_survivor() {
    for config in [TransportConfig::Inproc, TransportConfig::Tcp { base_port: 0 }] {
        let results = with_deadline(60, move || {
            let counters = Arc::new(Counters::default());
            run_workers_on(&config, 3, NetworkModel::free(), counters, |rank, comm| {
                let mut scomm = comm.plane(Plane::Sampling);
                let boxes = |v: u32| (0..3).map(|_| vec![v]).collect::<Vec<Vec<u32>>>();
                // Round 1 on each plane: everyone healthy.
                scomm.exchange(RoundKind::SampleRequest, boxes(1)).unwrap();
                comm.exchange(RoundKind::GradSync, boxes(2)).unwrap();
                if rank == 1 {
                    return None; // rank 1 dies mid-epoch; its links close on drop
                }
                // Round 2: both planes must fail cleanly, not hang.
                let sampling = scomm.exchange(RoundKind::SampleRequest, boxes(3));
                let gradient = comm.exchange(RoundKind::GradSync, boxes(4));
                Some((sampling, gradient))
            })
            .unwrap()
        });
        assert!(results[1].is_none(), "{config}: the dropped rank should have exited");
        for rank in [0usize, 2] {
            let Some((sampling, gradient)) = &results[rank] else {
                panic!("{config}: rank {rank} returned no results");
            };
            for (plane, r) in [("sampling", sampling), ("gradient", gradient)] {
                match r {
                    Err(CommError::PeerLost { rank: lost }) => {
                        assert_ne!(*lost, rank, "{config}: rank {rank} lost itself?");
                    }
                    other => panic!(
                        "{config}: rank {rank} {plane} plane expected Err(PeerLost), \
                         got {other:?}"
                    ),
                }
            }
        }
        // Rank 0's receive order reaches the dead peer first on the
        // Sampling plane; the Gradient plane then reports the fabric's
        // sealed root cause — the same lost peer.
        let (s0, g0) = results[0].as_ref().unwrap();
        assert_eq!(s0, &Err(CommError::PeerLost { rank: 1 }), "{config}: sampling plane");
        assert_eq!(g0, &Err(CommError::PeerLost { rank: 1 }), "{config}: gradient plane");
    }
}
