//! Integration: the full distributed training loop on the quickstart
//! dataset/variant, including the paper's central invariant — vanilla,
//! hybrid, and hybrid+fused runs are **mathematically identical** (§4.2:
//! "Activating or disabling these two techniques lead to mathematically
//! equivalent training results") — here pinned to bit-equal loss curves.

use fastsample::dist::NetworkModel;
use fastsample::graph::datasets;
use fastsample::train::{train_distributed, TrainConfig};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn base_cfg(mode: &str) -> TrainConfig {
    let mut cfg = TrainConfig::mode("quickstart", mode, 4).unwrap();
    cfg.epochs = 2;
    cfg.max_batches = Some(3);
    cfg.net = NetworkModel::free();
    cfg.eval_last_batch = true;
    cfg
}

#[test]
fn every_replication_point_produces_identical_loss_curves() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    };
    let d = datasets::quickstart(1);
    // The cache arms ride along: dynamic adjacency caching must leave the
    // loss curve bit-identical too (cached rows are complete, so every
    // sample is the same draw).
    let modes = [
        "vanilla",
        "budget:16k",
        "vanilla+cache:8k",
        "budget:16k+cache:8k",
        "hybrid",
        "hybrid+fused",
    ];
    let reports: Vec<_> = modes
        .iter()
        .map(|m| train_distributed(&d, &dir, &base_cfg(m)).unwrap())
        .collect();

    assert!(!reports[0].loss_curve.is_empty());
    // Bit-identical loss curves across the whole spectrum.
    for (i, r) in reports.iter().enumerate().skip(1) {
        assert_eq!(
            reports[0].loss_curve, r.loss_curve,
            "{} diverged from {}",
            modes[i], modes[0]
        );
    }

    // Round structure: vanilla pays sampling rounds, a mid budget pays no
    // more than vanilla, the cache arms pay no more than their uncached
    // counterparts, full replication pays none.
    let rounds: Vec<u64> = reports.iter().map(|r| r.comm_total.sampling_rounds()).collect();
    assert!(rounds[0] > 0);
    assert!(rounds[1] <= rounds[0], "budget:16k vs vanilla: {rounds:?}");
    assert!(rounds[2] <= rounds[0], "vanilla+cache vs vanilla: {rounds:?}");
    assert!(rounds[3] <= rounds[1], "budget+cache vs budget: {rounds:?}");
    assert_eq!(rounds[4], 0);
    assert_eq!(rounds[5], 0);
    // Everyone pays the 2 feature rounds and grad sync.
    for r in &reports {
        assert!(r.comm_total.rounds[2] > 0, "feature requests missing");
        assert!(r.comm_total.rounds[4] > 0, "grad sync missing");
    }
}

#[test]
fn training_learns_the_planted_task() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    };
    let d = datasets::quickstart(2);
    let mut cfg = base_cfg("hybrid+fused");
    cfg.epochs = 6;
    cfg.max_batches = Some(3);
    let report = train_distributed(&d, &dir, &cfg).unwrap();

    let first = report.epochs.first().unwrap().mean_loss;
    let last = report.epochs.last().unwrap().mean_loss;
    assert!(
        last < 0.6 * first,
        "loss failed to decrease: {first} -> {last} (curve {:?})",
        report.loss_curve
    );
    // The planted task is easy: accuracy on the last batch should beat
    // chance (1/8) by a wide margin after 6 epochs.
    let acc = report.epochs.last().unwrap().acc.unwrap();
    assert!(acc > 0.5, "accuracy {acc}");
}

#[test]
fn feature_cache_does_not_change_training() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    };
    let d = datasets::quickstart(3);
    let plain = train_distributed(&d, &dir, &base_cfg("hybrid+fused")).unwrap();
    let mut cached_cfg = base_cfg("hybrid+fused");
    cached_cfg.cache_capacity = 400;
    let cached = train_distributed(&d, &dir, &cached_cfg).unwrap();
    assert_eq!(plain.loss_curve, cached.loss_curve);
    // And it must actually cut feature bytes.
    use fastsample::dist::RoundKind;
    assert!(
        cached.comm_total.bytes_of(RoundKind::FeatureResponse)
            < plain.comm_total.bytes_of(RoundKind::FeatureResponse),
        "cache saved no bytes"
    );
}

#[test]
fn worker_counts_give_same_math_different_rounds() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    };
    let d = datasets::quickstart(4);
    for workers in [2, 4] {
        let mut cfg = base_cfg("vanilla");
        cfg.workers = workers;
        let r = train_distributed(&d, &dir, &cfg).unwrap();
        // 2(L-1) sampling rounds per batch, L=3 → 4 per batch.
        let batches: u64 = r.epochs.iter().map(|e| e.batches as u64).sum();
        assert_eq!(r.comm_total.sampling_rounds(), 4 * batches, "workers={workers}");
        assert!(r.loss_curve.iter().all(|l| l.is_finite()));
    }
}
