//! Randomized property tests (in-tree proptest substitute — see
//! `util::prop`): structural invariants of the sampling kernels,
//! partitioner, JSON parser, collectives, and padding over hundreds of
//! randomized cases. Failures print a `check_one(seed, case, ..)` repro.

use fastsample::dist::{
    run_workers, sample_mfgs_distributed, sample_mfgs_distributed_wire, CachePolicy, Frame,
    NetworkModel, RoundKind, SamplingWire, TcpMesh, Transport,
};
use fastsample::graph::generator::{erdos_renyi, make_dataset, planted_communities, rmat, DatasetParams};
use fastsample::graph::{CooGraph, CscGraph, NodeId};
use fastsample::partition::{
    build_shards, partition_graph, PartitionBook, PartitionConfig, ReplicationPolicy,
};
use fastsample::sampling::rng::RngKey;
use fastsample::sampling::{
    sample_level_baseline, sample_level_fused, sample_mfgs, KernelKind, SamplerWorkspace,
};
use fastsample::util::json::Json;
use fastsample::util::prop::{check, gen};

/// Random graph from the stream: mixes the three generators.
fn random_graph(i: usize, s: &mut fastsample::sampling::rng::RngStream) -> CscGraph {
    let n = gen::size(s, 2, 60 + i * 4);
    match s.next_below(3) {
        0 => erdos_renyi(n, gen::size(s, 0, 12), RngKey::new(s.next_u64())),
        1 => {
            let np2 = n.next_power_of_two();
            rmat(np2, np2 * gen::size(s, 1, 8), (0.45, 0.25, 0.2, 0.1), RngKey::new(s.next_u64()))
        }
        _ => {
            planted_communities(
                n.max(4),
                gen::size(s, 1, 4),
                gen::size(s, 1, 8),
                0.7,
                RngKey::new(s.next_u64()),
            )
            .0
        }
    }
}

#[test]
fn prop_fused_equals_baseline_always() {
    check(101, 120, |i, s| {
        let g = random_graph(i, s);
        let n = g.num_nodes();
        let k = gen::size(s, 0, n.min(40));
        let seeds: Vec<NodeId> = gen::subset(s, n, k);
        if seeds.is_empty() {
            return;
        }
        let fanout = gen::size(s, 1, 12);
        let key = RngKey::new(s.next_u64());
        let mut ws_a = SamplerWorkspace::new();
        let mut ws_b = SamplerWorkspace::new();
        let a = sample_level_fused(&g, &seeds, fanout, key, &mut ws_a);
        let b = sample_level_baseline(&g, &seeds, fanout, key, &mut ws_b);
        assert_eq!(a, b);
        a.validate(&seeds, fanout).unwrap();
    });
}

#[test]
fn prop_mfg_structure_invariants() {
    check(102, 80, |i, s| {
        let g = random_graph(i, s);
        let n = g.num_nodes();
        let k = gen::size(s, 1, n.min(24));
        let seeds: Vec<NodeId> = gen::subset(s, n, k);
        if seeds.is_empty() {
            return;
        }
        let levels = gen::size(s, 1, 3);
        let fanouts: Vec<usize> = (0..levels).map(|_| gen::size(s, 1, 6)).collect();
        let key = RngKey::new(s.next_u64());
        let mut ws = SamplerWorkspace::new();
        let mfgs = sample_mfgs(&g, &seeds, &fanouts, key, &mut ws, KernelKind::Fused);
        assert_eq!(mfgs.len(), levels);
        // Chaining: dst of level l == src of level l+1; top dst == seeds.
        assert_eq!(&mfgs[levels - 1].src_nodes[..mfgs[levels - 1].n_dst], &seeds[..]);
        for w in mfgs.windows(2) {
            assert_eq!(&w[0].src_nodes[..w[0].n_dst], &w[1].src_nodes[..]);
        }
        for (li, m) in mfgs.iter().enumerate() {
            let fanout = fanouts[levels - 1 - li];
            let dst: Vec<NodeId> = m.src_nodes[..m.n_dst].to_vec();
            m.validate(&dst, fanout).unwrap();
            // Every sampled edge (u -> v) exists in the original graph.
            for d in 0..m.n_dst {
                let v = m.src_nodes[d];
                for &p in m.neighbors(d) {
                    let u = m.src_nodes[p as usize];
                    assert!(
                        g.neighbors(v).contains(&u),
                        "sampled edge {u}->{v} not in graph"
                    );
                }
                // Degree semantics: min(graph degree, fanout) — uniform
                // without replacement takes all when deg <= fanout.
                assert_eq!(m.degree(d), g.degree(v).min(fanout));
            }
        }
    });
}

#[test]
fn prop_coo_csc_round_trip() {
    check(103, 150, |_i, s| {
        let n = gen::size(s, 1, 200);
        let m = gen::size(s, 0, 400);
        let src = gen::vec_below(s, m, n);
        let dst = gen::vec_below(s, m, n);
        let coo = CooGraph::new(n, src.clone(), dst.clone()).unwrap();
        let csc = coo.to_csc();
        assert_eq!(csc.num_edges(), m);
        // Every original edge appears in CSC exactly as many times.
        for (&u, &v) in src.iter().zip(&dst) {
            assert!(csc.neighbors(v).contains(&u));
        }
        // Round trip back preserves the multiset of edges.
        let back = csc.to_coo();
        let mut a: Vec<(u32, u32)> = src.into_iter().zip(dst).collect();
        let mut b: Vec<(u32, u32)> =
            back.src().iter().copied().zip(back.dst().iter().copied()).collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    });
}

#[test]
fn prop_partitioner_invariants() {
    check(104, 30, |i, s| {
        let g = random_graph(i + 5, s);
        let n = g.num_nodes();
        let parts = gen::size(s, 1, 6);
        let tk = gen::size(s, 0, n / 2);
        let train: Vec<NodeId> = gen::subset(s, n, tk);
        let book = partition_graph(&g, &train, &PartitionConfig::new(parts));
        assert_eq!(book.num_parts(), parts);
        assert_eq!(book.num_nodes(), n);
        // Every node assigned to a valid part; counts sum to n.
        let counts = book.node_counts();
        assert_eq!(counts.iter().sum::<usize>(), n);
        // Balance within the configured factor + integer slack (only for
        // graphs big enough for the multilevel path to apply).
        if n > 8 * parts && parts > 1 {
            let imb = PartitionBook::imbalance(&counts);
            assert!(imb < 1.6, "imbalance {imb} (n={n}, parts={parts})");
        }
        // Edge cut is a valid fraction.
        let cf = book.cut_fraction(&g);
        assert!((0.0..=1.0).contains(&cf));
    });
}

#[test]
fn prop_json_round_trips_random_values() {
    fn random_json(s: &mut fastsample::sampling::rng::RngStream, depth: usize) -> Json {
        match if depth == 0 { s.next_below(4) } else { s.next_below(6) } {
            0 => Json::Null,
            1 => Json::Bool(s.next_below(2) == 0),
            2 => Json::Num((s.next_below(2_000_001) as f64 - 1e6) / 8.0),
            3 => {
                let len = s.next_below(8);
                Json::Str((0..len).map(|_| char::from(32 + s.next_below(90) as u8)).collect())
            }
            4 => Json::Arr((0..s.next_below(5)).map(|_| random_json(s, depth - 1)).collect()),
            _ => Json::Obj(
                (0..s.next_below(5))
                    .map(|k| (format!("k{k}"), random_json(s, depth - 1)))
                    .collect(),
            ),
        }
    }
    check(105, 200, |_i, s| {
        let v = random_json(s, 3);
        let text = v.dump();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("reparse {text:?}: {e}"));
        assert_eq!(v, back);
    });
}

#[test]
fn prop_ring_allreduce_matches_serial_sum() {
    check(106, 25, |_i, s| {
        let world = gen::size(s, 1, 6);
        let n = gen::size(s, 1, 300);
        let inputs: Vec<Vec<f32>> = (0..world)
            .map(|_| (0..n).map(|_| s.next_range_f32(-5.0, 5.0)).collect())
            .collect();
        let mut expect = vec![0f32; n];
        for w in &inputs {
            for (e, x) in expect.iter_mut().zip(w) {
                *e += x;
            }
        }
        for e in expect.iter_mut() {
            *e /= world as f32;
        }
        let inputs_ref = &inputs;
        let results = run_workers(world, NetworkModel::free(), move |rank, comm| {
            let mut data = inputs_ref[rank].clone();
            comm.all_reduce_mean_f32(RoundKind::GradSync, &mut data).unwrap();
            data
        });
        for r in &results {
            for (a, b) in r.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
    });
}

/// Random dataset wrapper around [`random_graph`]-style sizes, for shard
/// building (features/labels are irrelevant to the topology properties
/// but `build_shards` carries them).
fn random_dataset(i: usize, s: &mut fastsample::sampling::rng::RngStream) -> fastsample::graph::Dataset {
    make_dataset(&DatasetParams {
        name: format!("prop-repl-{i}"),
        num_nodes: gen::size(s, 40, 160),
        avg_degree: gen::size(s, 2, 10),
        feat_dim: 3,
        num_classes: 3,
        labeled_frac: 0.4,
        p_intra: 0.7,
        noise: 0.4,
        seed: s.next_u64(),
    })
}

#[test]
fn prop_budgeted_sampling_equals_single_machine() {
    // The bit-equality invariant at random budget points: same RngKey ⇒
    // identical MFGs regardless of where adjacency lives.
    check(108, 20, |i, s| {
        let d = random_dataset(i, s);
        let parts = gen::size(s, 1, 3);
        let book = std::sync::Arc::new(partition_graph(
            &d.graph,
            &d.train_ids,
            &PartitionConfig::new(parts),
        ));
        let policy = match s.next_below(4) {
            0 => ReplicationPolicy::vanilla(),
            1 => ReplicationPolicy::budgeted(s.next_u64() % 4096),
            2 => ReplicationPolicy::halo(gen::size(s, 1, 2)),
            _ => ReplicationPolicy::hybrid(),
        };
        let shards = build_shards(&d, &book, &policy);
        // Every rank needs at least one seed (empty minibatches are not a
        // sampling contract the single-machine pipeline supports either).
        if (0..parts).any(|p| !d.train_ids.iter().any(|&v| book.part_of(v) == p)) {
            return;
        }
        let fanouts = [gen::size(s, 1, 4), gen::size(s, 1, 4)];
        let key = RngKey::new(s.next_u64());
        let shards_ref = &shards;
        let d_ref = &d;
        let book_ref = &book;
        let results = run_workers(parts, NetworkModel::free(), move |rank, comm| {
            let seeds: Vec<NodeId> = d_ref
                .train_ids
                .iter()
                .copied()
                .filter(|&v| book_ref.part_of(v) == rank)
                .take(8)
                .collect();
            let mut ws = SamplerWorkspace::new();
            let mut view = shards_ref[rank].topology.clone();
            let mfgs = sample_mfgs_distributed(
                comm,
                &shards_ref[rank],
                &mut view,
                &seeds,
                &fanouts,
                key,
                &mut ws,
                KernelKind::Fused,
            )
            .unwrap();
            (seeds, mfgs)
        });
        let mut ws = SamplerWorkspace::new();
        for (seeds, mfgs) in &results {
            let expect = sample_mfgs(&d.graph, seeds, &fanouts, key, &mut ws, KernelKind::Fused);
            assert_eq!(mfgs, &expect, "{policy:?} diverged from single-machine");
        }
    });
}

#[test]
fn prop_adjacency_cached_sampling_equals_single_machine() {
    // The cache spectrum's bit-equality invariant at random points:
    // random replication budgets (0 included) × random cache capacities
    // (tiny, mid, unbounded) × both eviction policies, over several
    // minibatches so later batches actually sample cache-resident rows.
    check(110, 16, |i, s| {
        let d = random_dataset(i, s);
        let parts = gen::size(s, 2, 3);
        let book = std::sync::Arc::new(partition_graph(
            &d.graph,
            &d.train_ids,
            &PartitionConfig::new(parts),
        ));
        let policy = match s.next_below(3) {
            0 => ReplicationPolicy::vanilla(),
            1 => ReplicationPolicy::budgeted(s.next_u64() % 4096),
            _ => ReplicationPolicy::halo(1),
        };
        let cache_bytes = match s.next_below(3) {
            0 => 128 + s.next_u64() % 512,
            1 => 4096,
            _ => u64::MAX >> 1,
        };
        let cache_policy = if s.next_below(2) == 0 {
            CachePolicy::StaticDegree
        } else {
            CachePolicy::Clock
        };
        let shards = build_shards(&d, &book, &policy);
        if (0..parts).any(|p| !d.train_ids.iter().any(|&v| book.part_of(v) == p)) {
            return;
        }
        let fanouts = [gen::size(s, 1, 4), gen::size(s, 1, 4)];
        let key = RngKey::new(s.next_u64());
        let shards_ref = &shards;
        let d_ref = &d;
        let book_ref = &book;
        let results = run_workers(parts, NetworkModel::free(), move |rank, comm| {
            let seeds: Vec<NodeId> = d_ref
                .train_ids
                .iter()
                .copied()
                .filter(|&v| book_ref.part_of(v) == rank)
                .take(8)
                .collect();
            let mut ws = SamplerWorkspace::new();
            let mut view = shards_ref[rank].topology.clone();
            view.enable_cache(cache_bytes, cache_policy);
            let per_batch: Vec<_> = (0..3u64)
                .map(|b| {
                    sample_mfgs_distributed(
                        comm,
                        &shards_ref[rank],
                        &mut view,
                        &seeds,
                        &fanouts,
                        key.fold(b),
                        &mut ws,
                        KernelKind::Fused,
                    )
                    .unwrap()
                })
                .collect();
            (seeds, per_batch)
        });
        let mut ws = SamplerWorkspace::new();
        for (seeds, per_batch) in &results {
            for (b, mfgs) in per_batch.iter().enumerate() {
                let expect = sample_mfgs(
                    &d.graph,
                    seeds,
                    &fanouts,
                    key.fold(b as u64),
                    &mut ws,
                    KernelKind::Fused,
                );
                assert_eq!(
                    mfgs, &expect,
                    "{policy:?} cache {cache_bytes}B {cache_policy:?} diverged at batch {b}"
                );
            }
        }
    });
}

#[test]
fn prop_bulk_wire_equals_scalar_wire() {
    // The wire-invariance property at random points: random replication
    // budgets × random cache capacities (off included) × random fanouts,
    // over several minibatches — the columnar bulk encoding and the
    // run-length scalar encoding must yield bit-identical MFGs on every
    // rank at every batch (cache-state evolution included, since later
    // batches sample whatever earlier decodes inserted).
    check(114, 16, |i, s| {
        let d = random_dataset(i + 7, s);
        let parts = gen::size(s, 2, 3);
        let book = std::sync::Arc::new(partition_graph(
            &d.graph,
            &d.train_ids,
            &PartitionConfig::new(parts),
        ));
        let policy = match s.next_below(3) {
            0 => ReplicationPolicy::vanilla(),
            1 => ReplicationPolicy::budgeted(s.next_u64() % 4096),
            _ => ReplicationPolicy::halo(1),
        };
        let cache_bytes = match s.next_below(3) {
            0 => 0,
            1 => 128 + s.next_u64() % 512,
            _ => u64::MAX >> 1,
        };
        let cache_policy = if s.next_below(2) == 0 {
            CachePolicy::StaticDegree
        } else {
            CachePolicy::Clock
        };
        let shards = build_shards(&d, &book, &policy);
        if (0..parts).any(|p| !d.train_ids.iter().any(|&v| book.part_of(v) == p)) {
            return;
        }
        let fanouts = [gen::size(s, 1, 4), gen::size(s, 1, 4)];
        let key = RngKey::new(s.next_u64());
        let shards_ref = &shards;
        let d_ref = &d;
        let book_ref = &book;
        let mut per_wire = Vec::new();
        for wire in [SamplingWire::Scalar, SamplingWire::Bulk] {
            per_wire.push(run_workers(parts, NetworkModel::free(), move |rank, comm| {
                let seeds: Vec<NodeId> = d_ref
                    .train_ids
                    .iter()
                    .copied()
                    .filter(|&v| book_ref.part_of(v) == rank)
                    .take(8)
                    .collect();
                let mut ws = SamplerWorkspace::new();
                let mut view = shards_ref[rank].topology.clone();
                if cache_bytes > 0 {
                    view.enable_cache(cache_bytes, cache_policy);
                }
                (0..3u64)
                    .map(|b| {
                        sample_mfgs_distributed_wire(
                            comm,
                            &shards_ref[rank],
                            &mut view,
                            &seeds,
                            &fanouts,
                            key.fold(b),
                            &mut ws,
                            KernelKind::Fused,
                            wire,
                        )
                        .unwrap()
                    })
                    .collect::<Vec<_>>()
            }));
        }
        assert_eq!(
            per_wire[0], per_wire[1],
            "{policy:?} cache {cache_bytes}B {cache_policy:?}: wires diverged"
        );
    });
}

#[test]
fn prop_replica_sets_are_nested_and_budget_respecting() {
    // Prefix semantics: a larger budget replicates a superset; replicated
    // bytes never exceed the budget; the endpoints degenerate exactly.
    check(109, 20, |i, s| {
        let d = random_dataset(i + 3, s);
        let parts = gen::size(s, 2, 4);
        let book = std::sync::Arc::new(partition_graph(
            &d.graph,
            &d.train_ids,
            &PartitionConfig::new(parts),
        ));
        let mut budgets: Vec<u64> =
            (0..3).map(|_| s.next_u64() % 8192).collect();
        budgets.push(0);
        budgets.sort_unstable();
        let mut prev: Option<Vec<Vec<bool>>> = None;
        for &b in &budgets {
            let shards = build_shards(&d, &book, &ReplicationPolicy::budgeted(b));
            let cover: Vec<Vec<bool>> = shards
                .iter()
                .map(|sh| {
                    assert!(sh.topology.replicated_bytes() <= b, "budget {b} overspent");
                    if b == 0 {
                        assert_eq!(sh.topology.replicated_rows(), 0);
                    }
                    (0..d.num_nodes() as NodeId)
                        .map(|v| sh.topology.try_neighbors(v).is_some())
                        .collect()
                })
                .collect();
            if let Some(small) = &prev {
                for (lo, hi) in small.iter().zip(&cover) {
                    for (vl, vh) in lo.iter().zip(hi) {
                        assert!(!*vl || *vh, "larger budget dropped a covered node");
                    }
                }
            }
            prev = Some(cover);
        }
        // Full replication covers everything on every worker.
        for sh in build_shards(&d, &book, &ReplicationPolicy::hybrid()) {
            assert!(sh.topology.covers_all());
        }
    });
}

#[test]
fn prop_frame_codec_round_trips_any_payload() {
    // The transport frame codec: arbitrary payload sizes (0 bytes and
    // >64 KiB included), arbitrary round kinds (data and control tags),
    // arbitrary src/seq — several frames concatenated into one byte
    // stream decode back exactly and self-delimit.
    check(112, 40, |i, s| {
        let n_frames = gen::size(s, 1, 5);
        let frames: Vec<Frame> = (0..n_frames)
            .map(|j| {
                let kind = match s.next_below(3) {
                    // A data round kind...
                    0 => RoundKind::ALL[s.next_below(RoundKind::COUNT)].index() as u8,
                    // ...a control tag...
                    1 => 200 + s.next_below(4) as u8,
                    // ...or any byte at all — framing must not care.
                    _ => s.next_u64() as u8,
                };
                let len = if i == 0 && j == 0 {
                    0 // the smallest case first: the empty payload
                } else if s.next_below(8) == 0 {
                    (64 << 10) + gen::size(s, 1, 4096) // > 64 KiB
                } else {
                    gen::size(s, 0, 2048)
                };
                Frame {
                    kind,
                    elem: [1u8, 4, 8][s.next_below(3)],
                    // Any plane byte must round-trip: the codec does not
                    // validate planes (only the endpoint demux does).
                    plane: s.next_u64() as u8,
                    src: s.next_u64() as u16,
                    seq: s.next_u64() as u32,
                    payload: (0..len).map(|_| s.next_u64() as u8).collect(),
                }
            })
            .collect();
        let mut wire = Vec::new();
        for f in &frames {
            f.encode_to(&mut wire);
        }
        let mut cursor = std::io::Cursor::new(&wire);
        for f in &frames {
            let back = Frame::decode_from(&mut cursor).unwrap();
            assert_eq!(&back, f);
        }
        // Nothing left over: length-prefixed framing is self-delimiting.
        assert_eq!(cursor.position() as usize, wire.len());
        assert!(Frame::decode_from(&mut cursor).is_err());
    });
}

#[test]
fn prop_interleaved_frames_demultiplex_by_source() {
    // Multiple ranks pushing multiple rounds of frames through TcpMesh
    // concurrently, each sending to its peers in a different (rotated)
    // destination order with jittered pacing: every frame must come out
    // of the correct per-source inbox, in per-source FIFO order,
    // regardless of cross-source arrival interleaving.
    fn payload(src: usize, dst: usize, round: usize) -> Vec<u8> {
        let len = (src * 5 + dst * 3 + round * 2) % 11;
        vec![(src * 31 + dst * 7 + round * 3) as u8; len]
    }
    check(113, 10, |_i, s| {
        let world = gen::size(s, 2, 4);
        let rounds = gen::size(s, 1, 4);
        let jitter: Vec<u64> = (0..world).map(|_| s.next_below(200) as u64).collect();
        let meshes = TcpMesh::loopback(world, 0).unwrap();
        let handles: Vec<_> = meshes
            .into_iter()
            .map(|t| {
                let jitter = jitter.clone();
                std::thread::spawn(move || {
                    let rank = t.rank();
                    // Send everything first (buffered), flushing between
                    // rounds with rank-dependent pacing, so arrivals from
                    // different sources interleave at each receiver.
                    for round in 0..rounds {
                        for k in 1..world {
                            let dst = (rank + k) % world;
                            t.send(
                                dst,
                                Frame {
                                    kind: (round % 200) as u8,
                                    elem: 1,
                                    plane: (round % 2) as u8,
                                    src: rank as u16,
                                    seq: round as u32,
                                    payload: payload(rank, dst, round),
                                },
                            )
                            .unwrap();
                        }
                        t.flush().unwrap();
                        std::thread::sleep(std::time::Duration::from_micros(jitter[rank]));
                    }
                    // Drain in (round, src) order: each per-source link
                    // must yield that source's frames in send order.
                    for round in 0..rounds {
                        for src in 0..world {
                            if src == rank {
                                continue;
                            }
                            let f = t.recv(src).unwrap();
                            assert_eq!(f.src as usize, src, "frame on the wrong link");
                            assert_eq!(f.seq as usize, round, "per-source FIFO violated");
                            assert_eq!(f.payload, payload(src, rank, round));
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

#[test]
fn prop_serve_request_codec_round_trips() {
    use fastsample::dist::{ServeErrorKind, ServeOp, ServeReply, ServeRequest};
    use std::io::Cursor;
    check(115, 40, |i, s| {
        // Request side: 0-length batches, typical batches, and payloads
        // past 64 KiB (node ids are 4 bytes; 17k+ ids cross it).
        let n = if i == 0 {
            0
        } else if s.next_below(8) == 0 {
            (16 << 10) + gen::size(s, 1, 2048)
        } else {
            gen::size(s, 0, 512)
        };
        let op = if n == 0 && s.next_below(4) == 0 {
            ServeOp::Shutdown
        } else {
            ServeOp::Query((0..n).map(|_| s.next_u64() as u32).collect())
        };
        let req = ServeRequest { id: s.next_u64(), op };
        let mut buf = Vec::new();
        req.encode_to(&mut buf);
        let mut cur = Cursor::new(buf.as_slice());
        let back = ServeRequest::decode_from(&mut cur).unwrap();
        assert_eq!(back, req);
        assert_eq!(cur.position() as usize, buf.len(), "decoder must consume the exact frame");

        // Reply side: arbitrary f32 bit patterns (NaNs included) must
        // survive by bits, so equality is checked on the raw bits.
        let dim = gen::size(s, 1, 8);
        let rows = gen::size(s, 0, 64);
        let values: Vec<f32> =
            (0..dim * rows).map(|_| f32::from_bits(s.next_u64() as u32)).collect();
        let reply = ServeReply::ok(s.next_u64(), dim, values.clone());
        let mut buf = Vec::new();
        reply.encode_to(&mut buf);
        let mut cur = Cursor::new(buf.as_slice());
        let back = ServeReply::decode_from(&mut cur).unwrap();
        assert_eq!(cur.position() as usize, buf.len());
        assert_eq!(back.id, reply.id);
        let emb = back.body.unwrap();
        assert_eq!(emb.dim, dim);
        assert_eq!(
            emb.rows.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            values.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );

        // Error replies round-trip kind and detail exactly.
        let kinds = [
            ServeErrorKind::Overloaded,
            ServeErrorKind::PeerLost,
            ServeErrorKind::BadRequest,
            ServeErrorKind::ShuttingDown,
            ServeErrorKind::Internal,
        ];
        let kind = kinds[s.next_below(kinds.len())];
        let detail: String =
            (0..gen::size(s, 0, 80)).map(|_| (b'a' + s.next_below(26) as u8) as char).collect();
        let err = ServeReply::error(s.next_u64(), kind, detail);
        let mut buf = Vec::new();
        err.encode_to(&mut buf);
        let back = ServeReply::decode_from(&mut Cursor::new(buf.as_slice())).unwrap();
        assert_eq!(back, err);
    });
}

#[test]
fn prop_coalesced_batches_equal_individual_queries() {
    use fastsample::train::{propagate_mean, serve_key};
    check(116, 30, |i, s| {
        let d = random_dataset(i, s);
        let n = d.num_nodes();
        let dim = d.feat_dim;
        let key = serve_key(s.next_u64());
        let fanouts = [gen::size(s, 1, 4), gen::size(s, 1, 4)];

        // A random interleaving of client requests, with duplicates
        // within and across requests.
        let k = gen::size(s, 1, 5);
        let requests: Vec<Vec<NodeId>> =
            (0..k).map(|_| gen::vec_below(s, gen::size(s, 1, 5), n)).collect();

        // The frontend's coalesced batch: first-occurrence dedup order.
        let mut batch: Vec<NodeId> = Vec::new();
        for req in &requests {
            for &v in req {
                if !batch.contains(&v) {
                    batch.push(v);
                }
            }
        }
        let mut ws = SamplerWorkspace::new();
        let mfgs = sample_mfgs(&d.graph, &batch, &fanouts, key, &mut ws, KernelKind::Fused);
        let mut feats = Vec::new();
        for &src in &mfgs[0].src_nodes {
            feats.extend_from_slice(d.feat(src));
        }
        let coalesced = propagate_mean(&mfgs, &feats, dim);

        // One-at-a-time: every requested node sampled alone under the
        // same serve key must answer bit-identically — batch composition
        // is invisible because sampling streams are keyed per node.
        for (ri, req) in requests.iter().enumerate() {
            for &v in req {
                let m1 = sample_mfgs(&d.graph, &[v], &fanouts, key, &mut ws, KernelKind::Fused);
                let mut f1 = Vec::new();
                for &src in &m1[0].src_nodes {
                    f1.extend_from_slice(d.feat(src));
                }
                let solo = propagate_mean(&m1, &f1, dim);
                let bi = batch.iter().position(|&b| b == v).unwrap();
                assert_eq!(
                    coalesced[bi * dim..(bi + 1) * dim]
                        .iter()
                        .map(|x| x.to_bits())
                        .collect::<Vec<_>>(),
                    solo.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "request {ri}, node {v}: coalesced answer diverged from a solo query"
                );
            }
        }
    });
}

#[test]
fn prop_workspace_reuse_never_leaks_between_graphs() {
    // Reusing one workspace across random graphs of different sizes must
    // behave as if fresh (epoch stamping correctness).
    check(107, 60, |i, s| {
        let mut ws = SamplerWorkspace::new();
        let mut fresh = SamplerWorkspace::new();
        for round in 0..3 {
            let g = random_graph(i + round, s);
            let n = g.num_nodes();
            let sk = gen::size(s, 1, n.min(16));
            let seeds: Vec<NodeId> = gen::subset(s, n, sk);
            if seeds.is_empty() {
                continue;
            }
            let key = RngKey::new(s.next_u64());
            let a = sample_level_fused(&g, &seeds, 4, key, &mut ws);
            let b = sample_level_fused(&g, &seeds, 4, key, &mut fresh);
            assert_eq!(a, b, "round {round}");
        }
    });
}
