//! `fastsample` — CLI for the FastSample reproduction.
//!
//! Subcommands:
//!   train         distributed training, all ranks in this process
//!   worker        ONE rank of a multi-process run (real TCP rendezvous)
//!   query         client for a serving mesh (`worker --task serve`)
//!   partition     partition a dataset and print quality metrics
//!   sample-bench  quick fused-vs-baseline sampling comparison
//!   gen-data      generate + save a synthetic dataset to disk
//!   report        regenerate a paper table/figure or ablation
//!   info          list AOT variants and environment

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use fastsample::config;
use fastsample::coordinator::experiments as exp;
use fastsample::dist::{
    query_once, request_shutdown, run_worker_process, Comm, Counters, NetworkModel,
    RendezvousConfig, TransportConfig,
};
use fastsample::graph::{datasets, io as graph_io, NodeId};
use fastsample::partition::{partition_graph, PartitionBook, PartitionConfig, ReplicationPolicy};
use fastsample::runtime::Manifest;
use fastsample::sampling::rng::RngKey;
use fastsample::sampling::{sample_mfgs, KernelKind, MinibatchSchedule, SamplerWorkspace};
use fastsample::train::{
    propagate_mean, sample_rank, serve_key, serve_rank, train_distributed, train_rank,
    ServeAnswer, ServeConfig, TrainConfig,
};
use fastsample::util::cli::Args;

const USAGE: &str = "\
fastsample — FastSample (distributed GNN sampling) reproduction

USAGE: fastsample <command> [--flags]

COMMANDS:
  train         --dataset products-sim:0.01 --variant e2e_products
                --mode hybrid+fused --workers 4 --epochs 3 [--lr 0.006]
                [--optimizer adam] [--net infiniband] [--max-batches N]
                [--cache N] [--seed S] [--eval]
                [--replication-budget 0|64k|2m|inf]  (overrides the
                mode's replication policy; modes also accept
                budget:<bytes> and halo:<hops>, optionally +fused,
                +cache:<bytes>, +tcp, +wire:<scalar|bulk>, and/or +pipe)
                [--pipeline on|off]  (off = serial phases, the default;
                on = a sampler thread prefetches minibatch t+1 on the
                Sampling plane while t trains — bit-identical results)
                [--adj-cache 0|32k|2m|inf] [--adj-cache-policy clock|static]
                (the dynamic remote-adjacency cache over the static halo)
                [--sampling-wire scalar|bulk]  (miss-response encoding:
                bulk = columnar counts + ids blob, the default; scalar =
                the run-length stream — bit-identical content either way)
                [--transport inproc|tcp|tcp:<base_port>]  (how collective
                frames move between workers; tcp uses per-peer loopback
                sockets, base port 0 = ephemeral)
                [--checkpoint-dir DIR]  (atomic per-rank snapshots at each
                epoch fence: params, optimizer state, RNG cursor, fenced
                counters) [--checkpoint-every N]  (cadence in epochs,
                default 1) [--resume]  (continue bit-identically from the
                newest checkpoint every rank holds; config mismatches are
                typed errors)
  worker        ONE rank of a multi-process training run: launch N of
                these (one per rank, any machines) and they rendezvous
                over real TCP. See OPERATIONS.md for the full guide.
                --rank R (or env FASTSAMPLE_RANK)
                --peers host:port,host:port,...  (rank r listens on the
                r-th entry; or env FASTSAMPLE_PEERS) [--world N  (cross-
                check against the peer list)] [--bind addr  (listen
                address override, e.g. 0.0.0.0:9400)]
                [--rendezvous-timeout SECS]  (default 30; env fallback
                FASTSAMPLE_RENDEZVOUS_TIMEOUT_MS) [--recv-timeout SECS]
                (0 = wait forever, the default)
                [--task auto|train|sample|serve]  (train = real training,
                needs artifacts; sample = artifact-free sampling +
                feature + grad-sync rounds with a merged digest curve;
                serve = stay resident after startup and answer embedding
                queries — rank 0 listens for `fastsample query` clients,
                all ranks cooperatively sample + fetch each batch; auto
                picks train iff artifacts exist)
                plus the train flags (--dataset --variant --mode --epochs
                --lr --optimizer --seed --net --max-batches --cache
                --adj-cache --adj-cache-policy --sampling-wire --pipeline
                --replication-budget --checkpoint-dir --checkpoint-every
                --resume) and, for the sample/serve tasks,
                [--batch 32] [--fanouts 4,3]; serve also takes
                [--serve-port 9550]  (rank 0's client listener; 0 =
                ephemeral) [--serve-max-inflight 4]  (admitted-but-
                unanswered bound; beyond it clients get `overloaded`)
                [--serve-max-batch 64]  (node ids coalesced per
                collective batch) [--serve-max-wait-ms 2]  (coalescing
                window) [--serve-heartbeat-ms 250]  (idle liveness
                cadence: an empty collective round after this long with
                no traffic, so a dead peer is detected while idle)
                [--serve-answer features|logits]  (logits runs
                the trained model — needs artifacts, and --resume
                restores params from a train-task checkpoint)
  query         one request against a serving mesh:
                --addr host:port --nodes 0,1,2 [--id N] prints one
                `node <v>: [..]` line per requested node; --shutdown
                (with --addr) stops the whole mesh cleanly; --reference
                --dataset <spec> --nodes ... [--fanouts 4,3] [--seed S]
                computes the same rows single-machine (no server) in the
                same format, so served output can be diffed against it
  partition     --dataset <spec> --parts 8 [--seed S]
  sample-bench  --dataset <spec> --batch 1024 --fanouts 15,10,5 [--iters 10]
  gen-data      --dataset <spec> --out graph.bin [--seed S]
  report        --id table1|fig4|fig5|fig5-e2e|fig6|rounds|cache-ablation|
                     fanout-ablation|memory|replication-frontier|cache-decay
                [--quick] [--scale S] [--workers W]
                [--transport inproc|tcp|tcp:<base_port>]  (rounds and
                cache-decay tally their counters over this transport)
  info
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let Some(cmd) = args.command.clone() else {
        print!("{USAGE}");
        return Ok(());
    };
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "worker" => cmd_worker(&args),
        "query" => cmd_query(&args),
        "partition" => cmd_partition(&args),
        "sample-bench" => cmd_sample_bench(&args),
        "gen-data" => cmd_gen_data(&args),
        "report" => cmd_report(&args),
        "info" => cmd_info(&args),
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

/// Parse the training-shaped flags shared by `train` and `worker` into a
/// [`TrainConfig`] for `workers` ranks, returning the dataset spec too.
/// `default_net` differs per caller: the in-process harness simulates
/// the paper fabric by default, a real multi-process run defaults to
/// `free` (the actual network provides the latency).
fn parse_train_flags(
    args: &Args,
    workers: usize,
    default_net: &str,
) -> Result<(String, TrainConfig)> {
    let spec = args.get_str("dataset", "quickstart");
    let variant = args.get_str("variant", "quickstart");
    let mode = args.get_str("mode", "hybrid+fused");
    let seed = args.get("seed", 0u64)?;

    let mut cfg = TrainConfig::mode(&variant, &mode, workers)?;
    if let Some(budget) = args.get_opt_str("replication-budget") {
        cfg.policy = ReplicationPolicy::from_budget(config::parse_budget(&budget)?);
    }
    cfg.epochs = args.get("epochs", 3usize)?;
    cfg.lr = args.get("lr", 0.006f32)?;
    cfg.optimizer = args.get_str("optimizer", "adam");
    cfg.seed = seed;
    cfg.net = config::network(&args.get_str("net", default_net))?;
    cfg.cache_capacity = args.get("cache", 0usize)?;
    if let Some(spec) = args.get_opt_str("adj-cache") {
        cfg.adj_cache_bytes = config::parse_cache_bytes(&spec)?;
    }
    cfg.adj_cache_policy = config::cache_policy(&args.get_str("adj-cache-policy", "clock"))?;
    if let Some(spec) = args.get_opt_str("sampling-wire") {
        cfg.sampling_wire = config::sampling_wire(&spec)?;
    }
    if let Some(spec) = args.get_opt_str("pipeline") {
        cfg.pipeline = config::pipeline(&spec)?;
    }
    if let Some(spec) = args.get_opt_str("transport") {
        cfg.transport = config::transport(&spec)?;
    }
    cfg.max_batches = match args.get("max-batches", 0usize)? {
        0 => None,
        n => Some(n),
    };
    if let Some(dir) = args.get_opt_str("checkpoint-dir") {
        cfg.checkpoint_dir = Some(std::path::PathBuf::from(dir));
    }
    cfg.checkpoint_every = args.get("checkpoint-every", 1usize)?;
    ensure!(cfg.checkpoint_every >= 1, "--checkpoint-every must be >= 1");
    cfg.resume = args.has("resume");
    ensure!(
        !cfg.resume || cfg.checkpoint_dir.is_some(),
        "--resume needs --checkpoint-dir (where should the checkpoints come from?)"
    );
    cfg.eval_last_batch = args.has("eval");
    cfg.verbose = true;
    Ok((spec, cfg))
}

fn cmd_train(args: &Args) -> Result<()> {
    let workers = args.get("workers", 4usize)?;
    let (spec, cfg) = parse_train_flags(args, workers, "infiniband")?;
    args.finish()?;

    let dataset = config::dataset(&spec, cfg.seed)?;
    eprintln!(
        "training {} on {} ({} nodes, {} edges), {} workers, mode {}, transport {}",
        cfg.variant,
        dataset.name,
        dataset.num_nodes(),
        dataset.num_edges(),
        workers,
        cfg.policy.label(),
        cfg.transport
    );
    let report = train_distributed(&dataset, &config::artifacts_dir(), &cfg)?;
    println!(
        "\nmean epoch time: {:.2}s   total comm bytes: {}",
        report.mean_epoch_wall_s(),
        report.comm_total.total_bytes()
    );
    println!("{}", report.comm_total.report());
    Ok(())
}

/// Worker task codes for the startup agreement vote (and the branch
/// taken in [`cmd_worker`]).
const TASK_SAMPLE: u64 = 0;
const TASK_TRAIN: u64 = 1;
const TASK_SERVE: u64 = 2;

/// Every rank must run the same task, but `--task auto` resolves from
/// the **local** filesystem (are artifacts present?), which can diverge
/// across machines. One uncharged control-plane vote per task code
/// before the first data collective turns a mixed launch into a clear
/// startup error on every rank instead of a confusing mid-run
/// `SequenceMismatch` (a rank's XOR against candidate `t` is zero iff
/// its own code is `t`; the vote passes iff that holds on every rank).
fn agree_on_task(comm: &mut Comm, code: u64) -> Result<()> {
    let mut agreed = false;
    for t in [TASK_SAMPLE, TASK_TRAIN, TASK_SERVE] {
        agreed |= comm.all_zero_u64(code ^ t)?;
    }
    ensure!(
        agreed,
        "ranks disagree on the worker task (train vs sample vs serve): artifacts exist \
         on some machines but not others — pass --task explicitly on every rank"
    );
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    // Identity: flags first, env fallbacks second, so a launch script
    // can export FASTSAMPLE_PEERS once and vary only the rank.
    let rank = match args.get_opt_str("rank") {
        Some(v) => v.parse::<usize>().with_context(|| format!("--rank {v:?}"))?,
        None => std::env::var("FASTSAMPLE_RANK")
            .context("worker needs --rank (or env FASTSAMPLE_RANK)")?
            .trim()
            .parse::<usize>()
            .context("FASTSAMPLE_RANK")?,
    };
    let peers_spec = match args.get_opt_str("peers") {
        Some(p) => p,
        None => std::env::var("FASTSAMPLE_PEERS")
            .context("worker needs --peers host:port,... (or env FASTSAMPLE_PEERS)")?,
    };
    let peers: Vec<String> = peers_spec
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let world = peers.len();
    ensure!(world >= 1, "--peers lists no addresses");
    ensure!(rank < world, "--rank {rank} out of range for {world} peers");
    if let Some(w) = args.get_opt_str("world") {
        let w: usize = w.parse().with_context(|| format!("--world {w:?}"))?;
        ensure!(w == world, "--world {w} does not match the {world}-entry peer list");
    }

    let mut rdv = RendezvousConfig::from_env();
    if let Some(secs) = args.get_opt_str("rendezvous-timeout") {
        let secs: f64 =
            secs.parse().with_context(|| format!("--rendezvous-timeout {secs:?}"))?;
        ensure!(secs > 0.0, "--rendezvous-timeout must be positive");
        rdv.timeout = Duration::from_secs_f64(secs);
    }
    rdv.bind = args.get_opt_str("bind");
    let recv_timeout = {
        let secs = args.get("recv-timeout", 0.0f64)?;
        (secs > 0.0).then(|| Duration::from_secs_f64(secs))
    };

    let task = args.get_str("task", "auto");
    let batch = args.get("batch", 32usize)?;
    let fanouts = args.get_list("fanouts", &[4, 3])?;
    let serve_port = args.get("serve-port", 9550u16)?;
    let serve_max_inflight = args.get("serve-max-inflight", 4usize)?;
    let serve_max_batch = args.get("serve-max-batch", 64usize)?;
    let serve_max_wait_ms = args.get("serve-max-wait-ms", 2u64)?;
    let serve_heartbeat_ms = args.get("serve-heartbeat-ms", 250u64)?;
    let serve_answer = args.get_str("serve-answer", "features");
    let (spec, cfg) = parse_train_flags(args, world, "free")?;
    args.finish()?;

    let task_code = match task.as_str() {
        "train" => TASK_TRAIN,
        "sample" => TASK_SAMPLE,
        "serve" => TASK_SERVE,
        "auto" => {
            if config::artifacts_available() {
                TASK_TRAIN
            } else {
                TASK_SAMPLE
            }
        }
        other => bail!("unknown worker task {other:?} (auto | train | sample | serve)"),
    };
    let dataset = config::dataset(&spec, cfg.seed)?;
    if cfg.transport != TransportConfig::Inproc {
        eprintln!(
            "[rank {rank}] note: --transport/+tcp is ignored by `worker` — the \
             multi-process mesh is always real TCP"
        );
    }
    let task_name = match task_code {
        TASK_TRAIN => "train",
        TASK_SERVE => "serve",
        _ => "sample",
    };
    eprintln!(
        "[rank {rank}/{world}] task {task_name} on {} ({} nodes), mode {}, rendezvous timeout {:?}",
        dataset.name,
        dataset.num_nodes(),
        cfg.policy.label(),
        rdv.timeout
    );
    let counters = Arc::new(Counters::default());
    if task_code == TASK_TRAIN {
        let report = run_worker_process(
            rank,
            &peers,
            &rdv,
            recv_timeout,
            cfg.net.clone(),
            counters,
            |rank, comm| {
                agree_on_task(comm, task_code)?;
                train_rank(&dataset, &config::artifacts_dir(), &cfg, rank, comm)
            },
        )
        .context("multi-process rendezvous failed")??;
        for e in &report.epochs {
            println!(
                "[rank {rank}] epoch {} loss {:.4} wall {:.2}s",
                e.epoch, e.mean_loss, e.wall_s
            );
        }
        if rank == 0 {
            println!("loss curve: {:?}", report.loss_curve);
        }
        println!("comm (per-process view — see OPERATIONS.md):");
        println!("{}", report.comm_total.report());
    } else if task_code == TASK_SERVE {
        let mut scfg = ServeConfig::new(fanouts.clone());
        scfg.port = serve_port;
        scfg.max_inflight = serve_max_inflight;
        scfg.max_batch = serve_max_batch;
        scfg.max_wait = Duration::from_millis(serve_max_wait_ms);
        scfg.idle_heartbeat = Duration::from_millis(serve_heartbeat_ms.max(1));
        scfg.answer = ServeAnswer::parse(&serve_answer)?;
        // Logits answers come from a trained model, so a `--resume`
        // restores a train-task checkpoint; feature answers pair with
        // the artifact-free sample task and its adjacency-cache rows.
        scfg.ckpt_task = match scfg.answer {
            ServeAnswer::Logits => "train".to_string(),
            ServeAnswer::Features => "sample".to_string(),
        };
        scfg.ckpt_batch = batch;
        let report = run_worker_process(
            rank,
            &peers,
            &rdv,
            recv_timeout,
            cfg.net.clone(),
            counters,
            |rank, comm| {
                agree_on_task(comm, task_code)?;
                serve_rank(&dataset, &config::artifacts_dir(), &cfg, &scfg, rank, comm)
            },
        )
        .context("multi-process rendezvous failed")??;
        println!("[rank {rank}] {}", report.summary_line());
        println!("comm (per-process view — see OPERATIONS.md):");
        println!("{}", report.comm_total.report());
    } else {
        let report = run_worker_process(
            rank,
            &peers,
            &rdv,
            recv_timeout,
            cfg.net.clone(),
            counters,
            |rank, comm| {
                agree_on_task(comm, task_code)?;
                sample_rank(&dataset, &cfg, batch, &fanouts, false, rank, comm)
            },
        )
        .context("multi-process rendezvous failed")??;
        println!(
            "[rank {rank}] {} steps, {} sampled edges",
            report.steps, report.sampled_edges
        );
        if rank == 0 {
            println!("digest curve: {:?}", report.curve);
        }
        println!("comm (per-process view — see OPERATIONS.md):");
        println!("{}", report.comm_total.report());
    }
    Ok(())
}

/// One client request against a serving mesh — or, with `--reference`,
/// the same rows computed single-machine so the two outputs diff clean
/// (the serve determinism contract: per-node sampled trees depend only
/// on the serve key and the node id, never on batch composition).
fn cmd_query(args: &Args) -> Result<()> {
    if args.has("reference") {
        let spec = args.get_str("dataset", "quickstart");
        let node_list = args.get_list("nodes", &[])?;
        let fanouts = args.get_list("fanouts", &[4, 3])?;
        let seed = args.get("seed", 0u64)?;
        args.finish()?;
        ensure!(!node_list.is_empty(), "--nodes lists no node ids");
        let d = config::dataset(&spec, seed)?;
        let mut batch: Vec<NodeId> = Vec::new();
        for &v in &node_list {
            ensure!(v < d.num_nodes(), "node {v} out of range for {} nodes", d.num_nodes());
            let v = v as NodeId;
            if !batch.contains(&v) {
                batch.push(v);
            }
        }
        let mut ws = SamplerWorkspace::new();
        let mfgs =
            sample_mfgs(&d.graph, &batch, &fanouts, serve_key(seed), &mut ws, KernelKind::Fused);
        let mut feats = Vec::with_capacity(mfgs[0].src_nodes.len() * d.feat_dim);
        for &s in &mfgs[0].src_nodes {
            let off = s as usize * d.feat_dim;
            feats.extend_from_slice(&d.feats[off..off + d.feat_dim]);
        }
        let rows = propagate_mean(&mfgs, &feats, d.feat_dim);
        for &v in &node_list {
            let i = batch
                .iter()
                .position(|&b| b == v as NodeId)
                .context("query node missing from its own batch")?;
            println!("node {v}: {:?}", &rows[i * d.feat_dim..(i + 1) * d.feat_dim]);
        }
        return Ok(());
    }

    let addr = args.require_str("addr")?;
    if args.has("shutdown") {
        args.finish()?;
        let reply = request_shutdown(&addr).with_context(|| format!("shutdown via {addr}"))?;
        match reply.body {
            Ok(_) => println!("shutdown acknowledged"),
            Err(e) => bail!("shutdown refused: {e}"),
        }
        return Ok(());
    }
    let node_list = args.get_list("nodes", &[])?;
    let id = args.get("id", 1u64)?;
    args.finish()?;
    ensure!(!node_list.is_empty(), "--nodes lists no node ids");
    let nodes: Vec<NodeId> = node_list
        .iter()
        .map(|&v| u32::try_from(v).map_err(|_| anyhow::anyhow!("node id {v} exceeds u32")))
        .collect::<Result<_>>()?;
    let reply = query_once(&addr, id, &nodes).with_context(|| format!("query via {addr}"))?;
    match reply.body {
        Ok(emb) => {
            ensure!(
                emb.num_rows() == nodes.len(),
                "reply carries {} rows for {} requested nodes",
                emb.num_rows(),
                nodes.len()
            );
            for (i, &v) in nodes.iter().enumerate() {
                println!("node {v}: {:?}", emb.row(i));
            }
        }
        Err(e) => bail!("query {id} failed: {e}"),
    }
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let spec = args.get_str("dataset", "products-sim:0.01");
    let parts = args.get("parts", 8usize)?;
    let seed = args.get("seed", 0u64)?;
    args.finish()?;
    let d = config::dataset(&spec, seed)?;
    let t0 = std::time::Instant::now();
    let book = partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(parts));
    println!(
        "partitioned {} ({} nodes, {} edges) into {parts} parts in {:.2}s",
        d.name,
        d.num_nodes(),
        d.num_edges(),
        t0.elapsed().as_secs_f64()
    );
    println!("edge cut:        {:.3}", book.cut_fraction(&d.graph));
    println!("node imbalance:  {:.3}", PartitionBook::imbalance(&book.node_counts()));
    println!("edge imbalance:  {:.3}", PartitionBook::imbalance(&book.edge_counts(&d.graph)));
    println!(
        "label imbalance: {:.3}",
        PartitionBook::imbalance(&book.label_counts(&d.train_ids))
    );
    // The replication-budget denominator: what the complete 1-hop halo
    // would cost each worker (budget >= this ⇒ the first sampling
    // exchange of every minibatch is cleared).
    let halo = book.halo_profile(&d.graph);
    let max_nodes = halo.iter().map(|h| h.boundary_nodes).max().unwrap_or(0);
    let max_bytes = halo.iter().map(|h| h.halo_bytes).max().unwrap_or(0);
    println!("1-hop halo:      up to {max_nodes} nodes / {max_bytes} bytes per worker");
    Ok(())
}

fn cmd_sample_bench(args: &Args) -> Result<()> {
    let spec = args.get_str("dataset", "papers100m-sim:0.005");
    let batch = args.get("batch", 1024usize)?;
    let fanouts = args.get_list("fanouts", &[15, 10, 5])?;
    let iters = args.get("iters", 10usize)?;
    let seed = args.get("seed", 0u64)?;
    args.finish()?;
    let d = config::dataset(&spec, seed)?;
    let key = RngKey::new(seed);
    let schedule = MinibatchSchedule::new(&d.train_ids, batch.min(d.train_ids.len()), key);
    let seeds = schedule.batch(0);
    let mut ws = SamplerWorkspace::new();
    println!(
        "sampling {} seeds from {} with fanouts {:?} ({} iters)",
        seeds.len(),
        d.name,
        fanouts,
        iters
    );
    for kind in [KernelKind::Baseline, KernelKind::Fused] {
        let _ = sample_mfgs(&d.graph, seeds, &fanouts, key, &mut ws, kind);
        let t0 = std::time::Instant::now();
        let mut edges = 0usize;
        for i in 0..iters {
            let mfgs =
                sample_mfgs(&d.graph, seeds, &fanouts, key.fold(i as u64), &mut ws, kind);
            edges = mfgs.iter().map(|m| m.num_edges()).sum();
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        println!("{kind:?}: {:.3} ms/batch ({edges} sampled edges)", dt * 1e3);
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let spec = args.get_str("dataset", "products-sim:0.01");
    let out = args.require_str("out")?;
    let seed = args.get("seed", 0u64)?;
    args.finish()?;
    let d = config::dataset(&spec, seed)?;
    graph_io::save(&d, &out)?;
    println!(
        "wrote {} ({} nodes, {} edges, {} feature bytes) to {out}",
        d.name,
        d.num_nodes(),
        d.num_edges(),
        d.feature_bytes()
    );
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let which = args.get_str("id", "");
    let quick = args.has("quick");
    let seed = args.get("seed", 7u64)?;
    let workers = args.get("workers", 4usize)?;
    let scale = args.get("scale", 0.0f64)?;
    let transport = config::transport(&args.get_str("transport", "inproc"))?;
    args.finish()?;

    let text = match which.as_str() {
        "table1" => exp::table1(pick(scale, 0.01), pick(scale, 0.001), seed)?,
        "fig4" => exp::fig4(pick(scale, 0.01), pick(scale, 0.001), seed)?,
        "fig5" => {
            let mut opts = exp::Fig5Opts { seed, ..Default::default() };
            if quick {
                opts.dataset_spec = "papers100m-sim:0.001".into();
                opts.batch_sizes = vec![1024, 2048];
                opts.fanout_sets = vec![vec![5, 5, 5], vec![15, 10, 5]];
                opts.iters = 3;
            }
            if scale > 0.0 {
                opts.dataset_spec = format!("papers100m-sim:{scale}");
            }
            exp::fig5_sampling(&opts)?
        }
        "fig5-e2e" => {
            let mut opts = exp::Fig5Opts { seed, ..Default::default() };
            if quick {
                opts.dataset_spec = "papers100m-sim:0.001".into();
                opts.iters = 2;
            }
            if scale > 0.0 {
                opts.dataset_spec = format!("papers100m-sim:{scale}");
            }
            exp::fig5_e2e(&opts)?
        }
        "fig6" => {
            let mut opts = exp::Fig6Opts { seed, ..Default::default() };
            if quick {
                opts.runs = vec![("products-sim:0.02".into(), "fig6_products_small".into())];
                opts.workers = vec![4];
                opts.epochs = 1;
                opts.max_batches = Some(3);
            }
            exp::fig6(&opts)?
        }
        "rounds" => exp::rounds_report(workers, seed, &transport)?,
        "cache-ablation" => exp::cache_ablation(workers, seed)?,
        "fanout-ablation" => exp::fanout_ablation(workers, seed)?,
        "memory" => exp::partition_memory(
            &format!("products-sim:{}", pick(scale, 0.01)),
            workers,
            seed,
        )?,
        "replication-frontier" => {
            let spec = if scale > 0.0 {
                format!("products-sim:{scale}")
            } else {
                "quickstart".to_string()
            };
            exp::replication_frontier(&spec, workers, seed)?
        }
        "cache-decay" => {
            let spec = if scale > 0.0 {
                format!("products-sim:{scale}")
            } else {
                "quickstart".to_string()
            };
            exp::cache_decay(&spec, workers, seed, &transport)?
        }
        other => bail!("unknown report {other:?} — see `fastsample` usage"),
    };
    println!("{text}");
    Ok(())
}

fn pick(scale: f64, default: f64) -> f64 {
    if scale > 0.0 {
        scale
    } else {
        default
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    args.finish()?;
    println!("artifacts dir: {:?}", config::artifacts_dir());
    if config::artifacts_available() {
        let m = Manifest::load(config::artifacts_dir())?;
        let mut names: Vec<&String> = m.variants.keys().collect();
        names.sort();
        println!(
            "{:<16} {:>7} {:<14} {:<28} {:>9}",
            "variant", "batch", "fanouts", "caps", "params"
        );
        for n in names {
            let v = m.variant(n)?;
            println!(
                "{:<16} {:>7} {:<14} {:<28} {:>9}",
                n,
                v.batch,
                format!("{:?}", v.fanouts),
                format!("{:?}", v.caps),
                v.param_numel()
            );
        }
    } else {
        println!("artifacts missing — run `make artifacts`");
    }
    println!("datasets: products-sim[:scale] papers100m-sim[:scale] quickstart");
    println!("threads: {}", fastsample::util::par::num_threads());
    let net = NetworkModel::infiniband_200g();
    println!(
        "default fabric: {:?} latency, {:.0} GB/s bandwidth",
        net.latency,
        net.bandwidth / 1e9
    );
    let _ = datasets::OGBN_PRODUCTS;
    Ok(())
}
