//! Tiny CLI flag parser (offline substitute for clap).
//!
//! Grammar: `binary <subcommand> [--key value]... [--flag]...`
//! Values never start with `--`; everything is typed at the call site.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed arguments: one positional subcommand + `--key [value]` pairs.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, Option<String>>,
    /// Keys read at least once (for unknown-flag detection).
    seen: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from `std::env::args` (skipping argv[0]).
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(items: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        if let Some(first) = iter.peek() {
            if !first.starts_with("--") {
                out.command = iter.next();
            }
        }
        while let Some(item) = iter.next() {
            let key = item
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {item:?}"))?
                .to_string();
            if key.is_empty() {
                bail!("empty flag name");
            }
            let value = match iter.peek() {
                Some(v) if !v.starts_with("--") => iter.next(),
                _ => None,
            };
            if out.flags.insert(key.clone(), value).is_some() {
                bail!("duplicate flag --{key}");
            }
        }
        Ok(out)
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().insert(key.to_string());
    }

    /// String flag with a default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.mark(key);
        match self.flags.get(key) {
            Some(Some(v)) => v.clone(),
            _ => default.to_string(),
        }
    }

    /// Optional string flag: `Some(value)` only when the flag was passed
    /// with a value (used for overrides that must distinguish "absent"
    /// from any default, e.g. `--replication-budget`).
    pub fn get_opt_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        match self.flags.get(key) {
            Some(Some(v)) => Some(v.clone()),
            _ => None,
        }
    }

    /// Required string flag.
    pub fn require_str(&self, key: &str) -> Result<String> {
        self.mark(key);
        match self.flags.get(key) {
            Some(Some(v)) => Ok(v.clone()),
            _ => bail!("missing required flag --{key}"),
        }
    }

    /// Typed flag with a default (usize, f64, u64, ...).
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.mark(key);
        match self.flags.get(key) {
            Some(Some(v)) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
            Some(None) => bail!("--{key} needs a value"),
            None => Ok(default),
        }
    }

    /// Boolean presence flag.
    pub fn has(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.contains_key(key)
    }

    /// Comma-separated list flag, e.g. `--fanouts 15,10,5`.
    pub fn get_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        self.mark(key);
        match self.flags.get(key) {
            Some(Some(v)) => v
                .split(',')
                .map(|s| s.trim().parse::<usize>().map_err(|e| anyhow::anyhow!("--{key}: {e}")))
                .collect(),
            Some(None) => bail!("--{key} needs a value"),
            None => Ok(default.to_vec()),
        }
    }

    /// Error if any provided flag was never consumed (typo guard). Call
    /// after all get_* calls.
    pub fn finish(&self) -> Result<()> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !seen.contains(k.as_str())).collect();
        if !unknown.is_empty() {
            bail!("unknown flags: {unknown:?}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --dataset products-sim:0.01 --workers 8 --verbose");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get_str("dataset", "x"), "products-sim:0.01");
        assert_eq!(a.get("workers", 1usize).unwrap(), 8);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn optional_flags_distinguish_absent_from_default() {
        let a = parse("train --replication-budget 64k --bare");
        assert_eq!(a.get_opt_str("replication-budget").as_deref(), Some("64k"));
        assert_eq!(a.get_opt_str("missing"), None);
        assert_eq!(a.get_opt_str("bare"), None); // present but valueless
        a.finish().unwrap();
    }

    #[test]
    fn defaults_and_lists() {
        let a = parse("bench --fanouts 15,10,5");
        assert_eq!(a.get_list("fanouts", &[3]).unwrap(), vec![15, 10, 5]);
        assert_eq!(a.get_list("other", &[2, 2]).unwrap(), vec![2, 2]);
        assert_eq!(a.get("epochs", 3usize).unwrap(), 3);
    }

    #[test]
    fn errors() {
        assert!(Args::parse(vec!["x".into(), "y".into()]).is_err()); // y not a flag
        assert!(Args::parse(vec!["--a".into(), "--a".into()]).is_err()); // dup (second --a parsed as flag)
        let a = parse("run --typo 3");
        let _ = a.get("ok", 0usize);
        assert!(a.finish().is_err());
        assert!(parse("run").require_str("missing").is_err());
        assert!(parse("run --n abc").get("n", 0usize).is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.command, None);
        assert!(a.has("help"));
    }
}
