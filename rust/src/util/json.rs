//! Minimal JSON parser + writer (offline substitute for serde_json).
//!
//! Covers the full JSON grammar minus exotic number forms; good enough for
//! `artifacts/manifest.json` (written by python's `json.dump`) and for the
//! report binaries' machine-readable output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).with_context(|| format!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("{n} is not a non-negative integer");
        }
        Ok(n as usize)
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }

    /// Serialize (stable key order; floats in shortest round-trip form).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes.get(self.pos).copied().context("unexpected end of input")
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}, got {:?}", b as char, self.pos, self.peek()? as char);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .context("truncated \\u escape")?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // Surrogate pairs: only BMP expected in our
                            // manifests; map unpaired surrogates to U+FFFD.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at pos-1.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let chunk =
                        self.bytes.get(start..start + len).context("truncated utf8")?;
                    s.push_str(std::str::from_utf8(chunk)?);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| format!("bad number {text:?}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_json() {
        let text = r#"{
            "variants": {
                "q": {"batch": 32, "fanouts": [3, 3], "dropout": 0.5,
                       "params": [{"name": "w", "shape": [4, 8]}],
                       "train_hlo": "q_train.hlo.txt"}
            }
        }"#;
        let j = Json::parse(text).unwrap();
        let v = j.get("variants").unwrap().get("q").unwrap();
        assert_eq!(v.get("batch").unwrap().as_usize().unwrap(), 32);
        assert_eq!(v.get("fanouts").unwrap().usize_vec().unwrap(), vec![3, 3]);
        assert_eq!(v.get("dropout").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(
            v.get("params").unwrap().as_arr().unwrap()[0].get("name").unwrap().as_str().unwrap(),
            "w"
        );
    }

    #[test]
    fn parses_scalars_and_escapes() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nbA π""#).unwrap(),
            Json::Str("a\nbA π".into())
        );
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"unterminated", "{\"a\" 1}", "01x", "[1] trailing"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn dump_round_trips() {
        let text = r#"{"a": [1, 2.5, "s\"x", null, true], "b": {"c": -3}}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn as_usize_guards() {
        assert!(Json::Num(1.5).as_usize().is_err());
        assert!(Json::Num(-2.0).as_usize().is_err());
        assert_eq!(Json::Num(7.0).as_usize().unwrap(), 7);
    }
}
