//! In-tree substrates for an offline environment.
//!
//! This build runs with no network and a vendored crate set that contains
//! only `xla` and `anyhow`, so the supporting libraries a production crate
//! would normally pull in are implemented here from std:
//!
//! * [`json`] — minimal JSON parser/writer (for `artifacts/manifest.json`
//!   and report output);
//! * [`par`] — scoped-thread data-parallel helpers (the rayon patterns the
//!   sampling kernels and generators need);
//! * [`cli`] — flag parsing for the `fastsample` binary;
//! * [`bench`] — timing harness with warmup and robust stats (criterion
//!   replacement; used by `cargo bench` targets);
//! * [`prop`] — randomized property-testing loop with reproducible
//!   per-case seeds (proptest replacement).

pub mod bench;
pub mod cli;
pub mod json;
pub mod par;
pub mod prop;
