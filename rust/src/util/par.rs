//! Scoped-thread data-parallel helpers (offline substitute for rayon).
//!
//! The sampling kernels and generators need exactly three patterns:
//! a parallel indexed map, a parallel mutable-chunk sweep, and a parallel
//! sweep over (strided chunk, per-item slot, shared input) triples. All are
//! implemented with `std::thread::scope` over contiguous ranges — no work
//! stealing, which is fine because our loops are statically balanced (the
//! per-seed work varies only within a fanout factor).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads (clamped so tiny inputs stay serial).
pub fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn threads_for(n_items: usize) -> usize {
    // ~1k items per thread minimum: below that the spawn cost dominates
    // (§Perf: 4096 left the 2k-seed top sampling level single-threaded).
    num_threads().min(n_items.div_ceil(1024)).max(1)
}

/// Parallel indexed map: `out[i] = f(i)` for `i in 0..n`.
pub fn par_map<T: Send>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let threads = threads_for(n);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<T> = Vec::with_capacity(n);
    let slots = out.spare_capacity_mut();
    let next = AtomicUsize::new(0);
    // Block-cyclic over fixed-size blocks keeps threads balanced when the
    // per-item cost is skewed (hub nodes).
    const BLOCK: usize = 1024;
    std::thread::scope(|s| {
        // Split the spare capacity into raw block pointers up front.
        let base = slots.as_mut_ptr() as usize;
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let start = next.fetch_add(BLOCK, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + BLOCK).min(n);
                for i in start..end {
                    // Safety: each index is claimed exactly once via the
                    // atomic counter; slots are disjoint.
                    unsafe {
                        let p = (base as *mut T).add(i);
                        p.write(f(i));
                    }
                }
            });
        }
    });
    // Safety: all n slots were initialized by the scope above.
    unsafe { out.set_len(n) };
    out
}

/// Parallel sweep over equal-size mutable chunks: `f(i, &mut data[i*stride..][..stride])`.
pub fn par_chunks_mut<T: Send>(
    data: &mut [T],
    stride: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(stride > 0 && data.len() % stride == 0);
    let n = data.len() / stride;
    let threads = threads_for(n);
    if threads <= 1 {
        for (i, chunk) in data.chunks_mut(stride).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut base = 0usize;
        while !rest.is_empty() {
            let take = per.min(rest.len() / stride);
            let (head, tail) = rest.split_at_mut(take * stride);
            rest = tail;
            let start = base;
            base += take;
            let f = &f;
            s.spawn(move || {
                for (j, chunk) in head.chunks_mut(stride).enumerate() {
                    f(start + j, chunk);
                }
            });
        }
    });
}

/// The sampler's pattern: for each item `i`, `f` gets the item index, a
/// mutable strided chunk of `a`, and a mutable slot of `b`. Thread-local
/// scratch is created once per worker via `init`.
pub fn par_zip_chunks<A: Send, B: Send, S>(
    a: &mut [A],
    b: &mut [B],
    stride: usize,
    init: impl Fn() -> S + Sync,
    f: impl Fn(&mut S, usize, &mut [A], &mut B) + Sync,
) {
    assert!(stride > 0 && a.len() == b.len() * stride);
    let n = b.len();
    let threads = threads_for(n);
    if threads <= 1 {
        let mut scratch = init();
        for (i, (ac, bc)) in a.chunks_mut(stride).zip(b.iter_mut()).enumerate() {
            f(&mut scratch, i, ac, bc);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut a_rest = a;
        let mut b_rest = b;
        let mut base = 0usize;
        while !b_rest.is_empty() {
            let take = per.min(b_rest.len());
            let (a_head, a_tail) = a_rest.split_at_mut(take * stride);
            let (b_head, b_tail) = b_rest.split_at_mut(take);
            a_rest = a_tail;
            b_rest = b_tail;
            let start = base;
            base += take;
            let f = &f;
            let init = &init;
            s.spawn(move || {
                let mut scratch = init();
                for (j, (ac, bc)) in a_head.chunks_mut(stride).zip(b_head.iter_mut()).enumerate()
                {
                    f(&mut scratch, start + j, ac, bc);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let out = par_map(10_000, |i| i * i);
        assert_eq!(out.len(), 10_000);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn par_map_empty_and_tiny() {
        assert!(par_map(0, |i| i).is_empty());
        assert_eq!(par_map(3, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn par_chunks_mut_writes_every_chunk() {
        let mut data = vec![0usize; 9 * 4096];
        par_chunks_mut(&mut data, 9, |i, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = i * 9 + j;
            }
        });
        for (k, &v) in data.iter().enumerate() {
            assert_eq!(v, k);
        }
    }

    #[test]
    fn par_zip_chunks_strided_write() {
        let n = 5000;
        let stride = 3;
        let mut a = vec![0u32; n * stride];
        let mut b = vec![0u32; n];
        par_zip_chunks(
            &mut a,
            &mut b,
            stride,
            Vec::<u32>::new,
            |scratch, i, chunk, slot| {
                scratch.push(i as u32); // exercise per-thread scratch
                for (j, x) in chunk.iter_mut().enumerate() {
                    *x = (i * stride + j) as u32;
                }
                *slot = i as u32;
            },
        );
        for (k, &v) in a.iter().enumerate() {
            assert_eq!(v, k as u32);
        }
        for (i, &v) in b.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    #[should_panic]
    fn par_zip_chunks_length_mismatch_panics() {
        let mut a = vec![0u8; 10];
        let mut b = vec![0u8; 4];
        par_zip_chunks(&mut a, &mut b, 3, || (), |_, _, _, _| {});
    }
}
