//! Scoped-thread data-parallel helpers (offline substitute for rayon).
//!
//! The sampling kernels and generators need exactly five patterns:
//! a parallel indexed map, a parallel mutable-chunk sweep, a parallel
//! sweep over (strided chunk, per-item slot, shared input) triples, a
//! parallel sweep over *ragged* (prefix-sum delimited) chunks, and a
//! parallel scatter of segments into disjoint strided rows. All are
//! implemented with `std::thread::scope` over contiguous ranges — no work
//! stealing, which is fine because our loops are statically balanced (the
//! per-seed work varies only within a fanout factor).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads (clamped so tiny inputs stay serial).
pub fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn threads_for(n_items: usize) -> usize {
    // ~1k items per thread minimum: below that the spawn cost dominates
    // (§Perf: 4096 left the 2k-seed top sampling level single-threaded).
    num_threads().min(n_items.div_ceil(1024)).max(1)
}

/// Parallel indexed map: `out[i] = f(i)` for `i in 0..n`.
pub fn par_map<T: Send>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let threads = threads_for(n);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<T> = Vec::with_capacity(n);
    let slots = out.spare_capacity_mut();
    let next = AtomicUsize::new(0);
    // Block-cyclic over fixed-size blocks keeps threads balanced when the
    // per-item cost is skewed (hub nodes).
    const BLOCK: usize = 1024;
    std::thread::scope(|s| {
        // Split the spare capacity into raw block pointers up front.
        let base = slots.as_mut_ptr() as usize;
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let start = next.fetch_add(BLOCK, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + BLOCK).min(n);
                for i in start..end {
                    // Safety: each index is claimed exactly once via the
                    // atomic counter; slots are disjoint.
                    unsafe {
                        let p = (base as *mut T).add(i);
                        p.write(f(i));
                    }
                }
            });
        }
    });
    // Safety: all n slots were initialized by the scope above.
    unsafe { out.set_len(n) };
    out
}

/// Parallel sweep over equal-size mutable chunks: `f(i, &mut data[i*stride..][..stride])`.
pub fn par_chunks_mut<T: Send>(
    data: &mut [T],
    stride: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(stride > 0 && data.len() % stride == 0);
    let n = data.len() / stride;
    let threads = threads_for(n);
    if threads <= 1 {
        for (i, chunk) in data.chunks_mut(stride).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut base = 0usize;
        while !rest.is_empty() {
            let take = per.min(rest.len() / stride);
            let (head, tail) = rest.split_at_mut(take * stride);
            rest = tail;
            let start = base;
            base += take;
            let f = &f;
            s.spawn(move || {
                for (j, chunk) in head.chunks_mut(stride).enumerate() {
                    f(start + j, chunk);
                }
            });
        }
    });
}

/// The sampler's pattern: for each item `i`, `f` gets the item index, a
/// mutable strided chunk of `a`, and a mutable slot of `b`. Thread-local
/// scratch is created once per worker via `init`.
pub fn par_zip_chunks<A: Send, B: Send, S>(
    a: &mut [A],
    b: &mut [B],
    stride: usize,
    init: impl Fn() -> S + Sync,
    f: impl Fn(&mut S, usize, &mut [A], &mut B) + Sync,
) {
    assert!(stride > 0 && a.len() == b.len() * stride);
    let n = b.len();
    let threads = threads_for(n);
    if threads <= 1 {
        let mut scratch = init();
        for (i, (ac, bc)) in a.chunks_mut(stride).zip(b.iter_mut()).enumerate() {
            f(&mut scratch, i, ac, bc);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut a_rest = a;
        let mut b_rest = b;
        let mut base = 0usize;
        while !b_rest.is_empty() {
            let take = per.min(b_rest.len());
            let (a_head, a_tail) = a_rest.split_at_mut(take * stride);
            let (b_head, b_tail) = b_rest.split_at_mut(take);
            a_rest = a_tail;
            b_rest = b_tail;
            let start = base;
            base += take;
            let f = &f;
            let init = &init;
            s.spawn(move || {
                let mut scratch = init();
                for (j, (ac, bc)) in a_head.chunks_mut(stride).zip(b_head.iter_mut()).enumerate()
                {
                    f(&mut scratch, start + j, ac, bc);
                }
            });
        }
    });
}

/// Parallel sweep over contiguous **variable-length** chunks of `data`:
/// chunk `k` is `data[offsets[k]..offsets[k + 1]]`, so `offsets` is a
/// prefix-sum array (monotone, `offsets[0] == 0`, last entry ==
/// `data.len()`). Thread-local scratch is created once per worker via
/// `init`, like [`par_zip_chunks`]. This is the bulk serve kernel's
/// pattern: fill a response blob whose per-request segment lengths were
/// prefix-summed up front.
pub fn par_ragged_chunks<T: Send, S>(
    data: &mut [T],
    offsets: &[usize],
    init: impl Fn() -> S + Sync,
    f: impl Fn(&mut S, usize, &mut [T]) + Sync,
) {
    assert!(!offsets.is_empty() && offsets[0] == 0, "offsets must start at 0");
    assert!(offsets.windows(2).all(|w| w[0] <= w[1]), "offsets must be monotone");
    let n = offsets.len() - 1;
    assert_eq!(offsets[n], data.len(), "offsets must cover data exactly");
    let threads = threads_for(n);
    if threads <= 1 {
        let mut scratch = init();
        for (k, w) in offsets.windows(2).enumerate() {
            f(&mut scratch, k, &mut data[w[0]..w[1]]);
        }
        return;
    }
    // Contiguous ranges of chunks per thread, split at range-boundary
    // offsets; within a thread, chunks are peeled off by split_at_mut.
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut consumed = 0usize;
        let mut base = 0usize;
        while base < n {
            let take = per.min(n - base);
            let end = offsets[base + take];
            let (head, tail) = rest.split_at_mut(end - consumed);
            rest = tail;
            consumed = end;
            let start = base;
            base += take;
            let f = &f;
            let init = &init;
            s.spawn(move || {
                let mut scratch = init();
                let mut head = head;
                for k in start..start + take {
                    let (chunk, t) = head.split_at_mut(offsets[k + 1] - offsets[k]);
                    head = t;
                    f(&mut scratch, k, chunk);
                }
            });
        }
    });
}

/// Parallel scatter of variable-length source segments into **disjoint**
/// strided rows: for every `(row, off, len)` triple,
/// `dst[row * stride ..][.. len]` is overwritten with
/// `src[off ..][.. len]`. Every triple is bounds-checked up front (and
/// row uniqueness in debug builds), so the raw-pointer parallel phase
/// cannot fault and the destination writes are provably disjoint. This
/// is the bulk decode's pattern: scatter a response blob's per-request
/// segments into the strided sample buffer.
pub fn par_scatter_rows<T: Copy + Send + Sync>(
    dst: &mut [T],
    stride: usize,
    src: &[T],
    rows: &[(u32, u32, u32)],
) {
    assert!(stride > 0, "stride must be >= 1");
    for &(row, off, len) in rows {
        let (row, off, len) = (row as usize, off as usize, len as usize);
        assert!(len <= stride, "segment longer than a destination row");
        assert!(row * stride + len <= dst.len(), "destination row out of range");
        assert!(off + len <= src.len(), "source segment out of range");
    }
    #[cfg(debug_assertions)]
    {
        let mut seen = std::collections::HashSet::with_capacity(rows.len());
        for &(row, _, _) in rows {
            debug_assert!(seen.insert(row), "duplicate destination row {row}");
        }
    }
    let threads = threads_for(rows.len());
    if threads <= 1 {
        for &(row, off, len) in rows {
            let (row, off, len) = (row as usize, off as usize, len as usize);
            dst[row * stride..row * stride + len].copy_from_slice(&src[off..off + len]);
        }
        return;
    }
    let base = dst.as_mut_ptr() as usize;
    let per = rows.len().div_ceil(threads);
    std::thread::scope(|s| {
        for part in rows.chunks(per) {
            s.spawn(move || {
                for &(row, off, len) in part {
                    let (row, off, len) = (row as usize, off as usize, len as usize);
                    // Safety: triples were bounds-checked above and rows
                    // are unique, so every write range is in-bounds and
                    // disjoint from every other thread's writes.
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            src.as_ptr().add(off),
                            (base as *mut T).add(row * stride),
                            len,
                        );
                    }
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let out = par_map(10_000, |i| i * i);
        assert_eq!(out.len(), 10_000);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn par_map_empty_and_tiny() {
        assert!(par_map(0, |i| i).is_empty());
        assert_eq!(par_map(3, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn par_chunks_mut_writes_every_chunk() {
        let mut data = vec![0usize; 9 * 4096];
        par_chunks_mut(&mut data, 9, |i, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = i * 9 + j;
            }
        });
        for (k, &v) in data.iter().enumerate() {
            assert_eq!(v, k);
        }
    }

    #[test]
    fn par_zip_chunks_strided_write() {
        let n = 5000;
        let stride = 3;
        let mut a = vec![0u32; n * stride];
        let mut b = vec![0u32; n];
        par_zip_chunks(
            &mut a,
            &mut b,
            stride,
            Vec::<u32>::new,
            |scratch, i, chunk, slot| {
                scratch.push(i as u32); // exercise per-thread scratch
                for (j, x) in chunk.iter_mut().enumerate() {
                    *x = (i * stride + j) as u32;
                }
                *slot = i as u32;
            },
        );
        for (k, &v) in a.iter().enumerate() {
            assert_eq!(v, k as u32);
        }
        for (i, &v) in b.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    #[should_panic]
    fn par_zip_chunks_length_mismatch_panics() {
        let mut a = vec![0u8; 10];
        let mut b = vec![0u8; 4];
        par_zip_chunks(&mut a, &mut b, 3, || (), |_, _, _, _| {});
    }

    #[test]
    fn par_ragged_chunks_writes_every_segment() {
        // Ragged lengths cycling 0..=6 over enough chunks to go parallel.
        let n = 5000;
        let lens: Vec<usize> = (0..n).map(|k| k % 7).collect();
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0usize);
        for &l in &lens {
            offsets.push(offsets.last().copied().unwrap() + l);
        }
        let mut data = vec![0u64; *offsets.last().unwrap()];
        par_ragged_chunks(&mut data, &offsets, Vec::<u8>::new, |scratch, k, seg| {
            scratch.push(0); // exercise per-thread scratch
            assert_eq!(seg.len(), k % 7);
            for (j, x) in seg.iter_mut().enumerate() {
                *x = (k * 10 + j) as u64;
            }
        });
        for k in 0..n {
            for j in 0..lens[k] {
                assert_eq!(data[offsets[k] + j], (k * 10 + j) as u64);
            }
        }
    }

    #[test]
    fn par_ragged_chunks_empty_and_serial() {
        par_ragged_chunks::<u32, ()>(&mut [], &[0], || (), |_, _, _| panic!("no chunks"));
        let mut data = vec![0u32; 5];
        par_ragged_chunks(&mut data, &[0, 2, 2, 5], || (), |_, k, seg| {
            seg.fill(k as u32 + 1);
        });
        assert_eq!(data, [1, 1, 3, 3, 3]);
    }

    #[test]
    #[should_panic]
    fn par_ragged_chunks_rejects_short_offsets() {
        let mut data = vec![0u32; 4];
        par_ragged_chunks(&mut data, &[0, 2], || (), |_, _, _| {});
    }

    #[test]
    fn par_scatter_rows_fills_disjoint_rows() {
        let stride = 5;
        let n = 4000;
        let src: Vec<u32> = (0..n as u32 * 3).collect();
        // Row k gets the segment [3k, 3k+1, 3k+2) of length k % 4 from a
        // shuffled row order, so destination order != triple order.
        let rows: Vec<(u32, u32, u32)> =
            (0..n).map(|k| (((k * 997) % n) as u32, (k * 3) as u32, (k % 4) as u32)).collect();
        let mut dst = vec![u32::MAX; n * stride];
        par_scatter_rows(&mut dst, stride, &src, &rows);
        for &(row, off, len) in &rows {
            let base = row as usize * stride;
            for j in 0..len as usize {
                assert_eq!(dst[base + j], src[off as usize + j]);
            }
            for j in len as usize..stride {
                assert_eq!(dst[base + j], u32::MAX, "untouched tail overwritten");
            }
        }
    }

    #[test]
    #[should_panic]
    fn par_scatter_rows_rejects_out_of_range_row() {
        let mut dst = vec![0u32; 6];
        par_scatter_rows(&mut dst, 3, &[1, 2], &[(2, 0, 2)]);
    }
}
