//! Self-contained timing harness (offline substitute for criterion).
//!
//! Used by the `cargo bench` targets in `benches/` and the `report`
//! subcommands. Warmup + fixed-duration sampling + robust statistics;
//! results can be printed as an aligned table or dumped as JSON for
//! EXPERIMENTS.md.

use std::time::{Duration, Instant};

/// Statistics of one measured benchmark case (times in seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Stats {
    fn from_samples(name: &str, mut samples: Vec<f64>) -> Self {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len().max(1);
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n as f64;
        let pct = |p: f64| samples[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Stats {
            name: name.to_string(),
            iters: n,
            mean,
            std: var.sqrt(),
            min: samples.first().copied().unwrap_or(0.0),
            p50: pct(0.50),
            p95: pct(0.95),
        }
    }

    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>12} {:>6}",
            self.name,
            fmt_time(self.mean),
            fmt_time(self.p50),
            fmt_time(self.p95),
            fmt_time(self.std),
            self.iters
        )
    }
}

pub fn header() -> String {
    format!(
        "{:<44} {:>10} {:>12} {:>12} {:>12} {:>6}",
        "benchmark", "mean", "p50", "p95", "std", "iters"
    )
}

fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

/// Benchmark runner: warms up, then samples `f` until `budget` elapses
/// (at least `min_iters`, at most `max_iters`).
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

impl Bencher {
    /// Quick preset for heavyweight end-to-end cases (epoch benches).
    pub fn heavy() -> Self {
        Self {
            warmup: Duration::ZERO,
            budget: Duration::from_secs(4),
            min_iters: 2,
            max_iters: 20,
        }
    }

    /// Measure `f`, using its return value to keep the work observable
    /// (the value is passed to `std::hint::black_box`).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        let wu_start = Instant::now();
        while wu_start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while (samples.len() < self.min_iters)
            || (start.elapsed() < self.budget && samples.len() < self.max_iters)
        {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        Stats::from_samples(name, samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered_and_sane() {
        let b = Bencher {
            warmup: Duration::ZERO,
            budget: Duration::from_millis(50),
            min_iters: 5,
            max_iters: 100,
        };
        let s = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(s.iters >= 5);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
        assert!(s.mean > 0.0);
    }

    #[test]
    fn formatting_has_units() {
        assert!(fmt_time(2.5e-9).ends_with("ns"));
        assert!(fmt_time(2.5e-5).ends_with("µs"));
        assert!(fmt_time(2.5e-2).ends_with("ms"));
        assert!(fmt_time(2.5).ends_with('s'));
        assert!(header().contains("benchmark"));
    }
}
