//! Randomized property testing (offline substitute for proptest).
//!
//! `check` runs a property over `cases` randomized inputs derived from a
//! deterministic per-case key. On failure it panics with the case index
//! and seed so the exact input is reproducible with `check_one`. No
//! shrinking — generators are expected to produce small cases at low
//! indices (pass `i` to your size function).

use crate::sampling::rng::{RngKey, RngStream};

/// Run `property` for `cases` cases. The closure receives the case index
/// and a fresh RNG stream; generate inputs from the stream and assert
/// inside. Sizes should grow with the index so early failures are small.
pub fn check(seed: u64, cases: usize, property: impl Fn(usize, &mut RngStream)) {
    for i in 0..cases {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut s = RngKey::new(seed).fold(0x9409).stream(i as u64);
            property(i, &mut s);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {i} (reproduce: check_one({seed}, {i}, ..)): {msg}"
            );
        }
    }
}

/// Re-run a single failing case from `check`'s panic message.
pub fn check_one(seed: u64, case: usize, mut property: impl FnMut(usize, &mut RngStream)) {
    let mut s = RngKey::new(seed).fold(0x9409).stream(case as u64);
    property(case, &mut s);
}

/// Helpers for building random test inputs from a stream.
pub mod gen {
    use crate::sampling::rng::RngStream;

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn size(s: &mut RngStream, lo: usize, hi: usize) -> usize {
        lo + s.next_below(hi - lo + 1)
    }

    /// Vector of uniform u32 below `bound`.
    pub fn vec_below(s: &mut RngStream, len: usize, bound: usize) -> Vec<u32> {
        (0..len).map(|_| s.next_below(bound) as u32).collect()
    }

    /// Random subset of `0..n` of the given size (distinct, unsorted).
    pub fn subset(s: &mut RngStream, n: usize, k: usize) -> Vec<u32> {
        let mut out = Vec::new();
        s.sample_distinct(n, k, &mut out);
        out.into_iter().map(|v| v as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::sync::atomic::AtomicUsize::new(0);
        check(1, 25, |_i, s| {
            counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let v = s.next_below(10);
            assert!(v < 10);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), 25);
    }

    #[test]
    #[should_panic(expected = "reproduce: check_one(2, 3")]
    fn failing_property_reports_case() {
        check(2, 10, |i, _s| {
            assert!(i != 3, "boom at {i}");
        });
    }

    #[test]
    fn check_one_reproduces_stream() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        check_one(3, 7, |_i, s| a.push(s.next_u64()));
        check_one(3, 7, |_i, s| b.push(s.next_u64()));
        assert_eq!(a, b);
    }

    #[test]
    fn gen_subset_is_distinct() {
        check(4, 20, |i, s| {
            let n = gen::size(s, 1, 50 + i);
            let k = gen::size(s, 0, n);
            let sub = gen::subset(s, n, k);
            let mut sorted = sub.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), sub.len());
        });
    }
}
