//! Thin wrapper over the `xla` crate's PJRT CPU client — feature-gated.
//!
//! With the `xla` feature (requires the vendored `xla` crate):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//! Compilation happens once per artifact at startup; only
//! `Executable::run` sits on the hot path.
//!
//! Without the feature (the default, hermetic build), [`Engine::cpu`]
//! returns a descriptive error. Everything that needs an executable —
//! the trainer, the E2E tests — already skips cleanly when `artifacts/`
//! is absent, so `cargo test -q` stays green either way; the sampling,
//! partitioning, and dist layers are fully exercised regardless.

use std::path::Path;

use anyhow::Result;

use super::tensor::HostTensor;

#[cfg(feature = "xla")]
mod imp {
    use anyhow::{Context, Result};
    use std::path::Path;
    use xla::Literal;

    use super::HostTensor;

    /// Owns the PJRT client. One per worker (PjRtClient is Rc-based; one
    /// per worker also mirrors one per machine of the testbed).
    pub struct Engine {
        client: xla::PjRtClient,
    }

    impl Engine {
        /// Create the CPU PJRT engine.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self { client })
        }

        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO **text** artifact (text, not proto: jax
        /// ≥ 0.5 emits 64-bit instruction ids which xla_extension 0.5.1
        /// rejects; the text parser reassigns ids — see DESIGN.md §AOT).
        pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Executable> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {path:?}"))?;
            Ok(Executable { exe })
        }
    }

    /// A compiled, ready-to-run XLA executable with a tuple result (all
    /// our AOT artifacts are lowered with `return_tuple=True`).
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
    }

    impl Executable {
        /// Execute with host tensors; returns the flattened output tuple.
        pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            let literals: Vec<Literal> =
                inputs.iter().map(HostTensor::to_literal).collect::<Result<_>>()?;
            let outs = self.run_literals(&literals)?;
            outs.iter().map(HostTensor::from_literal).collect()
        }

        /// Lower-level entry point when the caller already holds literals.
        pub fn run_literals(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
            let result = self.exe.execute::<Literal>(inputs).context("executing")?;
            let tuple = result[0][0].to_literal_sync()?;
            Ok(tuple.to_tuple()?)
        }
    }
}

#[cfg(not(feature = "xla"))]
mod imp {
    use anyhow::{bail, Result};
    use std::path::Path;

    use super::HostTensor;

    const UNAVAILABLE: &str = "fastsample was built without the `xla` feature; \
         the PJRT runtime is unavailable. Rebuild with `--features xla` \
         (needs the vendored `xla` crate) to execute AOT artifacts.";

    /// Stub engine for hermetic (no-XLA) builds: construction fails with
    /// a clear message instead of a missing-symbol error at link time.
    pub struct Engine {
        _priv: (),
    }

    impl Engine {
        pub fn cpu() -> Result<Self> {
            bail!(UNAVAILABLE);
        }

        pub fn platform_name(&self) -> String {
            "unavailable (built without the xla feature)".to_string()
        }

        pub fn load_hlo(&self, _path: impl AsRef<Path>) -> Result<Executable> {
            bail!(UNAVAILABLE);
        }
    }

    /// Unconstructible in this configuration ([`Engine::cpu`] always
    /// errors first); methods exist so downstream code typechecks.
    pub struct Executable {
        _priv: (),
    }

    impl Executable {
        pub fn run(&self, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            bail!(UNAVAILABLE);
        }
    }
}

pub use imp::{Engine, Executable};

// Keep the re-exported API surface identical across configurations for
// the pieces the crate itself uses.
#[allow(dead_code)]
fn _assert_api_surface(e: &Engine, x: &Executable, p: &Path) -> Result<Vec<HostTensor>> {
    let _ = e.platform_name();
    let _ = e.load_hlo(p);
    x.run(&[])
}
