//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Compilation happens once per artifact at
//! startup; only `Executable::run` sits on the hot path.

use std::path::Path;

use anyhow::{Result, Context};
use xla::Literal;

use super::tensor::HostTensor;

/// Owns the PJRT client. One per process (workers share it: XLA CPU
/// executables are thread-safe to execute concurrently).
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create the CPU PJRT engine.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO **text** artifact (see module docs for why
    /// text is the interchange format).
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable { exe })
    }
}

/// A compiled, ready-to-run XLA executable with a tuple result (all our
/// AOT artifacts are lowered with `return_tuple=True`).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host tensors; returns the flattened output tuple.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<Literal> =
            inputs.iter().map(HostTensor::to_literal).collect::<Result<_>>()?;
        let outs = self.run_literals(&literals)?;
        outs.iter().map(HostTensor::from_literal).collect()
    }

    /// Lower-level entry point when the caller already holds literals.
    pub fn run_literals(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let result = self.exe.execute::<Literal>(inputs).context("executing")?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}
