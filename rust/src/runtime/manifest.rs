//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the rust runtime: which HLO files exist, their padded shapes
//! (`caps`), fanouts, and the flat argument order.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One model parameter: name + shape, in the order the AOT executables
/// expect them as leading arguments (and return their grads).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT model variant (fixed batch/fanouts/caps → fixed HLO shapes).
#[derive(Debug, Clone)]
pub struct Variant {
    pub feat_dim: usize,
    pub hidden: usize,
    pub classes: usize,
    pub batch: usize,
    /// Top level first: `(N_L, ..., N_1)` — paper §4.1 notation.
    pub fanouts: Vec<usize>,
    /// Input level first: `caps[0] ≥ ... ≥ caps[L] == batch`.
    pub caps: Vec<usize>,
    pub dropout: f64,
    pub params: Vec<ParamSpec>,
    pub train_hlo: String,
    pub eval_hlo: String,
    pub train_args: Vec<String>,
    pub eval_args: Vec<String>,
}

impl Variant {
    pub fn layers(&self) -> usize {
        self.fanouts.len()
    }

    /// Fanout used when expanding level `l` seeds into level `l-1` nodes
    /// (`l` is 1-indexed from the bottom, as in the paper's Algorithm 1).
    pub fn fanout_at_layer(&self, l: usize) -> usize {
        self.fanouts[self.layers() - l]
    }

    /// Total number of parameter scalars (for flat optimizer state).
    pub fn param_numel(&self) -> usize {
        self.params.iter().map(ParamSpec::numel).sum()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let str_vec = |key: &str| -> Result<Vec<String>> {
            j.get(key)?
                .as_arr()?
                .iter()
                .map(|x| x.as_str().map(str::to_string))
                .collect()
        };
        Ok(Variant {
            feat_dim: j.get("feat_dim")?.as_usize()?,
            hidden: j.get("hidden")?.as_usize()?,
            classes: j.get("classes")?.as_usize()?,
            batch: j.get("batch")?.as_usize()?,
            fanouts: j.get("fanouts")?.usize_vec()?,
            caps: j.get("caps")?.usize_vec()?,
            dropout: j.get("dropout")?.as_f64()?,
            params: j
                .get("params")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.get("name")?.as_str()?.to_string(),
                        shape: p.get("shape")?.usize_vec()?,
                    })
                })
                .collect::<Result<_>>()?,
            train_hlo: j.get("train_hlo")?.as_str()?.to_string(),
            eval_hlo: j.get("eval_hlo")?.as_str()?.to_string(),
            train_args: str_vec("train_args")?,
            eval_args: str_vec("eval_args")?,
        })
    }
}

/// The whole manifest: variant name → [`Variant`].
#[derive(Debug, Clone)]
pub struct Manifest {
    pub variants: HashMap<String, Variant>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated from I/O for testability).
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let mut variants = HashMap::new();
        for (name, v) in j.get("variants")?.as_obj()? {
            let variant = Variant::from_json(v)
                .with_context(|| format!("manifest variant {name:?}"))?;
            variants.insert(name.clone(), variant);
        }
        Ok(Manifest { variants, dir: dir.to_path_buf() })
    }

    pub fn variant(&self, name: &str) -> Result<&Variant> {
        self.variants.get(name).ok_or_else(|| {
            anyhow!(
                "variant {name:?} not in manifest (have: {:?}) — re-run `make artifacts`",
                self.variants.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "variants": {
            "q": {
                "feat_dim": 32, "hidden": 64, "classes": 8, "batch": 32,
                "fanouts": [15, 10, 5], "caps": [2048, 512, 128, 32], "dropout": 0.5,
                "params": [
                    {"name": "l1.w_self", "shape": [32, 64]},
                    {"name": "l1.bias", "shape": [64]}
                ],
                "train_hlo": "q_train.hlo.txt", "eval_hlo": "q_eval.hlo.txt",
                "train_args": ["l1.w_self", "l1.bias", "feats", "labels", "label_mask", "seed"],
                "eval_args": ["l1.w_self", "l1.bias", "feats"]
            }
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let v = m.variant("q").unwrap();
        assert_eq!(v.batch, 32);
        assert_eq!(v.fanouts, vec![15, 10, 5]);
        assert_eq!(v.params.len(), 2);
        assert_eq!(v.params[0].numel(), 32 * 64);
        assert_eq!(v.param_numel(), 32 * 64 + 64);
        assert_eq!(m.hlo_path(&v.train_hlo), Path::new("/tmp/a/q_train.hlo.txt"));
    }

    #[test]
    fn fanout_at_layer_is_top_first() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        let v = m.variant("q").unwrap();
        assert_eq!(v.fanout_at_layer(3), 15); // top layer expands with N_3
        assert_eq!(v.fanout_at_layer(1), 5);
    }

    #[test]
    fn missing_variant_is_error() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert!(m.variant("nope").is_err());
    }

    #[test]
    fn malformed_manifest_is_error() {
        assert!(Manifest::parse("{}", Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{"variants": {"q": {"batch": 1}}}"#, Path::new(".")).is_err());
    }
}
