//! Model-level runtime: wraps the train/eval executables of one manifest
//! variant behind a typed interface, and owns parameter initialization.

use anyhow::{ensure, Result};

use crate::sampling::rng::RngKey;

use super::client::{Engine, Executable};
use super::manifest::{Manifest, Variant};
use super::tensor::HostTensor;

/// A fully padded minibatch, shaped exactly as the AOT executable expects
/// (see `python/compile/model.py` docstring for the convention). Built by
/// `train::padding` from sampled MFGs.
#[derive(Debug, Clone)]
pub struct PaddedBatch {
    /// `[caps[0], F]` input features of the level-0 nodes.
    pub feats: HostTensor,
    /// Bottom layer first: `(idx_l [caps[l], K_l], cnt_l [caps[l]])`.
    pub levels: Vec<(HostTensor, HostTensor)>,
    /// `[batch]` seed labels (zero-filled beyond the real seed count).
    pub labels: Vec<i32>,
    /// `[batch]` 1.0 for real seeds, 0.0 for padding.
    pub label_mask: Vec<f32>,
}

/// Result of one train step.
#[derive(Debug)]
pub struct TrainOutput {
    pub loss: f32,
    /// Gradients in `Variant::params` order.
    pub grads: Vec<HostTensor>,
}

/// Result of one eval step.
#[derive(Debug)]
pub struct EvalOutput {
    /// `[batch, classes]` seed logits.
    pub logits: HostTensor,
}

/// One variant's compiled executables + metadata.
pub struct ModelRuntime {
    pub variant: Variant,
    train_exe: Executable,
    eval_exe: Executable,
}

impl ModelRuntime {
    /// Compile the train+eval artifacts of `name` (once, at startup).
    pub fn load(engine: &Engine, manifest: &Manifest, name: &str) -> Result<Self> {
        let variant = manifest.variant(name)?.clone();
        let train_exe = engine.load_hlo(manifest.hlo_path(&variant.train_hlo))?;
        let eval_exe = engine.load_hlo(manifest.hlo_path(&variant.eval_hlo))?;
        Ok(Self { variant, train_exe, eval_exe })
    }

    /// Xavier-uniform weights, zero biases — matches the reference
    /// `init_params` in python/compile/model.py (scheme, not bits).
    pub fn init_params(&self, seed: u64) -> Vec<HostTensor> {
        let key = RngKey::new(seed).fold(0x9a7a_11ce);
        self.variant
            .params
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let n = spec.numel();
                if spec.shape.len() == 2 {
                    let limit = (6.0 / (spec.shape[0] + spec.shape[1]) as f32).sqrt();
                    let mut s = key.stream(i as u64);
                    let data = (0..n).map(|_| s.next_range_f32(-limit, limit)).collect();
                    HostTensor::f32(data, &spec.shape)
                } else {
                    HostTensor::zeros_f32(&spec.shape)
                }
            })
            .collect()
    }

    fn check_batch(&self, batch: &PaddedBatch) -> Result<()> {
        let v = &self.variant;
        ensure!(
            batch.levels.len() == v.layers(),
            "batch has {} levels, variant expects {}",
            batch.levels.len(),
            v.layers()
        );
        ensure!(
            batch.feats.shape() == [v.caps[0], v.feat_dim],
            "feats shape {:?} != [{}, {}]",
            batch.feats.shape(),
            v.caps[0],
            v.feat_dim
        );
        for (l, (idx, cnt)) in batch.levels.iter().enumerate() {
            let layer = l + 1;
            let k = v.fanout_at_layer(layer);
            ensure!(
                idx.shape() == [v.caps[layer], k],
                "idx_{layer} shape {:?} != [{}, {}]",
                idx.shape(),
                v.caps[layer],
                k
            );
            ensure!(cnt.shape() == [v.caps[layer]], "cnt_{layer} shape mismatch");
        }
        ensure!(batch.labels.len() == v.batch && batch.label_mask.len() == v.batch);
        Ok(())
    }

    /// Flat argument assembly shared by train/eval (params first, then
    /// feats, then per-layer idx/cnt — must match `arg_order` in model.py).
    fn base_args(&self, params: &[HostTensor], batch: &PaddedBatch) -> Vec<HostTensor> {
        let mut args = Vec::with_capacity(params.len() + 1 + 2 * batch.levels.len() + 3);
        args.extend_from_slice(params);
        args.push(batch.feats.clone());
        for (idx, cnt) in &batch.levels {
            args.push(idx.clone());
            args.push(cnt.clone());
        }
        args
    }

    /// Run one training step: returns the masked-CE loss and grads.
    pub fn train_step(
        &self,
        params: &[HostTensor],
        batch: &PaddedBatch,
        dropout_seed: i32,
    ) -> Result<TrainOutput> {
        self.check_batch(batch)?;
        ensure!(params.len() == self.variant.params.len(), "param count mismatch");
        let mut args = self.base_args(params, batch);
        args.push(HostTensor::i32(batch.labels.clone(), &[self.variant.batch]));
        args.push(HostTensor::f32(batch.label_mask.clone(), &[self.variant.batch]));
        args.push(HostTensor::scalar_i32(dropout_seed));

        let mut outs = self.train_exe.run(&args)?;
        ensure!(outs.len() == 1 + params.len(), "train step returned {} outputs", outs.len());
        let grads = outs.split_off(1);
        let loss = outs[0].as_f32()?[0];
        Ok(TrainOutput { loss, grads })
    }

    /// Run one eval step: seed logits only (no dropout).
    pub fn eval_step(&self, params: &[HostTensor], batch: &PaddedBatch) -> Result<EvalOutput> {
        self.check_batch(batch)?;
        let args = self.base_args(params, batch);
        let mut outs = self.eval_exe.run(&args)?;
        ensure!(outs.len() == 1, "eval step returned {} outputs", outs.len());
        Ok(EvalOutput { logits: outs.pop().unwrap() })
    }
}
