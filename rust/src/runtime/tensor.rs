//! Host-side tensors and conversion to/from PJRT literals.
//!
//! The training loop works with plain `Vec`-backed tensors; conversion to
//! `xla::Literal` happens once per step at the executable boundary (and
//! only exists under the `xla` feature — the hermetic default build keeps
//! the tensor type but has no literal boundary to cross).

use anyhow::{bail, Result};
#[cfg(feature = "xla")]
use xla::{ElementType, Literal, PrimitiveType};

/// A dense host tensor, either f32 or i32 — the only two dtypes crossing
/// the L3↔L2 boundary (see `python/compile/model.py`).
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Self::F32 { data, shape: shape.to_vec() }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Self::I32 { data, shape: shape.to_vec() }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self::F32 { data: vec![v], shape: vec![] }
    }

    pub fn scalar_i32(v: i32) -> Self {
        Self::I32 { data: vec![v], shape: vec![] }
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        Self::f32(vec![0.0; shape.iter().product()], shape)
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Self::F32 { shape, .. } | Self::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Self::F32 { data, .. } => data.len(),
            Self::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Self::F32 { data, .. } => Ok(data),
            Self::I32 { .. } => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Self::I32 { data, .. } => Ok(data),
            Self::F32 { .. } => bail!("tensor is f32, expected i32"),
        }
    }

    /// Build a PJRT literal (row-major, matching jax's default layout).
    #[cfg(feature = "xla")]
    pub fn to_literal(&self) -> Result<Literal> {
        let lit = match self {
            Self::F32 { data, shape } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr().cast::<u8>(), data.len() * 4)
                };
                Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, bytes)?
            }
            Self::I32 { data, shape } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr().cast::<u8>(), data.len() * 4)
                };
                Literal::create_from_shape_and_untyped_data(ElementType::S32, shape, bytes)?
            }
        };
        Ok(lit)
    }

    /// Read a literal back into a host tensor.
    #[cfg(feature = "xla")]
    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match lit.primitive_type()? {
            PrimitiveType::F32 => Ok(Self::F32 { data: lit.to_vec::<f32>()?, shape: dims }),
            PrimitiveType::S32 => Ok(Self::I32 { data: lit.to_vec::<i32>()?, shape: dims }),
            ty => bail!("unsupported literal type {ty:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "xla")]
    #[test]
    fn f32_literal_round_trip() {
        let t = HostTensor::f32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn i32_literal_round_trip() {
        let t = HostTensor::i32(vec![-1, 0, 7, 42], &[4]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn scalar_round_trip() {
        let t = HostTensor::scalar_i32(3);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
        assert_eq!(back.shape(), &[] as &[usize]);
    }

    #[test]
    fn shape_and_len_agree() {
        let t = HostTensor::zeros_f32(&[3, 4]);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.len(), 12);
        assert!(!t.is_empty());
        assert!(HostTensor::f32(vec![], &[0]).is_empty());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = HostTensor::scalar_f32(1.0);
        assert!(t.as_i32().is_err());
        assert!(t.as_f32().is_ok());
    }
}
