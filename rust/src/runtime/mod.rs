//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! training hot path.
//!
//! The interchange with the python build path (`python/compile/aot.py`) is
//! **HLO text** + `artifacts/manifest.json`. Text (not serialized proto) is
//! required: jax ≥ 0.5 emits 64-bit instruction ids which xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see DESIGN.md §AOT).

mod client;
mod manifest;
mod model;
mod tensor;

pub use client::{Engine, Executable};
pub use manifest::{Manifest, ParamSpec, Variant};
pub use model::{EvalOutput, ModelRuntime, PaddedBatch, TrainOutput};
pub use tensor::HostTensor;
