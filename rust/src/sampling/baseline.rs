//! The DGL-style two-step baseline sampler the paper compares against
//! (§3.2, Fig 1): step 1 samples neighbors into a **COO** edge list; step 2
//! casts it to a bipartite block (compaction/relabel) and converts
//! COO → CSC.
//!
//! The redundant work the fused kernel eliminates is kept here on purpose
//! — this is the *measured baseline* of Fig 5:
//!
//! 1. the sampled edges are materialized as two global-id COO arrays and
//!    re-read by the next step;
//! 2. per-seed sample counts, already known during sampling, are
//!    **re-computed** by the COO→CSC counting pass;
//! 3. a separate scatter pass builds `C` (and needs a cursor array).
//!
//! Everything else — RNG streams, neighbor choice, parallelization of the
//! sampling loop, the relabel map — is identical to the fused kernel, so
//! benchmarks isolate exactly the fusion effect (and the equivalence test
//! can require bit-identical output).

use crate::graph::{CscGraph, NodeId};
use crate::util::par;

use super::fused::sample_node;
use super::mfg::{Mfg, SamplerWorkspace};
use super::rng::RngKey;

/// Sample one level through the two-step COO pipeline. Same contract and
/// same (seed → samples) mapping as
/// [`sample_level_fused`](super::fused::sample_level_fused).
pub fn sample_level_baseline(
    graph: &CscGraph,
    seeds: &[NodeId],
    fanout: usize,
    key: RngKey,
    ws: &mut SamplerWorkspace,
) -> Mfg {
    assert!(fanout >= 1, "fanout must be >= 1");
    let n = seeds.len();
    ws.begin(graph.num_nodes());
    ws.samples.resize(n * fanout, 0);
    ws.counts.resize(n, 0);

    // ---- Step 1a: sample (identical RNG to the fused kernel).
    par::par_zip_chunks(
        &mut ws.samples,
        &mut ws.counts,
        fanout,
        Vec::new,
        |scratch, i, chunk, cnt| {
            let v = seeds[i];
            *cnt = sample_node(graph.neighbors(v), v, fanout, key, scratch, chunk);
        },
    );

    // ---- Steps 1b–2b: COO materialization, relabel, and the COO → CSC
    // counting + scatter conversion (see `SamplerWorkspace::
    // assemble_baseline` — shared with the distributed vanilla sampler's
    // baseline arm, which pays the same redundant passes).
    ws.assemble_baseline(seeds, fanout)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{erdos_renyi, planted_communities, rmat};
    use crate::sampling::fused::sample_level_fused;

    /// The headline equivalence: baseline and fused are bit-identical on
    /// the same key — the paper's "mathematically equivalent" claim,
    /// strengthened to exact equality by the shared RNG.
    #[test]
    fn identical_to_fused_er() {
        let g = erdos_renyi(500, 25, RngKey::new(1));
        let seeds: Vec<NodeId> = (0..200).step_by(2).collect();
        let mut ws_a = SamplerWorkspace::new();
        let mut ws_b = SamplerWorkspace::new();
        for fanout in [1, 3, 10, 40] {
            let a = sample_level_fused(&g, &seeds, fanout, RngKey::new(2), &mut ws_a);
            let b = sample_level_baseline(&g, &seeds, fanout, RngKey::new(2), &mut ws_b);
            assert_eq!(a, b, "fanout {fanout}");
        }
    }

    #[test]
    fn identical_to_fused_rmat() {
        let g = rmat(1 << 10, 8_000, (0.57, 0.19, 0.19, 0.05), RngKey::new(3));
        let seeds: Vec<NodeId> = (0..256).collect();
        let mut ws_a = SamplerWorkspace::new();
        let mut ws_b = SamplerWorkspace::new();
        let a = sample_level_fused(&g, &seeds, 7, RngKey::new(4), &mut ws_a);
        let b = sample_level_baseline(&g, &seeds, 7, RngKey::new(4), &mut ws_b);
        assert_eq!(a, b);
    }

    #[test]
    fn identical_to_fused_communities() {
        let (g, _) = planted_communities(800, 8, 12, 0.9, RngKey::new(5));
        let seeds: Vec<NodeId> = (0..800).step_by(7).collect();
        let mut ws_a = SamplerWorkspace::new();
        let mut ws_b = SamplerWorkspace::new();
        let a = sample_level_fused(&g, &seeds, 5, RngKey::new(6), &mut ws_a);
        let b = sample_level_baseline(&g, &seeds, 5, RngKey::new(6), &mut ws_b);
        assert_eq!(a, b);
    }

    #[test]
    fn baseline_validates() {
        let g = erdos_renyi(100, 8, RngKey::new(7));
        let seeds: Vec<NodeId> = (0..30).collect();
        let mut ws = SamplerWorkspace::new();
        let m = sample_level_baseline(&g, &seeds, 4, RngKey::new(8), &mut ws);
        m.validate(&seeds, 4).unwrap();
    }
}
