//! Graph sampling: the paper's core contribution.
//!
//! * [`fused`] — the single-pass CSC-direct kernel (Algorithm 1).
//! * [`baseline`] — the DGL-style two-step COO pipeline it is compared to.
//! * [`pipeline`] — the L-level recursive driver + minibatch schedule.
//! * [`adaptive`] — adaptive fanout schedules (paper §5 future work).
//! * [`rng`] — counter-based RNG making both kernels draw identical
//!   samples (and the parallel loops deterministic).

pub mod adaptive;
pub mod baseline;
pub mod fused;
pub mod mfg;
pub mod pipeline;
pub mod rng;

pub use baseline::sample_level_baseline;
pub use fused::sample_level_fused;
pub use mfg::{Mfg, SamplerWorkspace};
pub use pipeline::{sample_mfgs, KernelKind, MinibatchSchedule};
pub use rng::{RngKey, RngStream};
