//! Counter-based splittable RNG for sampling.
//!
//! Both sampling kernels (fused and the DGL-style baseline) must draw
//! **identical** neighbor choices given the same `(seed, node, level)`
//! counter so their outputs are bit-comparable (the equivalence tests and
//! the paper's "mathematically unchanged" claim rely on this). A
//! counter-based generator also makes the per-seed loop embarrassingly
//! parallel: no shared mutable state, any iteration order.
//!
//! The mix is SplitMix64 (Steele et al.), a full-period 64-bit finalizer
//! with good avalanche — more than enough for neighbor subsampling.

/// Immutable key; cheap to copy into parallel loops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RngKey(pub u64);

impl RngKey {
    pub fn new(seed: u64) -> Self {
        Self(seed)
    }

    /// Derive an independent stream, e.g. per epoch / per level / per worker.
    pub fn fold(self, data: u64) -> Self {
        Self(mix(self.0 ^ data.wrapping_mul(0x9E37_79B9_7F4A_7C15)))
    }

    /// Stateful stream for one logical task (e.g. one seed node).
    pub fn stream(self, counter: u64) -> RngStream {
        RngStream { state: mix(self.0.wrapping_add(counter.wrapping_mul(0xBF58_476D_1CE4_E5B9))) }
    }
}

/// Sequential generator derived from a key + counter.
#[derive(Debug, Clone)]
pub struct RngStream {
    state: u64,
}

impl RngStream {
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        mix(self.state)
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift; n > 0).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn next_range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Floyd's algorithm: sample `k` distinct values from `[0, n)` without
    /// replacement, O(k) expected time, no allocation beyond the output.
    /// Falls back to the identity when `k >= n`.
    pub fn sample_distinct(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        out.clear();
        if k >= n {
            out.extend(0..n);
            return;
        }
        // For small k relative to n, rejection off a small scratch set is
        // cache-friendlier than HashSet; out doubles as the seen-set.
        for j in (n - k)..n {
            let t = self.next_below(j + 1);
            if out.contains(&t) {
                out.push(j);
            } else {
                out.push(t);
            }
        }
    }
}

#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_counter() {
        let key = RngKey::new(42);
        let a: Vec<u64> = (0..8).map(|_| 0).scan(key.stream(7), |s, _| Some(s.next_u64())).collect();
        let b: Vec<u64> = (0..8).map(|_| 0).scan(key.stream(7), |s, _| Some(s.next_u64())).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map(|_| 0).scan(key.stream(8), |s, _| Some(s.next_u64())).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn fold_produces_independent_keys() {
        let k = RngKey::new(1);
        assert_ne!(k.fold(0).0, k.fold(1).0);
        assert_ne!(k.fold(0).0, k.0);
    }

    #[test]
    fn next_below_in_range_and_roughly_uniform() {
        let mut s = RngKey::new(3).stream(0);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            let v = s.next_below(10);
            counts[v] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn next_f32_in_unit_interval() {
        let mut s = RngKey::new(4).stream(0);
        for _ in 0..1000 {
            let v = s.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut s = RngKey::new(5).stream(0);
        let mut out = Vec::new();
        for n in [1usize, 5, 50, 1000] {
            for k in [0usize, 1, 3, n.min(17)] {
                s.sample_distinct(n, k, &mut out);
                assert_eq!(out.len(), k.min(n));
                let mut sorted = out.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), out.len(), "duplicates for n={n} k={k}");
                assert!(out.iter().all(|&v| v < n));
            }
        }
    }

    #[test]
    fn sample_distinct_k_ge_n_is_identity() {
        let mut s = RngKey::new(6).stream(0);
        let mut out = Vec::new();
        s.sample_distinct(4, 10, &mut out);
        assert_eq!(out, vec![0, 1, 2, 3]);
    }
}
