//! Multi-level sampling driver + minibatch iteration (paper §3.1, Fig 1).
//!
//! Recursively applies a level sampler for `l = L, ..., 1`: the source
//! nodes of one level become the seeds of the level below. Returns the
//! MFG stack **bottom layer first** (the order the L2 model consumes).

use crate::graph::{CscGraph, NodeId};

use super::baseline::sample_level_baseline;
use super::fused::sample_level_fused;
use super::mfg::{Mfg, SamplerWorkspace};
use super::rng::RngKey;

/// Which level kernel to use — the Fig 5 / Fig 6 A-B comparison axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// The paper's Algorithm 1 (CSC-direct, single pass).
    Fused,
    /// DGL-style two-step COO pipeline.
    Baseline,
}

impl KernelKind {
    pub fn sample_level(
        self,
        graph: &CscGraph,
        seeds: &[NodeId],
        fanout: usize,
        key: RngKey,
        ws: &mut SamplerWorkspace,
    ) -> Mfg {
        match self {
            Self::Fused => sample_level_fused(graph, seeds, fanout, key, ws),
            Self::Baseline => sample_level_baseline(graph, seeds, fanout, key, ws),
        }
    }
}

/// Key for one sampling level: every sampler — single-machine or
/// distributed, fused or baseline — must derive per-level randomness
/// through this exact fold chain, or the bit-equality between them breaks.
#[inline]
pub(crate) fn level_key(key: RngKey, level: usize) -> RngKey {
    key.fold(0x1e7e1).fold(level as u64)
}

/// Sample all `L` levels for one minibatch of seed nodes.
///
/// `fanouts` is top level first — `(N_L, ..., N_1)`, the paper's tuple
/// notation. The returned vector is bottom layer first: `out[0]` is the
/// layer-1 MFG whose `src_nodes` are the input (level-0) nodes.
pub fn sample_mfgs(
    graph: &CscGraph,
    seeds: &[NodeId],
    fanouts: &[usize],
    key: RngKey,
    ws: &mut SamplerWorkspace,
    kind: KernelKind,
) -> Vec<Mfg> {
    let mut out: Vec<Mfg> = Vec::with_capacity(fanouts.len());
    for (li, &f) in fanouts.iter().enumerate() {
        // Each level seeds from the previous level's relabel table —
        // borrowed in place, not cloned (the table can be 10-100x the
        // minibatch at the bottom levels, all on the hot path).
        let mfg = match out.last() {
            None => kind.sample_level(graph, seeds, f, level_key(key, li), ws),
            Some(prev) => kind.sample_level(graph, &prev.src_nodes, f, level_key(key, li), ws),
        };
        out.push(mfg);
    }
    out.reverse();
    out
}

/// Per-epoch minibatch schedule: a deterministic shuffle of the seed pool
/// chopped into fixed-size batches (the trailing remainder is dropped, as
/// DGL's `drop_last=True` — keeps AOT shapes full).
pub struct MinibatchSchedule {
    order: Vec<NodeId>,
    batch: usize,
}

impl MinibatchSchedule {
    pub fn new(train_ids: &[NodeId], batch: usize, epoch_key: RngKey) -> Self {
        assert!(batch >= 1);
        let mut order = train_ids.to_vec();
        // Fisher–Yates with the epoch key.
        let mut s = epoch_key.fold(0x5c4ed).stream(0);
        for i in (1..order.len()).rev() {
            order.swap(i, s.next_below(i + 1));
        }
        Self { order, batch }
    }

    pub fn num_batches(&self) -> usize {
        self.order.len() / self.batch
    }

    pub fn batch(&self, i: usize) -> &[NodeId] {
        &self.order[i * self.batch..(i + 1) * self.batch]
    }

    pub fn iter(&self) -> impl Iterator<Item = &[NodeId]> {
        (0..self.num_batches()).map(move |i| self.batch(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::erdos_renyi;

    #[test]
    fn levels_chain_and_are_bottom_first() {
        let g = erdos_renyi(400, 15, RngKey::new(1));
        let seeds: Vec<NodeId> = (0..32).collect();
        let mut ws = SamplerWorkspace::new();
        let fanouts = [4, 3, 2]; // N_3, N_2, N_1
        let mfgs = sample_mfgs(&g, &seeds, &fanouts, RngKey::new(2), &mut ws, KernelKind::Fused);
        assert_eq!(mfgs.len(), 3);
        // Top MFG (last) has the minibatch as dst.
        assert_eq!(mfgs[2].n_dst, 32);
        assert_eq!(&mfgs[2].src_nodes[..32], &seeds[..]);
        // Chaining: dst set of level l == src set of level l+1.
        assert_eq!(mfgs[1].n_dst, mfgs[2].num_src());
        assert_eq!(mfgs[0].n_dst, mfgs[1].num_src());
        assert_eq!(&mfgs[1].src_nodes[..mfgs[1].n_dst], &mfgs[2].src_nodes[..]);
        // Fanouts applied top-first: top level sampled ≤ 4 per seed.
        for i in 0..mfgs[2].n_dst {
            assert!(mfgs[2].degree(i) <= 4);
        }
        for i in 0..mfgs[0].n_dst {
            assert!(mfgs[0].degree(i) <= 2);
        }
    }

    #[test]
    fn fused_and_baseline_pipelines_identical() {
        let g = erdos_renyi(600, 20, RngKey::new(3));
        let seeds: Vec<NodeId> = (100..164).collect();
        let mut ws_a = SamplerWorkspace::new();
        let mut ws_b = SamplerWorkspace::new();
        let a = sample_mfgs(&g, &seeds, &[5, 5], RngKey::new(4), &mut ws_a, KernelKind::Fused);
        let b = sample_mfgs(&g, &seeds, &[5, 5], RngKey::new(4), &mut ws_b, KernelKind::Baseline);
        assert_eq!(a, b);
    }

    #[test]
    fn schedule_is_permutation_and_deterministic() {
        let ids: Vec<NodeId> = (0..103).collect();
        let s1 = MinibatchSchedule::new(&ids, 10, RngKey::new(5));
        let s2 = MinibatchSchedule::new(&ids, 10, RngKey::new(5));
        let s3 = MinibatchSchedule::new(&ids, 10, RngKey::new(6));
        assert_eq!(s1.num_batches(), 10); // 103/10, remainder dropped
        let flat1: Vec<NodeId> = s1.iter().flatten().copied().collect();
        let flat2: Vec<NodeId> = s2.iter().flatten().copied().collect();
        assert_eq!(flat1, flat2);
        let flat3: Vec<NodeId> = s3.iter().flatten().copied().collect();
        assert_ne!(flat1, flat3);
        // Permutation: all distinct, all in range.
        let mut sorted = flat1.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 100);
    }
}
