//! The paper's fused sampling kernel (Algorithm 1).
//!
//! One level of neighbor sampling that writes **straight into CSC**:
//!
//! * the row-pointer vector `R` falls out of the sampling loop for free
//!   (a running sum of per-seed sample counts);
//! * no intermediate COO graph is materialized, re-read, or converted;
//! * compaction/relabeling happens in the same pass that writes `C`,
//!   using the `M` map vector (here epoch-stamped so the reset is O(1),
//!   see [`SamplerWorkspace`]).
//!
//! The sampling loop (the paper's first `for`) is parallelized with scoped
//! threads over seeds — each seed draws from its own counter-based RNG
//! stream, so the result is independent of thread scheduling. The relabel
//! loop (the paper's second `for`) is kept sequential and deterministic:
//! it is a pure O(nnz) pass over data already in cache.

use crate::graph::{CscGraph, NodeId};
use crate::util::par;

use super::mfg::{Mfg, SamplerWorkspace};
use super::rng::RngKey;

/// Sample one level: for every seed draw at most `fanout` in-neighbors
/// (without replacement), returning the relabeled bipartite CSC block and
/// (inside it) the next level's seed set `src_nodes`.
///
/// Seeds must be unique (they are: they come from the previous level's
/// relabel table, or from a minibatch of distinct training nodes).
pub fn sample_level_fused(
    graph: &CscGraph,
    seeds: &[NodeId],
    fanout: usize,
    key: RngKey,
    ws: &mut SamplerWorkspace,
) -> Mfg {
    assert!(fanout >= 1, "fanout must be >= 1");
    let n = seeds.len();
    ws.begin(graph.num_nodes());
    ws.samples.resize(n * fanout, 0);
    ws.counts.resize(n, 0);

    // ---- Phase 1 (paper's first loop, parallel): sample into a strided
    // buffer; counts[i] doubles as the degree R needs.
    par::par_zip_chunks(
        &mut ws.samples,
        &mut ws.counts,
        fanout,
        Vec::new,
        |scratch, i, chunk, cnt| {
            let v = seeds[i];
            *cnt = sample_node(graph.neighbors(v), v, fanout, key, scratch, chunk);
        },
    );

    // ---- Phase 2 (paper's second loop): R from the running sum, C and
    // the relabel table in one pass — no COO, no conversion.
    ws.assemble_fused(seeds, fanout)
}

/// Draw at most `fanout` of `neigh` (the in-neighbors of `v`) into the
/// front of `chunk`, returning how many were written. Degree ≤ fanout
/// takes all neighbors in order; otherwise Floyd-samples positions from
/// the counter-based stream keyed by `(key, v)`.
///
/// This is *the* neighbor-choice function: the fused kernel, the DGL-style
/// baseline, and the distributed vanilla sampler (remote owners included)
/// all call it, so any worker sampling node `v` under the same level key
/// draws identical neighbors — the bit-equality the paper's
/// "mathematically equivalent" claim is pinned to.
#[inline]
pub(crate) fn sample_node(
    neigh: &[NodeId],
    v: NodeId,
    fanout: usize,
    key: RngKey,
    scratch: &mut Vec<usize>,
    chunk: &mut [NodeId],
) -> u32 {
    let d = neigh.len();
    if d <= fanout {
        chunk[..d].copy_from_slice(neigh);
        d as u32
    } else {
        let mut s = key.stream(v as u64);
        s.sample_distinct(d, fanout, scratch);
        for (slot, &pos) in chunk.iter_mut().zip(scratch.iter()) {
            *slot = neigh[pos];
        }
        fanout as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::erdos_renyi;

    fn toy() -> CscGraph {
        // 0 <- {1,2,3}; 1 <- {2}; 2 <- {}; 3 <- {0}
        CscGraph::new(vec![0, 3, 4, 4, 5], vec![1, 2, 3, 2, 0]).unwrap()
    }

    #[test]
    fn low_degree_takes_all_neighbors() {
        let g = toy();
        let mut ws = SamplerWorkspace::new();
        let mfg = sample_level_fused(&g, &[0, 1, 2], 5, RngKey::new(1), &mut ws);
        mfg.validate(&[0, 1, 2], 5).unwrap();
        assert_eq!(mfg.degree(0), 3);
        assert_eq!(mfg.degree(1), 1);
        assert_eq!(mfg.degree(2), 0);
        // Seed prefix + newly seen {3} (1 and 2 are already seeds).
        assert_eq!(mfg.src_nodes, vec![0, 1, 2, 3]);
        // Neighbor order preserved when taking all.
        let n0: Vec<u32> = mfg.neighbors(0).to_vec();
        assert_eq!(n0, vec![1, 2, 3]);
    }

    #[test]
    fn high_degree_subsamples_without_replacement() {
        let g = erdos_renyi(200, 30, RngKey::new(2));
        let mut ws = SamplerWorkspace::new();
        let seeds: Vec<NodeId> = (0..50).collect();
        let mfg = sample_level_fused(&g, &seeds, 10, RngKey::new(3), &mut ws);
        mfg.validate(&seeds, 10).unwrap();
        for i in 0..50 {
            assert_eq!(mfg.degree(i), g.degree(seeds[i]).min(10));
            // Without replacement: positions distinct (graph may hold
            // duplicate edges, so compare positions via sorted dedup of
            // the *sampled global ids* against multiset membership).
            let picked: Vec<NodeId> =
                mfg.neighbors(i).iter().map(|&p| mfg.src_nodes[p as usize]).collect();
            for &s in &picked {
                assert!(g.neighbors(seeds[i]).contains(&s));
            }
        }
    }

    #[test]
    fn deterministic_in_key() {
        let g = erdos_renyi(300, 20, RngKey::new(4));
        let seeds: Vec<NodeId> = (0..100).step_by(3).collect();
        let mut ws = SamplerWorkspace::new();
        let a = sample_level_fused(&g, &seeds, 5, RngKey::new(5), &mut ws);
        let b = sample_level_fused(&g, &seeds, 5, RngKey::new(5), &mut ws);
        assert_eq!(a, b);
        let c = sample_level_fused(&g, &seeds, 5, RngKey::new(6), &mut ws);
        assert_ne!(a, c);
    }

    #[test]
    fn workspace_reuse_is_clean_across_graphs() {
        let g1 = erdos_renyi(100, 10, RngKey::new(7));
        let g2 = erdos_renyi(50, 5, RngKey::new(8));
        let mut ws = SamplerWorkspace::new();
        let seeds1: Vec<NodeId> = (0..20).collect();
        let seeds2: Vec<NodeId> = (0..10).collect();
        sample_level_fused(&g1, &seeds1, 4, RngKey::new(9), &mut ws);
        let m = sample_level_fused(&g2, &seeds2, 4, RngKey::new(9), &mut ws);
        m.validate(&seeds2, 4).unwrap();
        assert!(m.src_nodes.iter().all(|&v| (v as usize) < 50));
    }
}
