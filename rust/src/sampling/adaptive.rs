//! Adaptive fanout schedules — the paper's §5 future-work extension:
//! "use an adaptive fanout schedule to dynamically adjust the sampling
//! fanouts based on the training dynamics."
//!
//! A schedule maps (epoch, observed loss) → per-level fanouts, always
//! bounded by the AOT variant's compiled fanouts (shapes are static, so
//! adaptation can only *shrink* the sample; the padding masks absorb the
//! difference). Shrinking early epochs' fanouts cuts sampling + feature
//! traffic when gradients are noisy anyway; the ablation bench
//! (`report fanout-ablation`) measures the trade-off.

/// A fanout schedule. Fanouts are top level first, like everywhere else.
pub trait FanoutSchedule: Send + Sync {
    /// Fanouts to use for `epoch` given the smoothed loss (`None` before
    /// any loss is observed). Must be elementwise ≤ `max_fanouts`.
    fn fanouts(&self, epoch: usize, smoothed_loss: Option<f32>) -> Vec<usize>;
    fn max_fanouts(&self) -> &[usize];
}

/// The paper's default: constant fanouts.
#[derive(Debug, Clone)]
pub struct FixedSchedule {
    pub fanouts: Vec<usize>,
}

impl FanoutSchedule for FixedSchedule {
    fn fanouts(&self, _epoch: usize, _loss: Option<f32>) -> Vec<usize> {
        self.fanouts.clone()
    }

    fn max_fanouts(&self) -> &[usize] {
        &self.fanouts
    }
}

/// Linear ramp: start at `start_frac` of the full fanout and reach 100%
/// at `ramp_epochs`. A simple, deterministic instance of the paper's
/// adaptive-fanout idea.
#[derive(Debug, Clone)]
pub struct RampSchedule {
    pub max: Vec<usize>,
    pub start_frac: f32,
    pub ramp_epochs: usize,
}

impl FanoutSchedule for RampSchedule {
    fn fanouts(&self, epoch: usize, _loss: Option<f32>) -> Vec<usize> {
        let t = if self.ramp_epochs == 0 {
            1.0
        } else {
            (epoch as f32 / self.ramp_epochs as f32).min(1.0)
        };
        let frac = self.start_frac + (1.0 - self.start_frac) * t;
        self.max
            .iter()
            .map(|&f| ((f as f32 * frac).round() as usize).clamp(1, f))
            .collect()
    }

    fn max_fanouts(&self) -> &[usize] {
        &self.max
    }
}

/// Loss-plateau escalation: keep fanouts at `start_frac` until the
/// smoothed loss improves by less than `tol` between epochs, then step up
/// by `step_frac` (sticky). Mirrors "adjust based on training dynamics".
#[derive(Debug)]
pub struct PlateauSchedule {
    pub max: Vec<usize>,
    pub start_frac: f32,
    pub step_frac: f32,
    pub tol: f32,
    state: std::sync::Mutex<PlateauState>,
}

#[derive(Debug, Default)]
struct PlateauState {
    frac: f32,
    last_loss: Option<f32>,
}

impl PlateauSchedule {
    pub fn new(max: Vec<usize>, start_frac: f32, step_frac: f32, tol: f32) -> Self {
        Self {
            max,
            start_frac,
            step_frac,
            tol,
            state: std::sync::Mutex::new(PlateauState { frac: start_frac, last_loss: None }),
        }
    }
}

impl FanoutSchedule for PlateauSchedule {
    fn fanouts(&self, _epoch: usize, smoothed_loss: Option<f32>) -> Vec<usize> {
        let mut st = self.state.lock().unwrap();
        if st.frac == 0.0 {
            st.frac = self.start_frac;
        }
        if let (Some(prev), Some(cur)) = (st.last_loss, smoothed_loss) {
            if prev - cur < self.tol {
                st.frac = (st.frac + self.step_frac).min(1.0);
            }
        }
        if smoothed_loss.is_some() {
            st.last_loss = smoothed_loss;
        }
        let frac = st.frac;
        self.max
            .iter()
            .map(|&f| ((f as f32 * frac).round() as usize).clamp(1, f))
            .collect()
    }

    fn max_fanouts(&self) -> &[usize] {
        &self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_is_constant() {
        let s = FixedSchedule { fanouts: vec![15, 10, 5] };
        assert_eq!(s.fanouts(0, None), vec![15, 10, 5]);
        assert_eq!(s.fanouts(99, Some(0.1)), vec![15, 10, 5]);
    }

    #[test]
    fn ramp_reaches_max_and_stays() {
        let s = RampSchedule { max: vec![10, 10], start_frac: 0.3, ramp_epochs: 10 };
        assert_eq!(s.fanouts(0, None), vec![3, 3]);
        assert_eq!(s.fanouts(10, None), vec![10, 10]);
        assert_eq!(s.fanouts(50, None), vec![10, 10]);
        // Monotone non-decreasing.
        let mut prev = 0;
        for e in 0..12 {
            let f = s.fanouts(e, None)[0];
            assert!(f >= prev);
            prev = f;
        }
    }

    #[test]
    fn ramp_never_exceeds_or_hits_zero() {
        let s = RampSchedule { max: vec![3], start_frac: 0.0, ramp_epochs: 5 };
        for e in 0..8 {
            let f = s.fanouts(e, None)[0];
            assert!((1..=3).contains(&f));
        }
    }

    #[test]
    fn plateau_escalates_on_stall() {
        let s = PlateauSchedule::new(vec![10], 0.5, 0.25, 0.01);
        assert_eq!(s.fanouts(0, Some(1.0)), vec![5]);
        // Loss improving fast: stays.
        assert_eq!(s.fanouts(1, Some(0.5)), vec![5]);
        // Stalled: escalates.
        assert_eq!(s.fanouts(2, Some(0.499)), vec![8]);
        assert_eq!(s.fanouts(3, Some(0.498)), vec![10]);
        // Capped at max.
        assert_eq!(s.fanouts(4, Some(0.497)), vec![10]);
    }
}
