//! Message Flow Graphs — the bipartite per-layer graphs produced by
//! sampling (paper §3.1): `G^l = (V^{l-1}, V^l; E^{l-1})` with edges from
//! source nodes (level l-1) to target nodes (level l), stored in CSC so
//! GNN aggregation fetches a node's sampled neighbors in O(1).

use anyhow::{ensure, Result};

use crate::graph::NodeId;

/// One sampled bipartite level in CSC form with *relabeled* (compacted)
/// indices.
///
/// Convention (DGL's, which the L2 model relies on): the destination
/// nodes are the **prefix** of `src_nodes`, i.e. `src_nodes[i]` for
/// `i < n_dst` is destination `i` itself. This is the one deliberate
/// deviation from the paper's Algorithm 1 (which builds `V^{l-1}` from
/// sampled sources only): GraphSAGE's self path needs `h_dst` at every
/// level, so the relabel map is seeded with the destinations first.
#[derive(Debug, Clone, PartialEq)]
pub struct Mfg {
    /// `R` — row pointers over destinations, `len == n_dst + 1`.
    pub indptr: Vec<usize>,
    /// `C` — compacted source positions (into `src_nodes`), `len == nnz`.
    pub indices: Vec<u32>,
    /// Global ids of the level-(l-1) node array; `[..n_dst]` mirrors the
    /// destination (seed) list.
    pub src_nodes: Vec<NodeId>,
    /// Number of destination (seed) nodes at this level.
    pub n_dst: usize,
}

impl Mfg {
    pub fn num_edges(&self) -> usize {
        self.indices.len()
    }

    pub fn num_src(&self) -> usize {
        self.src_nodes.len()
    }

    /// Sampled in-neighbor count of destination `i`.
    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// Compacted neighbor positions of destination `i`.
    #[inline]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.indices[self.indptr[i]..self.indptr[i + 1]]
    }

    /// Check every structural invariant; used by tests and debug builds.
    pub fn validate(&self, seeds: &[NodeId], fanout: usize) -> Result<()> {
        ensure!(self.n_dst == seeds.len(), "n_dst != |seeds|");
        ensure!(self.indptr.len() == self.n_dst + 1, "indptr length");
        ensure!(self.indptr[0] == 0, "indptr[0]");
        ensure!(self.indptr.windows(2).all(|w| w[0] <= w[1]), "indptr monotone");
        ensure!(*self.indptr.last().unwrap() == self.indices.len(), "nnz");
        ensure!(self.src_nodes.len() >= self.n_dst, "src shorter than dst");
        ensure!(&self.src_nodes[..self.n_dst] == seeds, "dst prefix != seeds");
        for i in 0..self.n_dst {
            ensure!(self.degree(i) <= fanout, "degree exceeds fanout");
        }
        ensure!(
            self.indices.iter().all(|&p| (p as usize) < self.src_nodes.len()),
            "compacted index out of range"
        );
        // src_nodes must be unique (it is a relabel table).
        let mut seen = std::collections::HashSet::with_capacity(self.src_nodes.len());
        ensure!(self.src_nodes.iter().all(|&v| seen.insert(v)), "duplicate src node");
        Ok(())
    }
}

/// Reusable scratch space shared across sampling calls so the hot loop
/// allocates nothing proportional to the *full* graph per call.
///
/// `map` is the paper's `M` vector (global node id → compacted position)
/// with epoch stamping instead of a `fill(-1)` per level: an entry is
/// valid only if its stamp half matches `stamp`, so resetting is O(1).
/// Stamp and index are packed into one u64 (`stamp << 32 | idx`) so a
/// lookup touches one cache line instead of two (§Perf).
#[derive(Debug, Default)]
pub struct SamplerWorkspace {
    pub(crate) map: Vec<u64>,
    pub(crate) stamp: u32,
    /// Strided sample buffer for the fused kernel's parallel phase.
    pub(crate) samples: Vec<NodeId>,
    /// Per-seed sample counts (fused) / scratch degrees (baseline).
    pub(crate) counts: Vec<u32>,
    /// Baseline scratch: materialized COO src/dst arrays.
    pub(crate) coo_src: Vec<NodeId>,
    pub(crate) coo_dst: Vec<NodeId>,
    // --- Distributed-sampler scratch (`dist::sampling::sample_level`),
    // hoisted here so per-level state is reused across levels and
    // minibatches instead of reallocated every call.
    /// Seed indices whose adjacency was not materialized this level.
    pub(crate) miss_slots: Vec<u32>,
    /// Per-owner response cursor for the decode pass.
    pub(crate) owner_cursor: Vec<usize>,
    /// Recycled per-owner payload vectors: outbox/reply vectors are moved
    /// into the fabric each round, but the vectors *received* from peers
    /// come back here, so the pool reaches a steady state of ~2·world
    /// buffers after the first exchanged level.
    pub(crate) vec_pool: Vec<Vec<NodeId>>,
    /// Serve-side Floyd-sampling scratch and fanout-sized sample chunk.
    pub(crate) serve_scratch: Vec<usize>,
    pub(crate) serve_chunk: Vec<NodeId>,
    // --- Bulk-wire scratch (`dist::sampling`, `SamplingWire::Bulk`).
    /// Per-owner request slot lists, filled at miss-queue time in the
    /// same seed order as the outboxes — the decode's map from the k-th
    /// count word of owner p's columnar response back to a seed slot.
    pub(crate) owner_slots: Vec<Vec<u32>>,
    /// Prefix-sum offsets of the current blob (serve: segment fill
    /// bounds; one entry per request plus the leading 0).
    pub(crate) offsets: Vec<usize>,
    /// Decode scatter triples `(seed slot, blob offset, length)` for the
    /// parallel strided copy into `samples`.
    pub(crate) scatter: Vec<(u32, u32, u32)>,
    /// Per-owner cursors for the decode's cache-insert pass: next count
    /// word and next blob word (`owner_cursor` above doubles as the
    /// row-section cursor).
    pub(crate) owner_entry: Vec<usize>,
    pub(crate) owner_blob: Vec<usize>,
}

impl SamplerWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ensure the relabel map covers `num_nodes` and start a fresh epoch.
    /// (Public for benches.)
    pub fn begin(&mut self, num_nodes: usize) {
        if self.map.len() < num_nodes {
            self.map.resize(num_nodes, 0);
        }
        // Stamp 0 is reserved for "never touched"; on wrap, hard-reset.
        self.stamp = self.stamp.wrapping_add(1);
        if self.stamp == 0 {
            self.map.fill(0);
            self.stamp = 1;
        }
    }

    /// Map `v` to its compacted position, appending to `order` on first
    /// sight. The sequential heart of Algorithm 1's second loop.
    #[inline]
    /// (Public for benches.)
    pub fn intern(&mut self, v: NodeId, order: &mut Vec<NodeId>) -> u32 {
        let vi = v as usize;
        let entry = self.map[vi];
        if (entry >> 32) as u32 == self.stamp {
            entry as u32
        } else {
            let idx = order.len() as u32;
            order.push(v);
            self.map[vi] = ((self.stamp as u64) << 32) | idx as u64;
            idx
        }
    }

    /// Compacted position of an already-interned node (panics in debug if
    /// `v` was not interned this epoch). Used by the baseline converter.
    #[inline]
    pub(crate) fn position(&self, v: NodeId) -> u32 {
        let entry = self.map[v as usize];
        debug_assert_eq!((entry >> 32) as u32, self.stamp, "node {v} not interned");
        entry as u32
    }

    /// Algorithm 1's second loop: build the relabeled CSC block straight
    /// from the strided sample buffer (`samples`/`counts` filled for
    /// `seeds.len()` rows of stride `fanout`, under the current `begin`
    /// epoch). Shared by the single-machine fused kernel and the
    /// distributed vanilla sampler, which is what makes their outputs
    /// bit-identical by construction.
    pub(crate) fn assemble_fused(&mut self, seeds: &[NodeId], fanout: usize) -> Mfg {
        let n = seeds.len();
        let mut indptr = Vec::with_capacity(n + 1);
        indptr.push(0usize);
        let mut total = 0usize;
        for i in 0..n {
            total += self.counts[i] as usize;
            indptr.push(total);
        }
        let mut src_nodes = Vec::with_capacity(n + total);
        for &v in seeds {
            let pos = self.intern(v, &mut src_nodes);
            debug_assert_eq!(pos as usize, src_nodes.len() - 1, "seeds must be unique");
        }
        let mut indices = Vec::with_capacity(total);
        for i in 0..n {
            let base = i * fanout;
            for j in 0..self.counts[i] as usize {
                indices.push(self.intern(self.samples[base + j], &mut src_nodes));
            }
        }
        Mfg { indptr, indices, src_nodes, n_dst: n }
    }

    /// The DGL-style two-step assembly over the same strided sample
    /// buffer: materialize a COO edge list, then relabel and convert
    /// COO → CSC with a counting + scatter pass. Deliberately keeps the
    /// baseline's redundant memory traffic (the cost Fig 5 measures);
    /// the output is bit-identical to [`Self::assemble_fused`].
    pub(crate) fn assemble_baseline(&mut self, seeds: &[NodeId], fanout: usize) -> Mfg {
        let n = seeds.len();
        // Step 1b: materialize the COO graph (the extra memory round-trip
        // the fused kernel avoids).
        self.coo_src.clear();
        self.coo_dst.clear();
        for i in 0..n {
            let base = i * fanout;
            for j in 0..self.counts[i] as usize {
                self.coo_src.push(self.samples[base + j]);
                self.coo_dst.push(seeds[i]);
            }
        }
        let nnz = self.coo_src.len();

        // Step 2a (to_block): compact/relabel the COO endpoints. Seeds
        // first (dst prefix convention), then sources in edge order.
        let mut src_nodes = Vec::with_capacity(n + nnz);
        for &v in seeds {
            let pos = self.intern(v, &mut src_nodes);
            debug_assert_eq!(pos as usize, src_nodes.len() - 1, "seeds must be unique");
        }
        let mut rel_src: Vec<u32> = Vec::with_capacity(nnz);
        for e in 0..nnz {
            let p = self.intern(self.coo_src[e], &mut src_nodes);
            rel_src.push(p);
        }

        // Step 2b: COO → CSC conversion — degrees re-computed by a
        // counting pass, then a scatter with a cursor array. Edges were
        // emitted seed-major, so per-row order is preserved.
        let mut indptr = vec![0usize; n + 1];
        for e in 0..nnz {
            let row = self.position(self.coo_dst[e]) as usize;
            indptr[row + 1] += 1;
        }
        for i in 0..n {
            indptr[i + 1] += indptr[i];
        }
        let mut cursor = indptr.clone();
        let mut indices = vec![0u32; nnz];
        for e in 0..nnz {
            let row = self.position(self.coo_dst[e]) as usize;
            indices[cursor[row]] = rel_src[e];
            cursor[row] += 1;
        }

        Mfg { indptr, indices, src_nodes, n_dst: n }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workspace_epoch_reset_is_cheap_and_correct() {
        let mut ws = SamplerWorkspace::new();
        ws.begin(10);
        let mut order = Vec::new();
        assert_eq!(ws.intern(3, &mut order), 0);
        assert_eq!(ws.intern(7, &mut order), 1);
        assert_eq!(ws.intern(3, &mut order), 0);
        assert_eq!(order, vec![3, 7]);

        ws.begin(10); // new epoch invalidates everything
        let mut order2 = Vec::new();
        assert_eq!(ws.intern(7, &mut order2), 0);
        assert_eq!(order2, vec![7]);
    }

    #[test]
    fn workspace_grows_on_demand() {
        let mut ws = SamplerWorkspace::new();
        ws.begin(4);
        let mut order = Vec::new();
        ws.intern(3, &mut order);
        ws.begin(100);
        ws.intern(99, &mut order);
    }

    #[test]
    fn mfg_validate_catches_corruption() {
        let mfg = Mfg {
            indptr: vec![0, 1, 2],
            indices: vec![0, 2],
            src_nodes: vec![5, 6, 9],
            n_dst: 2,
        };
        assert!(mfg.validate(&[5, 6], 1).is_ok());
        assert!(mfg.validate(&[5, 7], 1).is_err()); // wrong seeds
        assert!(mfg.validate(&[5, 6], 0).is_err()); // fanout exceeded
        let mut bad = mfg.clone();
        bad.indices[0] = 9;
        assert!(bad.validate(&[5, 6], 1).is_err()); // index out of range
        let mut dup = mfg;
        dup.src_nodes = vec![5, 6, 5];
        assert!(dup.validate(&[5, 6], 1).is_err()); // duplicate src
    }
}
