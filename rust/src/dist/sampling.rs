//! Distributed minibatch sampling over the replication-budget spectrum
//! (paper §3.3, generalized) — bit-equal to single-machine
//! [`sample_mfgs`] by construction at **every** budget point, with or
//! without the dynamic remote-adjacency cache.
//!
//! One unified path replaces the old vanilla/hybrid split: every level,
//! each worker samples every frontier node whose adjacency it holds —
//! local rows, whatever halo its [`ReplicationPolicy`] bought, plus any
//! row resident in its [`TopologyView`] cache overlay — and batches only
//! the *misses* into a [`RoundKind::SampleRequest`] /
//! [`RoundKind::SampleResponse`] pair. Before paying that pair, the
//! ranks vote with one uncharged control-plane reduce
//! ([`Comm::all_zero_u64`], built on `all_reduce_min_u64`): when every
//! rank has zero misses the exchange is skipped entirely. Sampling
//! rounds per minibatch are therefore **data-dependent**, anywhere in
//! `0..=2(L−1)` — `Counters` report what actually happened, not what a
//! scheme constant assumes. Budget 0 with no cache reproduces the
//! paper's vanilla counts; full replication reproduces hybrid's zero
//! (the vote is short-circuited without communication when the *policy*
//! is full replication, which is uniform across ranks).
//!
//! **Adjacency caching on the wire.** When the cache is enabled (a
//! uniform, SPMD-contract setting, like the policy), each non-empty
//! request is prefixed with the requester's admission threshold
//! ([`TopologyView::cache_admission_limit`], derived from its remaining
//! cache bytes). The owner serves every miss as before and, for nodes
//! whose degree falls under the threshold, appends the **full**
//! adjacency row; the decode inserts it into the requester's overlay.
//! When such a node's degree also clears the fanout (so the sample *is*
//! the full row), the sampled ids are **elided** — one `ELIDED` marker
//! plus the row replaces both copies, and the decode reuses the row as
//! the sampled set (see the batching regression test
//! `cache_mode_elides_duplicate_ids_when_degree_clears_fanout`).
//! Future levels and future minibatches then sample those nodes
//! locally, so measured `SampleRequest` rounds/bytes *decay over
//! epochs* on skewed workloads (report id `cache-decay`). With the
//! cache disabled the wire format is byte-identical to the uncached
//! runtime. Per-rank cache divergence is safe by the same argument as
//! per-rank halo coverage: it only changes each rank's miss count
//! feeding the uniform `all_zero_u64` vote.
//!
//! **Wire formats.** The miss exchange speaks one of two response
//! encodings ([`SamplingWire`], a uniform SPMD-contract setting like the
//! policy): the historical *scalar* stream — per miss, an interleaved
//! `cnt, ids…` run plus the cache suffix above — or the default *bulk*
//! columnar layout, where each owner→requester payload is three
//! sections: a `counts[]` block (one flag-bearing word per miss — the
//! validated header), an `ids[]` blob (all sampled ids back to back,
//! segment offsets recovered by prefix-summing the counts), and a
//! trailing cache-row section. The bulk serve is a two-phase kernel —
//! serial count/offset pass, then a parallel ragged sweep
//! ([`par::par_ragged_chunks`]) filling the blob with the same
//! `sample_node` calls the local path makes — and the bulk decode is one
//! header validation, a prefix sum, and parallel strided copies into the
//! sample buffer ([`par::par_scatter_rows`]), replacing the scalar
//! word-at-a-time cursor walk. Both wires carry bit-identical
//! information (requests, rounds, and sampled MFGs are invariant across
//! the choice; cache inserts replay in the same seed order); response
//! bytes are equal with the cache off and strictly smaller in bulk for
//! every `NO_ROW`/`ELIDED` entry with it on. See DESIGN.md §"Bulk
//! sampling kernel" for the frame diagram.
//!
//! Equality with the single-machine sampler holds bit-for-bit because
//! neighbor choice depends only on `(level_key, node, its neighbor
//! list)` — `sample_node` keyed by the counter-based RNG — and any
//! materialized row (local, replicated halo, or cached) carries exactly
//! the full graph's neighbor list, as does the owner serving a miss
//! remotely. Assembly then replays the same relabel pass over the same
//! per-seed chunks in the same order.
//!
//! **Remote-slot ordering invariant:** within one owner, requests are
//! queued in seed order, owners serve them in arrival order, and the
//! decode walks the recorded miss slots in order advancing one cursor
//! per owner — so the k-th miss sent to partition `p` is answered by
//! the k-th count-prefixed run in `p`'s response. The decode asserts
//! that every response is consumed exactly (see `sample_level`), and
//! the `remote_responses_decode_in_seed_order` regression test drives
//! the interleaved multi-owner case.
//!
//! [`sample_mfgs`]: crate::sampling::sample_mfgs
//! [`ReplicationPolicy`]: crate::partition::ReplicationPolicy

use crate::graph::NodeId;
use crate::partition::{TopologyView, WorkerShard};
use crate::sampling::fused::sample_node;
use crate::sampling::pipeline::level_key;
use crate::sampling::rng::RngKey;
use crate::sampling::{KernelKind, Mfg, SamplerWorkspace};
use crate::util::par;

use super::comm::{Comm, CommError, RoundKind};

/// "No adjacency row appended" marker in a cache-mode response.
const NO_ROW: NodeId = NodeId::MAX;

/// Cache-mode response marker in the *count* position: the sampled ids
/// are elided because the appended full adjacency row IS the sample
/// (`deg <= fanout` means `sample_node` took every neighbor in row
/// order). The decode reads the row once, using it both as the sampled
/// set and as the cache insert — cutting `2 + 2·deg` response words to
/// `2 + deg` for exactly the rows the cache wants most (low-degree
/// ones). Distinct from any real count (counts never exceed the fanout)
/// and only ever emitted while the requester's admission limit is
/// non-zero, so the uncached wire shape is untouched.
const ELIDED: NodeId = NodeId::MAX - 1;

/// Bulk-wire count-word flag: this miss's full adjacency row follows in
/// the trailing row section (`deg, row[deg]`, in count-word order) — the
/// bulk twin of the scalar row suffix, minus the per-miss `NO_ROW`
/// marker (absence of the flag already says it).
const ROW_FLAG: NodeId = 1 << 31;

/// Bulk-wire count-word flag: the blob segment IS the full adjacency row
/// (the bulk twin of [`ELIDED`]). The count field holds `deg`
/// (`deg <= fanout`), and the decode uses the segment both as the
/// sampled set and as the cache insert.
const ELIDED_FLAG: NodeId = 1 << 30;

/// Low bits of a bulk count word: the sample count (or elided degree).
/// Counts never exceed the fanout, so reserving the two flag bits is
/// free; flags are only legal while the requester's admission limit is
/// non-zero, keeping the uncached bulk wire flag-less.
const COUNT_MASK: NodeId = ELIDED_FLAG - 1;

/// Wire format of the per-level miss exchange — how one owner's
/// [`RoundKind::SampleResponse`] payload to one requester is laid out.
/// Uniform across ranks (an SPMD-contract setting, like the replication
/// policy and the cache capacity). Both formats carry bit-identical
/// information: sampled MFGs, measured rounds, request bytes, and the
/// cache-insert order are invariant across the choice — only response
/// bytes differ, and bulk is never larger (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SamplingWire {
    /// Interleaved run-length stream: per miss, `cnt, ids[cnt]` plus the
    /// cache-mode `NO_ROW`-marker / row / `ELIDED` suffix. Served by a
    /// serial per-request push loop, decoded by a per-word cursor walk.
    Scalar,
    /// Columnar sections: `counts[]` block, `ids[]` blob, cache-row
    /// section. Served by a two-phase bulk kernel (serial prefix sum,
    /// parallel blob fill), decoded by one header validation plus
    /// parallel strided scatters.
    #[default]
    Bulk,
}

impl std::fmt::Display for SamplingWire {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SamplingWire::Scalar => "scalar",
            SamplingWire::Bulk => "bulk",
        })
    }
}

/// Checked read of one word of rank `src`'s response. Remote data is
/// untrusted: a short buffer is a malformed round from that peer, reported
/// as a `CommError` instead of an index panic on this rank.
fn read_word(resp: &[NodeId], cur: usize, src: usize) -> Result<NodeId, CommError> {
    resp.get(cur).copied().ok_or_else(|| CommError::Malformed {
        src,
        detail: format!("sampling response truncated at word {cur} of {}", resp.len()),
    })
}

/// Checked read of `len` contiguous words of rank `src`'s response.
fn read_run<'a>(
    resp: &'a [NodeId],
    cur: usize,
    len: usize,
    src: usize,
) -> Result<&'a [NodeId], CommError> {
    resp.get(cur..cur + len).ok_or_else(|| CommError::Malformed {
        src,
        detail: format!(
            "sampling response truncated: words {cur}..{} of {}",
            cur + len,
            resp.len()
        ),
    })
}

/// Sample all levels of one minibatch against a worker shard. Same
/// contract as single-machine [`sample_mfgs`] (fanouts top level first,
/// MFGs returned bottom first) plus the SPMD one: every rank in the
/// world must call this collectively, with shards built from the same
/// [`ReplicationPolicy`] and views configured with the same cache
/// capacity/policy. Seeds are normally the worker's own labeled nodes
/// (then level 0 costs no exchange), but any frontier node — seed
/// included — whose adjacency is absent is resolved through the miss
/// rounds.
///
/// `view` is this worker's topology view — typically
/// `shard.topology.clone()` (three `Arc` bumps), optionally with
/// [`TopologyView::enable_cache`] called on it. It is mutable because
/// the response decode feeds admissible remote rows into the cache
/// overlay; keep one view alive across minibatches so the cache pays
/// off.
///
/// Fabric failures (a peer exiting mid-collective, transport I/O
/// errors) surface as `Err(CommError)` — see [`super::comm::CommError`]
/// — rather than a hang or a panic, on every transport.
///
/// [`sample_mfgs`]: crate::sampling::sample_mfgs
/// [`ReplicationPolicy`]: crate::partition::ReplicationPolicy
#[allow(clippy::too_many_arguments)]
pub fn sample_mfgs_distributed(
    comm: &mut Comm,
    shard: &WorkerShard,
    view: &mut TopologyView,
    seeds: &[NodeId],
    fanouts: &[usize],
    key: RngKey,
    ws: &mut SamplerWorkspace,
    kind: KernelKind,
) -> Result<Vec<Mfg>, CommError> {
    sample_mfgs_distributed_wire(
        comm,
        shard,
        view,
        seeds,
        fanouts,
        key,
        ws,
        kind,
        SamplingWire::default(),
    )
}

/// [`sample_mfgs_distributed`] with an explicit miss-exchange wire
/// format — the `--sampling-wire` escape hatch. `wire` is part of the
/// SPMD contract: every rank must pass the same value (like the policy
/// and the cache capacity), or the columnar and run-length codecs
/// disagree and the round fails as [`CommError::Malformed`].
#[allow(clippy::too_many_arguments)]
pub fn sample_mfgs_distributed_wire(
    comm: &mut Comm,
    shard: &WorkerShard,
    view: &mut TopologyView,
    seeds: &[NodeId],
    fanouts: &[usize],
    key: RngKey,
    ws: &mut SamplerWorkspace,
    kind: KernelKind,
    wire: SamplingWire,
) -> Result<Vec<Mfg>, CommError> {
    debug_assert_eq!(
        view.local_rows(),
        shard.topology.local_rows(),
        "view does not belong to this shard"
    );
    let mut out: Vec<Mfg> = Vec::with_capacity(fanouts.len());
    for (li, &f) in fanouts.iter().enumerate() {
        let mfg = {
            let cur: &[NodeId] = match out.last() {
                None => seeds,
                Some(prev) => &prev.src_nodes,
            };
            sample_level(comm, shard, view, cur, f, level_key(key, li), ws, kind, wire)?
        };
        out.push(mfg);
    }
    out.reverse();
    Ok(out)
}

/// One level: frontier nodes with materialized adjacency (static or
/// cached) sampled in place; misses resolved through one request + one
/// response round — skipped when a control-plane vote agrees no rank has
/// any — then assembled exactly like the corresponding single-machine
/// kernel. Per-level buffers (outboxes, cursors, serve scratch) live in
/// the workspace and are reused across levels and minibatches.
#[allow(clippy::too_many_arguments)]
fn sample_level(
    comm: &mut Comm,
    shard: &WorkerShard,
    view: &mut TopologyView,
    seeds: &[NodeId],
    fanout: usize,
    key: RngKey,
    ws: &mut SamplerWorkspace,
    kind: KernelKind,
    wire: SamplingWire,
) -> Result<Mfg, CommError> {
    assert!(fanout >= 1, "fanout must be >= 1");
    assert!(
        (fanout as u64) <= COUNT_MASK as u64,
        "fanout must fit the bulk count encoding"
    );
    let n = seeds.len();
    let world = comm.world();
    ws.begin(shard.book.num_nodes());
    ws.samples.resize(n * fanout, 0);
    ws.counts.resize(n, 0);

    // ---- Queue misses first (order within an owner follows seed order —
    // the remote-slot ordering invariant the decode below asserts). Under
    // a full-replication policy no node can miss, so the paper's headline
    // hybrid arm skips the scan and the per-owner outboxes entirely — its
    // hot path stays the pure local sampling loop below. When the cache
    // is enabled, each non-empty outbox leads with this rank's admission
    // threshold so owners know which rows are worth shipping whole.
    let full = shard.policy.is_full();
    let cache_on = view.cache_enabled();
    // This rank's admission threshold, sent once per level as the prefix
    // of every non-empty outbox. A limit of 0 (nothing admissible — e.g.
    // a filled StaticDegree cache) tells owners to skip the per-miss
    // row/marker suffix entirely, so a saturated cache stops paying
    // response-side overhead; the decode below mirrors the same rule.
    let limit = if full { 0 } else { view.cache_admission_limit() };
    let bulk = wire == SamplingWire::Bulk;
    ws.miss_slots.clear();
    if bulk {
        // The bulk decode consumes each owner's columnar response as a
        // unit, so record which seed slots went to which owner (in the
        // same order the outboxes queue them).
        if ws.owner_slots.len() < world {
            ws.owner_slots.resize_with(world, Vec::new);
        }
        for slots in &mut ws.owner_slots[..world] {
            slots.clear();
        }
    }
    let mut outboxes: Vec<Vec<NodeId>> = Vec::new();
    if !full {
        outboxes.reserve(world);
        for _ in 0..world {
            let mut buf = ws.vec_pool.pop().unwrap_or_default();
            buf.clear();
            outboxes.push(buf);
        }
        for (i, &v) in seeds.iter().enumerate() {
            if view.try_neighbors(v).is_none() {
                let p = shard.book.part_of(v);
                debug_assert_ne!(p, shard.part, "own nodes always have a materialized row");
                if cache_on && outboxes[p].is_empty() {
                    outboxes[p].push(limit);
                }
                outboxes[p].push(v);
                if bulk {
                    ws.owner_slots[p].push(i as u32);
                }
                ws.miss_slots.push(i as u32);
            }
        }
    }
    let misses = ws.miss_slots.len() as u64;

    // ---- Covered seeds: sample into the strided buffer with the same
    // parallel per-seed loop as the single-machine kernels, so budget
    // comparisons isolate communication cost rather than a
    // serial-sampling artifact. Miss slots get a placeholder count and
    // are filled by the response decode below. (Cache hits are read
    // through a shared reference; the reference bits are atomic.)
    {
        let topo: &TopologyView = view;
        par::par_zip_chunks(
            &mut ws.samples,
            &mut ws.counts,
            fanout,
            Vec::new,
            |scratch, i, chunk, cnt| {
                let v = seeds[i];
                *cnt = match topo.try_neighbors(v) {
                    Some(neigh) => sample_node(neigh, v, fanout, key, scratch, chunk),
                    None => 0,
                };
            },
        );
    }

    // ---- The round-skip vote + (when needed) the level's two data
    // rounds. Under a full-replication *policy* no rank can miss, so the
    // vote itself is skipped without communication — keyed off the
    // policy (uniform across ranks), never off per-rank view coverage,
    // which a finite budget or a divergent cache can make differ.
    // Otherwise the vote is one uncharged control-plane reduce; the data
    // rounds run only when some rank actually misses — and then *every*
    // rank participates, empty payloads included: rounds are a property
    // of the fabric, not of one worker.
    let need_exchange = !full && !comm.all_zero_u64(misses)?;
    if need_exchange {
        let granted = comm.exchange(RoundKind::SampleRequest, outboxes)?;
        let replies = match wire {
            SamplingWire::Scalar => serve_scalar(shard, view, &granted, fanout, key, cache_on, ws)?,
            SamplingWire::Bulk => serve_bulk(shard, view, &granted, fanout, key, cache_on, ws)?,
        };
        let responses = comm.exchange(RoundKind::SampleResponse, replies)?;
        match wire {
            SamplingWire::Scalar => {
                decode_scalar(shard, view, seeds, &responses, fanout, limit, ws)?
            }
            SamplingWire::Bulk => decode_bulk(shard, view, seeds, &responses, fanout, limit, ws)?,
        }

        // Recycle the buffers that came back from the fabric (our own
        // outboxes/replies were moved to their receivers).
        for mut buf in granted.into_iter().chain(responses) {
            buf.clear();
            ws.vec_pool.push(buf);
        }
    } else {
        for mut buf in outboxes {
            buf.clear();
            ws.vec_pool.push(buf);
        }
    }

    // ---- Assembly: replay the chosen kernel's relabel pass over the
    // filled buffer. Both produce bit-identical MFGs (the baseline arm
    // just pays the COO round-trip, as it does on a single machine).
    Ok(match kind {
        KernelKind::Fused => ws.assemble_fused(seeds, fanout),
        KernelKind::Baseline => ws.assemble_baseline(seeds, fanout),
    })
}

/// Resolve one requested node's adjacency row. A request for a node this
/// rank does not hold (or an id past the node space) is a malformed
/// round from `src`: fail the collective so every peer sees the error,
/// rather than panicking this server rank and hanging the rest.
fn resolve<'a>(
    shard: &WorkerShard,
    view: &'a TopologyView,
    src: usize,
    u: NodeId,
) -> Result<&'a [NodeId], CommError> {
    let neigh =
        if (u as usize) < shard.book.num_nodes() { view.try_neighbors(u) } else { None };
    neigh.ok_or_else(|| CommError::Malformed {
        src,
        detail: format!(
            "sampling request for node {u}, which rank {} does not hold",
            shard.part
        ),
    })
}

/// Scalar-wire serve: sample each requested node with the same
/// key/stream the single-machine kernel would use, pushing the
/// interleaved run-length stream. Wire format per node: `count,
/// id*count` (u32 each) in request arrival order; when the requester's
/// prefixed admission limit is non-zero, additionally `deg, id*deg` (the
/// full adjacency row) if `deg` clears that limit, else `NO_ROW` — or
/// the combined `ELIDED, deg, row` shape when the sample is the row.
fn serve_scalar(
    shard: &WorkerShard,
    view: &TopologyView,
    granted: &[Vec<NodeId>],
    fanout: usize,
    key: RngKey,
    cache_on: bool,
    ws: &mut SamplerWorkspace,
) -> Result<Vec<Vec<NodeId>>, CommError> {
    ws.serve_chunk.clear();
    ws.serve_chunk.resize(fanout, 0);
    let mut replies: Vec<Vec<NodeId>> = Vec::with_capacity(granted.len());
    for (src, req) in granted.iter().enumerate() {
        let mut rep = ws.vec_pool.pop().unwrap_or_default();
        rep.clear();
        let (peer_limit, ids) = match req.split_first() {
            Some((&peer_limit, ids)) if cache_on => (peer_limit, ids),
            _ => (0, &req[..]),
        };
        if peer_limit == 0 {
            // Bare shape: `1 + cnt <= 1 + fanout` words per node, so this
            // bound can only over-shoot — never reallocates mid-loop.
            rep.reserve(ids.len() * (fanout + 1));
        } else {
            // Cache mode appends a row/marker suffix per node, so the
            // fanout bound reallocates mid-loop; pre-pass the exact shape
            // instead (counts need no sampling: cnt = min(deg, fanout)).
            let mut need = 0usize;
            for &u in ids {
                let deg = resolve(shard, view, src, u)?.len();
                let cnt = deg.min(fanout);
                let admissible = (deg as u64) < peer_limit as u64;
                need += if admissible && cnt == deg {
                    2 + deg
                } else if admissible {
                    1 + cnt + 1 + deg
                } else {
                    1 + cnt + 1
                };
            }
            rep.reserve(need);
        }
        for &u in ids {
            let neigh = resolve(shard, view, src, u)?;
            let cnt =
                sample_node(neigh, u, fanout, key, &mut ws.serve_scratch, &mut ws.serve_chunk);
            let admissible = peer_limit > 0 && (neigh.len() as u64) < peer_limit as u64;
            if admissible && cnt as usize == neigh.len() {
                // deg <= fanout: the sample is the full row in row
                // order, so ship the row once (`ELIDED, deg, row`)
                // instead of `cnt, ids, deg, row`.
                rep.push(ELIDED);
                rep.push(neigh.len() as NodeId);
                rep.extend_from_slice(neigh);
                continue;
            }
            rep.push(cnt);
            rep.extend_from_slice(&ws.serve_chunk[..cnt as usize]);
            // Row/marker suffix only while the requester can still
            // admit something (peer_limit 0 ⇒ the bare uncached shape).
            if peer_limit > 0 {
                if admissible {
                    rep.push(neigh.len() as NodeId);
                    rep.extend_from_slice(neigh);
                } else {
                    rep.push(NO_ROW);
                }
            }
        }
        replies.push(rep);
    }
    Ok(replies)
}

/// Bulk-wire serve: the two-phase columnar kernel. Phase A (serial)
/// resolves each request once, emits its flagged count word, and
/// prefix-sums the blob segment offsets — no sampling happens yet, since
/// a segment's length is `min(deg, fanout)` either way. Phase B fills
/// the blob with a parallel ragged sweep making the same [`sample_node`]
/// calls the local path makes (`sample_node` writes exactly
/// `min(deg, fanout)` words — precisely each segment's length; an elided
/// segment is the full row, which is what sampling a `deg <= fanout`
/// node produces, in row order). Phase C (serial) appends the cache-row
/// section: `deg, row[deg]` per `ROW_FLAG`-ged count word, in order.
fn serve_bulk(
    shard: &WorkerShard,
    view: &TopologyView,
    granted: &[Vec<NodeId>],
    fanout: usize,
    key: RngKey,
    cache_on: bool,
    ws: &mut SamplerWorkspace,
) -> Result<Vec<Vec<NodeId>>, CommError> {
    let mut replies: Vec<Vec<NodeId>> = Vec::with_capacity(granted.len());
    for (src, req) in granted.iter().enumerate() {
        let mut rep = ws.vec_pool.pop().unwrap_or_default();
        rep.clear();
        let (peer_limit, ids) = match req.split_first() {
            Some((&peer_limit, ids)) if cache_on => (peer_limit, ids),
            _ => (0, &req[..]),
        };
        let n = ids.len();
        // Phase A: the counts block — the validated header the decode
        // mirrors — plus the blob prefix sum and the row-section tally.
        ws.offsets.clear();
        ws.offsets.push(0);
        let mut blob = 0usize;
        let mut row_words = 0usize;
        rep.reserve(n);
        for &u in ids {
            let deg = resolve(shard, view, src, u)?.len();
            let cnt = deg.min(fanout);
            let admissible = peer_limit > 0 && (deg as u64) < peer_limit as u64;
            let word = if admissible && cnt == deg {
                ELIDED_FLAG | deg as NodeId
            } else if admissible {
                row_words += 1 + deg;
                ROW_FLAG | cnt as NodeId
            } else {
                cnt as NodeId
            };
            rep.push(word);
            blob += cnt; // elided segments carry deg == cnt words
            ws.offsets.push(blob);
        }
        // The exact remaining shape is now known — one reservation, no
        // mid-fill reallocation.
        rep.reserve(blob + row_words);
        // Phase B: parallel blob fill.
        rep.resize(n + blob, 0);
        par::par_ragged_chunks(&mut rep[n..], &ws.offsets, Vec::new, |scratch, k, seg| {
            // Phase A resolved every id against the same immutable view,
            // so the lookup cannot fail; the empty-row fallback keeps
            // the closure total without a panic path in fabric code.
            let neigh = view.try_neighbors(ids[k]).unwrap_or(&[]);
            sample_node(neigh, ids[k], fanout, key, scratch, seg);
        });
        // Phase C: the cache-row section.
        if row_words > 0 {
            for (k, &u) in ids.iter().enumerate() {
                if rep[k] & ROW_FLAG != 0 {
                    let neigh = resolve(shard, view, src, u)?;
                    rep.push(neigh.len() as NodeId);
                    rep.extend_from_slice(neigh);
                }
            }
        }
        replies.push(rep);
    }
    Ok(replies)
}

/// Scalar-wire decode: walk the recorded miss slots in seed order so
/// each owner's response cursor advances in the order we requested,
/// copying runs into the strided buffer one checked word at a time.
/// Appended adjacency rows go straight into the cache overlay (inserts
/// may be rejected once the budget fills — correctness never depends on
/// residency).
fn decode_scalar(
    shard: &WorkerShard,
    view: &mut TopologyView,
    seeds: &[NodeId],
    responses: &[Vec<NodeId>],
    fanout: usize,
    limit: NodeId,
    ws: &mut SamplerWorkspace,
) -> Result<(), CommError> {
    let world = responses.len();
    ws.owner_cursor.clear();
    ws.owner_cursor.resize(world, 0);
    let miss_slots = std::mem::take(&mut ws.miss_slots);
    for &slot in &miss_slots {
        let i = slot as usize;
        let v = seeds[i];
        let p = shard.book.part_of(v);
        let resp = &responses[p];
        let mut cur = ws.owner_cursor[p];
        if limit > 0 && read_word(resp, cur, p)? == ELIDED {
            // Elided shape: the appended full row doubles as the
            // sampled set (deg <= fanout ⇒ sample_node took every
            // neighbor in row order — bit-identical to the eager
            // shape by construction).
            let deg = read_word(resp, cur + 1, p)? as usize;
            if deg > fanout {
                return Err(CommError::Malformed {
                    src: p,
                    detail: format!("elided row of degree {deg} exceeds fanout {fanout}"),
                });
            }
            let row = read_run(resp, cur + 2, deg, p)?;
            ws.samples[i * fanout..i * fanout + deg].copy_from_slice(row);
            ws.counts[i] = deg as u32;
            view.cache_insert(v, row);
            ws.owner_cursor[p] = cur + 2 + deg;
            continue;
        }
        let cnt = read_word(resp, cur, p)? as usize;
        if cnt > fanout {
            return Err(CommError::Malformed {
                src: p,
                detail: format!("sample count {cnt} exceeds fanout {fanout}"),
            });
        }
        ws.samples[i * fanout..i * fanout + cnt]
            .copy_from_slice(read_run(resp, cur + 1, cnt, p)?);
        ws.counts[i] = cnt as u32;
        cur += 1 + cnt;
        // Owners append the row/marker suffix iff the limit we sent
        // this level was non-zero (mirrors the serve side above).
        if limit > 0 {
            let marker = read_word(resp, cur, p)?;
            cur += 1;
            if marker != NO_ROW {
                let deg = marker as usize;
                view.cache_insert(v, read_run(resp, cur, deg, p)?);
                cur += deg;
            }
        }
        ws.owner_cursor[p] = cur;
    }
    ws.miss_slots = miss_slots;
    // The ordering invariant, checked: every byte of every response
    // was matched to a miss slot — a skewed cursor would mean seed
    // order and request order diverged somewhere, and trailing bytes
    // must fail the round, not linger as silent desync.
    for (p, resp) in responses.iter().enumerate() {
        if ws.owner_cursor[p] != resp.len() {
            return Err(CommError::Malformed {
                src: p,
                detail: format!(
                    "rank {}: consumed {} of {} response words — remote-slot \
                     ordering invariant violated",
                    shard.part,
                    ws.owner_cursor[p],
                    resp.len()
                ),
            });
        }
    }
    Ok(())
}

/// Bulk-wire decode, mirroring [`serve_bulk`]'s sections. Pass 1, per
/// owner: validate the counts block (the header — length, flag legality,
/// count <= fanout), prefix-sum the blob offsets, bounds-check the blob
/// and row section against the payload length (exact consumption
/// included — the columnar restatement of the remote-slot ordering
/// invariant), record each miss's count, then scatter the blob segments
/// into the strided sample buffer in parallel (seed slots are unique, so
/// the destination rows are disjoint). Pass 2 (cache mode only): replay
/// the cache inserts in global seed order — the same order the scalar
/// wire inserts in, so the overlay reaches a byte-identical state
/// whichever wire ran.
fn decode_bulk(
    shard: &WorkerShard,
    view: &mut TopologyView,
    seeds: &[NodeId],
    responses: &[Vec<NodeId>],
    fanout: usize,
    limit: NodeId,
    ws: &mut SamplerWorkspace,
) -> Result<(), CommError> {
    let world = responses.len();
    ws.owner_cursor.clear();
    ws.owner_cursor.resize(world, 0);
    for (p, resp) in responses.iter().enumerate() {
        let slots = &ws.owner_slots[p];
        let n = slots.len();
        if n == 0 {
            if !resp.is_empty() {
                return Err(CommError::Malformed {
                    src: p,
                    detail: format!("unsolicited sampling response of {} words", resp.len()),
                });
            }
            continue;
        }
        if resp.len() < n {
            return Err(CommError::Malformed {
                src: p,
                detail: format!("truncated counts block: {} of {n} count words", resp.len()),
            });
        }
        ws.scatter.clear();
        let mut blob = 0usize;
        for (k, &slot) in slots.iter().enumerate() {
            let word = resp[k];
            let flags = word & (ROW_FLAG | ELIDED_FLAG);
            if flags != 0 && limit == 0 {
                return Err(CommError::Malformed {
                    src: p,
                    detail: format!("cache flags {flags:#010x} on an uncached round"),
                });
            }
            if flags == (ROW_FLAG | ELIDED_FLAG) {
                return Err(CommError::Malformed {
                    src: p,
                    detail: "count word carries both ROW and ELIDED flags".into(),
                });
            }
            let cnt = (word & COUNT_MASK) as usize;
            if cnt > fanout {
                return Err(CommError::Malformed {
                    src: p,
                    detail: format!("sample count {cnt} exceeds fanout {fanout}"),
                });
            }
            ws.scatter.push((slot, (n + blob) as u32, cnt as u32));
            ws.counts[slot as usize] = cnt as u32;
            blob += cnt;
        }
        let blob_end = n + blob;
        if resp.len() < blob_end {
            return Err(CommError::Malformed {
                src: p,
                detail: format!(
                    "ids blob shorter than its prefix sum: {} of {blob_end} words",
                    resp.len()
                ),
            });
        }
        // Row-section structural walk (contents are consumed by pass 2);
        // every word of the payload must be accounted for.
        let mut cur = blob_end;
        if limit > 0 {
            for &word in &resp[..n] {
                if word & ROW_FLAG != 0 {
                    let deg = read_word(resp, cur, p)? as usize;
                    read_run(resp, cur + 1, deg, p)?;
                    cur += 1 + deg;
                }
            }
        }
        if cur != resp.len() {
            return Err(CommError::Malformed {
                src: p,
                detail: format!(
                    "rank {}: consumed {cur} of {} response words — remote-slot \
                     ordering invariant violated",
                    shard.part,
                    resp.len()
                ),
            });
        }
        // Row-section start, kept for pass 2.
        ws.owner_cursor[p] = blob_end;
        par::par_scatter_rows(&mut ws.samples, fanout, resp, &ws.scatter);
    }

    if limit > 0 {
        ws.owner_entry.clear();
        ws.owner_entry.resize(world, 0);
        ws.owner_blob.clear();
        ws.owner_blob.resize(world, 0);
        // Blob cursors start right after each owner's counts block.
        for (blob_cur, slots) in ws.owner_blob.iter_mut().zip(&ws.owner_slots) {
            *blob_cur = slots.len();
        }
        for &slot in &ws.miss_slots {
            let i = slot as usize;
            let v = seeds[i];
            let p = shard.book.part_of(v);
            let resp = &responses[p];
            let k = ws.owner_entry[p];
            ws.owner_entry[p] += 1;
            let word = read_word(resp, k, p)?;
            let cnt = (word & COUNT_MASK) as usize;
            if word & ELIDED_FLAG != 0 {
                // The blob segment IS the full row (deg <= fanout):
                // sampled set and cache insert from one wire copy.
                let row = read_run(resp, ws.owner_blob[p], cnt, p)?;
                view.cache_insert(v, row);
            } else if word & ROW_FLAG != 0 {
                let cur = ws.owner_cursor[p];
                let deg = read_word(resp, cur, p)? as usize;
                view.cache_insert(v, read_run(resp, cur + 1, deg, p)?);
                ws.owner_cursor[p] = cur + 1 + deg;
            }
            ws.owner_blob[p] += cnt;
        }
    }
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use std::sync::Arc;

    use super::super::cache::CachePolicy;
    use super::super::net::NetworkModel;
    use super::super::worker::run_workers;
    use super::*;
    use crate::graph::generator::{make_dataset, DatasetParams};
    use crate::graph::Dataset;
    use crate::partition::{build_shards, partition_graph, PartitionConfig, ReplicationPolicy};
    use crate::sampling::sample_mfgs;

    fn dataset() -> Dataset {
        make_dataset(&DatasetParams {
            name: "dist-sampling-unit".into(),
            num_nodes: 400,
            avg_degree: 9,
            feat_dim: 4,
            num_classes: 3,
            labeled_frac: 0.25,
            p_intra: 0.8,
            noise: 0.2,
            seed: 5,
        })
    }

    #[test]
    fn single_worker_vanilla_matches_single_machine() {
        let d = dataset();
        let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(1)));
        let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
        let fanouts = [3usize, 2];
        let key = RngKey::new(21);
        let seeds: Vec<NodeId> = d.train_ids.iter().copied().take(10).collect();
        let shards_ref = &shards;
        let seeds_ref = &seeds;
        let got = run_workers(1, NetworkModel::free(), move |_rank, comm| {
            let mut ws = SamplerWorkspace::new();
            let mut view = shards_ref[0].topology.clone();
            sample_mfgs_distributed(
                comm,
                &shards_ref[0],
                &mut view,
                seeds_ref,
                &fanouts,
                key,
                &mut ws,
                KernelKind::Fused,
            )
            .unwrap()
        });
        let mut ws = SamplerWorkspace::new();
        let expect = sample_mfgs(&d.graph, &seeds, &fanouts, key, &mut ws, KernelKind::Fused);
        assert_eq!(got[0], expect);
    }

    #[test]
    fn full_replication_is_pure_local_sampling() {
        let d = dataset();
        let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(2)));
        let shards = build_shards(&d, &book, &ReplicationPolicy::hybrid());
        let fanouts = [4usize, 3];
        let key = RngKey::new(8);
        let shards_ref = &shards;
        let d_ref = &d;
        let book_ref = &book;
        let results = run_workers(2, NetworkModel::free(), move |rank, comm| {
            let seeds: Vec<NodeId> = d_ref
                .train_ids
                .iter()
                .copied()
                .filter(|&v| book_ref.part_of(v) == rank)
                .take(8)
                .collect();
            let mut ws = SamplerWorkspace::new();
            let mut view = shards_ref[rank].topology.clone();
            let mfgs = sample_mfgs_distributed(
                comm,
                &shards_ref[rank],
                &mut view,
                &seeds,
                &fanouts,
                key,
                &mut ws,
                KernelKind::Baseline,
            )
            .unwrap();
            (seeds, mfgs)
        });
        let mut ws = SamplerWorkspace::new();
        for (seeds, mfgs) in &results {
            let expect =
                sample_mfgs(&d.graph, seeds, &fanouts, key, &mut ws, KernelKind::Baseline);
            assert_eq!(mfgs, &expect);
        }
    }

    /// Satellite regression for the remote-slot ordering invariant: force
    /// level-0 misses with seeds that *interleave* local nodes and remote
    /// nodes of multiple owners in non-sorted order — each owner's
    /// response must decode back into exactly the requesting slots. Runs
    /// with the adjacency cache both off and on (tiny and large budgets),
    /// since the cache-mode wire format threads extra fields through the
    /// same cursors.
    #[test]
    fn remote_responses_decode_in_seed_order() {
        let d = dataset();
        let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(3)));
        let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
        let fanouts = [3usize, 2];
        let key = RngKey::new(33);
        // Per rank: walk all nodes striding so ownership interleaves, and
        // keep an unsorted mix of ~8 locals and ~8 remotes (unique).
        let mk_seeds = |rank: usize| -> Vec<NodeId> {
            let mut local = 0;
            let mut remote = 0;
            let mut out = Vec::new();
            for i in 0..d.num_nodes() {
                let v = ((i * 53 + 17 * (rank + 1)) % d.num_nodes()) as NodeId;
                if out.contains(&v) {
                    continue;
                }
                let is_local = book.part_of(v) == rank;
                if is_local && local < 8 {
                    local += 1;
                    out.push(v);
                } else if !is_local && remote < 8 {
                    remote += 1;
                    out.push(v);
                }
                if local == 8 && remote == 8 {
                    break;
                }
            }
            assert!(remote > 0, "seed mix must include remote nodes");
            out
        };
        for cache_bytes in [None, Some(256u64), Some(1 << 20)] {
            let shards_ref = &shards;
            let results = run_workers(3, NetworkModel::free(), move |rank, comm| {
                let seeds = mk_seeds(rank);
                let mut ws = SamplerWorkspace::new();
                let mut view = shards_ref[rank].topology.clone();
                if let Some(b) = cache_bytes {
                    view.enable_cache(b, CachePolicy::Clock);
                }
                let mfgs = sample_mfgs_distributed(
                    comm,
                    &shards_ref[rank],
                    &mut view,
                    &seeds,
                    &fanouts,
                    key,
                    &mut ws,
                    KernelKind::Fused,
                )
                .unwrap();
                (seeds, mfgs)
            });
            let mut ws = SamplerWorkspace::new();
            for (seeds, mfgs) in &results {
                let expect =
                    sample_mfgs(&d.graph, seeds, &fanouts, key, &mut ws, KernelKind::Fused);
                assert_eq!(
                    mfgs, &expect,
                    "interleaved remote seeds decoded out of order (cache {cache_bytes:?})"
                );
            }
        }
    }

    /// Regression for the response-batching satellite: under cache mode,
    /// a miss whose degree clears both the admission limit and the
    /// fanout must cost exactly `2 + deg` response words on the scalar
    /// wire (ELIDED marker, degree, row) — not the old `2 + 2·deg`
    /// (sample AND row) — and exactly `1 + deg` on the bulk wire (one
    /// flagged count word, the row as the blob segment), while staying
    /// bit-identical to single-machine sampling on both.
    #[test]
    fn cache_mode_elides_duplicate_ids_when_degree_clears_fanout() {
        use super::super::comm::Counters;
        use super::super::worker::run_workers_with;
        use std::sync::Arc as StdArc;

        let d = dataset();
        let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(2)));
        let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
        // Fanout >= every degree ⇒ every served miss samples its full
        // row ⇒ every admissible response uses the elided shape.
        let max_deg = (0..d.num_nodes() as NodeId).map(|v| d.graph.degree(v)).max().unwrap();
        let fanouts = [max_deg.max(1)];
        let key = RngKey::new(11);
        // Seeds: each rank's first 6 locals + first 6 remotes, so level 0
        // has deterministic cross-partition misses.
        let mk_seeds = |rank: usize| -> Vec<NodeId> {
            let mut local = Vec::new();
            let mut remote = Vec::new();
            for v in 0..d.num_nodes() as NodeId {
                if book.part_of(v) == rank {
                    if local.len() < 6 {
                        local.push(v);
                    }
                } else if remote.len() < 6 {
                    remote.push(v);
                }
            }
            local.into_iter().chain(remote).collect()
        };
        // Elided misses are exactly each rank's remote seeds (single
        // level, unbounded cold cache admits everything). Scalar pays
        // `2 + deg` words per miss, bulk `1 + deg`.
        let mut elided = 0u64;
        let mut deg_sum = 0u64;
        for rank in 0..2usize {
            for v in mk_seeds(rank) {
                if book.part_of(v) != rank {
                    elided += 1;
                    deg_sum += d.graph.degree(v) as u64;
                }
            }
        }
        assert!(elided > 0, "workload produced no misses — test too weak");
        for (wire, expect_words) in [
            (SamplingWire::Scalar, 2 * elided + deg_sum),
            (SamplingWire::Bulk, elided + deg_sum),
        ] {
            let counters = StdArc::new(Counters::default());
            let shards_ref = &shards;
            let mk_seeds_ref = &mk_seeds;
            let results = run_workers_with(
                2,
                NetworkModel::free(),
                StdArc::clone(&counters),
                move |rank, comm| {
                    let seeds = mk_seeds_ref(rank);
                    let mut ws = SamplerWorkspace::new();
                    let mut view = shards_ref[rank].topology.clone();
                    view.enable_cache(u64::MAX >> 1, CachePolicy::StaticDegree);
                    let mfgs = sample_mfgs_distributed_wire(
                        comm,
                        &shards_ref[rank],
                        &mut view,
                        &seeds,
                        &fanouts,
                        key,
                        &mut ws,
                        KernelKind::Fused,
                        wire,
                    )
                    .unwrap();
                    (seeds, mfgs)
                },
            );
            // Bit-equality first.
            let mut ws = SamplerWorkspace::new();
            for (seeds, mfgs) in &results {
                let expect =
                    sample_mfgs(&d.graph, seeds, &fanouts, key, &mut ws, KernelKind::Fused);
                assert_eq!(mfgs, &expect, "elided responses decoded wrong ({wire})");
            }
            let s = counters.snapshot();
            assert_eq!(
                s.bytes_of(RoundKind::SampleResponse),
                expect_words * 4,
                "response bytes are not the elided shape ({wire})"
            );
        }
    }

    /// The cache fast path end to end: the same worker resampling the
    /// same minibatch stops missing once the rows are resident, and the
    /// results stay bit-identical throughout.
    #[test]
    fn cached_rows_serve_repeat_minibatches_locally() {
        let d = dataset();
        let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(2)));
        let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
        let fanouts = [3usize, 3];
        let key = RngKey::new(77);
        let shards_ref = &shards;
        let book_ref = &book;
        let d_ref = &d;
        let results = run_workers(2, NetworkModel::free(), move |rank, comm| {
            let seeds: Vec<NodeId> = d_ref
                .train_ids
                .iter()
                .copied()
                .filter(|&v| book_ref.part_of(v) == rank)
                .take(12)
                .collect();
            let mut ws = SamplerWorkspace::new();
            let mut view = shards_ref[rank].topology.clone();
            view.enable_cache(u64::MAX >> 1, CachePolicy::StaticDegree);
            let a = sample_mfgs_distributed(
                comm,
                &shards_ref[rank],
                &mut view,
                &seeds,
                &fanouts,
                key,
                &mut ws,
                KernelKind::Fused,
            )
            .unwrap();
            let cached_after_first = view.cached_rows();
            let b = sample_mfgs_distributed(
                comm,
                &shards_ref[rank],
                &mut view,
                &seeds,
                &fanouts,
                key,
                &mut ws,
                KernelKind::Fused,
            )
            .unwrap();
            (seeds, a, b, cached_after_first, view.cached_rows())
        });
        let mut ws = SamplerWorkspace::new();
        for (seeds, a, b, cached1, cached2) in &results {
            let expect = sample_mfgs(&d.graph, seeds, &fanouts, key, &mut ws, KernelKind::Fused);
            assert_eq!(a, &expect, "first (miss-resolving) pass diverged");
            assert_eq!(b, &expect, "second (cache-served) pass diverged");
            assert!(*cached1 > 0, "unbounded cache admitted nothing");
            assert_eq!(
                cached1, cached2,
                "second pass over the same seeds should miss nothing new"
            );
        }
    }
}
