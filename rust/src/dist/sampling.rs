//! Distributed minibatch sampling over the replication-budget spectrum
//! (paper §3.3, generalized) — bit-equal to single-machine
//! [`sample_mfgs`] by construction at **every** budget point.
//!
//! One unified path replaces the old vanilla/hybrid split: every level,
//! each worker samples every frontier node whose adjacency it holds
//! (local rows plus whatever halo its [`ReplicationPolicy`] bought) and
//! batches only the *misses* into a [`RoundKind::SampleRequest`] /
//! [`RoundKind::SampleResponse`] pair. Before paying that pair, the
//! ranks vote with one uncharged control-plane reduce
//! ([`Comm::all_zero_u64`], built on `all_reduce_min_u64`): when every
//! rank has zero misses the exchange is skipped entirely. Sampling
//! rounds per minibatch are therefore **data-dependent**, anywhere in
//! `0..=2(L−1)` — `Counters` report what actually happened, not what a
//! scheme constant assumes. Budget 0 reproduces the paper's vanilla
//! counts (2 rounds per non-seed level with any cross-partition
//! frontier); full replication reproduces hybrid's zero (the vote is
//! short-circuited without communication when the view covers the whole
//! graph, which is uniform across ranks because all shards share one
//! policy).
//!
//! Equality with the single-machine sampler holds bit-for-bit because
//! neighbor choice depends only on `(level_key, node, its neighbor
//! list)` — [`sample_node`] keyed by the counter-based RNG — and any
//! materialized row (local or replicated halo) carries exactly the full
//! graph's neighbor list, as does the owner serving a miss remotely.
//! Assembly then replays the same relabel pass over the same per-seed
//! chunks in the same order.
//!
//! **Remote-slot ordering invariant:** within one owner, requests are
//! queued in seed order, owners serve them in arrival order, and the
//! decode walks seeds in order advancing one cursor per owner — so the
//! k-th miss sent to partition `p` is answered by the k-th
//! count-prefixed run in `p`'s response. The decode asserts that every
//! response is consumed exactly (see `sample_level`), and the
//! `remote_responses_decode_in_seed_order` regression test drives the
//! interleaved multi-owner case.

use crate::graph::NodeId;
use crate::partition::WorkerShard;
use crate::sampling::fused::sample_node;
use crate::sampling::pipeline::level_key;
use crate::sampling::rng::RngKey;
use crate::sampling::{KernelKind, Mfg, SamplerWorkspace};
use crate::util::par;

use super::comm::{Comm, RoundKind};

/// Sample all levels of one minibatch against a worker shard. Same
/// contract as single-machine [`sample_mfgs`] (fanouts top level first,
/// MFGs returned bottom first) plus the SPMD one: every rank in the
/// world must call this collectively, with shards built from the same
/// [`crate::partition::ReplicationPolicy`]. Seeds are normally the
/// worker's own labeled nodes (then level 0 costs no exchange), but any
/// frontier node — seed included — whose adjacency is absent is resolved
/// through the miss rounds.
///
/// [`sample_mfgs`]: crate::sampling::sample_mfgs
pub fn sample_mfgs_distributed(
    comm: &mut Comm,
    shard: &WorkerShard,
    seeds: &[NodeId],
    fanouts: &[usize],
    key: RngKey,
    ws: &mut SamplerWorkspace,
    kind: KernelKind,
) -> Vec<Mfg> {
    let mut out: Vec<Mfg> = Vec::with_capacity(fanouts.len());
    for (li, &f) in fanouts.iter().enumerate() {
        let mfg = {
            let cur: &[NodeId] = match out.last() {
                None => seeds,
                Some(prev) => &prev.src_nodes,
            };
            sample_level(comm, shard, cur, f, level_key(key, li), ws, kind)
        };
        out.push(mfg);
    }
    out.reverse();
    out
}

/// One level: frontier nodes with materialized adjacency sampled in
/// place; misses resolved through one request + one response round —
/// skipped when a control-plane vote agrees no rank has any — then
/// assembled exactly like the corresponding single-machine kernel.
fn sample_level(
    comm: &mut Comm,
    shard: &WorkerShard,
    seeds: &[NodeId],
    fanout: usize,
    key: RngKey,
    ws: &mut SamplerWorkspace,
    kind: KernelKind,
) -> Mfg {
    assert!(fanout >= 1, "fanout must be >= 1");
    let n = seeds.len();
    let world = comm.world();
    ws.begin(shard.book.num_nodes());
    ws.samples.resize(n * fanout, 0);
    ws.counts.resize(n, 0);
    let mut scratch: Vec<usize> = Vec::new();

    // ---- Queue misses first (order within an owner follows seed order —
    // the remote-slot ordering invariant the decode below asserts). Under
    // a full-replication policy no node can miss, so the paper's headline
    // hybrid arm skips the scan and the per-owner outbox allocation
    // entirely — its hot path stays the pure local sampling loop below.
    let full = shard.policy.is_full();
    let mut requests: Vec<Vec<NodeId>> = Vec::new();
    let mut misses = 0u64;
    if !full {
        requests.resize_with(world, Vec::new);
        for &v in seeds {
            if shard.topology.try_neighbors(v).is_none() {
                let p = shard.book.part_of(v);
                debug_assert_ne!(p, shard.part, "own nodes always have a materialized row");
                requests[p].push(v);
                misses += 1;
            }
        }
    }

    // ---- Covered seeds: sample into the strided buffer with the same
    // parallel per-seed loop as the single-machine kernels, so budget
    // comparisons isolate communication cost rather than a
    // serial-sampling artifact. Miss slots get a placeholder count and
    // are filled by the response decode below.
    let topo = &shard.topology;
    par::par_zip_chunks(
        &mut ws.samples,
        &mut ws.counts,
        fanout,
        Vec::new,
        |scratch, i, chunk, cnt| {
            let v = seeds[i];
            *cnt = match topo.try_neighbors(v) {
                Some(neigh) => sample_node(neigh, v, fanout, key, scratch, chunk),
                None => 0,
            };
        },
    );

    // ---- The round-skip vote + (when needed) the level's two data
    // rounds. Under a full-replication *policy* no rank can miss, so the
    // vote itself is skipped without communication — keyed off the
    // policy (uniform across ranks), never off per-rank view coverage,
    // which a finite budget can make diverge. Otherwise the vote is one
    // uncharged control-plane reduce; the data rounds run only when some
    // rank actually misses — and then *every* rank participates, empty
    // payloads included: rounds are a property of the fabric, not of
    // one worker.
    let need_exchange = !full && !comm.all_zero_u64(misses);
    if need_exchange {
        let granted = comm.exchange(RoundKind::SampleRequest, requests);

        // Serve: sample each requested node with the same key/stream the
        // single-machine kernel would use. Wire format per node:
        // `count, id, id, ...` (u32 each), in request arrival order.
        let mut chunk: Vec<NodeId> = vec![0; fanout];
        let mut replies: Vec<Vec<NodeId>> = Vec::with_capacity(world);
        for req in &granted {
            let mut rep: Vec<NodeId> = Vec::with_capacity(req.len() * (fanout + 1));
            for &u in req {
                let neigh = shard
                    .topology
                    .try_neighbors(u)
                    .expect("received a sampling request for a node this worker does not own");
                let cnt = sample_node(neigh, u, fanout, key, &mut scratch, &mut chunk);
                rep.push(cnt);
                rep.extend_from_slice(&chunk[..cnt as usize]);
            }
            replies.push(rep);
        }
        let responses = comm.exchange(RoundKind::SampleResponse, replies);

        // Decode into the strided buffer, walking seeds in order so each
        // owner's response cursor advances in the order we requested.
        let mut cursor = vec![0usize; world];
        for (i, &v) in seeds.iter().enumerate() {
            if shard.topology.try_neighbors(v).is_some() {
                continue;
            }
            let p = shard.book.part_of(v);
            let resp = &responses[p];
            let cnt = resp[cursor[p]] as usize;
            debug_assert!(cnt <= fanout);
            let ids = &resp[cursor[p] + 1..cursor[p] + 1 + cnt];
            ws.samples[i * fanout..i * fanout + cnt].copy_from_slice(ids);
            ws.counts[i] = cnt as u32;
            cursor[p] += 1 + cnt;
        }
        // The ordering invariant, asserted: every byte of every response
        // was matched to a miss slot — a skewed cursor would mean seed
        // order and request order diverged somewhere.
        for (p, resp) in responses.iter().enumerate() {
            assert_eq!(
                cursor[p],
                resp.len(),
                "rank {}: response from rank {p} not fully consumed — \
                 remote-slot ordering invariant violated",
                shard.part
            );
        }
    }

    // ---- Assembly: replay the chosen kernel's relabel pass over the
    // filled buffer. Both produce bit-identical MFGs (the baseline arm
    // just pays the COO round-trip, as it does on a single machine).
    match kind {
        KernelKind::Fused => ws.assemble_fused(seeds, fanout),
        KernelKind::Baseline => ws.assemble_baseline(seeds, fanout),
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::super::net::NetworkModel;
    use super::super::worker::run_workers;
    use super::*;
    use crate::graph::generator::{make_dataset, DatasetParams};
    use crate::graph::Dataset;
    use crate::partition::{build_shards, partition_graph, PartitionConfig, ReplicationPolicy};
    use crate::sampling::sample_mfgs;

    fn dataset() -> Dataset {
        make_dataset(&DatasetParams {
            name: "dist-sampling-unit".into(),
            num_nodes: 400,
            avg_degree: 9,
            feat_dim: 4,
            num_classes: 3,
            labeled_frac: 0.25,
            p_intra: 0.8,
            noise: 0.2,
            seed: 5,
        })
    }

    #[test]
    fn single_worker_vanilla_matches_single_machine() {
        let d = dataset();
        let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(1)));
        let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
        let fanouts = [3usize, 2];
        let key = RngKey::new(21);
        let seeds: Vec<NodeId> = d.train_ids.iter().copied().take(10).collect();
        let shards_ref = &shards;
        let seeds_ref = &seeds;
        let got = run_workers(1, NetworkModel::free(), move |_rank, comm| {
            let mut ws = SamplerWorkspace::new();
            sample_mfgs_distributed(
                comm,
                &shards_ref[0],
                seeds_ref,
                &fanouts,
                key,
                &mut ws,
                KernelKind::Fused,
            )
        });
        let mut ws = SamplerWorkspace::new();
        let expect = sample_mfgs(&d.graph, &seeds, &fanouts, key, &mut ws, KernelKind::Fused);
        assert_eq!(got[0], expect);
    }

    #[test]
    fn full_replication_is_pure_local_sampling() {
        let d = dataset();
        let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(2)));
        let shards = build_shards(&d, &book, &ReplicationPolicy::hybrid());
        let fanouts = [4usize, 3];
        let key = RngKey::new(8);
        let shards_ref = &shards;
        let d_ref = &d;
        let book_ref = &book;
        let results = run_workers(2, NetworkModel::free(), move |rank, comm| {
            let seeds: Vec<NodeId> = d_ref
                .train_ids
                .iter()
                .copied()
                .filter(|&v| book_ref.part_of(v) == rank)
                .take(8)
                .collect();
            let mut ws = SamplerWorkspace::new();
            let mfgs = sample_mfgs_distributed(
                comm,
                &shards_ref[rank],
                &seeds,
                &fanouts,
                key,
                &mut ws,
                KernelKind::Baseline,
            );
            (seeds, mfgs)
        });
        let mut ws = SamplerWorkspace::new();
        for (seeds, mfgs) in &results {
            let expect =
                sample_mfgs(&d.graph, seeds, &fanouts, key, &mut ws, KernelKind::Baseline);
            assert_eq!(mfgs, &expect);
        }
    }

    /// Satellite regression for the remote-slot ordering invariant: force
    /// level-0 misses with seeds that *interleave* local nodes and remote
    /// nodes of multiple owners in non-sorted order — each owner's
    /// response must decode back into exactly the requesting slots.
    #[test]
    fn remote_responses_decode_in_seed_order() {
        let d = dataset();
        let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(3)));
        let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
        let fanouts = [3usize, 2];
        let key = RngKey::new(33);
        // Per rank: walk all nodes striding so ownership interleaves, and
        // keep an unsorted mix of ~8 locals and ~8 remotes (unique).
        let mk_seeds = |rank: usize| -> Vec<NodeId> {
            let mut local = 0;
            let mut remote = 0;
            let mut out = Vec::new();
            for i in 0..d.num_nodes() {
                let v = ((i * 53 + 17 * (rank + 1)) % d.num_nodes()) as NodeId;
                if out.contains(&v) {
                    continue;
                }
                let is_local = book.part_of(v) == rank;
                if is_local && local < 8 {
                    local += 1;
                    out.push(v);
                } else if !is_local && remote < 8 {
                    remote += 1;
                    out.push(v);
                }
                if local == 8 && remote == 8 {
                    break;
                }
            }
            assert!(remote > 0, "seed mix must include remote nodes");
            out
        };
        let shards_ref = &shards;
        let results = run_workers(3, NetworkModel::free(), move |rank, comm| {
            let seeds = mk_seeds(rank);
            let mut ws = SamplerWorkspace::new();
            let mfgs = sample_mfgs_distributed(
                comm,
                &shards_ref[rank],
                &seeds,
                &fanouts,
                key,
                &mut ws,
                KernelKind::Fused,
            );
            (seeds, mfgs)
        });
        let mut ws = SamplerWorkspace::new();
        for (seeds, mfgs) in &results {
            let expect = sample_mfgs(&d.graph, seeds, &fanouts, key, &mut ws, KernelKind::Fused);
            assert_eq!(mfgs, &expect, "interleaved remote seeds decoded out of order");
        }
    }
}
