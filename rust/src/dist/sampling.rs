//! Distributed minibatch sampling under the two partitioning schemes
//! (paper §3.3) — bit-equal to single-machine [`sample_mfgs`] by
//! construction.
//!
//! **Hybrid** (the paper's scheme): topology is replicated, so sampling
//! runs entirely locally — **zero** communication rounds. The call is
//! literally the single-machine pipeline on the shared adjacency.
//!
//! **Vanilla** (DistDGL-style): a worker only sees the in-edges of its
//! own nodes, so every level past the first must ship non-local frontier
//! nodes to their owners ([`RoundKind::SampleRequest`]), have the owners
//! draw the samples, and ship the sampled neighborhoods back
//! ([`RoundKind::SampleResponse`]) — 2 rounds per level, `2(L−1)` per
//! minibatch (level 0 seeds are the worker's own labeled nodes).
//!
//! Equality with the single-machine sampler holds bit-for-bit because
//! neighbor choice depends only on `(level_key, node, its neighbor
//! list)` — [`sample_node`] keyed by the counter-based RNG — and the
//! owner of a node sees exactly the full graph's neighbor list for it.
//! Assembly then replays the same relabel pass over the same per-seed
//! chunks in the same order.

use crate::graph::NodeId;
use crate::partition::{TopologyView, WorkerShard};
use crate::sampling::fused::sample_node;
use crate::sampling::pipeline::level_key;
use crate::sampling::rng::RngKey;
use crate::sampling::{sample_mfgs, KernelKind, Mfg, SamplerWorkspace};
use crate::util::par;

use super::comm::{Comm, RoundKind};

/// Sample all levels of one minibatch against a worker shard. Same
/// contract as single-machine [`sample_mfgs`] (fanouts top level first,
/// MFGs returned bottom first) plus the SPMD one: under vanilla
/// partitioning every rank in the world must call this collectively, with
/// level-0 `seeds` it owns.
pub fn sample_mfgs_distributed(
    comm: &mut Comm,
    shard: &WorkerShard,
    seeds: &[NodeId],
    fanouts: &[usize],
    key: RngKey,
    ws: &mut SamplerWorkspace,
    kind: KernelKind,
) -> Vec<Mfg> {
    match &shard.topology {
        // Hybrid: replicated topology ⇒ fully local, zero rounds.
        TopologyView::Full(g) => sample_mfgs(g, seeds, fanouts, key, ws, kind),
        TopologyView::Halo { .. } => sample_vanilla(comm, shard, seeds, fanouts, key, ws, kind),
    }
}

fn sample_vanilla(
    comm: &mut Comm,
    shard: &WorkerShard,
    seeds: &[NodeId],
    fanouts: &[usize],
    key: RngKey,
    ws: &mut SamplerWorkspace,
    kind: KernelKind,
) -> Vec<Mfg> {
    let mut out: Vec<Mfg> = Vec::with_capacity(fanouts.len());
    for (li, &f) in fanouts.iter().enumerate() {
        let mfg = {
            let cur: &[NodeId] = match out.last() {
                None => seeds,
                Some(prev) => &prev.src_nodes,
            };
            sample_level_vanilla(comm, shard, cur, f, level_key(key, li), ws, li > 0, kind)
        };
        out.push(mfg);
    }
    out.reverse();
    out
}

/// One vanilla level: local seeds sampled in place, non-local seeds
/// resolved through one request + one response round, then assembled
/// exactly like the corresponding single-machine kernel.
#[allow(clippy::too_many_arguments)]
fn sample_level_vanilla(
    comm: &mut Comm,
    shard: &WorkerShard,
    seeds: &[NodeId],
    fanout: usize,
    key: RngKey,
    ws: &mut SamplerWorkspace,
    exchange: bool,
    kind: KernelKind,
) -> Mfg {
    assert!(fanout >= 1, "fanout must be >= 1");
    let n = seeds.len();
    let world = comm.world();
    ws.begin(shard.book.num_nodes());
    ws.samples.resize(n * fanout, 0);
    ws.counts.resize(n, 0);
    let mut scratch: Vec<usize> = Vec::new();

    // ---- Queue remote seeds first (order within an owner follows seed
    // order, which is how responses are matched back up).
    let mut requests: Vec<Vec<NodeId>> = vec![Vec::new(); world];
    for &v in seeds {
        if shard.topology.try_neighbors(v).is_none() {
            assert!(
                exchange,
                "level-0 seed {v} is not local to partition {} — vanilla workers \
                 must seed from their own labeled nodes",
                shard.part
            );
            requests[shard.book.part_of(v)].push(v);
        }
    }

    // ---- Local seeds: sample into the strided buffer with the same
    // parallel per-seed loop as the single-machine kernels, so the Fig 6
    // vanilla-vs-hybrid comparison isolates communication cost rather
    // than a serial-sampling artifact. Remote slots get a placeholder
    // count and are filled by the response decode below.
    let topo = &shard.topology;
    par::par_zip_chunks(
        &mut ws.samples,
        &mut ws.counts,
        fanout,
        Vec::new,
        |scratch, i, chunk, cnt| {
            let v = seeds[i];
            *cnt = match topo.try_neighbors(v) {
                Some(neigh) => sample_node(neigh, v, fanout, key, scratch, chunk),
                None => 0,
            };
        },
    );

    // ---- The level's two collective rounds (every rank participates,
    // with empty payloads if it happens to have an all-local frontier —
    // rounds are a property of the fabric, not of one worker).
    if exchange {
        let granted = comm.exchange(RoundKind::SampleRequest, requests);

        // Serve: sample each requested node with the same key/stream the
        // single-machine kernel would use. Wire format per node:
        // `count, id, id, ...` (u32 each).
        let mut chunk: Vec<NodeId> = vec![0; fanout];
        let mut replies: Vec<Vec<NodeId>> = Vec::with_capacity(world);
        for req in &granted {
            let mut rep: Vec<NodeId> = Vec::with_capacity(req.len() * (fanout + 1));
            for &u in req {
                let neigh = shard
                    .topology
                    .try_neighbors(u)
                    .expect("received a sampling request for a node this worker does not own");
                let cnt = sample_node(neigh, u, fanout, key, &mut scratch, &mut chunk);
                rep.push(cnt);
                rep.extend_from_slice(&chunk[..cnt as usize]);
            }
            replies.push(rep);
        }
        let responses = comm.exchange(RoundKind::SampleResponse, replies);

        // Decode into the strided buffer, walking seeds in order so each
        // owner's response cursor advances in the order we requested.
        let mut cursor = vec![0usize; world];
        for (i, &v) in seeds.iter().enumerate() {
            if shard.topology.try_neighbors(v).is_some() {
                continue;
            }
            let p = shard.book.part_of(v);
            let resp = &responses[p];
            let cnt = resp[cursor[p]] as usize;
            debug_assert!(cnt <= fanout);
            let ids = &resp[cursor[p] + 1..cursor[p] + 1 + cnt];
            ws.samples[i * fanout..i * fanout + cnt].copy_from_slice(ids);
            ws.counts[i] = cnt as u32;
            cursor[p] += 1 + cnt;
        }
    }

    // ---- Assembly: replay the chosen kernel's relabel pass over the
    // filled buffer. Both produce bit-identical MFGs (the baseline arm
    // just pays the COO round-trip, as it does on a single machine).
    match kind {
        KernelKind::Fused => ws.assemble_fused(seeds, fanout),
        KernelKind::Baseline => ws.assemble_baseline(seeds, fanout),
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::super::net::NetworkModel;
    use super::super::worker::run_workers;
    use super::*;
    use crate::graph::generator::{make_dataset, DatasetParams};
    use crate::graph::Dataset;
    use crate::partition::{build_shards, partition_graph, PartitionConfig, Scheme};

    fn dataset() -> Dataset {
        make_dataset(&DatasetParams {
            name: "dist-sampling-unit".into(),
            num_nodes: 400,
            avg_degree: 9,
            feat_dim: 4,
            num_classes: 3,
            labeled_frac: 0.25,
            p_intra: 0.8,
            noise: 0.2,
            seed: 5,
        })
    }

    #[test]
    fn single_worker_vanilla_matches_single_machine() {
        let d = dataset();
        let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(1)));
        let shards = build_shards(&d, &book, Scheme::Vanilla);
        let fanouts = [3usize, 2];
        let key = RngKey::new(21);
        let seeds: Vec<NodeId> = d.train_ids.iter().copied().take(10).collect();
        let shards_ref = &shards;
        let seeds_ref = &seeds;
        let got = run_workers(1, NetworkModel::free(), move |_rank, comm| {
            let mut ws = SamplerWorkspace::new();
            sample_mfgs_distributed(
                comm,
                &shards_ref[0],
                seeds_ref,
                &fanouts,
                key,
                &mut ws,
                KernelKind::Fused,
            )
        });
        let mut ws = SamplerWorkspace::new();
        let expect = sample_mfgs(&d.graph, &seeds, &fanouts, key, &mut ws, KernelKind::Fused);
        assert_eq!(got[0], expect);
    }

    #[test]
    fn hybrid_shard_is_pure_local_sampling() {
        let d = dataset();
        let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(2)));
        let shards = build_shards(&d, &book, Scheme::Hybrid);
        let fanouts = [4usize, 3];
        let key = RngKey::new(8);
        let shards_ref = &shards;
        let d_ref = &d;
        let book_ref = &book;
        let results = run_workers(2, NetworkModel::free(), move |rank, comm| {
            let seeds: Vec<NodeId> = d_ref
                .train_ids
                .iter()
                .copied()
                .filter(|&v| book_ref.part_of(v) == rank)
                .take(8)
                .collect();
            let mut ws = SamplerWorkspace::new();
            let mfgs = sample_mfgs_distributed(
                comm,
                &shards_ref[rank],
                &seeds,
                &fanouts,
                key,
                &mut ws,
                KernelKind::Baseline,
            );
            (seeds, mfgs)
        });
        let mut ws = SamplerWorkspace::new();
        for (seeds, mfgs) in &results {
            let expect =
                sample_mfgs(&d.graph, seeds, &fanouts, key, &mut ws, KernelKind::Baseline);
            assert_eq!(mfgs, &expect);
        }
    }
}
