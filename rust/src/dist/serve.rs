//! Serve-mode client plane: the request/reply wire between external
//! clients and the resident rank-0 frontend.
//!
//! This module owns everything on the *client* side of serve mode and
//! nothing on the collective side (that lives in [`crate::train::serve`]):
//!
//! * **Wire codec** — [`ServeRequest`] (`FSRQ` magic) and [`ServeReply`]
//!   (`FSRP` magic), little-endian, with explicit length fields and hard
//!   caps so a malformed client cannot make the frontend allocate
//!   unboundedly. Errors travel *typed* on the wire as a
//!   [`ServeErrorKind`] status byte plus a human-readable detail string —
//!   a rejected or failed request always gets a reply, never a silent
//!   drop or a closed socket.
//! * **[`Frontend`]** — rank 0's listener: a polling accept thread plus
//!   one blocking handler thread per connection. Admission control is an
//!   atomic count of admitted-but-unanswered requests
//!   (`--serve-max-inflight`): a request that would push the count past
//!   the bound is answered immediately with
//!   [`ServeErrorKind::Overloaded`], and the slot is released only when
//!   the reply comes back to the handler — the bound really is
//!   outstanding requests, not queue depth. The serve loop drains
//!   admitted requests through [`Frontend::next_batch`], which coalesces
//!   concurrent requests into one batch under a node-count cap and a
//!   max-wait window, and returns an empty batch after `idle_wait` so
//!   the caller can run liveness checks while no traffic flows.
//! * **[`LatencyHistogram`]** — exact nearest-rank percentiles over
//!   recorded per-request latencies (p50/p99/max in the serve report).
//! * **Client helpers** — [`query_once`] / [`request_shutdown`], shared
//!   by `fastsample query` and the test suites.
//!
//! Threading contract: handler threads block on a per-request reply
//! channel, so every pending request holds exactly one `Sender`. The
//! serve loop answers by sending on it; if the loop dies first the
//! `Sender` is dropped and the handler synthesizes a typed
//! `ShuttingDown` reply — a client is *never* left hanging on a socket
//! with no reply on the way.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::graph::NodeId;

/// Magic prefix of every client request frame.
pub const REQUEST_MAGIC: [u8; 4] = *b"FSRQ";
/// Magic prefix of every reply frame.
pub const REPLY_MAGIC: [u8; 4] = *b"FSRP";

/// Hard cap on node ids per request frame (16 MiB of ids). Requests
/// above this are malformed by definition; the decode fails before any
/// allocation of that size happens.
pub const MAX_QUERY_NODES: usize = 1 << 22;
/// Hard cap on f32 values per reply frame (256 MiB of embeddings).
pub const MAX_REPLY_VALUES: usize = 1 << 26;
/// Hard cap on the error-detail string carried in a reply.
pub const MAX_ERROR_DETAIL: usize = 1 << 16;

/// Accept-thread poll interval while waiting for connections.
const ACCEPT_POLL: Duration = Duration::from_millis(10);
/// Accept-thread backoff when the process is out of file descriptors:
/// long enough for in-flight handlers to finish and free theirs.
const ACCEPT_FD_BACKOFF: Duration = Duration::from_millis(100);

const OP_QUERY: u8 = 0;
const OP_SHUTDOWN: u8 = 1;
const STATUS_OK: u8 = 0;

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn malformed(what: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("serve wire: {what}"))
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn read_u8<R: Read>(r: &mut R) -> io::Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// What a client asks the resident mesh to do.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeOp {
    /// Compute embeddings (or logits, depending on the server's answer
    /// mode) for these node ids, in order, duplicates allowed.
    Query(Vec<NodeId>),
    /// Ask the whole mesh to stop serving and exit cleanly.
    Shutdown,
}

/// One client request frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeRequest {
    /// Client-chosen correlation id, echoed verbatim in the reply.
    pub id: u64,
    pub op: ServeOp,
}

impl ServeRequest {
    /// Append the wire encoding of this request to `out`.
    pub fn encode_to(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&REQUEST_MAGIC);
        match &self.op {
            ServeOp::Query(nodes) => {
                out.push(OP_QUERY);
                put_u64(out, self.id);
                put_u32(out, nodes.len() as u32);
                for &v in nodes {
                    put_u32(out, v);
                }
            }
            ServeOp::Shutdown => {
                out.push(OP_SHUTDOWN);
                put_u64(out, self.id);
                put_u32(out, 0);
            }
        }
    }

    /// Decode one request frame from `r`, consuming exactly the frame.
    pub fn decode_from<R: Read>(r: &mut R) -> io::Result<ServeRequest> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if magic != REQUEST_MAGIC {
            return Err(malformed("bad request magic"));
        }
        let op = read_u8(r)?;
        let id = read_u64(r)?;
        let n = read_u32(r)? as usize;
        if n > MAX_QUERY_NODES {
            return Err(malformed("query node count exceeds cap"));
        }
        match op {
            OP_QUERY => {
                let mut raw = vec![0u8; n * 4];
                r.read_exact(&mut raw)?;
                let nodes = raw
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Ok(ServeRequest { id, op: ServeOp::Query(nodes) })
            }
            OP_SHUTDOWN => {
                if n != 0 {
                    return Err(malformed("shutdown request carries node ids"));
                }
                Ok(ServeRequest { id, op: ServeOp::Shutdown })
            }
            _ => Err(malformed("unknown request op")),
        }
    }
}

/// Typed failure classes a reply can carry. The discriminant is the
/// wire status byte (0 is reserved for Ok).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeErrorKind {
    /// Admission control: the bounded in-flight queue was full. The
    /// request was *not* enqueued; retrying later is safe.
    Overloaded,
    /// A rank died mid-query; the mesh is poisoned and the server is
    /// going down. The query was not answered.
    PeerLost,
    /// The request itself is invalid (out-of-range node id, batch over
    /// the model's seed cap, ...). Retrying the same request will fail
    /// the same way.
    BadRequest,
    /// The server is stopping and will not answer new queries.
    ShuttingDown,
    /// Any other server-side failure.
    Internal,
}

impl ServeErrorKind {
    fn code(self) -> u8 {
        match self {
            ServeErrorKind::Overloaded => 1,
            ServeErrorKind::PeerLost => 2,
            ServeErrorKind::BadRequest => 3,
            ServeErrorKind::ShuttingDown => 4,
            ServeErrorKind::Internal => 5,
        }
    }

    fn from_code(code: u8) -> Option<ServeErrorKind> {
        match code {
            1 => Some(ServeErrorKind::Overloaded),
            2 => Some(ServeErrorKind::PeerLost),
            3 => Some(ServeErrorKind::BadRequest),
            4 => Some(ServeErrorKind::ShuttingDown),
            5 => Some(ServeErrorKind::Internal),
            _ => None,
        }
    }
}

impl std::fmt::Display for ServeErrorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ServeErrorKind::Overloaded => "overloaded",
            ServeErrorKind::PeerLost => "peer-lost",
            ServeErrorKind::BadRequest => "bad-request",
            ServeErrorKind::ShuttingDown => "shutting-down",
            ServeErrorKind::Internal => "internal",
        };
        f.write_str(s)
    }
}

/// A typed error reply: kind plus a human-readable detail.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeError {
    pub kind: ServeErrorKind,
    pub detail: String,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.kind, self.detail)
    }
}

/// A successful reply: `rows` holds one `dim`-length row per requested
/// node, in request order (duplicates answered per occurrence).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeEmbeddings {
    pub dim: usize,
    pub rows: Vec<f32>,
}

impl ServeEmbeddings {
    /// Number of rows carried (0 when `dim` is 0).
    pub fn num_rows(&self) -> usize {
        if self.dim == 0 { 0 } else { self.rows.len() / self.dim }
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.rows[i * self.dim..(i + 1) * self.dim]
    }
}

/// One reply frame, correlated to its request by `id`.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReply {
    pub id: u64,
    pub body: Result<ServeEmbeddings, ServeError>,
}

impl ServeReply {
    /// A successful reply.
    pub fn ok(id: u64, dim: usize, rows: Vec<f32>) -> ServeReply {
        ServeReply { id, body: Ok(ServeEmbeddings { dim, rows }) }
    }

    /// A typed error reply.
    pub fn error(id: u64, kind: ServeErrorKind, detail: impl Into<String>) -> ServeReply {
        ServeReply { id, body: Err(ServeError { kind, detail: detail.into() }) }
    }

    /// Append the wire encoding of this reply to `out`.
    pub fn encode_to(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&REPLY_MAGIC);
        put_u64(out, self.id);
        match &self.body {
            Ok(emb) => {
                out.push(STATUS_OK);
                put_u32(out, emb.dim as u32);
                put_u32(out, emb.num_rows() as u32);
                for &x in &emb.rows {
                    put_u32(out, x.to_bits());
                }
            }
            Err(e) => {
                out.push(e.kind.code());
                // Truncate on a char boundary: a cut mid-codepoint would
                // make the client's decode fail on utf-8 instead of
                // delivering the typed error.
                let mut take = e.detail.len().min(MAX_ERROR_DETAIL);
                while !e.detail.is_char_boundary(take) {
                    take -= 1;
                }
                put_u32(out, take as u32);
                out.extend_from_slice(&e.detail.as_bytes()[..take]);
            }
        }
    }

    /// Decode one reply frame from `r`, consuming exactly the frame.
    pub fn decode_from<R: Read>(r: &mut R) -> io::Result<ServeReply> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if magic != REPLY_MAGIC {
            return Err(malformed("bad reply magic"));
        }
        let id = read_u64(r)?;
        let status = read_u8(r)?;
        if status == STATUS_OK {
            let dim = read_u32(r)? as usize;
            let nrows = read_u32(r)? as usize;
            let values = dim.checked_mul(nrows).ok_or_else(|| malformed("reply size overflow"))?;
            if values > MAX_REPLY_VALUES {
                return Err(malformed("reply value count exceeds cap"));
            }
            let mut rows = Vec::with_capacity(values);
            for _ in 0..values {
                rows.push(f32::from_bits(read_u32(r)?));
            }
            Ok(ServeReply { id, body: Ok(ServeEmbeddings { dim, rows }) })
        } else {
            let kind = ServeErrorKind::from_code(status)
                .ok_or_else(|| malformed("unknown reply status"))?;
            let len = read_u32(r)? as usize;
            if len > MAX_ERROR_DETAIL {
                return Err(malformed("error detail exceeds cap"));
            }
            let mut raw = vec![0u8; len];
            r.read_exact(&mut raw)?;
            let detail = String::from_utf8(raw).map_err(|_| malformed("error detail not utf-8"))?;
            Ok(ServeReply { id, body: Err(ServeError { kind, detail }) })
        }
    }
}

/// Exact per-request latency histogram: every sample is kept (serve
/// batches are small relative to memory), so percentiles are the true
/// nearest-rank order statistics, not bucket approximations — merged
/// histograms stay exact too.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LatencyHistogram {
    samples: Vec<u64>,
}

impl LatencyHistogram {
    /// Record one latency sample in microseconds.
    pub fn record(&mut self, micros: u64) {
        self.samples.push(micros);
    }

    /// Record a [`Duration`] (saturating at `u64::MAX` microseconds).
    pub fn record_duration(&mut self, d: Duration) {
        self.record(u64::try_from(d.as_micros()).unwrap_or(u64::MAX));
    }

    /// Number of recorded samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Fold another histogram into this one; the merge is exact (the
    /// union of the sample sets), so any percentile of the merge lies
    /// between the same percentile of the two parts.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        self.samples.extend_from_slice(&other.samples);
    }

    /// Nearest-rank percentile: the smallest sample such that at least
    /// `p`% of samples are ≤ it. `None` on an empty histogram.
    pub fn percentile(&self, p: f64) -> Option<u64> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let n = sorted.len();
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        Some(sorted[rank.clamp(1, n) - 1])
    }

    /// Median latency in microseconds.
    pub fn p50(&self) -> Option<u64> {
        self.percentile(50.0)
    }

    /// 99th-percentile latency in microseconds.
    pub fn p99(&self) -> Option<u64> {
        self.percentile(99.0)
    }

    /// Worst recorded latency in microseconds.
    pub fn max(&self) -> Option<u64> {
        self.samples.iter().copied().max()
    }

    /// One-line report fragment: `p50=..µs p99=..µs max=..µs n=..`.
    pub fn summary(&self) -> String {
        match (self.p50(), self.p99(), self.max()) {
            (Some(p50), Some(p99), Some(max)) => {
                format!("p50={p50}µs p99={p99}µs max={max}µs n={}", self.samples.len())
            }
            _ => "n=0".to_string(),
        }
    }
}

/// A one-shot rendezvous slot: the serving rank publishes its bound
/// listener address (useful with port 0), a client-side thread waits on
/// it. `Condvar`-based so it is `Sync` and usable under the worker
/// harness's `Fn + Sync` closures.
#[derive(Debug, Default)]
pub struct AddrSlot {
    addr: Mutex<Option<SocketAddr>>,
    ready: Condvar,
}

impl AddrSlot {
    /// Publish the bound address and wake all waiters.
    pub fn publish(&self, addr: SocketAddr) {
        *lock(&self.addr) = Some(addr);
        self.ready.notify_all();
    }

    /// Wait up to `timeout` for the address; `None` on timeout.
    pub fn wait(&self, timeout: Duration) -> Option<SocketAddr> {
        let deadline = Instant::now() + timeout;
        let mut slot = lock(&self.addr);
        while slot.is_none() {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return None;
            }
            slot = match self.ready.wait_timeout(slot, left) {
                Ok((guard, _)) => guard,
                Err(poisoned) => poisoned.into_inner().0,
            };
        }
        *slot
    }
}

/// One admitted, not-yet-answered client request. Dropping a `Pending`
/// without sending on `reply` is safe: the handler thread synthesizes a
/// typed `ShuttingDown` reply when the channel closes.
#[derive(Debug)]
pub struct Pending {
    pub id: u64,
    pub nodes: Vec<NodeId>,
    pub shutdown: bool,
    pub reply: mpsc::Sender<ServeReply>,
    pub arrived: Instant,
}

/// One coalesced batch handed to the serve loop.
#[derive(Debug, Default)]
pub struct Gathered {
    /// Query requests admitted into this batch, arrival order.
    pub pending: Vec<Pending>,
    /// True when a shutdown request arrived (already acked) or the
    /// frontend is closing; the serve loop should finish `pending` and
    /// then stop.
    pub shutdown: bool,
}

/// The open-connection registry: one entry per live handler thread,
/// keyed by an accept-order token, inserted by the accept loop and
/// removed by the handler on exit — so [`Frontend::stop`] can unblock
/// every handler, and a closed connection costs nothing after its
/// handler returns (no per-request FD leak on a resident server).
type ConnRegistry = Arc<Mutex<HashMap<u64, TcpStream>>>;

/// Removes a handler's registry entry (and with it the last clone of
/// its socket) however the handler exits.
struct ConnGuard {
    token: u64,
    conns: ConnRegistry,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        lock(&self.conns).remove(&self.token);
    }
}

/// What the per-connection handlers need from the frontend.
#[derive(Clone)]
struct HandlerShared {
    queue: Sender<Pending>,
    /// Admitted-but-unanswered queries; the admission-control gauge.
    outstanding: Arc<AtomicUsize>,
    max_inflight: usize,
    rejected: Arc<AtomicU64>,
}

impl HandlerShared {
    /// Try to claim an admission slot; `false` ⇒ answer `Overloaded`.
    fn try_admit(&self) -> bool {
        self.outstanding
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |cur| {
                (cur < self.max_inflight).then_some(cur + 1)
            })
            .is_ok()
    }

    /// Release an admission slot once the request has its answer.
    fn release(&self) {
        self.outstanding.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Rank 0's client listener: accepts connections, admission-controls
/// decoded requests by an outstanding-request count, and coalesces them
/// into batches for the serve loop.
#[derive(Debug)]
pub struct Frontend {
    addr: SocketAddr,
    queue: Receiver<Pending>,
    stash: Option<Pending>,
    stop: Arc<AtomicBool>,
    conns: ConnRegistry,
    rejected: Arc<AtomicU64>,
    accept: Option<JoinHandle<()>>,
}

impl Frontend {
    /// Bind the client listener on `127.0.0.1:port` (0 ⇒ ephemeral; read
    /// the real port back via [`Frontend::local_addr`]) with at most
    /// `max_inflight` admitted-but-unanswered requests.
    pub fn bind(port: u16, max_inflight: usize) -> io::Result<Frontend> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let (tx, rx) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        let conns: ConnRegistry = Arc::new(Mutex::new(HashMap::new()));
        let rejected = Arc::new(AtomicU64::new(0));
        let shared = HandlerShared {
            queue: tx,
            outstanding: Arc::new(AtomicUsize::new(0)),
            max_inflight: max_inflight.max(1),
            rejected: Arc::clone(&rejected),
        };
        let accept = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            thread::spawn(move || accept_loop(listener, shared, stop, conns))
        };
        Ok(Frontend {
            addr,
            queue: rx,
            stash: None,
            stop,
            conns,
            rejected,
            accept: Some(accept),
        })
    }

    /// The bound listener address.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Requests rejected by admission control so far.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Live client connections right now (registry size; an entry dies
    /// with its handler thread, so a resident server holds FDs only for
    /// clients that are actually connected).
    pub fn open_connections(&self) -> usize {
        lock(&self.conns).len()
    }

    /// Wait up to `idle_wait` for a first request, then coalesce: keep
    /// draining the queue until the batch holds at least `max_nodes`
    /// node ids or `max_wait` has elapsed since the first request was
    /// taken. No request within `idle_wait` returns an *empty*,
    /// non-shutdown [`Gathered`] — the caller's cue to run a liveness
    /// round and come back, so an idle frontend never blocks forever. A
    /// request that would push a non-empty batch past `max_nodes` is
    /// stashed for the next call (the *first* request of a batch is
    /// always taken whole, so a single oversized request still forms a
    /// batch — per-request caps are the serve loop's job). A shutdown
    /// request is acked immediately and flips [`Gathered::shutdown`].
    pub fn next_batch(&mut self, max_nodes: usize, max_wait: Duration, idle_wait: Duration) -> Gathered {
        let mut out = Gathered::default();
        let mut total = 0usize;
        let first = match self.stash.take() {
            Some(p) => p,
            None => match self.queue.recv_timeout(idle_wait) {
                Ok(p) => p,
                Err(RecvTimeoutError::Timeout) => return out,
                Err(RecvTimeoutError::Disconnected) => {
                    out.shutdown = true;
                    return out;
                }
            },
        };
        admit(first, &mut out, &mut total);
        let deadline = Instant::now() + max_wait;
        while !out.shutdown && total < max_nodes {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            match self.queue.recv_timeout(left) {
                Ok(p) if !p.shutdown && total + p.nodes.len() > max_nodes => {
                    self.stash = Some(p);
                    break;
                }
                Ok(p) => admit(p, &mut out, &mut total),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    out.shutdown = true;
                    break;
                }
            }
        }
        out
    }

    /// Answer `pending` plus everything still queued or stashed with a
    /// typed error (queued shutdown requests are acked Ok). Used on the
    /// fabric-error path (`PeerLost`) and at clean stop (`ShuttingDown`)
    /// so no client is ever left without a reply.
    pub fn fail_all(&mut self, pending: Vec<Pending>, kind: ServeErrorKind, detail: &str) {
        let mut drained = pending;
        if let Some(p) = self.stash.take() {
            drained.push(p);
        }
        while let Ok(p) = self.queue.try_recv() {
            drained.push(p);
        }
        for p in drained {
            let reply = if p.shutdown {
                ServeReply::ok(p.id, 0, Vec::new())
            } else {
                ServeReply::error(p.id, kind, detail)
            };
            let _ = p.reply.send(reply);
        }
    }

    /// Stop accepting: shut every open client socket (unblocking handler
    /// reads) and join the accept thread. Idempotent; also runs on drop.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        for conn in lock(&self.conns).values() {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Frontend {
    fn drop(&mut self) {
        self.stop();
    }
}

fn admit(p: Pending, out: &mut Gathered, total: &mut usize) {
    if p.shutdown {
        let _ = p.reply.send(ServeReply::ok(p.id, 0, Vec::new()));
        out.shutdown = true;
    } else {
        *total += p.nodes.len();
        out.pending.push(p);
    }
}

/// True for accept() errors that occur in normal operation and must not
/// stop the listener: an aborted handshake, a signal, or transient FD
/// exhaustion (EMFILE/ENFILE — raw errno, `io::ErrorKind` has no stable
/// name for them).
fn accept_error_is_transient(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::ConnectionAborted | io::ErrorKind::Interrupted)
        || matches!(e.raw_os_error(), Some(23 /* ENFILE */) | Some(24 /* EMFILE */))
}

fn accept_loop(
    listener: TcpListener,
    shared: HandlerShared,
    stop: Arc<AtomicBool>,
    conns: ConnRegistry,
) {
    let mut next_token = 0u64;
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nodelay(true);
                let token = next_token;
                next_token += 1;
                if let Ok(clone) = stream.try_clone() {
                    lock(&conns).insert(token, clone);
                }
                let guard = ConnGuard { token, conns: Arc::clone(&conns) };
                let shared = shared.clone();
                thread::spawn(move || {
                    let _guard = guard;
                    handle_conn(stream, shared);
                });
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => thread::sleep(ACCEPT_POLL),
            Err(e) if accept_error_is_transient(&e) => {
                // Out of FDs ⇒ back off so live handlers can finish and
                // free theirs; aborted/interrupted ⇒ just try again.
                if e.raw_os_error().is_some_and(|c| c == 23 || c == 24) {
                    eprintln!("[serve] accept backing off: {e}");
                    thread::sleep(ACCEPT_FD_BACKOFF);
                }
            }
            Err(e) => {
                eprintln!("[serve] accept failed, listener stopping: {e}");
                break;
            }
        }
    }
}

fn write_reply(stream: &mut TcpStream, reply: &ServeReply) -> io::Result<()> {
    let mut buf = Vec::new();
    reply.encode_to(&mut buf);
    stream.write_all(&buf)
}

/// Per-connection handler: decode requests in a loop, admission-control
/// each against the outstanding-request bound, block for the serve
/// loop's answer, and write it back. A client disconnect (EOF, reset,
/// garbage) just ends this thread — the serve loop is untouched, and if
/// the request was already admitted its reply is simply absorbed by the
/// dead socket (the admission slot is still released when the answer
/// arrives, so a vanished client cannot pin capacity forever).
fn handle_conn(mut stream: TcpStream, shared: HandlerShared) {
    loop {
        let req = match ServeRequest::decode_from(&mut stream) {
            Ok(r) => r,
            Err(_) => return,
        };
        match req.op {
            ServeOp::Query(nodes) if nodes.is_empty() => {
                // Answered locally: an empty query has an empty answer
                // and must not cost the mesh a collective round.
                if write_reply(&mut stream, &ServeReply::ok(req.id, 0, Vec::new())).is_err() {
                    return;
                }
            }
            ServeOp::Query(nodes) => {
                let reply = if shared.try_admit() {
                    let (tx, rx) = mpsc::channel();
                    let pending =
                        Pending { id: req.id, nodes, shutdown: false, reply: tx, arrived: Instant::now() };
                    let reply = match shared.queue.send(pending) {
                        Ok(()) => match rx.recv() {
                            Ok(r) => r,
                            Err(_) => ServeReply::error(
                                req.id,
                                ServeErrorKind::ShuttingDown,
                                "serve loop stopped before answering",
                            ),
                        },
                        Err(_) => {
                            ServeReply::error(req.id, ServeErrorKind::ShuttingDown, "serve loop stopped")
                        }
                    };
                    shared.release();
                    reply
                } else {
                    shared.rejected.fetch_add(1, Ordering::Relaxed);
                    ServeReply::error(
                        req.id,
                        ServeErrorKind::Overloaded,
                        "too many requests in flight; retry later",
                    )
                };
                if write_reply(&mut stream, &reply).is_err() {
                    return;
                }
            }
            ServeOp::Shutdown => {
                let (tx, rx) = mpsc::channel();
                let pending =
                    Pending { id: req.id, nodes: Vec::new(), shutdown: true, reply: tx, arrived: Instant::now() };
                // Outside admission control: shutdown must never be
                // load-shed.
                let reply = match shared.queue.send(pending) {
                    Ok(()) => match rx.recv() {
                        Ok(r) => r,
                        Err(_) => ServeReply::ok(req.id, 0, Vec::new()),
                    },
                    Err(_) => ServeReply::ok(req.id, 0, Vec::new()),
                };
                let _ = write_reply(&mut stream, &reply);
                return;
            }
        }
    }
}

/// Send one query to a serving frontend and block for the reply.
pub fn query_once(addr: &str, id: u64, nodes: &[NodeId]) -> io::Result<ServeReply> {
    send_request(addr, &ServeRequest { id, op: ServeOp::Query(nodes.to_vec()) })
}

/// Ask a serving frontend to shut the whole mesh down cleanly.
pub fn request_shutdown(addr: &str) -> io::Result<ServeReply> {
    send_request(addr, &ServeRequest { id: 0, op: ServeOp::Shutdown })
}

fn send_request(addr: &str, req: &ServeRequest) -> io::Result<ServeReply> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let mut buf = Vec::new();
    req.encode_to(&mut buf);
    stream.write_all(&buf)?;
    ServeReply::decode_from(&mut stream)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn round_trip_request(req: &ServeRequest) -> ServeRequest {
        let mut buf = Vec::new();
        req.encode_to(&mut buf);
        let mut cur = Cursor::new(buf.as_slice());
        let got = ServeRequest::decode_from(&mut cur).unwrap();
        assert_eq!(cur.position() as usize, buf.len(), "decode must consume the exact frame");
        got
    }

    fn round_trip_reply(reply: &ServeReply) -> ServeReply {
        let mut buf = Vec::new();
        reply.encode_to(&mut buf);
        let mut cur = Cursor::new(buf.as_slice());
        let got = ServeReply::decode_from(&mut cur).unwrap();
        assert_eq!(cur.position() as usize, buf.len(), "decode must consume the exact frame");
        got
    }

    #[test]
    fn request_codec_round_trips() {
        for req in [
            ServeRequest { id: 0, op: ServeOp::Query(Vec::new()) },
            ServeRequest { id: 7, op: ServeOp::Query(vec![0, 1, u32::MAX]) },
            ServeRequest { id: u64::MAX, op: ServeOp::Shutdown },
        ] {
            assert_eq!(round_trip_request(&req), req);
        }
    }

    #[test]
    fn reply_codec_round_trips() {
        for reply in [
            ServeReply::ok(3, 2, vec![1.0, -0.5, f32::MIN_POSITIVE, 0.0]),
            ServeReply::ok(4, 0, Vec::new()),
            ServeReply::error(5, ServeErrorKind::Overloaded, "queue full"),
            ServeReply::error(6, ServeErrorKind::PeerLost, ""),
        ] {
            assert_eq!(round_trip_reply(&reply), reply);
        }
        // NaN payloads round-trip by bit pattern (PartialEq would lie).
        let nan = ServeReply::ok(9, 1, vec![f32::from_bits(0x7fc0_1234)]);
        let got = round_trip_reply(&nan);
        match (got.body, nan.body) {
            (Ok(a), Ok(b)) => assert_eq!(
                a.rows.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.rows.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            ),
            _ => panic!("expected Ok bodies"),
        }
    }

    #[test]
    fn codec_rejects_malformed_frames() {
        // Wrong magic.
        let mut cur = Cursor::new(&b"XXXX\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00"[..]);
        assert!(ServeRequest::decode_from(&mut cur).is_err());
        // Truncated query payload.
        let mut buf = Vec::new();
        ServeRequest { id: 1, op: ServeOp::Query(vec![1, 2, 3]) }.encode_to(&mut buf);
        buf.truncate(buf.len() - 2);
        assert!(ServeRequest::decode_from(&mut Cursor::new(buf.as_slice())).is_err());
        // Node count above the cap fails before allocating.
        let mut huge = Vec::new();
        huge.extend_from_slice(&REQUEST_MAGIC);
        huge.push(OP_QUERY);
        put_u64(&mut huge, 1);
        put_u32(&mut huge, u32::MAX);
        assert!(ServeRequest::decode_from(&mut Cursor::new(huge.as_slice())).is_err());
        // Unknown reply status byte.
        let mut bad = Vec::new();
        bad.extend_from_slice(&REPLY_MAGIC);
        put_u64(&mut bad, 1);
        bad.push(250);
        put_u32(&mut bad, 0);
        assert!(ServeReply::decode_from(&mut Cursor::new(bad.as_slice())).is_err());
    }

    #[test]
    fn histogram_exact_percentiles_on_known_distribution() {
        let mut h = LatencyHistogram::default();
        for v in (1..=100u64).rev() {
            h.record(v);
        }
        assert_eq!(h.p50(), Some(50));
        assert_eq!(h.p99(), Some(99));
        assert_eq!(h.max(), Some(100));
        assert_eq!(h.percentile(100.0), Some(100));
        assert_eq!(h.percentile(1.0), Some(1));
        assert_eq!(h.len(), 100);
        // Skewed distribution: 99 fast samples and one slow outlier.
        let mut h = LatencyHistogram::default();
        for _ in 0..99 {
            h.record(10);
        }
        h.record(5000);
        assert_eq!(h.p50(), Some(10));
        assert_eq!(h.p99(), Some(10));
        assert_eq!(h.max(), Some(5000));
    }

    #[test]
    fn histogram_empty_and_single_sample_edges() {
        let h = LatencyHistogram::default();
        assert!(h.is_empty());
        assert_eq!(h.p50(), None);
        assert_eq!(h.p99(), None);
        assert_eq!(h.max(), None);
        assert_eq!(h.summary(), "n=0");

        let mut h = LatencyHistogram::default();
        h.record(42);
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p), Some(42), "p={p}");
        }
        assert_eq!(h.max(), Some(42));
        assert_eq!(h.summary(), "p50=42µs p99=42µs max=42µs n=1");
    }

    #[test]
    fn merged_histogram_percentiles_are_bounded_by_the_parts() {
        let mut a = LatencyHistogram::default();
        for v in [3u64, 9, 27, 81, 243] {
            a.record(v);
        }
        let mut b = LatencyHistogram::default();
        for v in [5u64, 10, 20, 40, 80, 160, 320] {
            b.record(v);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.len(), a.len() + b.len());
        for p in [1.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
            let (pa, pb) = (a.percentile(p).unwrap(), b.percentile(p).unwrap());
            let pm = merged.percentile(p).unwrap();
            assert!(pa.min(pb) <= pm && pm <= pa.max(pb), "p={p}: {pa} {pb} merged {pm}");
        }
        // Merging an empty histogram is the identity.
        let mut same = a.clone();
        same.merge(&LatencyHistogram::default());
        assert_eq!(same, a);
    }

    #[test]
    fn admission_overflow_returns_typed_overloaded() {
        let mut front = Frontend::bind(0, 1).unwrap();
        let addr = front.local_addr();
        // Occupy the single admission slot: write a query and leave the
        // socket open without reading the reply.
        let mut occupant = TcpStream::connect(addr).unwrap();
        let mut buf = Vec::new();
        ServeRequest { id: 100, op: ServeOp::Query(vec![1, 2]) }.encode_to(&mut buf);
        occupant.write_all(&buf).unwrap();
        // Probe until a request is turned away: once the slot is held
        // (by the occupant, or by a probe that raced it in), every
        // further request must get a typed Overloaded reply — never a
        // silent drop. Probes that time out were admitted: keep their
        // sockets alive and keep probing.
        let deadline = Instant::now() + Duration::from_secs(20);
        let mut held = Vec::new();
        loop {
            assert!(Instant::now() < deadline, "no Overloaded reply before deadline");
            let mut probe = TcpStream::connect(addr).unwrap();
            let mut pbuf = Vec::new();
            ServeRequest { id: 200, op: ServeOp::Query(vec![3]) }.encode_to(&mut pbuf);
            probe.write_all(&pbuf).unwrap();
            probe.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
            match ServeReply::decode_from(&mut probe) {
                Ok(r) => {
                    let e = r.body.expect_err("nobody is serving; an Ok reply is impossible");
                    assert_eq!(e.kind, ServeErrorKind::Overloaded);
                    assert!(!e.detail.is_empty(), "rejection must say why");
                    break;
                }
                Err(_) => held.push(probe),
            }
        }
        assert!(front.rejected() >= 1);
        // The serving side is not wedged: exactly one request holds the
        // slot (capacity is 1, everything else was rejected) — drain
        // and answer it.
        let mut gathered = front.next_batch(16, Duration::from_millis(10), Duration::from_secs(10));
        assert!(!gathered.shutdown);
        assert_eq!(gathered.pending.len(), 1);
        let p = gathered.pending.pop().unwrap();
        let rows = vec![0.5; p.nodes.len()];
        p.reply.send(ServeReply::ok(p.id, 1, rows)).unwrap();
        drop(held);
        drop(occupant);
    }

    #[test]
    fn client_disconnect_mid_request_does_not_wedge_the_loop() {
        let mut front = Frontend::bind(0, 4).unwrap();
        let addr = front.local_addr();
        {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut buf = Vec::new();
            ServeRequest { id: 1, op: ServeOp::Query(vec![5]) }.encode_to(&mut buf);
            s.write_all(&buf).unwrap();
        } // client gone before reading its reply
        let mut gathered = front.next_batch(16, Duration::from_millis(50), Duration::from_secs(10));
        assert_eq!(gathered.pending.len(), 1);
        let p = gathered.pending.pop().unwrap();
        // Replying to the dead client is absorbed, not an error.
        let _ = p.reply.send(ServeReply::ok(p.id, 1, vec![1.0]));
        // A fresh client is still served afterwards.
        let addr_s = addr.to_string();
        let client = thread::spawn(move || query_once(&addr_s, 2, &[9]).unwrap());
        let mut gathered = front.next_batch(16, Duration::from_millis(200), Duration::from_secs(10));
        assert_eq!(gathered.pending.len(), 1);
        let p = gathered.pending.pop().unwrap();
        assert_eq!(p.nodes, vec![9]);
        p.reply.send(ServeReply::ok(p.id, 1, vec![2.5])).unwrap();
        let got = client.join().unwrap();
        assert_eq!(got.id, 2);
        assert_eq!(got.body.unwrap().rows, vec![2.5]);
    }

    #[test]
    fn coalesced_replies_route_to_the_right_client() {
        let mut front = Frontend::bind(0, 8).unwrap();
        let addr = front.local_addr().to_string();
        let clients: Vec<_> = (0..4u32)
            .map(|k| {
                let addr = addr.clone();
                thread::spawn(move || query_once(&addr, u64::from(k), &[k, k + 10]).unwrap())
            })
            .collect();
        let deadline = Instant::now() + Duration::from_secs(20);
        let mut got = Vec::new();
        while got.len() < 4 {
            assert!(Instant::now() < deadline, "requests never arrived");
            let mut g = front.next_batch(64, Duration::from_millis(20), Duration::from_secs(1));
            got.append(&mut g.pending);
        }
        // Answer each pending with rows derived from ITS node list.
        for p in got {
            let rows: Vec<f32> = p.nodes.iter().map(|&v| v as f32).collect();
            p.reply.send(ServeReply::ok(p.id, 1, rows)).unwrap();
        }
        for (k, client) in clients.into_iter().enumerate() {
            let r = client.join().unwrap();
            assert_eq!(r.id, k as u64, "reply correlated to the wrong request");
            let emb = r.body.unwrap();
            assert_eq!(emb.rows, vec![k as f32, (k + 10) as f32], "cross-request contamination");
        }
    }

    #[test]
    fn shutdown_request_is_acked_and_flags_the_batch() {
        let mut front = Frontend::bind(0, 4).unwrap();
        let addr = front.local_addr().to_string();
        let client = thread::spawn(move || request_shutdown(&addr).unwrap());
        let gathered = front.next_batch(16, Duration::from_millis(10), Duration::from_secs(10));
        assert!(gathered.shutdown);
        assert!(gathered.pending.is_empty());
        let reply = client.join().unwrap();
        assert!(reply.body.is_ok());
    }

    #[test]
    fn empty_query_is_answered_without_touching_the_queue() {
        let mut front = Frontend::bind(0, 1).unwrap();
        let addr = front.local_addr().to_string();
        let reply = query_once(&addr, 11, &[]).unwrap();
        assert_eq!(reply.id, 11);
        let emb = reply.body.unwrap();
        assert_eq!(emb.dim, 0);
        assert!(emb.rows.is_empty());
        // Nothing was enqueued: a subsequent gather only sees the real
        // request sent below.
        let addr2 = front.local_addr().to_string();
        let client = thread::spawn(move || query_once(&addr2, 12, &[3]).unwrap());
        let mut gathered = front.next_batch(4, Duration::from_millis(20), Duration::from_secs(10));
        assert_eq!(gathered.pending.len(), 1);
        let p = gathered.pending.pop().unwrap();
        assert_eq!(p.id, 12);
        p.reply.send(ServeReply::ok(p.id, 1, vec![0.0])).unwrap();
        client.join().unwrap();
    }

    #[test]
    fn error_detail_truncates_on_a_char_boundary() {
        // 3-byte chars with a cap that is not a multiple of 3: a byte
        // cut would land mid-codepoint and break the client's decode.
        assert_eq!(MAX_ERROR_DETAIL % 3, 1);
        let detail = "…".repeat(MAX_ERROR_DETAIL / 3 + 10);
        assert!(detail.len() > MAX_ERROR_DETAIL);
        let reply = ServeReply::error(1, ServeErrorKind::Internal, detail.clone());
        let mut buf = Vec::new();
        reply.encode_to(&mut buf);
        let got = ServeReply::decode_from(&mut Cursor::new(buf.as_slice()))
            .expect("truncated detail must still decode");
        let e = got.body.unwrap_err();
        assert_eq!(e.kind, ServeErrorKind::Internal);
        assert!(e.detail.len() <= MAX_ERROR_DETAIL);
        assert!(detail.starts_with(&e.detail), "truncation must be a prefix");
        assert!(!e.detail.is_empty());
    }

    #[test]
    fn idle_timeout_returns_an_empty_non_shutdown_batch() {
        let mut front = Frontend::bind(0, 4).unwrap();
        let start = Instant::now();
        let gathered = front.next_batch(16, Duration::from_millis(1), Duration::from_millis(30));
        assert!(gathered.pending.is_empty());
        assert!(!gathered.shutdown, "idle is not shutdown");
        assert!(start.elapsed() >= Duration::from_millis(30), "must wait out idle_wait");
    }

    #[test]
    fn closed_connections_are_pruned_from_the_registry() {
        const ROUNDS: usize = 8;
        let mut front = Frontend::bind(0, 4).unwrap();
        let addr = front.local_addr().to_string();
        // Each query_once opens a fresh connection and drops it after
        // the reply — the resident-server traffic pattern that must not
        // leak an FD per request.
        let client = thread::spawn(move || {
            for k in 0..ROUNDS as u64 {
                let got = query_once(&addr, k, &[1]).unwrap();
                assert_eq!(got.id, k);
            }
        });
        let mut served = 0;
        while served < ROUNDS {
            for p in front.next_batch(16, Duration::from_millis(5), Duration::from_secs(10)).pending {
                p.reply.send(ServeReply::ok(p.id, 1, vec![0.0])).unwrap();
                served += 1;
            }
        }
        client.join().unwrap();
        // Handlers notice the closed sockets and remove their registry
        // entries; poll briefly for the races to settle.
        let deadline = Instant::now() + Duration::from_secs(20);
        while front.open_connections() > 0 {
            assert!(Instant::now() < deadline, "registry still holds closed connections");
            thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn addr_slot_publishes_and_times_out() {
        let slot = Arc::new(AddrSlot::default());
        assert_eq!(slot.wait(Duration::from_millis(10)), None);
        let waiter = {
            let slot = Arc::clone(&slot);
            thread::spawn(move || slot.wait(Duration::from_secs(20)))
        };
        let addr: SocketAddr = "127.0.0.1:9550".parse().unwrap();
        slot.publish(addr);
        assert_eq!(waiter.join().unwrap(), Some(addr));
        assert_eq!(slot.wait(Duration::from_millis(1)), Some(addr));
    }
}
