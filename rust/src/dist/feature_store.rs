//! Partitioned feature store: fetch input-node features across workers in
//! the two fixed rounds of the paper's cost model.
//!
//! Features are partitioned under *both* schemes (they are the storage
//! that cannot be replicated — Fig 4), so every minibatch pays exactly one
//! [`RoundKind::FeatureRequest`] round (ship wanted node ids to their
//! owners) and one [`RoundKind::FeatureResponse`] round (rows come back),
//! regardless of worker count or cache configuration. A
//! [`FeatureCache`] in front short-circuits resident remote rows, cutting
//! response *bytes* while the round structure — and every returned row —
//! stays identical.
//!
//! This is a collective: all ranks must call [`fetch_features`] (or
//! [`prefill_cache`]) together, even ranks that need no remote rows.

use std::collections::HashMap;

use crate::graph::NodeId;
use crate::partition::WorkerShard;

use super::comm::{Comm, CommError, RoundKind};
use super::feature_cache::FeatureCache;

/// Accounting for one `fetch_features` call (per worker, per call — the
/// global aggregates live in [`super::comm::Counters`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FetchStats {
    /// Requested rows served from this worker's own shard.
    pub local_rows: usize,
    /// Requested rows owned by other workers (cache hits included).
    pub remote_rows: usize,
    /// Remote rows served from the cache instead of the fabric.
    pub cache_hits: usize,
    /// Feature bytes this worker shipped to peers in the response round.
    pub bytes_out: u64,
    /// Feature bytes this worker received from peers.
    pub bytes_in: u64,
}

/// Gather the feature rows of `nodes` (in order, duplicates allowed) into
/// `out` as a row-major `[nodes.len(), feat_dim]` buffer.
///
/// Local rows copy straight from the shard; remote rows come from the
/// cache when resident, otherwise from their owners via the two feature
/// rounds (deduplicated per call — each missing row crosses the wire at
/// most once). Freshly fetched rows are offered to the cache. Fabric
/// failures surface as `Err(CommError)` on every transport.
pub fn fetch_features(
    comm: &mut Comm,
    shard: &WorkerShard,
    nodes: &[NodeId],
    mut cache: Option<&mut FeatureCache>,
    out: &mut Vec<f32>,
) -> Result<FetchStats, CommError> {
    let f = shard.feat_dim;
    let world = comm.world();
    let rank = comm.rank();
    out.clear();
    out.resize(nodes.len() * f, 0.0);
    let mut stats = FetchStats::default();

    // ---- Pass 1: serve local + cached rows now; queue unique misses.
    // Cached rows are copied immediately (not after the exchange) so a
    // later insert can never evict a row we still owe the caller.
    // `fetched` records each miss's (owner, position-in-request) as it is
    // queued — the slot its row will occupy in the response.
    let mut requests: Vec<Vec<NodeId>> = vec![Vec::new(); world];
    let mut fetched: HashMap<NodeId, (usize, usize)> = HashMap::new();
    let mut deferred: Vec<(usize, NodeId)> = Vec::new();
    for (i, &v) in nodes.iter().enumerate() {
        let dst = &mut out[i * f..(i + 1) * f];
        if shard.owns(v) {
            dst.copy_from_slice(shard.local_feat(v));
            stats.local_rows += 1;
            continue;
        }
        stats.remote_rows += 1;
        if let Some(row) = cache.as_deref_mut().and_then(|c| c.get(v)) {
            dst.copy_from_slice(row);
            stats.cache_hits += 1;
            continue;
        }
        if let std::collections::hash_map::Entry::Vacant(slot) = fetched.entry(v) {
            let p = shard.book.part_of(v);
            slot.insert((p, requests[p].len()));
            requests[p].push(v);
        }
        deferred.push((i, v));
    }

    // ---- The two feature rounds (collective even with zero misses).
    let granted = comm.exchange(RoundKind::FeatureRequest, requests)?;
    let mut replies: Vec<Vec<f32>> = Vec::with_capacity(world);
    for (src, req) in granted.iter().enumerate() {
        let mut rep: Vec<f32> = Vec::with_capacity(req.len() * f);
        for &v in req {
            // Remote ids are untrusted: a request for a node outside the
            // id space or not stored here is a malformed round from `src`,
            // failing the collective instead of panicking this rank.
            if (v as usize) >= shard.feat_row.len() || !shard.owns(v) {
                return Err(CommError::Malformed {
                    src,
                    detail: format!("feature request for node {v} not owned by rank {rank}"),
                });
            }
            rep.extend_from_slice(shard.local_feat(v));
        }
        if src != rank {
            stats.bytes_out += (rep.len() * 4) as u64;
        }
        replies.push(rep);
    }
    let rows = comm.exchange(RoundKind::FeatureResponse, replies)?;
    for (src, inbox) in rows.iter().enumerate() {
        if src != rank {
            stats.bytes_in += (inbox.len() * 4) as u64;
        }
    }

    // ---- Pass 2: fill deferred slots from the responses, warm the cache.
    // Owners answer in request order, so slot `j` of our request to `p`
    // must exist in their reply; a short reply is a malformed round.
    for (i, v) in deferred {
        let (p, j) = fetched[&v];
        let row = rows[p].get(j * f..(j + 1) * f).ok_or_else(|| CommError::Malformed {
            src: p,
            detail: format!(
                "feature response from rank {p} truncated: row {j} of node {v} missing"
            ),
        })?;
        out[i * f..(i + 1) * f].copy_from_slice(row);
    }
    if let Some(c) = cache.as_deref_mut() {
        for (&v, &(p, j)) in &fetched {
            c.insert(v, &rows[p][j * f..(j + 1) * f]);
        }
    }
    Ok(stats)
}

/// Warm a cache with `nodes` (typically
/// [`super::feature_cache::hottest_remote_nodes`]) before training.
/// Collective, like `fetch_features` — all ranks call it together, each
/// with its own warm-up set.
pub fn prefill_cache(
    comm: &mut Comm,
    shard: &WorkerShard,
    nodes: &[NodeId],
    cache: &mut FeatureCache,
) -> Result<FetchStats, CommError> {
    let mut scratch = Vec::new();
    fetch_features(comm, shard, nodes, Some(cache), &mut scratch)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use std::sync::Arc;

    use super::super::feature_cache::CachePolicy;
    use super::super::net::NetworkModel;
    use super::super::worker::run_workers;
    use super::*;
    use crate::graph::generator::{make_dataset, DatasetParams};
    use crate::graph::Dataset;
    use crate::partition::{build_shards, partition_graph, PartitionConfig, ReplicationPolicy};

    fn dataset() -> Dataset {
        make_dataset(&DatasetParams {
            name: "feature-store-unit".into(),
            num_nodes: 300,
            avg_degree: 6,
            feat_dim: 5,
            num_classes: 3,
            labeled_frac: 0.3,
            p_intra: 0.8,
            noise: 0.2,
            seed: 13,
        })
    }

    #[test]
    fn duplicate_nodes_cross_the_wire_once() {
        let d = dataset();
        let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(3)));
        let shards = build_shards(&d, &book, &ReplicationPolicy::hybrid());
        let shards_ref = &shards;
        let d_ref = &d;
        let results = run_workers(3, NetworkModel::free(), move |rank, comm| {
            let shard = &shards_ref[rank];
            // Every node requested three times.
            let base: Vec<NodeId> =
                (0..40).map(|i| ((i * 31 + rank * 97) % d_ref.num_nodes()) as NodeId).collect();
            let nodes: Vec<NodeId> =
                base.iter().chain(base.iter()).chain(base.iter()).copied().collect();
            let mut out = Vec::new();
            let stats = fetch_features(comm, shard, &nodes, None, &mut out).unwrap();
            (nodes, out, stats)
        });
        for (nodes, out, stats) in &results {
            assert_eq!(stats.local_rows + stats.remote_rows, nodes.len());
            for (i, &v) in nodes.iter().enumerate() {
                assert_eq!(&out[i * d.feat_dim..(i + 1) * d.feat_dim], d.feat(v));
            }
            // Dedup: at most one wire row per *unique* remote node.
            let unique_remote = stats.remote_rows / 3;
            assert!(stats.bytes_in <= (unique_remote * d.feat_dim * 4) as u64);
        }
    }

    #[test]
    fn prefill_then_fetch_serves_from_cache() {
        let d = dataset();
        let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(2)));
        let shards = build_shards(&d, &book, &ReplicationPolicy::hybrid());
        let shards_ref = &shards;
        let d_ref = &d;
        let results = run_workers(2, NetworkModel::free(), move |rank, comm| {
            let shard = &shards_ref[rank];
            // Warm the cache with every remote node, then fetch them.
            let remote: Vec<NodeId> = (0..d_ref.num_nodes() as NodeId)
                .filter(|&v| !shard.owns(v))
                .collect();
            let mut cache =
                FeatureCache::new(CachePolicy::StaticDegree, remote.len(), d_ref.feat_dim);
            prefill_cache(comm, shard, &remote, &mut cache).unwrap();
            let mut out = Vec::new();
            let stats =
                fetch_features(comm, shard, &remote, Some(&mut cache), &mut out).unwrap();
            (remote, out, stats)
        });
        for (remote, out, stats) in &results {
            assert_eq!(stats.cache_hits, remote.len());
            assert_eq!(stats.bytes_in, 0);
            for (i, &v) in remote.iter().enumerate() {
                assert_eq!(&out[i * d.feat_dim..(i + 1) * d.feat_dim], d.feat(v));
            }
        }
    }
}
