//! Per-worker cache of *remote* feature rows (paper §5 extension).
//!
//! Hybrid partitioning removes sampling rounds; what remains is the
//! feature exchange, and most of its bytes fetch the same hot (high
//! in-degree) remote rows over and over. A small cache in front of
//! [`super::feature_store::fetch_features`] cuts
//! [`super::comm::RoundKind::FeatureResponse`] traffic without changing a
//! single returned row (training stays bit-identical — rows are copies).
//!
//! The slab + CLOCK machinery lives in the generic [`super::cache`]
//! subsystem (shared with the remote-adjacency overlay in
//! [`crate::partition::TopologyView`]); this module is the fixed-width
//! typed wrapper: capacity is counted in rows of `feat_dim` f32 cells,
//! with no per-row overhead, so N rows of budget hold exactly N rows.
//!
//! Two policies (see [`CachePolicy`]):
//! * [`CachePolicy::StaticDegree`] — fill once (warm-up with
//!   [`hottest_remote_nodes`]), never evict: the classic degree-static
//!   cache of GNS/BGL-style systems. Runtime inserts are accepted only
//!   while capacity remains.
//! * [`CachePolicy::Clock`] — second-chance (CLOCK) eviction, an LRU
//!   approximation with O(1) metadata per row.

use crate::graph::NodeId;

use super::cache::SlabCache;
pub use super::cache::CachePolicy;

/// Fixed-capacity cache of feature rows, keyed by global node id.
pub struct FeatureCache {
    inner: SlabCache<f32>,
    capacity: usize,
    feat_dim: usize,
}

impl FeatureCache {
    pub fn new(policy: CachePolicy, capacity: usize, feat_dim: usize) -> Self {
        assert!(feat_dim > 0, "feat_dim must be positive");
        let bytes = (capacity * feat_dim * std::mem::size_of::<f32>()) as u64;
        Self { inner: SlabCache::new(policy, bytes, 0), capacity, feat_dim }
    }

    pub fn policy(&self) -> CachePolicy {
        self.inner.policy()
    }

    /// Capacity in rows.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of resident rows.
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Is `v` resident? (Does not touch the reference bit.)
    pub fn contains(&self, v: NodeId) -> bool {
        self.inner.contains(v)
    }

    /// The cached row for `v`, marking it recently used.
    pub fn get(&mut self, v: NodeId) -> Option<&[f32]> {
        self.inner.get(v)
    }

    /// Offer a row to the cache. Below capacity it is always admitted;
    /// at capacity, `StaticDegree` rejects (static contents) and `Clock`
    /// evicts the first unreferenced row past the hand.
    pub fn insert(&mut self, v: NodeId, row: &[f32]) {
        assert_eq!(row.len(), self.feat_dim, "row width != feat_dim");
        self.inner.insert(v, row);
    }
}

/// Warm-up set for `StaticDegree`: the `k` highest in-degree nodes this
/// worker does *not* own — the rows most likely to be fetched every
/// minibatch. Ties break toward lower node id so every run (and every
/// worker pair) computes the same set. Selection is O(n) + O(k log k):
/// a partition around the k-th candidate, then a sort of the k-prefix
/// only (the degree-then-id order is total, so the selected set — and
/// with it every warm-up set — is deterministic).
pub fn hottest_remote_nodes(
    degree: impl Fn(NodeId) -> usize,
    num_nodes: usize,
    owns: impl Fn(NodeId) -> bool,
    k: usize,
) -> Vec<NodeId> {
    let mut cand: Vec<(usize, NodeId)> = (0..num_nodes as NodeId)
        .filter(|&v| !owns(v))
        .map(|v| (degree(v), v))
        .collect();
    let hotter = |a: &(usize, NodeId), b: &(usize, NodeId)| b.0.cmp(&a.0).then(a.1.cmp(&b.1));
    if k < cand.len() {
        cand.select_nth_unstable_by(k, hotter);
        cand.truncate(k);
    }
    cand.sort_unstable_by(hotter);
    cand.into_iter().map(|(_, v)| v).collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn row(v: NodeId, f: usize) -> Vec<f32> {
        (0..f).map(|j| (v as f32) * 10.0 + j as f32).collect()
    }

    #[test]
    fn below_capacity_nothing_is_evicted_under_either_policy() {
        for policy in [CachePolicy::StaticDegree, CachePolicy::Clock] {
            let mut c = FeatureCache::new(policy, 8, 3);
            for v in 0..8u32 {
                c.insert(v, &row(v, 3));
            }
            assert_eq!(c.len(), 8, "{policy:?}");
            for v in 0..8u32 {
                assert_eq!(c.get(v).unwrap(), &row(v, 3)[..], "{policy:?} node {v}");
            }
        }
    }

    #[test]
    fn static_degree_is_static_at_capacity() {
        let mut c = FeatureCache::new(CachePolicy::StaticDegree, 4, 2);
        for v in 0..4u32 {
            c.insert(v, &row(v, 2));
        }
        // Over-capacity inserts are rejected; the pinned set survives.
        for v in 100..150u32 {
            c.insert(v, &row(v, 2));
            assert!(!c.contains(v));
        }
        for v in 0..4u32 {
            assert!(c.contains(v), "pinned row {v} was evicted");
        }
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn clock_second_chance_protects_referenced_rows() {
        let mut c = FeatureCache::new(CachePolicy::Clock, 4, 2);
        for v in 0..4u32 {
            c.insert(v, &row(v, 2));
        }
        // All reference bits are set, so the first eviction degenerates to
        // FIFO: a full sweep clears every bit, then slot 0 (node 0) goes.
        c.insert(50, &row(50, 2));
        assert_eq!(c.len(), 4);
        assert!(c.contains(50));
        assert!(!c.contains(0));
        // Now bits are clear except node 50's. Touch node 1: the next
        // eviction gives it a second chance and takes node 2 instead.
        c.get(1).unwrap();
        c.insert(51, &row(51, 2));
        assert!(c.contains(1), "referenced row lost its second chance");
        assert!(!c.contains(2), "unreferenced row should have been evicted");
        assert!(c.contains(3) && c.contains(50) && c.contains(51));
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn zero_capacity_cache_is_inert() {
        let mut c = FeatureCache::new(CachePolicy::Clock, 0, 2);
        c.insert(1, &[1.0, 2.0]);
        assert!(c.is_empty());
        assert!(c.get(1).is_none());
    }

    #[test]
    fn reinsert_refreshes_in_place() {
        let mut c = FeatureCache::new(CachePolicy::Clock, 2, 1);
        c.insert(3, &[1.0]);
        c.insert(3, &[2.0]);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(3).unwrap(), &[2.0][..]);
    }

    #[test]
    fn hottest_remote_nodes_ranks_by_degree_skips_owned() {
        let degrees = [5usize, 9, 9, 1, 7, 3];
        let hot = hottest_remote_nodes(
            |v| degrees[v as usize],
            degrees.len(),
            |v| v == 1, // node 1 is local — must be skipped even at degree 9
            3,
        );
        assert_eq!(hot, [2, 4, 0]);
        // k larger than the candidate set returns all remotes.
        let all = hottest_remote_nodes(|v| degrees[v as usize], degrees.len(), |_| false, 100);
        assert_eq!(all.len(), 6);
        assert_eq!(all[0], 1); // degree 9, lower id wins the tie with 2
        assert_eq!(all[1], 2);
    }

    #[test]
    fn topk_selection_matches_full_sort_on_larger_inputs() {
        // The select-then-sort path must agree with the old full-sort
        // implementation for every k (deterministic tie-breaks included).
        let n = 500usize;
        let deg = |v: NodeId| (v as usize * 7919) % 23; // many degree ties
        let owns = |v: NodeId| v % 5 == 0;
        let mut full: Vec<(usize, NodeId)> = (0..n as NodeId)
            .filter(|&v| !owns(v))
            .map(|v| (deg(v), v))
            .collect();
        full.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        for k in [0usize, 1, 7, 100, 399, 400, 1000] {
            let got = hottest_remote_nodes(deg, n, owns, k);
            let want: Vec<NodeId> = full.iter().take(k).map(|&(_, v)| v).collect();
            assert_eq!(got, want, "k={k}");
        }
    }
}
