//! Per-worker cache of *remote* feature rows (paper §5 extension).
//!
//! Hybrid partitioning removes sampling rounds; what remains is the
//! feature exchange, and most of its bytes fetch the same hot (high
//! in-degree) remote rows over and over. A small cache in front of
//! [`super::feature_store::fetch_features`] cuts
//! [`super::comm::RoundKind::FeatureResponse`] traffic without changing a
//! single returned row (training stays bit-identical — rows are copies).
//!
//! Two policies:
//! * [`CachePolicy::StaticDegree`] — fill once (warm-up with
//!   [`hottest_remote_nodes`]), never evict: the classic degree-static
//!   cache of GNS/BGL-style systems. Runtime inserts are accepted only
//!   while capacity remains.
//! * [`CachePolicy::Clock`] — second-chance (CLOCK) eviction, an LRU
//!   approximation with O(1) metadata per row.

use std::collections::HashMap;

use crate::graph::NodeId;

/// Eviction policy selector (the A1 ablation axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePolicy {
    /// Static contents: first fill wins, nothing is ever evicted.
    StaticDegree,
    /// CLOCK / second-chance approximation of LRU.
    Clock,
}

/// Fixed-capacity cache of feature rows, keyed by global node id.
pub struct FeatureCache {
    policy: CachePolicy,
    capacity: usize,
    feat_dim: usize,
    /// Row-major slab, `len == len() * feat_dim`.
    rows: Vec<f32>,
    /// Slot → node id.
    node_of: Vec<NodeId>,
    /// CLOCK reference bits (set on hit, cleared as the hand sweeps).
    referenced: Vec<bool>,
    /// Node id → slot.
    index: HashMap<NodeId, u32>,
    hand: usize,
}

impl FeatureCache {
    pub fn new(policy: CachePolicy, capacity: usize, feat_dim: usize) -> Self {
        assert!(feat_dim > 0, "feat_dim must be positive");
        Self {
            policy,
            capacity,
            feat_dim,
            rows: Vec::new(),
            node_of: Vec::new(),
            referenced: Vec::new(),
            index: HashMap::with_capacity(capacity),
            hand: 0,
        }
    }

    pub fn policy(&self) -> CachePolicy {
        self.policy
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of resident rows.
    pub fn len(&self) -> usize {
        self.node_of.len()
    }

    pub fn is_empty(&self) -> bool {
        self.node_of.is_empty()
    }

    /// Is `v` resident? (Does not touch the reference bit.)
    pub fn contains(&self, v: NodeId) -> bool {
        self.index.contains_key(&v)
    }

    /// The cached row for `v`, marking it recently used.
    pub fn get(&mut self, v: NodeId) -> Option<&[f32]> {
        let slot = *self.index.get(&v)? as usize;
        self.referenced[slot] = true;
        let f = self.feat_dim;
        Some(&self.rows[slot * f..(slot + 1) * f])
    }

    /// Offer a row to the cache. Below capacity it is always admitted;
    /// at capacity, `StaticDegree` rejects (static contents) and `Clock`
    /// evicts the first unreferenced row past the hand.
    pub fn insert(&mut self, v: NodeId, row: &[f32]) {
        assert_eq!(row.len(), self.feat_dim, "row width != feat_dim");
        if self.capacity == 0 {
            return;
        }
        let f = self.feat_dim;
        if let Some(&slot) = self.index.get(&v) {
            // Refresh (rows are immutable in this workload, but stay exact).
            let slot = slot as usize;
            self.rows[slot * f..(slot + 1) * f].copy_from_slice(row);
            self.referenced[slot] = true;
            return;
        }
        if self.node_of.len() < self.capacity {
            let slot = self.node_of.len();
            self.node_of.push(v);
            self.referenced.push(true);
            self.rows.extend_from_slice(row);
            self.index.insert(v, slot as u32);
            return;
        }
        if self.policy == CachePolicy::StaticDegree {
            return;
        }
        // CLOCK sweep: give referenced rows a second chance.
        let slot = loop {
            let s = self.hand;
            self.hand = (self.hand + 1) % self.capacity;
            if self.referenced[s] {
                self.referenced[s] = false;
            } else {
                break s;
            }
        };
        self.index.remove(&self.node_of[slot]);
        self.node_of[slot] = v;
        self.referenced[slot] = true;
        self.rows[slot * f..(slot + 1) * f].copy_from_slice(row);
        self.index.insert(v, slot as u32);
    }
}

/// Warm-up set for `StaticDegree`: the `k` highest in-degree nodes this
/// worker does *not* own — the rows most likely to be fetched every
/// minibatch. Ties break toward lower node id so every run (and every
/// worker pair) computes the same set.
pub fn hottest_remote_nodes(
    degree: impl Fn(NodeId) -> usize,
    num_nodes: usize,
    owns: impl Fn(NodeId) -> bool,
    k: usize,
) -> Vec<NodeId> {
    let mut cand: Vec<(usize, NodeId)> = (0..num_nodes as NodeId)
        .filter(|&v| !owns(v))
        .map(|v| (degree(v), v))
        .collect();
    cand.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    cand.truncate(k);
    cand.into_iter().map(|(_, v)| v).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(v: NodeId, f: usize) -> Vec<f32> {
        (0..f).map(|j| (v as f32) * 10.0 + j as f32).collect()
    }

    #[test]
    fn below_capacity_nothing_is_evicted_under_either_policy() {
        for policy in [CachePolicy::StaticDegree, CachePolicy::Clock] {
            let mut c = FeatureCache::new(policy, 8, 3);
            for v in 0..8u32 {
                c.insert(v, &row(v, 3));
            }
            assert_eq!(c.len(), 8, "{policy:?}");
            for v in 0..8u32 {
                assert_eq!(c.get(v).unwrap(), &row(v, 3)[..], "{policy:?} node {v}");
            }
        }
    }

    #[test]
    fn static_degree_is_static_at_capacity() {
        let mut c = FeatureCache::new(CachePolicy::StaticDegree, 4, 2);
        for v in 0..4u32 {
            c.insert(v, &row(v, 2));
        }
        // Over-capacity inserts are rejected; the pinned set survives.
        for v in 100..150u32 {
            c.insert(v, &row(v, 2));
            assert!(!c.contains(v));
        }
        for v in 0..4u32 {
            assert!(c.contains(v), "pinned row {v} was evicted");
        }
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn clock_second_chance_protects_referenced_rows() {
        let mut c = FeatureCache::new(CachePolicy::Clock, 4, 2);
        for v in 0..4u32 {
            c.insert(v, &row(v, 2));
        }
        // All reference bits are set, so the first eviction degenerates to
        // FIFO: a full sweep clears every bit, then slot 0 (node 0) goes.
        c.insert(50, &row(50, 2));
        assert_eq!(c.len(), 4);
        assert!(c.contains(50));
        assert!(!c.contains(0));
        // Now bits are clear except node 50's. Touch node 1: the next
        // eviction gives it a second chance and takes node 2 instead.
        c.get(1).unwrap();
        c.insert(51, &row(51, 2));
        assert!(c.contains(1), "referenced row lost its second chance");
        assert!(!c.contains(2), "unreferenced row should have been evicted");
        assert!(c.contains(3) && c.contains(50) && c.contains(51));
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn zero_capacity_cache_is_inert() {
        let mut c = FeatureCache::new(CachePolicy::Clock, 0, 2);
        c.insert(1, &[1.0, 2.0]);
        assert!(c.is_empty());
        assert!(c.get(1).is_none());
    }

    #[test]
    fn reinsert_refreshes_in_place() {
        let mut c = FeatureCache::new(CachePolicy::Clock, 2, 1);
        c.insert(3, &[1.0]);
        c.insert(3, &[2.0]);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(3).unwrap(), &[2.0][..]);
    }

    #[test]
    fn hottest_remote_nodes_ranks_by_degree_skips_owned() {
        let degrees = [5usize, 9, 9, 1, 7, 3];
        let hot = hottest_remote_nodes(
            |v| degrees[v as usize],
            degrees.len(),
            |v| v == 1, // node 1 is local — must be skipped even at degree 9
            3,
        );
        assert_eq!(hot, [2, 4, 0]);
        // k larger than the candidate set returns all remotes.
        let all = hottest_remote_nodes(|v| degrees[v as usize], degrees.len(), |_| false, 100);
        assert_eq!(all.len(), 6);
        assert_eq!(all[0], 1); // degree 9, lower id wins the tie with 2
        assert_eq!(all[1], 2);
    }
}
