//! The fabric: the socket transport ([`TcpMesh`]), multi-process
//! rendezvous ([`TcpMesh::connect`] + [`RendezvousConfig`]), transport
//! selection ([`TransportConfig`]), and the network cost model
//! ([`NetworkModel`]).
//!
//! [`TcpMesh`] backs the typed-round API of [`super::comm`] with real
//! sockets: one TCP connection per directed (src, dst) pair, a
//! versioned rank handshake at connect, length-prefixed little-endian
//! frames (see [`Frame`]), a dedicated writer thread per outgoing link
//! (sends queue instead of blocking, so the symmetric all-to-all cannot
//! deadlock on kernel socket buffering — the round-boundary flush is an
//! error checkpoint; typed payloads are **encoded on the writer
//! thread**, overlapping serialization with the wire), and
//! poisoned-peer error propagation — a dead peer surfaces as
//! [`CommError::PeerLost`] from the next operation touching its link,
//! never as a hang or a panic. Because both transports serialize
//! payloads through the same [`super::comm::Wire`] encoding, a training
//! run is bit-identical over sockets and over the in-process channel
//! mesh (`rust/tests/transport_equivalence.rs` pins this).
//!
//! The mesh connects two ways: [`TcpMesh::loopback`] wires all ranks
//! inside one process (tests, `--transport tcp`), while
//! [`TcpMesh::connect`] rendezvouses **one rank per OS process** — bind
//! a listener, dial every peer with retry + exponential backoff under a
//! deadline, accept and validate every incoming handshake — which is
//! what `fastsample worker` and the multi-process integration tests run
//! (misconfiguration surfaces as [`CommError::Rendezvous`], not a hang).
//!
//! [`NetworkModel`] charges each collective round
//! `latency + bytes_sent / bandwidth` of wall time (injected with
//! `thread::sleep`, so the phase breakdowns of Fig 5/6 reflect the fabric
//! even when all "workers" are threads on one machine). The `free()` model
//! keeps the byte/round *accounting* but injects no delay — that is what
//! the equivalence tests and CI run under, so they stay fast and
//! deterministic in wall time.

use std::io::{BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::comm::{
    io_to_comm, ChannelMesh, CommError, Frame, FrameHeader, Transport, WirePayload,
};

/// Cost model of the fabric connecting workers (one worker ≈ one machine
/// of the paper's testbed).
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkModel {
    pub name: &'static str,
    /// Per-round fixed cost (rendezvous + software stack).
    pub latency: Duration,
    /// Bytes per second; `f64::INFINITY` for the free model.
    pub bandwidth: f64,
    /// When false, rounds are accounted but no wall time is injected.
    pub inject_delay: bool,
}

impl NetworkModel {
    /// Accounting-only fabric: zero cost, no injected delay. Use for
    /// correctness tests and round/byte counting.
    pub fn free() -> Self {
        Self {
            name: "free",
            latency: Duration::ZERO,
            bandwidth: f64::INFINITY,
            inject_delay: false,
        }
    }

    /// The paper's testbed fabric: 200 Gb/s InfiniBand (≈25 GB/s per
    /// direction) with a ~2 µs round latency.
    pub fn infiniband_200g() -> Self {
        Self {
            name: "infiniband-200g",
            latency: Duration::from_micros(2),
            bandwidth: 25e9,
            inject_delay: true,
        }
    }

    /// Commodity 10 Gb/s Ethernet (≈1.25 GB/s) with a ~50 µs round
    /// latency — the fabric where vanilla sampling rounds hurt most.
    pub fn ethernet_10g() -> Self {
        Self {
            name: "ethernet-10g",
            latency: Duration::from_micros(50),
            bandwidth: 1.25e9,
            inject_delay: true,
        }
    }

    /// Modeled wall time for one worker sending `bytes` in one round.
    pub fn cost(&self, bytes: u64) -> Duration {
        let transfer = bytes as f64 / self.bandwidth;
        self.latency + Duration::from_secs_f64(transfer)
    }

    /// Inject the modeled delay (no-op unless `inject_delay`).
    ///
    /// `thread::sleep` granularity is coarse (tens of µs on Linux), so
    /// sub-latency rounds are an upper bound — acceptable because the
    /// simulated fabrics are only used by the figure benches, never by
    /// the correctness tests.
    pub fn delay(&self, bytes: u64) {
        if !self.inject_delay {
            return;
        }
        let d = self.cost(bytes);
        if d > Duration::ZERO {
            std::thread::sleep(d);
        }
    }
}

// ---------------------------------------------------------------------------
// Transport selection
// ---------------------------------------------------------------------------

/// Which [`Transport`] a run's workers connect through. Parsed from
/// `--transport inproc|tcp|tcp:<base_port>` and the `+tcp` mode suffix;
/// uniform across ranks (like the replication policy — it is part of the
/// SPMD contract, not a per-rank knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportConfig {
    /// The in-process channel mesh (default): zero-copy-ish, no sockets.
    #[default]
    Inproc,
    /// Per-peer TCP sockets on loopback. `base_port` 0 (the default)
    /// binds ephemeral ports — always safe; a fixed base binds
    /// `base_port + rank` per rank, for deployments that need known
    /// ports.
    Tcp { base_port: u16 },
}

impl TransportConfig {
    /// Connect a full mesh for `world` ranks and return one endpoint per
    /// rank, in rank order.
    pub fn build_mesh(&self, world: usize) -> std::io::Result<Vec<Box<dyn Transport>>> {
        match *self {
            TransportConfig::Inproc => Ok(ChannelMesh::mesh(world)
                .into_iter()
                .map(|t| Box::new(t) as Box<dyn Transport>)
                .collect()),
            TransportConfig::Tcp { base_port } => Ok(TcpMesh::loopback(world, base_port)?
                .into_iter()
                .map(|t| Box::new(t) as Box<dyn Transport>)
                .collect()),
        }
    }
}

impl std::str::FromStr for TransportConfig {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "inproc" | "channel" | "chan" => Ok(TransportConfig::Inproc),
            "tcp" => Ok(TransportConfig::Tcp { base_port: 0 }),
            other => match other.strip_prefix("tcp:") {
                Some(port) => port
                    .parse::<u16>()
                    .map(|base_port| TransportConfig::Tcp { base_port })
                    .map_err(|e| format!("bad tcp base port {port:?}: {e}")),
                None => Err(format!(
                    "unknown transport {s:?} (inproc | tcp | tcp:<base_port>)"
                )),
            },
        }
    }
}

impl std::fmt::Display for TransportConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportConfig::Inproc => write!(f, "inproc"),
            TransportConfig::Tcp { base_port } => write!(f, "tcp:{base_port}"),
        }
    }
}

// ---------------------------------------------------------------------------
// TcpMesh
// ---------------------------------------------------------------------------

/// Handshake magic ("FSMP") sent once per connection, followed by the
/// protocol version, the world size, the connecting rank, and the rank
/// the connection is *for* — so an acceptor can demultiplex incoming
/// links by rank and reject cross-run, cross-world, or cross-version
/// strays at rendezvous time instead of desynchronizing later.
const HANDSHAKE_MAGIC: u32 = 0x4653_4D50;

/// Wire version of the FSMP handshake + framing. Bump on any change to
/// the handshake layout or the frame format; mismatched peers are
/// rejected at rendezvous ([`CommError::Rendezvous`]) instead of
/// mis-parsing each other's frames. Version 2 widened the frame header
/// from 12 to 13 bytes with the communication-plane byte (see
/// [`Frame`]); the 12-byte handshake layout itself is unchanged.
pub const PROTOCOL_VERSION: u16 = 2;

/// Handshake bytes on the wire:
/// `magic u32 | version u16 | world u16 | src u16 | dst u16` (LE).
const HANDSHAKE_LEN: usize = 12;

fn encode_handshake(world: usize, src: usize, dst: usize) -> [u8; HANDSHAKE_LEN] {
    let mut hs = [0u8; HANDSHAKE_LEN];
    hs[..4].copy_from_slice(&HANDSHAKE_MAGIC.to_le_bytes());
    hs[4..6].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    hs[6..8].copy_from_slice(&(world as u16).to_le_bytes());
    hs[8..10].copy_from_slice(&(src as u16).to_le_bytes());
    hs[10..12].copy_from_slice(&(dst as u16).to_le_bytes());
    hs
}

/// Does the buffer lead with the FSMP magic? Anything else is not a
/// FastSample peer at all — a stray connection (health check, scanner),
/// which the multi-process rendezvous drops rather than treating as a
/// fatal misconfiguration.
fn handshake_magic_ok(hs: &[u8; HANDSHAKE_LEN]) -> bool {
    u32::from_le_bytes([hs[0], hs[1], hs[2], hs[3]]) == HANDSHAKE_MAGIC
}

/// Validate an incoming handshake against this acceptor's identity.
/// Returns the connecting rank, or a human-readable rejection reason
/// (mismatched magic, protocol version, world size, or rank).
fn validate_handshake(
    hs: &[u8; HANDSHAKE_LEN],
    world: usize,
    me: usize,
) -> Result<usize, String> {
    let magic = u32::from_le_bytes([hs[0], hs[1], hs[2], hs[3]]);
    let version = u16::from_le_bytes([hs[4], hs[5]]);
    let hs_world = u16::from_le_bytes([hs[6], hs[7]]) as usize;
    let hs_src = u16::from_le_bytes([hs[8], hs[9]]) as usize;
    let hs_dst = u16::from_le_bytes([hs[10], hs[11]]) as usize;
    if magic != HANDSHAKE_MAGIC {
        return Err(format!("bad handshake magic {magic:#x} (not an FSMP peer?)"));
    }
    if version != PROTOCOL_VERSION {
        return Err(format!(
            "handshake protocol version {version} != {PROTOCOL_VERSION} (mixed builds?)"
        ));
    }
    if hs_world != world {
        return Err(format!("handshake world {hs_world} != this rank's world {world}"));
    }
    if hs_dst != me {
        return Err(format!(
            "handshake addressed to rank {hs_dst}, but this is rank {me} (peer list skew?)"
        ));
    }
    if hs_src >= world || hs_src == me {
        return Err(format!("handshake rank {hs_src} invalid for rank {me}"));
    }
    Ok(hs_src)
}

// ---------------------------------------------------------------------------
// Rendezvous configuration
// ---------------------------------------------------------------------------

/// Knobs of the per-rank rendezvous ([`TcpMesh::connect`]): how long the
/// whole dial + accept phase may take, and how dial retries back off
/// while a peer's listener has not appeared yet.
///
/// Environment fallbacks (read by [`RendezvousConfig::from_env`], flags
/// override them):
///
/// | variable | meaning |
/// |---|---|
/// | `FASTSAMPLE_RENDEZVOUS_TIMEOUT_MS` | overall deadline (default 30000) |
/// | `FASTSAMPLE_RENDEZVOUS_RETRY_MS`   | first dial backoff (default 25) |
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RendezvousConfig {
    /// Hard deadline for the whole rendezvous (binding, dialing every
    /// higher-cost peer with retries, accepting every incoming link).
    /// Expiry is a [`CommError::Rendezvous`], never a hang.
    pub timeout: Duration,
    /// Backoff before the first dial retry; doubles per retry.
    pub retry_initial: Duration,
    /// Backoff ceiling for dial retries.
    pub retry_max: Duration,
    /// Address to bind this rank's listener on instead of its own peer
    /// entry — for hosts that must listen on a wildcard/internal address
    /// (e.g. `0.0.0.0:9400`) while peers dial a routable one.
    pub bind: Option<String>,
}

impl Default for RendezvousConfig {
    fn default() -> Self {
        Self {
            timeout: Duration::from_secs(30),
            retry_initial: Duration::from_millis(25),
            retry_max: Duration::from_secs(1),
            bind: None,
        }
    }
}

impl RendezvousConfig {
    /// Defaults with an explicit overall deadline.
    pub fn with_timeout(timeout: Duration) -> Self {
        Self { timeout, ..Self::default() }
    }

    /// Defaults overridden by the `FASTSAMPLE_RENDEZVOUS_*` environment
    /// variables (see the type-level table) — the CI-able path: a launch
    /// script exports one timeout for every rank it spawns.
    pub fn from_env() -> Self {
        fn env_ms(key: &str) -> Option<Duration> {
            std::env::var(key).ok()?.trim().parse::<u64>().ok().map(Duration::from_millis)
        }
        let mut cfg = Self::default();
        if let Some(t) = env_ms("FASTSAMPLE_RENDEZVOUS_TIMEOUT_MS") {
            cfg.timeout = t;
        }
        if let Some(t) = env_ms("FASTSAMPLE_RENDEZVOUS_RETRY_MS") {
            cfg.retry_initial = t.max(Duration::from_millis(1));
        }
        cfg
    }
}

fn rdv(detail: String) -> CommError {
    CommError::Rendezvous { detail }
}

/// Poll interval of the nonblocking accept loop in [`TcpMesh::connect`].
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Longest a single accepted connection may take to deliver its 12-byte
/// handshake before being dropped as a stray (also capped by the
/// remaining rendezvous budget). Real peers write the handshake in the
/// same breath as the connect; only port scanners and health checks sit
/// silent.
const STRAY_HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(2);

/// Cap on one address's connect attempt within a dial retry, so a
/// blackholed first address (e.g. an unreachable IPv6) cannot starve
/// the remaining addresses of a dual-stack peer of budget.
const DIAL_ATTEMPT_CAP: Duration = Duration::from_secs(5);

/// Dial `addr` until the connection is accepted or the deadline expires.
/// Every connect error is treated as retryable — the dominant case is
/// "connection refused" because the peer process has not bound its
/// listener yet — with exponential backoff (`retry_initial`, doubling,
/// capped at `retry_max`). Each retry re-resolves the address (DNS may
/// warm up with the peer) and tries **every** resolved socket address
/// (dual-stack hosts often listen on only one family), each attempt
/// bounded by `connect_timeout` under the *remaining* rendezvous
/// budget — so a blackholed address (dropped SYNs, the classic
/// firewall misconfiguration) cannot out-wait the deadline the way a
/// blocking connect's ~2-minute OS retry cycle would. On expiry, the
/// last error is reported.
fn dial(addr: &str, deadline: Instant, cfg: &RendezvousConfig) -> Result<TcpStream, String> {
    use std::net::ToSocketAddrs;
    let mut backoff = cfg.retry_initial.max(Duration::from_millis(1));
    loop {
        let mut last_err: Option<std::io::Error> = None;
        match addr.to_socket_addrs() {
            Err(e) => last_err = Some(e),
            Ok(addrs) => {
                for sa in addrs {
                    let remaining = deadline.saturating_duration_since(Instant::now());
                    if remaining.is_zero() {
                        break;
                    }
                    match TcpStream::connect_timeout(&sa, remaining.min(DIAL_ATTEMPT_CAP)) {
                        Ok(s) => return Ok(s),
                        Err(e) => last_err = Some(e),
                    }
                }
            }
        }
        let now = Instant::now();
        if now >= deadline {
            return Err(match last_err {
                Some(e) => format!("deadline expired after retries; last error: {e}"),
                None => "deadline expired (address resolved to nothing)".into(),
            });
        }
        std::thread::sleep(backoff.min(deadline - now));
        backoff = (backoff * 2).min(cfg.retry_max.max(Duration::from_millis(1)));
    }
}

/// One unit of work for a link's writer thread: either a pre-encoded
/// wire buffer (header + payload) or a typed payload whose encoding is
/// **deferred to the writer thread** — the overlapped-encoding path of
/// [`Transport::send_typed`], which lets serialization of one peer's
/// outbox proceed concurrently with other links' writes and with the
/// collective thread moving on to its receive phase.
enum Job {
    /// Pre-encoded wire bytes, written as-is.
    Bytes(Vec<u8>),
    /// Typed payload; the writer encodes `header` + payload into the
    /// identical wire form `Frame::encode_to` would have produced.
    Typed { header: FrameHeader, data: Box<dyn WirePayload> },
}

/// Lock a mutex, recovering the inner data if a holder panicked (e.g. a
/// panicked writer thread poisoning its error slot): the protected
/// state is still the truth, and panicking here would cascade one
/// failure into many.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// One outgoing link: an unbounded job queue drained by a dedicated
/// writer thread. Queueing means `Transport::send` never blocks on the
/// peer's socket buffers — the collective loop always reaches its
/// receive phase, so the symmetric all-to-all cannot deadlock no matter
/// how large a round's payloads are. The first write error is parked in
/// `err` and surfaced by the next `send`/`flush` touching the link.
/// The queue and writer-handle slots sit behind mutexes so the
/// `&self` transport contract holds: any thread may send while
/// another shuts the mesh down.
struct OutLink {
    /// `None` once shut down (closing the channel stops the writer).
    queue: Mutex<Option<Sender<Job>>>,
    err: Arc<Mutex<Option<CommError>>>,
    writer: Mutex<Option<JoinHandle<()>>>,
}

impl OutLink {
    fn last_err(&self) -> Option<CommError> {
        lock(&self.err).clone()
    }
}

/// A rank's endpoint of the socket mesh: one outgoing queue + writer
/// thread per peer (this rank's frames to them) and one incoming stream
/// per peer (their frames to this rank). Frames are length-prefixed and
/// little-endian (see [`Frame`] for the exact layout); `TCP_NODELAY` is
/// set, and the writer threads push frames continuously, so
/// [`Transport::flush`] is purely an error checkpoint at the round
/// boundary.
pub struct TcpMesh {
    rank: usize,
    world: usize,
    /// `out[dst]`: this rank's link toward `dst`; self slot `None`.
    out: Vec<Option<OutLink>>,
    /// `inc[src]`: reader of `src`'s frames; self slot `None`. The
    /// per-source mutex upholds the one-reader-per-src contract at the
    /// transport level; readers of *different* sources never contend.
    inc: Vec<Option<Mutex<BufReader<TcpStream>>>>,
    /// `try_clone`d handles of the incoming sockets. `shutdown` *takes*
    /// and `Shutdown::Both`s each one, which unblocks a concurrent
    /// blocking read on the shared descriptor without touching the
    /// reader's mutex (no deadlock) and makes a second shutdown a no-op;
    /// `set_recv_timeout` uses them the same way (`setsockopt` is
    /// per-descriptor-family, shared by the clone).
    inc_shut: Mutex<Vec<Option<TcpStream>>>,
    /// Maximum bytes per write call, read by the writer threads (tests
    /// shrink this to force short writes + partial frames on the wire;
    /// `usize::MAX` normally).
    max_chunk: Arc<AtomicUsize>,
}

impl TcpMesh {
    /// Connect a full `world`-rank mesh on 127.0.0.1 and return the
    /// per-rank endpoints in rank order. `base_port` 0 binds ephemeral
    /// ports (collision-free — right for tests and single-host runs); a
    /// non-zero base binds `base_port + rank` for each rank.
    ///
    /// All endpoints are created by the caller and then moved to worker
    /// threads — the rendezvous happens here, single-threaded, which is
    /// sound because the kernel completes TCP handshakes into the listen
    /// backlog before `accept` runs.
    pub fn loopback(world: usize, base_port: u16) -> std::io::Result<Vec<TcpMesh>> {
        assert!(world >= 1, "world size must be >= 1");
        let listeners: Vec<TcpListener> = (0..world)
            .map(|r| {
                let port = if base_port == 0 {
                    0
                } else {
                    let p = base_port as u32 + r as u32;
                    u16::try_from(p).map_err(|_| {
                        std::io::Error::new(
                            std::io::ErrorKind::InvalidInput,
                            format!("base port {base_port} + rank {r} exceeds 65535"),
                        )
                    })?
                };
                TcpListener::bind(("127.0.0.1", port))
            })
            .collect::<std::io::Result<_>>()?;
        let addrs: Vec<SocketAddr> =
            listeners.iter().map(|l| l.local_addr()).collect::<std::io::Result<_>>()?;

        // One short-write knob per rank, shared with its writer threads.
        let chunks: Vec<Arc<AtomicUsize>> =
            (0..world).map(|_| Arc::new(AtomicUsize::new(usize::MAX))).collect();

        // Connect every directed pair, handshaking each link with the
        // connecting rank's identity and handing the connected stream to
        // a dedicated writer thread. Accepts are interleaved per source
        // rank — each listener holds at most ONE pending connection at a
        // time — so the single-threaded rendezvous never outruns a
        // listener's accept backlog, however large the world is.
        let mut out: Vec<Vec<Option<OutLink>>> =
            (0..world).map(|_| (0..world).map(|_| None).collect()).collect();
        let mut inc: Vec<Vec<Option<BufReader<TcpStream>>>> =
            (0..world).map(|_| (0..world).map(|_| None).collect()).collect();
        let mut shut: Vec<Vec<Option<TcpStream>>> =
            (0..world).map(|_| (0..world).map(|_| None).collect()).collect();
        for src in 0..world {
            for dst in 0..world {
                if src == dst {
                    continue;
                }
                let mut s = TcpStream::connect(addrs[dst])?;
                s.set_nodelay(true)?;
                s.write_all(&encode_handshake(world, src, dst))?;
                out[src][dst] = Some(spawn_writer(s, dst, Arc::clone(&chunks[src])));

                // Drain the one pending connection this iteration queued
                // on `dst`'s listener, demultiplexing by handshaked rank.
                let (mut s, _) = listeners[dst].accept()?;
                s.set_nodelay(true)?;
                let mut hs = [0u8; HANDSHAKE_LEN];
                s.read_exact(&mut hs)?;
                let bad = |detail: String| {
                    std::io::Error::new(std::io::ErrorKind::InvalidData, detail)
                };
                let hs_src = validate_handshake(&hs, world, dst)
                    .map_err(|detail| bad(format!("rank {dst}: {detail}")))?;
                if inc[dst][hs_src].is_some() {
                    return Err(bad(format!("duplicate link {hs_src} -> {dst}")));
                }
                shut[dst][hs_src] = Some(s.try_clone()?);
                inc[dst][hs_src] = Some(BufReader::new(s));
            }
        }

        Ok(out
            .into_iter()
            .zip(inc)
            .zip(shut)
            .zip(chunks)
            .enumerate()
            .map(|(rank, (((out, inc), shut), max_chunk))| TcpMesh {
                rank,
                world,
                out,
                inc: inc.into_iter().map(|r| r.map(Mutex::new)).collect(),
                inc_shut: Mutex::new(shut),
                max_chunk,
            })
            .collect())
    }

    /// Rendezvous **one rank of a multi-process mesh**: every rank —
    /// its own OS process, possibly its own machine — calls this with
    /// the same `peers` list (`peers[r]` = where rank `r` listens) and
    /// its own `rank`, and gets back its endpoint of the same full mesh
    /// [`TcpMesh::loopback`] builds inside one process.
    ///
    /// Three phases, all bounded by `cfg.timeout`:
    ///
    /// 1. **Bind** the listener at `peers[rank]` (or `cfg.bind`), first,
    ///    so peers' dials can land in the kernel backlog while this rank
    ///    is still dialing — the property that makes the symmetric
    ///    rendezvous deadlock-free in any start order.
    /// 2. **Dial** every peer to originate this rank's outgoing links,
    ///    retrying with exponential backoff (`cfg.retry_initial`,
    ///    doubling up to `cfg.retry_max`) while the peer's listener has
    ///    not appeared yet, and write the FSMP handshake
    ///    (`magic | version | world | src | dst`).
    /// 3. **Accept** `world − 1` incoming links, demultiplexed by the
    ///    handshaked source rank. A handshake naming the wrong protocol
    ///    version, world size, or destination rank fails the rendezvous
    ///    with [`CommError::Rendezvous`] — a misconfigured launch is
    ///    diagnosed at connect time, never by a hang or a desynchronized
    ///    collective later. (The misconfigured peer itself sees its
    ///    connection close, which surfaces as [`CommError::PeerLost`]
    ///    from its first collective.)
    ///
    /// Deadline expiry at any phase is a [`CommError::Rendezvous`]
    /// naming the ranks still missing.
    ///
    /// ```
    /// use fastsample::dist::{RendezvousConfig, TcpMesh, Transport};
    ///
    /// // A single-rank world rendezvouses with itself: it binds an
    /// // ephemeral port ("tcp:0"-style) and has no peers to dial.
    /// let peers = vec!["127.0.0.1:0".to_string()];
    /// let mesh = TcpMesh::connect(0, &peers, &RendezvousConfig::default()).unwrap();
    /// assert_eq!((mesh.rank(), mesh.world()), (0, 1));
    /// ```
    ///
    /// A 4-rank run is 4 shell commands (see `OPERATIONS.md`):
    ///
    /// ```sh
    /// PEERS=127.0.0.1:9400,127.0.0.1:9401,127.0.0.1:9402,127.0.0.1:9403
    /// for R in 1 2 3; do fastsample worker --rank $R --peers $PEERS & done
    /// fastsample worker --rank 0 --peers $PEERS
    /// ```
    pub fn connect(
        rank: usize,
        peers: &[String],
        cfg: &RendezvousConfig,
    ) -> Result<TcpMesh, CommError> {
        let world = peers.len();
        if world == 0 || rank >= world {
            return Err(rdv(format!(
                "rank {rank} out of range for a {world}-entry peer list"
            )));
        }
        let deadline = Instant::now() + cfg.timeout;
        let bind_addr = cfg.bind.as_deref().unwrap_or(peers[rank].as_str());
        let io_ctx = |what: &str, e: std::io::Error| rdv(format!("rank {rank}: {what}: {e}"));
        let listener = TcpListener::bind(bind_addr)
            .map_err(|e| io_ctx(&format!("cannot bind listener on {bind_addr}"), e))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| io_ctx("cannot poll the listener", e))?;

        let max_chunk = Arc::new(AtomicUsize::new(usize::MAX));
        let mut out: Vec<Option<OutLink>> = (0..world).map(|_| None).collect();
        let mut inc: Vec<Option<BufReader<TcpStream>>> = (0..world).map(|_| None).collect();
        let mut inc_shut: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();

        // ---- Dial phase: originate the outgoing half of every directed
        // pair this rank is the source of. Connects complete into the
        // peers' listen backlogs even while those peers are themselves
        // still dialing, so no ordering between ranks is required.
        for dst in 0..world {
            if dst == rank {
                continue;
            }
            let mut s = dial(&peers[dst], deadline, cfg).map_err(|detail| {
                rdv(format!("rank {rank} dialing rank {dst} ({}): {detail}", peers[dst]))
            })?;
            s.set_nodelay(true).map_err(|e| io_ctx("set_nodelay", e))?;
            s.write_all(&encode_handshake(world, rank, dst))
                .map_err(|e| io_ctx(&format!("handshaking rank {dst}"), e))?;
            out[dst] = Some(spawn_writer(s, dst, Arc::clone(&max_chunk)));
        }

        // ---- Accept phase: collect world − 1 incoming links, validated
        // and demultiplexed by the handshaked source rank.
        let mut pending = world - 1;
        while pending > 0 {
            match listener.accept() {
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        let missing: Vec<usize> =
                            (0..world).filter(|&p| p != rank && inc[p].is_none()).collect();
                        return Err(rdv(format!(
                            "rank {rank}: rendezvous deadline ({:?}) expired with no \
                             incoming link from ranks {missing:?}",
                            cfg.timeout
                        )));
                    }
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e) => return Err(io_ctx("accept failed", e)),
                Ok((mut s, from)) => {
                    s.set_nonblocking(false)
                        .map_err(|e| io_ctx("unsetting listener nonblock", e))?;
                    // Bound the handshake read tightly: a stray that
                    // connects and sends nothing (health check, port
                    // scanner) must neither consume the deadline nor
                    // abort the rendezvous.
                    let hs_budget = deadline
                        .saturating_duration_since(Instant::now())
                        .min(STRAY_HANDSHAKE_TIMEOUT)
                        .max(Duration::from_millis(1));
                    s.set_read_timeout(Some(hs_budget))
                        .map_err(|e| io_ctx("set handshake timeout", e))?;
                    let mut hs = [0u8; HANDSHAKE_LEN];
                    if s.read_exact(&mut hs).is_err() || !handshake_magic_ok(&hs) {
                        // Not an FSMP peer: drop it and keep accepting.
                        continue;
                    }
                    // An actual FSMP peer whose identity disagrees IS a
                    // fatal misconfiguration (mixed builds, divergent
                    // peer lists) — diagnosed now, not mid-run.
                    let src = validate_handshake(&hs, world, rank).map_err(|detail| {
                        rdv(format!("rank {rank}: rejecting connection from {from}: {detail}"))
                    })?;
                    if inc[src].is_some() {
                        return Err(rdv(format!(
                            "rank {rank}: duplicate incoming link from rank {src}"
                        )));
                    }
                    s.set_read_timeout(None).map_err(|e| io_ctx("clear handshake timeout", e))?;
                    s.set_nodelay(true).map_err(|e| io_ctx("set_nodelay", e))?;
                    inc_shut[src] =
                        Some(s.try_clone().map_err(|e| io_ctx("clone incoming socket", e))?);
                    inc[src] = Some(BufReader::new(s));
                    pending -= 1;
                }
            }
        }
        Ok(TcpMesh {
            rank,
            world,
            out,
            inc: inc.into_iter().map(|r| r.map(Mutex::new)).collect(),
            inc_shut: Mutex::new(inc_shut),
            max_chunk,
        })
    }

    /// Cap the bytes per write call, flushing between chunks — frames
    /// then cross the wire as many short writes, which the receiving
    /// side must reassemble. Test/diagnostic knob; the fault-injection
    /// suite drives it.
    pub fn set_max_chunk(&self, n: usize) {
        self.max_chunk.store(n.max(1), Ordering::Relaxed);
    }

    /// Bound blocking receives (default: none). A slow healthy peer is
    /// indistinguishable from a hung one, so production runs wait; tests
    /// that want a hard bound use this (or an outer deadline). Applied
    /// through the `try_clone`d handles — the timeout lands on the
    /// shared descriptors without taking any reader's mutex.
    pub fn set_recv_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        for s in lock(&self.inc_shut).iter().flatten() {
            s.set_read_timeout(t)?;
        }
        Ok(())
    }

    /// Queue one writer job on the link to `dst`, surfacing any parked
    /// link error (shared by `send` and `send_typed`).
    fn enqueue(&self, dst: usize, job: Job) -> Result<(), CommError> {
        // Self-sends go through the inbox pass-through, never the transport;
        // a vacant slot here is a routing bug reported as Malformed.
        let Some(link) = self.out[dst].as_ref() else {
            return Err(CommError::Malformed {
                src: dst,
                detail: "transport-level send to self (self slots bypass the transport)".into(),
            });
        };
        if let Some(e) = link.last_err() {
            return Err(e);
        }
        // Queue gone or writer exited: surface the parked error, or a
        // plain loss when the writer died without recording one.
        let lost = || link.last_err().unwrap_or(CommError::PeerLost { rank: dst });
        // Clone the sender out of the slot (an Arc bump) so no lock is
        // held across the channel send, and so a concurrent shutdown can
        // take the slot without waiting on senders.
        let q = lock(&link.queue).clone();
        let Some(q) = q else {
            return Err(lost());
        };
        if q.send(job).is_err() {
            return Err(lost());
        }
        Ok(())
    }
}

/// Spawn the writer thread for one outgoing link. It drains the queue
/// in FIFO order, encoding deferred typed payloads ([`Job::Typed`]) into
/// wire form on this thread and splitting frames into `max_chunk`-byte
/// writes when the knob is set; on the first write error it parks the
/// mapped [`CommError`] and exits (the closed queue then fails future
/// sends). On clean shutdown (queue closed) it half-closes the socket so
/// the peer reads EOF only after every queued frame.
fn spawn_writer(mut stream: TcpStream, dst: usize, max_chunk: Arc<AtomicUsize>) -> OutLink {
    let (tx, rx) = channel::<Job>();
    let err: Arc<Mutex<Option<CommError>>> = Arc::new(Mutex::new(None));
    let err_slot = Arc::clone(&err);
    let writer = std::thread::spawn(move || {
        while let Ok(job) = rx.recv() {
            let buf = match job {
                Job::Bytes(buf) => buf,
                Job::Typed { header, data } => {
                    // Overlapped encoding: serialize here, off the
                    // collective thread, byte-identical to the eager path
                    // (pinned by `deferred_encoding_is_byte_identical_to_
                    // eager` in comm.rs).
                    let len = data.byte_len();
                    let mut buf = Vec::with_capacity(super::comm::FRAME_HEADER + len);
                    header.encode_to(len, &mut buf);
                    data.append_to(&mut buf);
                    buf
                }
            };
            let limit = max_chunk.load(Ordering::Relaxed).max(1);
            let result = if buf.len() <= limit {
                stream.write_all(&buf)
            } else {
                buf.chunks(limit).try_for_each(|c| {
                    stream.write_all(c)?;
                    stream.flush()
                })
            };
            if let Err(e) = result {
                *err_slot.lock().unwrap_or_else(|p| p.into_inner()) = Some(io_to_comm(dst, e));
                return;
            }
        }
        let _ = stream.shutdown(Shutdown::Write);
    });
    OutLink { queue: Mutex::new(Some(tx)), err, writer: Mutex::new(Some(writer)) }
}

impl Transport for TcpMesh {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&self, dst: usize, frame: Frame) -> Result<(), CommError> {
        let mut buf = Vec::with_capacity(super::comm::FRAME_HEADER + frame.payload.len());
        frame.encode_to(&mut buf);
        self.enqueue(dst, Job::Bytes(buf))
    }

    fn send_typed(
        &self,
        dst: usize,
        header: FrameHeader,
        data: Box<dyn WirePayload>,
    ) -> Result<(), CommError> {
        // Overlapped encoding: hand the still-typed outbox straight to
        // the link's writer thread, which serializes it there.
        self.enqueue(dst, Job::Typed { header, data })
    }

    fn flush(&self) -> Result<(), CommError> {
        // Writer threads push continuously; the round boundary is an
        // error checkpoint so a poisoned link fails the collective here
        // rather than surfacing one round later.
        for link in self.out.iter().flatten() {
            if let Some(e) = link.last_err() {
                return Err(e);
            }
        }
        Ok(())
    }

    fn recv(&self, src: usize) -> Result<Frame, CommError> {
        let Some(r) = self.inc[src].as_ref() else {
            return Err(CommError::Malformed {
                src,
                detail: "transport-level recv from self (self slots bypass the transport)".into(),
            });
        };
        let mut r = lock(r);
        Frame::decode_from(&mut *r).map_err(|e| io_to_comm(src, e))
    }

    fn name(&self) -> &'static str {
        "tcp"
    }

    fn shutdown(&self) {
        // Close the incoming sockets FIRST: this rank is done reading,
        // and the close is what unblocks any peer writer still pushing
        // toward it — with every rank closing its read side before
        // joining its own writers, teardown can never deadlock on a
        // cycle of full socket buffers. Shutting down through the
        // *taken* `try_clone`d handles also unblocks a local thread
        // parked in `recv` (the shared descriptor reads EOF) without
        // waiting on the reader's mutex, and leaves the slots empty so
        // a second shutdown is a no-op.
        for s in lock(&self.inc_shut).iter_mut() {
            if let Some(s) = s.take() {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        // Then close every queue (writers drain, then FIN) and join.
        for link in self.out.iter().flatten() {
            let _ = lock(&link.queue).take();
        }
        for link in self.out.iter().flatten() {
            let handle = lock(&link.writer).take();
            if let Some(h) = handle {
                let _ = h.join();
            }
        }
    }
}

impl Drop for TcpMesh {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn free_charges_zero_for_any_size() {
        let net = NetworkModel::free();
        for bytes in [0u64, 1, 1 << 20, u64::MAX >> 8] {
            assert_eq!(net.cost(bytes), Duration::ZERO);
        }
        assert!(!net.inject_delay);
        // delay() must return immediately even for huge payloads.
        net.delay(u64::MAX >> 8);
    }

    #[test]
    fn cost_is_monotone_in_bytes() {
        for net in [NetworkModel::infiniband_200g(), NetworkModel::ethernet_10g()] {
            let mut prev = Duration::ZERO;
            for bytes in [0u64, 1 << 10, 1 << 20, 1 << 30] {
                let c = net.cost(bytes);
                assert!(c >= prev, "{}: cost({bytes}) < cost of fewer bytes", net.name);
                assert!(c >= net.latency, "{}: cost below latency floor", net.name);
                prev = c;
            }
        }
    }

    #[test]
    fn bandwidth_math_matches_the_fabric() {
        let ib = NetworkModel::infiniband_200g();
        // 25 GB over 25 GB/s = 1 s (+2 µs latency).
        let c = ib.cost(25_000_000_000);
        assert!((c.as_secs_f64() - 1.0).abs() < 1e-3, "{c:?}");
        // Ethernet is 20x slower per byte.
        let eth = NetworkModel::ethernet_10g();
        let ratio = (eth.cost(1 << 30) - eth.latency).as_secs_f64()
            / (ib.cost(1 << 30) - ib.latency).as_secs_f64();
        assert!((ratio - 20.0).abs() < 0.1, "ratio {ratio}");
    }

    #[test]
    fn transport_config_parses_and_prints() {
        use std::str::FromStr;
        assert_eq!(TransportConfig::from_str("inproc").unwrap(), TransportConfig::Inproc);
        assert_eq!(
            TransportConfig::from_str("tcp").unwrap(),
            TransportConfig::Tcp { base_port: 0 }
        );
        assert_eq!(
            TransportConfig::from_str("tcp:9100").unwrap(),
            TransportConfig::Tcp { base_port: 9100 }
        );
        assert!(TransportConfig::from_str("rdma").is_err());
        assert!(TransportConfig::from_str("tcp:notaport").is_err());
        assert_eq!(TransportConfig::Inproc.to_string(), "inproc");
        assert_eq!(TransportConfig::Tcp { base_port: 0 }.to_string(), "tcp:0");
        assert_eq!(TransportConfig::default(), TransportConfig::Inproc);
    }

    #[test]
    fn tcp_mesh_moves_frames_point_to_point() {
        // 3 ranks, each sends one frame to each peer, then receives —
        // driven directly at the Transport level, single process.
        let meshes = TcpMesh::loopback(3, 0).unwrap();
        let handles: Vec<_> = meshes
            .into_iter()
            .map(|t| {
                std::thread::spawn(move || {
                    let rank = t.rank();
                    for dst in 0..3 {
                        if dst == rank {
                            continue;
                        }
                        let frame = Frame {
                            kind: 0,
                            elem: 1,
                            plane: 0,
                            src: rank as u16,
                            seq: 5,
                            payload: vec![rank as u8; 3 + dst],
                        };
                        t.send(dst, frame).unwrap();
                    }
                    t.flush().unwrap();
                    let mut got = Vec::new();
                    for src in 0..3 {
                        if src == rank {
                            continue;
                        }
                        got.push(t.recv(src).unwrap());
                    }
                    (rank, got)
                })
            })
            .collect();
        for h in handles {
            let (rank, got) = h.join().unwrap();
            for f in got {
                let src = f.src as usize;
                assert_ne!(src, rank);
                assert_eq!(f.seq, 5);
                assert_eq!(f.payload, vec![src as u8; 3 + rank]);
            }
        }
    }

    #[test]
    fn tcp_mesh_single_rank_world_has_no_links() {
        let meshes = TcpMesh::loopback(1, 0).unwrap();
        assert_eq!(meshes.len(), 1);
        assert_eq!(meshes[0].world(), 1);
    }

    /// Reserve `n` distinct loopback ports by binding and dropping
    /// ephemeral listeners. The tiny window between drop and re-bind is
    /// the standard multi-process test trade-off; `connect`'s dial
    /// retries absorb start-order races, not port theft (vanishingly
    /// rare in CI).
    fn free_peer_list(n: usize) -> Vec<String> {
        let listeners: Vec<TcpListener> =
            (0..n).map(|_| TcpListener::bind(("127.0.0.1", 0)).unwrap()).collect();
        listeners
            .iter()
            .map(|l| format!("127.0.0.1:{}", l.local_addr().unwrap().port()))
            .collect()
    }

    fn quick_rdv() -> RendezvousConfig {
        RendezvousConfig {
            timeout: Duration::from_secs(20),
            retry_initial: Duration::from_millis(5),
            retry_max: Duration::from_millis(100),
            bind: None,
        }
    }

    #[test]
    fn connect_rendezvouses_ranks_that_start_in_any_order() {
        // 3 "processes" (threads here — the real child-process run lives
        // in rust/tests/process_rendezvous.rs) starting staggered, the
        // highest rank last: dial retries must bridge the gap, and the
        // connected mesh must move frames exactly like the loopback one.
        let peers = free_peer_list(3);
        let handles: Vec<_> = (0..3)
            .map(|rank| {
                let peers = peers.clone();
                std::thread::spawn(move || {
                    // Reverse start order: rank 0 first, rank 2 300ms late.
                    std::thread::sleep(Duration::from_millis(150 * rank as u64));
                    let t = TcpMesh::connect(rank, &peers, &quick_rdv()).unwrap();
                    for dst in 0..3 {
                        if dst == rank {
                            continue;
                        }
                        let frame = Frame {
                            kind: 0,
                            elem: 1,
                            plane: 1,
                            src: rank as u16,
                            seq: 1,
                            payload: vec![rank as u8; dst + 1],
                        };
                        t.send(dst, frame).unwrap();
                    }
                    t.flush().unwrap();
                    let mut got = Vec::new();
                    for src in 0..3 {
                        if src == rank {
                            continue;
                        }
                        got.push(t.recv(src).unwrap());
                    }
                    (rank, got)
                })
            })
            .collect();
        for h in handles {
            let (rank, got) = h.join().unwrap();
            for f in got {
                let src = f.src as usize;
                assert_eq!(f.payload, vec![src as u8; rank + 1]);
            }
        }
    }

    #[test]
    fn connect_deadline_expiry_is_a_rendezvous_error_not_a_hang() {
        // Nothing ever listens on the second peer: rank 0's dial must
        // give up at the deadline with CommError::Rendezvous.
        let peers = free_peer_list(2);
        let cfg = RendezvousConfig {
            timeout: Duration::from_millis(300),
            retry_initial: Duration::from_millis(5),
            retry_max: Duration::from_millis(50),
            bind: None,
        };
        let t0 = Instant::now();
        let err = TcpMesh::connect(0, &peers, &cfg).unwrap_err();
        assert!(
            matches!(err, CommError::Rendezvous { .. }),
            "expected Rendezvous, got {err:?}"
        );
        assert!(err.to_string().contains("deadline"), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(10), "did not respect the deadline");
    }

    #[test]
    fn connect_rejects_mismatched_handshakes() {
        // An FSMP peer whose handshake names the wrong world size or the
        // wrong destination rank is a real misconfiguration: it must
        // fail the acceptor's rendezvous with a named Rendezvous error.
        for (bad_hs, needle) in [
            (encode_handshake(3, 1, 0), "world 3"), // wrong world
            (encode_handshake(2, 1, 5), "rank 5"),  // wrong destination
        ] {
            let peers = free_peer_list(2);
            let cfg = RendezvousConfig {
                timeout: Duration::from_secs(10),
                retry_initial: Duration::from_millis(5),
                retry_max: Duration::from_millis(50),
                bind: None,
            };
            // Rank 1's slot accepts rank 0's dial but never handshakes
            // back correctly — instead the impostor dials rank 0.
            let impostor_target = peers[0].clone();
            let fake_rank1 = TcpListener::bind(peers[1].as_str()).unwrap();
            let impostor = std::thread::spawn(move || {
                // Keep rank 0's outgoing dial parked in the backlog.
                let _hold = fake_rank1;
                let mut s = loop {
                    match TcpStream::connect(impostor_target.as_str()) {
                        Ok(s) => break s,
                        Err(_) => std::thread::sleep(Duration::from_millis(5)),
                    }
                };
                s.write_all(&bad_hs).unwrap();
                // Hold the socket open until the acceptor has judged it.
                std::thread::sleep(Duration::from_millis(500));
            });
            let err = TcpMesh::connect(0, &peers, &cfg).unwrap_err();
            impostor.join().unwrap();
            match &err {
                CommError::Rendezvous { detail } => {
                    assert!(detail.contains(needle), "{needle:?} not in {detail:?}")
                }
                other => panic!("expected Rendezvous, got {other:?}"),
            }
        }
    }

    #[test]
    fn connect_drops_non_fsmp_strays_and_still_rendezvouses() {
        // A stray (wrong magic — e.g. a health check or scanner) hits
        // rank 0's listener during the rendezvous window. It must be
        // dropped, not fatal: the real rank 1, arriving later, still
        // completes the mesh and frames flow.
        let peers = free_peer_list(2);
        let stray_target = peers[0].clone();
        let stray = std::thread::spawn(move || {
            let mut s = loop {
                match TcpStream::connect(stray_target.as_str()) {
                    Ok(s) => break s,
                    Err(_) => std::thread::sleep(Duration::from_millis(5)),
                }
            };
            let _ = s.write_all(&[0xFFu8; HANDSHAKE_LEN]); // full-length garbage
            std::thread::sleep(Duration::from_millis(300));
        });
        let handles: Vec<_> = (0..2)
            .map(|rank| {
                let peers = peers.clone();
                std::thread::spawn(move || {
                    // Rank 1 arrives after the stray has already landed.
                    std::thread::sleep(Duration::from_millis(200 * rank as u64));
                    let t = TcpMesh::connect(rank, &peers, &quick_rdv()).unwrap();
                    let dst = 1 - rank;
                    let frame = Frame {
                        kind: 0,
                        elem: 1,
                        plane: 0,
                        src: rank as u16,
                        seq: 0,
                        payload: vec![rank as u8; 2],
                    };
                    t.send(dst, frame).unwrap();
                    t.flush().unwrap();
                    t.recv(dst).unwrap()
                })
            })
            .collect();
        for (rank, h) in handles.into_iter().enumerate() {
            let got = h.join().unwrap();
            assert_eq!(got.payload, vec![(1 - rank) as u8; 2]);
        }
        stray.join().unwrap();
    }

    #[test]
    fn rendezvous_config_reads_env_fallbacks() {
        // Serialize env mutation within this test only (no other test
        // reads these variables).
        std::env::set_var("FASTSAMPLE_RENDEZVOUS_TIMEOUT_MS", "1234");
        std::env::set_var("FASTSAMPLE_RENDEZVOUS_RETRY_MS", "7");
        let cfg = RendezvousConfig::from_env();
        std::env::remove_var("FASTSAMPLE_RENDEZVOUS_TIMEOUT_MS");
        std::env::remove_var("FASTSAMPLE_RENDEZVOUS_RETRY_MS");
        assert_eq!(cfg.timeout, Duration::from_millis(1234));
        assert_eq!(cfg.retry_initial, Duration::from_millis(7));
        let plain = RendezvousConfig::from_env();
        assert_eq!(plain, RendezvousConfig::default());
        assert_eq!(
            RendezvousConfig::with_timeout(Duration::from_secs(5)).timeout,
            Duration::from_secs(5)
        );
    }
}
