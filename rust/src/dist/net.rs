//! Network cost model for the simulated fabric.
//!
//! Every collective round a worker participates in is charged
//! `latency + bytes_sent / bandwidth` of wall time (injected with
//! `thread::sleep`, so the phase breakdowns of Fig 5/6 reflect the fabric
//! even when all "workers" are threads on one machine). The `free()` model
//! keeps the byte/round *accounting* but injects no delay — that is what
//! the equivalence tests and CI run under, so they stay fast and
//! deterministic in wall time.

use std::time::Duration;

/// Cost model of the fabric connecting workers (one worker ≈ one machine
/// of the paper's testbed).
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkModel {
    pub name: &'static str,
    /// Per-round fixed cost (rendezvous + software stack).
    pub latency: Duration,
    /// Bytes per second; `f64::INFINITY` for the free model.
    pub bandwidth: f64,
    /// When false, rounds are accounted but no wall time is injected.
    pub inject_delay: bool,
}

impl NetworkModel {
    /// Accounting-only fabric: zero cost, no injected delay. Use for
    /// correctness tests and round/byte counting.
    pub fn free() -> Self {
        Self {
            name: "free",
            latency: Duration::ZERO,
            bandwidth: f64::INFINITY,
            inject_delay: false,
        }
    }

    /// The paper's testbed fabric: 200 Gb/s InfiniBand (≈25 GB/s per
    /// direction) with a ~2 µs round latency.
    pub fn infiniband_200g() -> Self {
        Self {
            name: "infiniband-200g",
            latency: Duration::from_micros(2),
            bandwidth: 25e9,
            inject_delay: true,
        }
    }

    /// Commodity 10 Gb/s Ethernet (≈1.25 GB/s) with a ~50 µs round
    /// latency — the fabric where vanilla sampling rounds hurt most.
    pub fn ethernet_10g() -> Self {
        Self {
            name: "ethernet-10g",
            latency: Duration::from_micros(50),
            bandwidth: 1.25e9,
            inject_delay: true,
        }
    }

    /// Modeled wall time for one worker sending `bytes` in one round.
    pub fn cost(&self, bytes: u64) -> Duration {
        let transfer = bytes as f64 / self.bandwidth;
        self.latency + Duration::from_secs_f64(transfer)
    }

    /// Inject the modeled delay (no-op unless `inject_delay`).
    ///
    /// `thread::sleep` granularity is coarse (tens of µs on Linux), so
    /// sub-latency rounds are an upper bound — acceptable because the
    /// simulated fabrics are only used by the figure benches, never by
    /// the correctness tests.
    pub fn delay(&self, bytes: u64) {
        if !self.inject_delay {
            return;
        }
        let d = self.cost(bytes);
        if d > Duration::ZERO {
            std::thread::sleep(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn free_charges_zero_for_any_size() {
        let net = NetworkModel::free();
        for bytes in [0u64, 1, 1 << 20, u64::MAX >> 8] {
            assert_eq!(net.cost(bytes), Duration::ZERO);
        }
        assert!(!net.inject_delay);
        // delay() must return immediately even for huge payloads.
        net.delay(u64::MAX >> 8);
    }

    #[test]
    fn cost_is_monotone_in_bytes() {
        for net in [NetworkModel::infiniband_200g(), NetworkModel::ethernet_10g()] {
            let mut prev = Duration::ZERO;
            for bytes in [0u64, 1 << 10, 1 << 20, 1 << 30] {
                let c = net.cost(bytes);
                assert!(c >= prev, "{}: cost({bytes}) < cost of fewer bytes", net.name);
                assert!(c >= net.latency, "{}: cost below latency floor", net.name);
                prev = c;
            }
        }
    }

    #[test]
    fn bandwidth_math_matches_the_fabric() {
        let ib = NetworkModel::infiniband_200g();
        // 25 GB over 25 GB/s = 1 s (+2 µs latency).
        let c = ib.cost(25_000_000_000);
        assert!((c.as_secs_f64() - 1.0).abs() < 1e-3, "{c:?}");
        // Ethernet is 20x slower per byte.
        let eth = NetworkModel::ethernet_10g();
        let ratio = (eth.cost(1 << 30) - eth.latency).as_secs_f64()
            / (ib.cost(1 << 30) - ib.latency).as_secs_f64();
        assert!((ratio - 20.0).abs() < 0.1, "ratio {ratio}");
    }
}
