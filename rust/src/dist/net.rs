//! The fabric: the socket transport ([`TcpMesh`]), transport selection
//! ([`TransportConfig`]), and the network cost model ([`NetworkModel`]).
//!
//! [`TcpMesh`] backs the typed-round API of [`super::comm`] with real
//! sockets: one TCP connection per directed (src, dst) pair, a rank
//! handshake at connect, length-prefixed little-endian frames (see
//! [`Frame`]), a dedicated writer thread per outgoing link (sends queue
//! instead of blocking, so the symmetric all-to-all cannot deadlock on
//! kernel socket buffering — the round-boundary flush is an error
//! checkpoint), and poisoned-peer error propagation — a dead peer
//! surfaces as [`CommError::PeerLost`] from the next operation touching
//! its link, never as a hang or a panic. Because both transports
//! serialize payloads through the same [`super::comm::Wire`] encoding, a
//! training run is bit-identical over sockets and over the in-process
//! channel mesh (`rust/tests/transport_equivalence.rs` pins this).
//!
//! [`NetworkModel`] charges each collective round
//! `latency + bytes_sent / bandwidth` of wall time (injected with
//! `thread::sleep`, so the phase breakdowns of Fig 5/6 reflect the fabric
//! even when all "workers" are threads on one machine). The `free()` model
//! keeps the byte/round *accounting* but injects no delay — that is what
//! the equivalence tests and CI run under, so they stay fast and
//! deterministic in wall time.

use std::io::{BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::comm::{io_to_comm, ChannelMesh, CommError, Frame, Transport};

/// Cost model of the fabric connecting workers (one worker ≈ one machine
/// of the paper's testbed).
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkModel {
    pub name: &'static str,
    /// Per-round fixed cost (rendezvous + software stack).
    pub latency: Duration,
    /// Bytes per second; `f64::INFINITY` for the free model.
    pub bandwidth: f64,
    /// When false, rounds are accounted but no wall time is injected.
    pub inject_delay: bool,
}

impl NetworkModel {
    /// Accounting-only fabric: zero cost, no injected delay. Use for
    /// correctness tests and round/byte counting.
    pub fn free() -> Self {
        Self {
            name: "free",
            latency: Duration::ZERO,
            bandwidth: f64::INFINITY,
            inject_delay: false,
        }
    }

    /// The paper's testbed fabric: 200 Gb/s InfiniBand (≈25 GB/s per
    /// direction) with a ~2 µs round latency.
    pub fn infiniband_200g() -> Self {
        Self {
            name: "infiniband-200g",
            latency: Duration::from_micros(2),
            bandwidth: 25e9,
            inject_delay: true,
        }
    }

    /// Commodity 10 Gb/s Ethernet (≈1.25 GB/s) with a ~50 µs round
    /// latency — the fabric where vanilla sampling rounds hurt most.
    pub fn ethernet_10g() -> Self {
        Self {
            name: "ethernet-10g",
            latency: Duration::from_micros(50),
            bandwidth: 1.25e9,
            inject_delay: true,
        }
    }

    /// Modeled wall time for one worker sending `bytes` in one round.
    pub fn cost(&self, bytes: u64) -> Duration {
        let transfer = bytes as f64 / self.bandwidth;
        self.latency + Duration::from_secs_f64(transfer)
    }

    /// Inject the modeled delay (no-op unless `inject_delay`).
    ///
    /// `thread::sleep` granularity is coarse (tens of µs on Linux), so
    /// sub-latency rounds are an upper bound — acceptable because the
    /// simulated fabrics are only used by the figure benches, never by
    /// the correctness tests.
    pub fn delay(&self, bytes: u64) {
        if !self.inject_delay {
            return;
        }
        let d = self.cost(bytes);
        if d > Duration::ZERO {
            std::thread::sleep(d);
        }
    }
}

// ---------------------------------------------------------------------------
// Transport selection
// ---------------------------------------------------------------------------

/// Which [`Transport`] a run's workers connect through. Parsed from
/// `--transport inproc|tcp|tcp:<base_port>` and the `+tcp` mode suffix;
/// uniform across ranks (like the replication policy — it is part of the
/// SPMD contract, not a per-rank knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportConfig {
    /// The in-process channel mesh (default): zero-copy-ish, no sockets.
    #[default]
    Inproc,
    /// Per-peer TCP sockets on loopback. `base_port` 0 (the default)
    /// binds ephemeral ports — always safe; a fixed base binds
    /// `base_port + rank` per rank, for deployments that need known
    /// ports.
    Tcp { base_port: u16 },
}

impl TransportConfig {
    /// Connect a full mesh for `world` ranks and return one endpoint per
    /// rank, in rank order.
    pub fn build_mesh(&self, world: usize) -> std::io::Result<Vec<Box<dyn Transport>>> {
        match *self {
            TransportConfig::Inproc => Ok(ChannelMesh::mesh(world)
                .into_iter()
                .map(|t| Box::new(t) as Box<dyn Transport>)
                .collect()),
            TransportConfig::Tcp { base_port } => Ok(TcpMesh::loopback(world, base_port)?
                .into_iter()
                .map(|t| Box::new(t) as Box<dyn Transport>)
                .collect()),
        }
    }
}

impl std::str::FromStr for TransportConfig {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "inproc" | "channel" | "chan" => Ok(TransportConfig::Inproc),
            "tcp" => Ok(TransportConfig::Tcp { base_port: 0 }),
            other => match other.strip_prefix("tcp:") {
                Some(port) => port
                    .parse::<u16>()
                    .map(|base_port| TransportConfig::Tcp { base_port })
                    .map_err(|e| format!("bad tcp base port {port:?}: {e}")),
                None => Err(format!(
                    "unknown transport {s:?} (inproc | tcp | tcp:<base_port>)"
                )),
            },
        }
    }
}

impl std::fmt::Display for TransportConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportConfig::Inproc => write!(f, "inproc"),
            TransportConfig::Tcp { base_port } => write!(f, "tcp:{base_port}"),
        }
    }
}

// ---------------------------------------------------------------------------
// TcpMesh
// ---------------------------------------------------------------------------

/// Handshake magic ("FSMP") sent once per connection, followed by the
/// world size and the connecting rank — so an acceptor can demultiplex
/// incoming links by rank and reject cross-run or cross-world strays.
const HANDSHAKE_MAGIC: u32 = 0x4653_4D50;

/// One outgoing link: an unbounded frame queue drained by a dedicated
/// writer thread. Queueing means `Transport::send` never blocks on the
/// peer's socket buffers — the collective loop always reaches its
/// receive phase, so the symmetric all-to-all cannot deadlock no matter
/// how large a round's payloads are. The first write error is parked in
/// `err` and surfaced by the next `send`/`flush` touching the link.
struct OutLink {
    /// `None` once shut down (closing the channel stops the writer).
    queue: Option<Sender<Vec<u8>>>,
    err: Arc<Mutex<Option<CommError>>>,
    writer: Option<JoinHandle<()>>,
}

impl OutLink {
    fn last_err(&self) -> Option<CommError> {
        self.err.lock().expect("writer never poisons the error slot").clone()
    }
}

/// A rank's endpoint of the socket mesh: one outgoing queue + writer
/// thread per peer (this rank's frames to them) and one incoming stream
/// per peer (their frames to this rank). Frames are length-prefixed and
/// little-endian (see [`Frame`] for the exact layout); `TCP_NODELAY` is
/// set, and the writer threads push frames continuously, so
/// [`Transport::flush`] is purely an error checkpoint at the round
/// boundary.
pub struct TcpMesh {
    rank: usize,
    world: usize,
    /// `out[dst]`: this rank's link toward `dst`; self slot `None`.
    out: Vec<Option<OutLink>>,
    /// `inc[src]`: reader of `src`'s frames; self slot `None`.
    inc: Vec<Option<BufReader<TcpStream>>>,
    /// Maximum bytes per write call, read by the writer threads (tests
    /// shrink this to force short writes + partial frames on the wire;
    /// `usize::MAX` normally).
    max_chunk: Arc<AtomicUsize>,
}

impl TcpMesh {
    /// Connect a full `world`-rank mesh on 127.0.0.1 and return the
    /// per-rank endpoints in rank order. `base_port` 0 binds ephemeral
    /// ports (collision-free — right for tests and single-host runs); a
    /// non-zero base binds `base_port + rank` for each rank.
    ///
    /// All endpoints are created by the caller and then moved to worker
    /// threads — the rendezvous happens here, single-threaded, which is
    /// sound because the kernel completes TCP handshakes into the listen
    /// backlog before `accept` runs.
    pub fn loopback(world: usize, base_port: u16) -> std::io::Result<Vec<TcpMesh>> {
        assert!(world >= 1, "world size must be >= 1");
        let listeners: Vec<TcpListener> = (0..world)
            .map(|r| {
                let port = if base_port == 0 {
                    0
                } else {
                    let p = base_port as u32 + r as u32;
                    u16::try_from(p).map_err(|_| {
                        std::io::Error::new(
                            std::io::ErrorKind::InvalidInput,
                            format!("base port {base_port} + rank {r} exceeds 65535"),
                        )
                    })?
                };
                TcpListener::bind(("127.0.0.1", port))
            })
            .collect::<std::io::Result<_>>()?;
        let addrs: Vec<SocketAddr> =
            listeners.iter().map(|l| l.local_addr()).collect::<std::io::Result<_>>()?;

        // One short-write knob per rank, shared with its writer threads.
        let chunks: Vec<Arc<AtomicUsize>> =
            (0..world).map(|_| Arc::new(AtomicUsize::new(usize::MAX))).collect();

        // Connect every directed pair, handshaking each link with the
        // connecting rank's identity and handing the connected stream to
        // a dedicated writer thread. Accepts are interleaved per source
        // rank — each listener holds at most ONE pending connection at a
        // time — so the single-threaded rendezvous never outruns a
        // listener's accept backlog, however large the world is.
        let mut out: Vec<Vec<Option<OutLink>>> =
            (0..world).map(|_| (0..world).map(|_| None).collect()).collect();
        let mut inc: Vec<Vec<Option<BufReader<TcpStream>>>> =
            (0..world).map(|_| (0..world).map(|_| None).collect()).collect();
        for src in 0..world {
            for dst in 0..world {
                if src == dst {
                    continue;
                }
                let mut s = TcpStream::connect(addrs[dst])?;
                s.set_nodelay(true)?;
                let mut hs = [0u8; 8];
                hs[..4].copy_from_slice(&HANDSHAKE_MAGIC.to_le_bytes());
                hs[4..6].copy_from_slice(&(world as u16).to_le_bytes());
                hs[6..8].copy_from_slice(&(src as u16).to_le_bytes());
                s.write_all(&hs)?;
                out[src][dst] = Some(spawn_writer(s, dst, Arc::clone(&chunks[src])));

                // Drain the one pending connection this iteration queued
                // on `dst`'s listener, demultiplexing by handshaked rank.
                let (mut s, _) = listeners[dst].accept()?;
                s.set_nodelay(true)?;
                let mut hs = [0u8; 8];
                s.read_exact(&mut hs)?;
                let magic = u32::from_le_bytes([hs[0], hs[1], hs[2], hs[3]]);
                let hs_world = u16::from_le_bytes([hs[4], hs[5]]) as usize;
                let hs_src = u16::from_le_bytes([hs[6], hs[7]]) as usize;
                let bad = |detail: String| {
                    std::io::Error::new(std::io::ErrorKind::InvalidData, detail)
                };
                if magic != HANDSHAKE_MAGIC {
                    return Err(bad(format!("bad handshake magic {magic:#x} on rank {dst}")));
                }
                if hs_world != world {
                    return Err(bad(format!(
                        "handshake world {hs_world} != mesh world {world}"
                    )));
                }
                if hs_src >= world || hs_src == dst {
                    return Err(bad(format!(
                        "handshake rank {hs_src} invalid for rank {dst}"
                    )));
                }
                if inc[dst][hs_src].is_some() {
                    return Err(bad(format!("duplicate link {hs_src} -> {dst}")));
                }
                inc[dst][hs_src] = Some(BufReader::new(s));
            }
        }

        Ok(out
            .into_iter()
            .zip(inc)
            .zip(chunks)
            .enumerate()
            .map(|(rank, ((out, inc), max_chunk))| TcpMesh { rank, world, out, inc, max_chunk })
            .collect())
    }

    /// Cap the bytes per write call, flushing between chunks — frames
    /// then cross the wire as many short writes, which the receiving
    /// side must reassemble. Test/diagnostic knob; the fault-injection
    /// suite drives it.
    pub fn set_max_chunk(&mut self, n: usize) {
        self.max_chunk.store(n.max(1), Ordering::Relaxed);
    }

    /// Bound blocking receives (default: none). A slow healthy peer is
    /// indistinguishable from a hung one, so production runs wait; tests
    /// that want a hard bound use this (or an outer deadline).
    pub fn set_recv_timeout(&mut self, t: Option<Duration>) -> std::io::Result<()> {
        for r in self.inc.iter().flatten() {
            r.get_ref().set_read_timeout(t)?;
        }
        Ok(())
    }
}

/// Spawn the writer thread for one outgoing link. It drains the queue
/// in FIFO order, splitting frames into `max_chunk`-byte writes when the
/// knob is set; on the first write error it parks the mapped
/// [`CommError`] and exits (the closed queue then fails future sends).
/// On clean shutdown (queue closed) it half-closes the socket so the
/// peer reads EOF only after every queued frame.
fn spawn_writer(mut stream: TcpStream, dst: usize, max_chunk: Arc<AtomicUsize>) -> OutLink {
    let (tx, rx) = channel::<Vec<u8>>();
    let err: Arc<Mutex<Option<CommError>>> = Arc::new(Mutex::new(None));
    let err_slot = Arc::clone(&err);
    let writer = std::thread::spawn(move || {
        while let Ok(buf) = rx.recv() {
            let limit = max_chunk.load(Ordering::Relaxed).max(1);
            let result = if buf.len() <= limit {
                stream.write_all(&buf)
            } else {
                buf.chunks(limit).try_for_each(|c| {
                    stream.write_all(c)?;
                    stream.flush()
                })
            };
            if let Err(e) = result {
                *err_slot.lock().expect("writer error slot") = Some(io_to_comm(dst, e));
                return;
            }
        }
        let _ = stream.shutdown(Shutdown::Write);
    });
    OutLink { queue: Some(tx), err, writer: Some(writer) }
}

impl Transport for TcpMesh {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&mut self, dst: usize, frame: Frame) -> Result<(), CommError> {
        let link = self.out[dst]
            .as_ref()
            .expect("send to self goes through the inbox pass-through, not the transport");
        if let Some(e) = link.last_err() {
            return Err(e);
        }
        let mut buf = Vec::with_capacity(super::comm::FRAME_HEADER + frame.payload.len());
        frame.encode_to(&mut buf);
        // Queue gone or writer exited: surface the parked error, or a
        // plain loss when the writer died without recording one.
        let lost = || link.last_err().unwrap_or(CommError::PeerLost { rank: dst });
        let Some(q) = &link.queue else {
            return Err(lost());
        };
        if q.send(buf).is_err() {
            return Err(lost());
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<(), CommError> {
        // Writer threads push continuously; the round boundary is an
        // error checkpoint so a poisoned link fails the collective here
        // rather than surfacing one round later.
        for link in self.out.iter().flatten() {
            if let Some(e) = link.last_err() {
                return Err(e);
            }
        }
        Ok(())
    }

    fn recv(&mut self, src: usize) -> Result<Frame, CommError> {
        let r = self.inc[src]
            .as_mut()
            .expect("recv from self goes through the inbox pass-through, not the transport");
        Frame::decode_from(r).map_err(|e| io_to_comm(src, e))
    }

    fn name(&self) -> &'static str {
        "tcp"
    }

    fn shutdown(&mut self) {
        // Close the incoming sockets FIRST: this rank is done reading,
        // and the close is what unblocks any peer writer still pushing
        // toward it — with every rank closing its read side before
        // joining its own writers, teardown can never deadlock on a
        // cycle of full socket buffers.
        for r in self.inc.iter_mut().flatten() {
            let _ = r.get_ref().shutdown(Shutdown::Both);
        }
        // Then close every queue (writers drain, then FIN) and join.
        for link in self.out.iter_mut().flatten() {
            link.queue = None;
        }
        for link in self.out.iter_mut().flatten() {
            if let Some(h) = link.writer.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for TcpMesh {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn free_charges_zero_for_any_size() {
        let net = NetworkModel::free();
        for bytes in [0u64, 1, 1 << 20, u64::MAX >> 8] {
            assert_eq!(net.cost(bytes), Duration::ZERO);
        }
        assert!(!net.inject_delay);
        // delay() must return immediately even for huge payloads.
        net.delay(u64::MAX >> 8);
    }

    #[test]
    fn cost_is_monotone_in_bytes() {
        for net in [NetworkModel::infiniband_200g(), NetworkModel::ethernet_10g()] {
            let mut prev = Duration::ZERO;
            for bytes in [0u64, 1 << 10, 1 << 20, 1 << 30] {
                let c = net.cost(bytes);
                assert!(c >= prev, "{}: cost({bytes}) < cost of fewer bytes", net.name);
                assert!(c >= net.latency, "{}: cost below latency floor", net.name);
                prev = c;
            }
        }
    }

    #[test]
    fn bandwidth_math_matches_the_fabric() {
        let ib = NetworkModel::infiniband_200g();
        // 25 GB over 25 GB/s = 1 s (+2 µs latency).
        let c = ib.cost(25_000_000_000);
        assert!((c.as_secs_f64() - 1.0).abs() < 1e-3, "{c:?}");
        // Ethernet is 20x slower per byte.
        let eth = NetworkModel::ethernet_10g();
        let ratio = (eth.cost(1 << 30) - eth.latency).as_secs_f64()
            / (ib.cost(1 << 30) - ib.latency).as_secs_f64();
        assert!((ratio - 20.0).abs() < 0.1, "ratio {ratio}");
    }

    #[test]
    fn transport_config_parses_and_prints() {
        use std::str::FromStr;
        assert_eq!(TransportConfig::from_str("inproc").unwrap(), TransportConfig::Inproc);
        assert_eq!(
            TransportConfig::from_str("tcp").unwrap(),
            TransportConfig::Tcp { base_port: 0 }
        );
        assert_eq!(
            TransportConfig::from_str("tcp:9100").unwrap(),
            TransportConfig::Tcp { base_port: 9100 }
        );
        assert!(TransportConfig::from_str("rdma").is_err());
        assert!(TransportConfig::from_str("tcp:notaport").is_err());
        assert_eq!(TransportConfig::Inproc.to_string(), "inproc");
        assert_eq!(TransportConfig::Tcp { base_port: 0 }.to_string(), "tcp:0");
        assert_eq!(TransportConfig::default(), TransportConfig::Inproc);
    }

    #[test]
    fn tcp_mesh_moves_frames_point_to_point() {
        // 3 ranks, each sends one frame to each peer, then receives —
        // driven directly at the Transport level, single process.
        let meshes = TcpMesh::loopback(3, 0).unwrap();
        let handles: Vec<_> = meshes
            .into_iter()
            .map(|mut t| {
                std::thread::spawn(move || {
                    let rank = t.rank();
                    for dst in 0..3 {
                        if dst == rank {
                            continue;
                        }
                        let frame = Frame {
                            kind: 0,
                            elem: 1,
                            src: rank as u16,
                            seq: 5,
                            payload: vec![rank as u8; 3 + dst],
                        };
                        t.send(dst, frame).unwrap();
                    }
                    t.flush().unwrap();
                    let mut got = Vec::new();
                    for src in 0..3 {
                        if src == rank {
                            continue;
                        }
                        got.push(t.recv(src).unwrap());
                    }
                    (rank, got)
                })
            })
            .collect();
        for h in handles {
            let (rank, got) = h.join().unwrap();
            for f in got {
                let src = f.src as usize;
                assert_ne!(src, rank);
                assert_eq!(f.seq, 5);
                assert_eq!(f.payload, vec![src as u8; 3 + rank]);
            }
        }
    }

    #[test]
    fn tcp_mesh_single_rank_world_has_no_links() {
        let meshes = TcpMesh::loopback(1, 0).unwrap();
        assert_eq!(meshes.len(), 1);
        assert_eq!(meshes[0].world(), 1);
    }
}
