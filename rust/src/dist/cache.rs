//! Generic per-worker cache machinery shared by the remote-*feature*
//! cache ([`super::feature_cache::FeatureCache`]) and the remote-
//! *adjacency* overlay ([`crate::partition::TopologyView`]).
//!
//! One slab, two row shapes: fixed-width rows (feature vectors — every
//! row is `feat_dim` cells) and variable-width rows (adjacency lists —
//! one cell per in-edge). Both are byte-budgeted: a row of `len` cells
//! is charged `row_overhead + len * size_of::<V>()` bytes, so the
//! adjacency cache uses exactly the same `8 + 4·deg` accounting as the
//! static halo in `partition::shard`, and a `cache:<bytes>` knob and a
//! `budget:<bytes>` knob spend the same currency.
//!
//! Two policies (the A1 ablation axis, now shared by both caches):
//! * [`CachePolicy::StaticDegree`] — first fill wins, nothing is ever
//!   evicted: the classic degree-static cache of GNS/BGL-style systems.
//!   Runtime inserts are accepted only while budget remains.
//! * [`CachePolicy::Clock`] — second-chance (CLOCK) eviction, an LRU
//!   approximation with O(1) metadata per row.
//!
//! Lookups ([`SlabCache::get`]) take `&self` and mark the reference bit
//! atomically, so a read-only view of the cache can be shared across the
//! sampler's parallel per-seed loop; all mutation (insert/evict) is
//! `&mut self` and happens in the sequential decode phase.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};

use crate::graph::NodeId;

/// Eviction policy selector, shared by the feature and adjacency caches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePolicy {
    /// Static contents: first fill wins, nothing is ever evicted.
    StaticDegree,
    /// CLOCK / second-chance approximation of LRU.
    Clock,
}

/// One resident (or dead, reusable) row of the slab.
struct Slot {
    node: NodeId,
    off: usize,
    len: usize,
    /// CLOCK reference bit (set on hit, cleared as the hand sweeps);
    /// atomic so `get` can mark hits through a shared reference.
    referenced: AtomicBool,
    live: bool,
}

impl Clone for Slot {
    fn clone(&self) -> Self {
        Slot {
            node: self.node,
            off: self.off,
            len: self.len,
            referenced: AtomicBool::new(self.referenced.load(Ordering::Relaxed)),
            live: self.live,
        }
    }
}

/// Byte-budgeted cache of rows keyed by global node id, backed by one
/// contiguous slab of `V` cells. Fixed-width clients insert equal-length
/// rows (evictions then free exactly one slot's worth of space, and the
/// freed extent is reused in place); variable-width clients may insert
/// any length, with dead extents reclaimed by an amortized compaction.
pub struct SlabCache<V> {
    policy: CachePolicy,
    capacity_bytes: u64,
    /// Charged per row on top of the payload cells (0 for fixed-width
    /// feature rows, 8 for adjacency rows — matching the halo's
    /// row-pointer accounting).
    row_overhead: u64,
    used_bytes: u64,
    data: Vec<V>,
    slots: Vec<Slot>,
    /// Dead slot indices whose extents may be reused by a same-length row.
    free: Vec<u32>,
    dead_cells: usize,
    index: HashMap<NodeId, u32>,
    hand: usize,
}

impl<V: Copy> SlabCache<V> {
    pub fn new(policy: CachePolicy, capacity_bytes: u64, row_overhead: u64) -> Self {
        Self {
            policy,
            capacity_bytes,
            row_overhead,
            used_bytes: 0,
            data: Vec::new(),
            slots: Vec::new(),
            free: Vec::new(),
            dead_cells: 0,
            index: HashMap::new(),
            hand: 0,
        }
    }

    pub fn policy(&self) -> CachePolicy {
        self.policy
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Bytes currently charged to resident rows.
    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    /// Number of resident rows.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Resident rows in slot order — deterministic for a given
    /// insert/evict history, which is what lets the checkpoint subsystem
    /// persist the resident set reproducibly. Read-only: reference bits
    /// are not touched, so snapshotting does not perturb CLOCK.
    pub fn iter(&self) -> impl Iterator<Item = (NodeId, &[V])> {
        self.slots
            .iter()
            .filter(|s| s.live)
            .map(|s| (s.node, &self.data[s.off..s.off + s.len]))
    }

    /// Is `v` resident? (Does not touch the reference bit.)
    pub fn contains(&self, v: NodeId) -> bool {
        self.index.contains_key(&v)
    }

    /// Bytes a row of `len` cells is charged against the budget.
    #[inline]
    fn charge(&self, len: usize) -> u64 {
        self.row_overhead + (len * std::mem::size_of::<V>()) as u64
    }

    /// The longest row worth admitting right now, in cells — `None`
    /// when nothing (not even an empty row) fits. Derived from the
    /// *remaining* budget under `StaticDegree` (no eviction will make
    /// room); under `Clock`, eviction can always make room, but a row
    /// is only worth it up to a **quarter** of the total budget — wider
    /// rows would churn most of the resident (hit-bearing) set for one
    /// entry, and a byte-tight cache facing rows wider than that would
    /// thrash at a ~0% hit rate while still paying to ship every row.
    /// This is what the distributed sampler turns into its wire-level
    /// admission threshold; [`Self::insert`] itself accepts anything
    /// that fits the whole budget.
    pub fn admissible_len(&self) -> Option<usize> {
        let budget = match self.policy {
            CachePolicy::StaticDegree => self.capacity_bytes - self.used_bytes,
            CachePolicy::Clock => self.capacity_bytes / 4,
        };
        if budget < self.row_overhead {
            return None;
        }
        Some(((budget - self.row_overhead) / std::mem::size_of::<V>().max(1) as u64) as usize)
    }

    /// The cached row for `v`, marking it recently used. Empty rows are
    /// valid residents (`Some(&[])` — e.g. a degree-0 adjacency list).
    pub fn get(&self, v: NodeId) -> Option<&[V]> {
        let slot = &self.slots[*self.index.get(&v)? as usize];
        slot.referenced.store(true, Ordering::Relaxed);
        Some(&self.data[slot.off..slot.off + slot.len])
    }

    /// Offer a row to the cache; returns whether it is resident after the
    /// call. While the budget has room every row is admitted; at budget,
    /// `StaticDegree` rejects (static contents) and `Clock` evicts
    /// second-chance victims until the row fits. Rows wider than the
    /// whole budget are always rejected. Re-inserting a resident key of
    /// the same width refreshes it in place.
    pub fn insert(&mut self, v: NodeId, row: &[V]) -> bool {
        let charge = self.charge(row.len());
        if charge > self.capacity_bytes {
            return false;
        }
        if let Some(&s) = self.index.get(&v) {
            let s = s as usize;
            if self.slots[s].len == row.len() {
                let off = self.slots[s].off;
                self.data[off..off + row.len()].copy_from_slice(row);
                self.slots[s].referenced.store(true, Ordering::Relaxed);
                return true;
            }
            // Width changed (not a workload either client produces, but
            // stay correct): drop the stale row and fall through.
            self.evict_slot(s);
        }
        match self.policy {
            CachePolicy::StaticDegree => {
                if self.used_bytes + charge > self.capacity_bytes {
                    return false;
                }
            }
            CachePolicy::Clock => {
                while self.used_bytes + charge > self.capacity_bytes {
                    if !self.evict_victim() {
                        return false; // unreachable: empty cache fits any charge <= capacity
                    }
                }
            }
        }
        // Place the row. Dead slot *metadata* is always recycled so
        // `slots` stays bounded by the peak resident count: an extent of
        // exactly this width is rewritten in place (always the case for
        // fixed-width clients — the slot evicted just above is the last
        // free entry, which is why probing only the back of the free
        // list suffices and keeps inserts O(1) even when evictions have
        // piled up many dead slots); any other dead slot is given a
        // fresh tail extent (its old cells stay in `dead_cells` until
        // compaction). Only an empty free list grows the slot table.
        let probe = self.free.len().saturating_sub(8);
        let slot = match self.free[probe..]
            .iter()
            .rposition(|&s| self.slots[s as usize].len == row.len())
            .map(|rel| probe + rel)
        {
            Some(fpos) => {
                let s = self.free.swap_remove(fpos) as usize;
                let off = self.slots[s].off;
                self.data[off..off + row.len()].copy_from_slice(row);
                self.dead_cells -= row.len();
                self.slots[s] = Slot {
                    node: v,
                    off,
                    len: row.len(),
                    referenced: AtomicBool::new(true),
                    live: true,
                };
                s
            }
            None => {
                let off = self.data.len();
                self.data.extend_from_slice(row);
                let fresh = Slot {
                    node: v,
                    off,
                    len: row.len(),
                    referenced: AtomicBool::new(true),
                    live: true,
                };
                match self.free.pop() {
                    Some(s) => {
                        self.slots[s as usize] = fresh;
                        s as usize
                    }
                    None => {
                        self.slots.push(fresh);
                        self.slots.len() - 1
                    }
                }
            }
        };
        self.index.insert(v, slot as u32);
        self.used_bytes += charge;
        self.maybe_compact();
        true
    }

    /// CLOCK sweep: clear reference bits until an unreferenced live slot
    /// is found, then evict it. False iff the cache is empty.
    fn evict_victim(&mut self) -> bool {
        if self.index.is_empty() {
            return false;
        }
        loop {
            let s = self.hand;
            self.hand = (self.hand + 1) % self.slots.len();
            if !self.slots[s].live {
                continue;
            }
            if self.slots[s].referenced.swap(false, Ordering::Relaxed) {
                continue; // second chance
            }
            self.evict_slot(s);
            return true;
        }
    }

    fn evict_slot(&mut self, s: usize) {
        debug_assert!(self.slots[s].live);
        self.index.remove(&self.slots[s].node);
        self.used_bytes -= self.charge(self.slots[s].len);
        self.dead_cells += self.slots[s].len;
        self.slots[s].live = false;
        self.free.push(s as u32);
    }

    /// Reclaim dead extents once they dominate the slab (amortized O(1)
    /// per insert). Slot indices — and therefore the clock hand — stay
    /// stable; only offsets move.
    fn maybe_compact(&mut self) {
        if self.dead_cells <= 256 || self.dead_cells * 2 <= self.data.len() {
            return;
        }
        let mut packed: Vec<V> = Vec::with_capacity(self.data.len() - self.dead_cells);
        for slot in self.slots.iter_mut() {
            if slot.live {
                let off = packed.len();
                packed.extend_from_slice(&self.data[slot.off..slot.off + slot.len]);
                slot.off = off;
            } else {
                slot.off = 0;
                slot.len = 0;
            }
        }
        self.data = packed;
        // Dead slots keep their (now zero-length) entries on the free
        // list: their metadata is still recycled by `insert`, which keeps
        // the slot table bounded by the peak resident count.
        self.dead_cells = 0;
    }
}

impl<V: Copy> Clone for SlabCache<V> {
    fn clone(&self) -> Self {
        Self {
            policy: self.policy,
            capacity_bytes: self.capacity_bytes,
            row_overhead: self.row_overhead,
            used_bytes: self.used_bytes,
            data: self.data.clone(),
            slots: self.slots.clone(),
            free: self.free.clone(),
            dead_cells: self.dead_cells,
            index: self.index.clone(),
            hand: self.hand,
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn adj_cache(policy: CachePolicy, capacity: u64) -> SlabCache<NodeId> {
        SlabCache::new(policy, capacity, 8)
    }

    #[test]
    fn variable_width_rows_round_trip() {
        let mut c = adj_cache(CachePolicy::Clock, 1 << 16);
        c.insert(1, &[10, 11, 12]);
        c.insert(2, &[]);
        c.insert(3, &[7; 40]);
        assert_eq!(c.get(1).unwrap(), &[10, 11, 12][..]);
        assert_eq!(c.get(2).unwrap(), &[] as &[NodeId]);
        assert_eq!(c.get(3).unwrap(), &[7; 40][..]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.used_bytes(), 3 * 8 + (3 + 40) * 4);
    }

    #[test]
    fn iter_lists_live_rows_in_slot_order() {
        let mut c = adj_cache(CachePolicy::StaticDegree, 1 << 16);
        c.insert(5, &[50, 51]);
        c.insert(2, &[20]);
        c.insert(9, &[]);
        let rows: Vec<(NodeId, Vec<NodeId>)> =
            c.iter().map(|(n, r)| (n, r.to_vec())).collect();
        assert_eq!(rows, vec![(5, vec![50, 51]), (2, vec![20]), (9, vec![])]);
        // Snapshotting must not perturb the cache.
        assert_eq!(c.len(), 3);
        assert_eq!(c.get(2).unwrap(), &[20][..]);
    }

    #[test]
    fn rows_wider_than_the_budget_are_rejected() {
        let mut c = adj_cache(CachePolicy::Clock, 8 + 4 * 4);
        assert!(!c.insert(1, &[0; 5]));
        assert!(c.insert(2, &[0; 4]));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn static_degree_admits_while_budget_remains_then_pins() {
        let mut c = adj_cache(CachePolicy::StaticDegree, 2 * (8 + 4 * 2));
        assert!(c.insert(1, &[5, 6]));
        assert!(c.insert(2, &[7, 8]));
        assert!(!c.insert(3, &[9, 10]), "over budget must be rejected");
        assert!(c.contains(1) && c.contains(2) && !c.contains(3));
        // Admission threshold reflects the *remaining* budget.
        assert_eq!(c.admissible_len(), None);
    }

    #[test]
    fn clock_evicts_to_fit_variable_rows() {
        let mut c = adj_cache(CachePolicy::Clock, 2 * (8 + 4 * 2));
        c.insert(1, &[5, 6]);
        c.insert(2, &[7, 8]);
        // A 4-cell row needs both resident rows' space.
        assert!(c.insert(3, &[1, 2, 3, 4]));
        assert!(c.contains(3));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(3).unwrap(), &[1, 2, 3, 4][..]);
        // Clock's wire threshold is a quarter of the total budget:
        // eviction can make room, but churning most of the resident set
        // for one wide row is never worth shipping it.
        assert_eq!(c.admissible_len(), Some(0), "32B budget / 4 = 8B fits only empty rows");
        let wide = adj_cache(CachePolicy::Clock, 4 * (8 + 4 * 10));
        assert_eq!(wide.admissible_len(), Some(10), "a quarter of the budget, minus overhead");
    }

    #[test]
    fn compaction_preserves_resident_rows() {
        // Thrash a small clock cache with distinct-width rows so dead
        // extents accumulate past the compaction threshold.
        let mut c = adj_cache(CachePolicy::Clock, 8 + 4 * 600);
        for round in 0..50u32 {
            let len = 400 + (round as usize % 7);
            let row: Vec<NodeId> = (0..len as NodeId).map(|j| j + round).collect();
            assert!(c.insert(round, &row));
            assert_eq!(c.get(round).unwrap(), &row[..], "round {round}");
        }
        assert!(c.len() == 1, "cache fits only one wide row at a time");
        assert!(c.data.len() < 600 * 4, "dead extents never reclaimed");
        // Dead slot metadata is recycled, so the slot table stays bounded
        // by the peak resident count (+1 transient), not the insert count.
        assert!(c.slots.len() <= 2, "slot table leaked: {}", c.slots.len());
    }

    #[test]
    fn get_through_shared_reference_marks_hits() {
        let mut c = adj_cache(CachePolicy::Clock, 3 * (8 + 4));
        c.insert(1, &[10]);
        c.insert(2, &[20]);
        c.insert(3, &[30]);
        // Full sweep (all referenced) degenerates to FIFO: 1 is evicted.
        c.insert(4, &[40]);
        assert!(!c.contains(1));
        // Shared-ref hit on 2 gives it a second chance; 3 goes next.
        let shared: &SlabCache<NodeId> = &c;
        assert_eq!(shared.get(2).unwrap(), &[20][..]);
        c.insert(5, &[50]);
        assert!(c.contains(2) && !c.contains(3));
        assert!(c.contains(4) && c.contains(5));
    }

    #[test]
    fn clone_preserves_contents() {
        let mut c = adj_cache(CachePolicy::StaticDegree, 1 << 12);
        c.insert(9, &[1, 2, 3]);
        let d = c.clone();
        assert_eq!(d.get(9).unwrap(), &[1, 2, 3][..]);
        assert_eq!(d.used_bytes(), c.used_bytes());
    }
}
