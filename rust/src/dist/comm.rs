//! Typed collective rounds — the communication API everything else is
//! built on.
//!
//! The paper's cost analysis (§3.3) counts *rounds*: synchronized
//! collectives in which every worker exchanges one typed payload with its
//! peers. This module makes that the unit of the API: every data
//! collective is an [`Comm::exchange`] tagged with a [`RoundKind`], and is
//! charged to shared [`Counters`] (one round per *collective*, bytes per
//! *worker*), so "vanilla pays 2(L−1) sampling rounds, hybrid pays 0" is
//! an assertable fact rather than a claim.
//!
//! Transport is an in-process mesh of `mpsc` channels between worker
//! threads (see [`super::worker`]); the seam where a real RPC transport
//! would slot in is exactly the private `exchange_impl` below. Because
//! channels are FIFO per (src, dst) pair and every worker executes the
//! same sequence of collectives, no per-round barrier is needed; payloads
//! carry a round tag so a desynchronized worker fails loudly instead of
//! deadlocking or mismatching types.

use std::any::Any;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use super::net::NetworkModel;

/// What a collective round moves — the paper's round taxonomy.
///
/// The `usize` discriminants are stable and public: `CommStats::rounds`
/// and `::bytes` are indexable arrays (`rounds[RoundKind::GradSync as
/// usize]`), which keeps report code free of match boilerplate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum RoundKind {
    /// Vanilla sampling: frontier nodes shipped to their owners.
    SampleRequest = 0,
    /// Vanilla sampling: sampled neighborhoods shipped back.
    SampleResponse = 1,
    /// Feature exchange: input-node ids shipped to feature owners.
    FeatureRequest = 2,
    /// Feature exchange: feature rows shipped back.
    FeatureResponse = 3,
    /// Data-parallel gradient synchronization.
    GradSync = 4,
}

impl RoundKind {
    pub const COUNT: usize = 5;
    pub const ALL: [RoundKind; Self::COUNT] = [
        RoundKind::SampleRequest,
        RoundKind::SampleResponse,
        RoundKind::FeatureRequest,
        RoundKind::FeatureResponse,
        RoundKind::GradSync,
    ];

    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            RoundKind::SampleRequest => "sample-request",
            RoundKind::SampleResponse => "sample-response",
            RoundKind::FeatureRequest => "feature-request",
            RoundKind::FeatureResponse => "feature-response",
            RoundKind::GradSync => "grad-sync",
        }
    }
}

/// Shared, thread-safe round/byte accounting. One instance per training
/// run, shared by all workers (`Arc<Counters>`); snapshot at any sync
/// point to get a [`CommStats`].
#[derive(Debug, Default)]
pub struct Counters {
    rounds: [AtomicU64; RoundKind::COUNT],
    bytes: [AtomicU64; RoundKind::COUNT],
}

impl Counters {
    /// Record one collective round of `kind`. Called once per collective
    /// (by rank 0), not once per worker — Fig 6's round counts are
    /// per-fabric, not per-machine.
    pub fn add_round(&self, kind: RoundKind) {
        self.rounds[kind.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` bytes sent off-worker under `kind`. Called by every
    /// worker for its own payloads, so bytes aggregate over the fabric.
    pub fn add_bytes(&self, kind: RoundKind, n: u64) {
        if n > 0 {
            self.bytes[kind.index()].fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Consistent-enough copy of the counters (exact at join/barrier
    /// points, which is where every reader snapshots).
    pub fn snapshot(&self) -> CommStats {
        let mut s = CommStats::default();
        for k in RoundKind::ALL {
            s.rounds[k.index()] = self.rounds[k.index()].load(Ordering::Relaxed);
            s.bytes[k.index()] = self.bytes[k.index()].load(Ordering::Relaxed);
        }
        s
    }
}

/// Plain-data snapshot of [`Counters`], indexable by `RoundKind as usize`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CommStats {
    pub rounds: [u64; RoundKind::COUNT],
    pub bytes: [u64; RoundKind::COUNT],
}

impl CommStats {
    pub fn rounds_of(&self, kind: RoundKind) -> u64 {
        self.rounds[kind.index()]
    }

    pub fn bytes_of(&self, kind: RoundKind) -> u64 {
        self.bytes[kind.index()]
    }

    /// The paper's headline counter: sampling rounds (request + response).
    pub fn sampling_rounds(&self) -> u64 {
        self.rounds_of(RoundKind::SampleRequest) + self.rounds_of(RoundKind::SampleResponse)
    }

    pub fn feature_rounds(&self) -> u64 {
        self.rounds_of(RoundKind::FeatureRequest) + self.rounds_of(RoundKind::FeatureResponse)
    }

    pub fn total_rounds(&self) -> u64 {
        self.rounds.iter().sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().sum()
    }

    /// `self - before`, element-wise (for per-epoch deltas).
    pub fn diff(&self, before: &CommStats) -> CommStats {
        let mut out = CommStats::default();
        for i in 0..RoundKind::COUNT {
            out.rounds[i] = self.rounds[i].saturating_sub(before.rounds[i]);
            out.bytes[i] = self.bytes[i].saturating_sub(before.bytes[i]);
        }
        out
    }

    /// Aligned per-kind table (used by `fastsample train` and report A3).
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("{:<18} {:>10} {:>16}\n", "round kind", "rounds", "bytes"));
        for k in RoundKind::ALL {
            out.push_str(&format!(
                "{:<18} {:>10} {:>16}\n",
                k.name(),
                self.rounds_of(k),
                self.bytes_of(k)
            ));
        }
        out.push_str(&format!(
            "{:<18} {:>10} {:>16}",
            "total",
            self.total_rounds(),
            self.total_bytes()
        ));
        out
    }
}

/// Type-erased payload crossing a (src, dst) channel: a round tag plus the
/// typed vector, boxed. The tag catches lockstep bugs (two workers issuing
/// different collective sequences) with a readable panic.
type Payload = Box<dyn Any + Send>;

/// Tags for control-plane collectives that move no accountable data.
const TAG_BARRIER: u8 = 200;
const TAG_MIN_U64: u8 = 201;

/// One worker's handle to the fabric: rank/world identity, the channel
/// mesh, the network cost model, and the shared counters.
///
/// All collectives are *uniform*: every rank in the world must call the
/// same method in the same order (the usual SPMD contract). A violation
/// panics with a "collective sequence mismatch" rather than deadlocking.
pub struct Comm {
    rank: usize,
    world: usize,
    /// Shared accounting; public so trainers can snapshot per-epoch deltas.
    pub counters: Arc<Counters>,
    net: NetworkModel,
    /// `tx[dst]` sends to rank `dst`; the self slot exists but is unused.
    tx: Vec<Sender<Payload>>,
    /// `rx[src]` receives from rank `src`; the self slot is unused.
    rx: Vec<Receiver<Payload>>,
}

impl Comm {
    /// Build the fully-connected channel mesh for `world` ranks.
    pub(crate) fn mesh(world: usize, net: NetworkModel, counters: Arc<Counters>) -> Vec<Comm> {
        assert!(world >= 1, "world size must be >= 1");
        let mut tx_of_rank: Vec<Vec<Sender<Payload>>> =
            (0..world).map(|_| Vec::with_capacity(world)).collect();
        let mut rx_of_rank: Vec<Vec<Option<Receiver<Payload>>>> =
            (0..world).map(|_| (0..world).map(|_| None).collect()).collect();
        for src in 0..world {
            for dst in 0..world {
                let (tx, rx) = channel();
                tx_of_rank[src].push(tx);
                rx_of_rank[dst][src] = Some(rx);
            }
        }
        tx_of_rank
            .into_iter()
            .zip(rx_of_rank)
            .enumerate()
            .map(|(rank, (tx, rx))| Comm {
                rank,
                world,
                counters: Arc::clone(&counters),
                net: net.clone(),
                tx,
                rx: rx.into_iter().map(|r| r.expect("mesh slot filled")).collect(),
            })
            .collect()
    }

    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    #[inline]
    pub fn world(&self) -> usize {
        self.world
    }

    pub fn net(&self) -> &NetworkModel {
        &self.net
    }

    /// One typed all-to-all round: `outboxes[dst]` goes to rank `dst`,
    /// the return value's `[src]` slot is what rank `src` sent here (the
    /// self slot passes through untouched and untaxed).
    ///
    /// Accounting: the round is counted **once** per collective (rank 0
    /// increments), bytes are charged per worker for off-rank payloads
    /// only, and the network model injects `latency + bytes/bandwidth`
    /// of wall time on each worker.
    pub fn exchange<T: Send + 'static>(
        &mut self,
        kind: RoundKind,
        outboxes: Vec<Vec<T>>,
    ) -> Vec<Vec<T>> {
        self.exchange_impl(kind.index() as u8, Some(kind), outboxes)
    }

    /// Rendezvous: returns once every rank has entered the barrier.
    /// Control-plane only — not charged to any `RoundKind`.
    pub fn barrier(&mut self) {
        let empty: Vec<Vec<u8>> = (0..self.world).map(|_| Vec::new()).collect();
        let _ = self.exchange_impl(TAG_BARRIER, None, empty);
    }

    /// Global minimum (used to agree on batches/epoch). Control-plane —
    /// uncharged, like the barrier.
    pub fn all_reduce_min_u64(&mut self, v: u64) -> u64 {
        let outboxes: Vec<Vec<u64>> = (0..self.world)
            .map(|dst| if dst == self.rank { Vec::new() } else { vec![v] })
            .collect();
        let inboxes = self.exchange_impl(TAG_MIN_U64, None, outboxes);
        let mut m = v;
        for (src, inbox) in inboxes.iter().enumerate() {
            if src != self.rank {
                m = m.min(inbox[0]);
            }
        }
        m
    }

    /// Barrier-fenced snapshot of the shared counters: every rank gets
    /// the **same** [`CommStats`], taken after all ranks' prior traffic
    /// is recorded (first barrier) and before any rank can charge new
    /// bytes (second barrier). This is the only race-free way to slice
    /// the fabric-global counters into per-epoch deltas — a bare
    /// `barrier(); snapshot()` lets a fast rank charge the next epoch's
    /// first bytes before a slow rank has marked the boundary.
    /// Collective, control-plane only (uncharged).
    pub fn fenced_snapshot(&mut self) -> CommStats {
        self.barrier();
        let s = self.counters.snapshot();
        self.barrier();
        s
    }

    /// Round-skip vote: true iff `v == 0` on **every** rank. One
    /// uncharged control-plane min-reduce of the zero indicator — the
    /// protocol `dist::sampling` uses to skip a SampleRequest/Response
    /// pair when no rank has frontier misses (so sampling rounds are
    /// measured per level, not assumed per scheme).
    pub fn all_zero_u64(&mut self, v: u64) -> bool {
        self.all_reduce_min_u64(u64::from(v == 0)) == 1
    }

    /// Mean all-reduce over `data`, element-wise across ranks, in place.
    ///
    /// Every rank accumulates contributions in rank order 0..W, so all
    /// ranks compute **bit-identical** results — the loss-curve
    /// equivalence tests depend on this. The transport is a direct
    /// exchange (each rank broadcasts its buffer) rather than a ring:
    /// same math, simpler lockstep; the byte accounting reflects the
    /// broadcast honestly (`(W-1) * len * 4` per worker).
    pub fn all_reduce_mean_f32(&mut self, kind: RoundKind, data: &mut [f32]) {
        let mine = data.to_vec();
        let outboxes: Vec<Vec<f32>> = (0..self.world)
            .map(|dst| if dst == self.rank { Vec::new() } else { mine.clone() })
            .collect();
        let inboxes = self.exchange(kind, outboxes);
        data.fill(0.0);
        for src in 0..self.world {
            let part: &[f32] = if src == self.rank { &mine } else { &inboxes[src] };
            assert_eq!(part.len(), data.len(), "all-reduce length mismatch across ranks");
            for (acc, x) in data.iter_mut().zip(part) {
                *acc += *x;
            }
        }
        let inv = 1.0 / self.world as f32;
        for x in data.iter_mut() {
            *x *= inv;
        }
    }

    fn exchange_impl<T: Send + 'static>(
        &mut self,
        tag: u8,
        track: Option<RoundKind>,
        outboxes: Vec<Vec<T>>,
    ) -> Vec<Vec<T>> {
        assert_eq!(outboxes.len(), self.world, "need one outbox per rank");
        let mut inboxes: Vec<Option<Vec<T>>> = (0..self.world).map(|_| None).collect();
        let mut sent_bytes = 0u64;
        for (dst, data) in outboxes.into_iter().enumerate() {
            if dst == self.rank {
                inboxes[dst] = Some(data);
                continue;
            }
            sent_bytes += (data.len() * std::mem::size_of::<T>()) as u64;
            if self.tx[dst].send(Box::new((tag, data))).is_err() {
                panic!("rank {}: rank {dst} exited mid-collective", self.rank);
            }
        }
        if let Some(kind) = track {
            self.counters.add_bytes(kind, sent_bytes);
            if self.rank == 0 {
                self.counters.add_round(kind);
            }
        }
        self.net.delay(sent_bytes);
        for src in 0..self.world {
            if src == self.rank {
                continue;
            }
            let payload = match self.rx[src].recv() {
                Ok(p) => p,
                Err(_) => panic!("rank {}: rank {src} exited mid-collective", self.rank),
            };
            let boxed: Box<(u8, Vec<T>)> = payload.downcast().unwrap_or_else(|_| {
                panic!(
                    "rank {}: payload type mismatch from rank {src} — \
                     workers issued different collective sequences",
                    self.rank
                )
            });
            let (got_tag, data) = *boxed;
            assert_eq!(
                got_tag, tag,
                "rank {}: collective sequence mismatch with rank {src}",
                self.rank
            );
            inboxes[src] = Some(data);
        }
        inboxes.into_iter().map(|o| o.expect("inbox filled")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::worker::{run_workers, run_workers_with};
    use super::*;

    #[test]
    fn exchange_routes_payloads_by_rank() {
        let results = run_workers(3, NetworkModel::free(), |rank, comm| {
            // Rank r sends the single value r*10 + dst to each dst.
            let outboxes: Vec<Vec<u32>> =
                (0..3).map(|dst| vec![(rank * 10 + dst) as u32]).collect();
            comm.exchange(RoundKind::SampleRequest, outboxes)
        });
        for (rank, inboxes) in results.iter().enumerate() {
            for (src, inbox) in inboxes.iter().enumerate() {
                assert_eq!(inbox[..], [(src * 10 + rank) as u32], "src {src} -> dst {rank}");
            }
        }
    }

    #[test]
    fn rounds_count_once_per_collective_bytes_per_worker() {
        let counters = Arc::new(Counters::default());
        run_workers_with(4, NetworkModel::free(), Arc::clone(&counters), |rank, comm| {
            // Two rounds; each worker ships 8 bytes (2 u32) to each peer.
            for _ in 0..2 {
                let outboxes: Vec<Vec<u32>> = (0..4).map(|_| vec![rank as u32, 7]).collect();
                comm.exchange(RoundKind::FeatureRequest, outboxes);
            }
        });
        let s = counters.snapshot();
        assert_eq!(s.rounds_of(RoundKind::FeatureRequest), 2);
        // 4 workers x 3 peers x 8 bytes x 2 rounds; self slot untaxed.
        assert_eq!(s.bytes_of(RoundKind::FeatureRequest), 4 * 3 * 8 * 2);
        assert_eq!(s.total_rounds(), 2);
    }

    #[test]
    fn all_reduce_mean_is_identical_on_every_rank() {
        let results = run_workers(4, NetworkModel::free(), |rank, comm| {
            let mut data = vec![rank as f32, 1.0, -2.0 * rank as f32];
            comm.all_reduce_mean_f32(RoundKind::GradSync, &mut data);
            data
        });
        for r in &results {
            assert_eq!(r, &results[0], "ranks disagree bitwise");
        }
        assert_eq!(results[0][..], [1.5, 1.0, -3.0]);
    }

    #[test]
    fn min_and_barrier_are_uncharged() {
        let counters = Arc::new(Counters::default());
        let mins = run_workers_with(3, NetworkModel::free(), Arc::clone(&counters), |rank, comm| {
            comm.barrier();
            comm.all_reduce_min_u64(10 + rank as u64)
        });
        assert!(mins.iter().all(|&m| m == 10));
        let s = counters.snapshot();
        assert_eq!(s.total_rounds(), 0);
        assert_eq!(s.total_bytes(), 0);
    }

    #[test]
    fn fenced_snapshot_is_identical_on_every_rank() {
        let counters = Arc::new(Counters::default());
        let snaps = run_workers_with(3, NetworkModel::free(), Arc::clone(&counters), |rank, comm| {
            // Rank-skewed traffic before the fence; the fence must still
            // hand every rank one consistent cut of the counters.
            let outboxes: Vec<Vec<u8>> = (0..3).map(|_| vec![7u8; rank + 1]).collect();
            comm.exchange(RoundKind::GradSync, outboxes);
            comm.fenced_snapshot()
        });
        assert_eq!(snaps[0], snaps[1]);
        assert_eq!(snaps[1], snaps[2]);
        assert_eq!(snaps[0].rounds_of(RoundKind::GradSync), 1);
        // (1+2+3) payload bytes x 2 off-rank peers per rank.
        assert_eq!(snaps[0].bytes_of(RoundKind::GradSync), (1 + 2 + 3) * 2);
    }

    #[test]
    fn all_zero_vote_is_unanimous_and_uncharged() {
        let counters = Arc::new(Counters::default());
        let votes = run_workers_with(3, NetworkModel::free(), Arc::clone(&counters), |rank, comm| {
            // Everyone zero → true; then rank 1 non-zero → false everywhere.
            let a = comm.all_zero_u64(0);
            let b = comm.all_zero_u64(if rank == 1 { 5 } else { 0 });
            (a, b)
        });
        assert!(votes.iter().all(|&(a, b)| a && !b));
        let s = counters.snapshot();
        assert_eq!(s.total_rounds(), 0);
        assert_eq!(s.total_bytes(), 0);
    }

    #[test]
    fn single_rank_world_degenerates_cleanly() {
        let out = run_workers(1, NetworkModel::free(), |_rank, comm| {
            comm.barrier();
            let mut data = vec![3.0f32, -1.0];
            comm.all_reduce_mean_f32(RoundKind::GradSync, &mut data);
            let m = comm.all_reduce_min_u64(9);
            let echoed = comm.exchange(RoundKind::SampleRequest, vec![vec![42u32]]);
            (data, m, echoed)
        });
        let (data, m, echoed) = &out[0];
        assert_eq!(data[..], [3.0, -1.0]);
        assert_eq!(*m, 9);
        assert_eq!(echoed.len(), 1);
        assert_eq!(echoed[0][..], [42u32]);
    }

    #[test]
    fn stats_diff_and_report_are_consistent() {
        let a = CommStats { rounds: [0, 0, 0, 0, 5], bytes: [0, 0, 0, 0, 1000] };
        let b = CommStats { rounds: [0, 0, 0, 0, 8], bytes: [0, 0, 0, 0, 1600] };
        let d = b.diff(&a);
        assert_eq!(d.rounds_of(RoundKind::GradSync), 3);
        assert_eq!(d.bytes_of(RoundKind::GradSync), 600);
        assert_eq!(d.total_bytes(), 600);
        let rep = b.report();
        assert!(rep.contains("grad-sync"));
        assert!(rep.contains("total"));
    }
}
