//! Typed collective rounds — the communication API everything else is
//! built on.
//!
//! The paper's cost analysis (§3.3) counts *rounds*: synchronized
//! collectives in which every worker exchanges one typed payload with its
//! peers. This module makes that the unit of the API: every data
//! collective is an [`Comm::exchange`] tagged with a [`RoundKind`], and is
//! charged to shared [`Counters`] (one round per *collective*, bytes per
//! *worker*), so "vanilla pays 2(L−1) sampling rounds, hybrid pays 0" is
//! an assertable fact rather than a claim.
//!
//! Transport is pluggable: [`Comm`] drives a [`Transport`] trait object
//! that moves length-delimited byte [`Frame`]s between peers. Two
//! implementations ship: [`ChannelMesh`] (an in-process `mpsc` mesh, the
//! default — one worker thread ≈ one machine) and
//! [`super::net::TcpMesh`] (per-peer sockets, length-prefixed
//! little-endian framing). Every off-rank payload is serialized through
//! the same [`Wire`] encoding on both transports, so the byte counters
//! tally what is actually framed for the wire, and results are
//! bit-identical across transports by construction.
//!
//! Because each (src, dst) link is FIFO and every worker executes the
//! same sequence of collectives, no per-round barrier is needed; frames
//! carry a round tag, an element width, and a per-rank sequence number so
//! a desynchronized worker fails loudly with
//! [`CommError::SequenceMismatch`] instead of deadlocking or mismatching
//! types. A peer that exits mid-collective surfaces as
//! [`CommError::PeerLost`] on every rank still talking to it — no hang,
//! no panic.
//!
//! The fabric is split into independent **communication planes**
//! ([`Plane`]): every frame is stamped with a plane byte, each plane has
//! its own sequence stream, per-peer inboxes, and per-plane byte/round
//! accounting, and [`Comm::plane`] mints a handle scoped to one plane.
//! Two planes can have rounds in flight concurrently — the pipelined
//! trainer runs sampling collectives for minibatch *t+1* on
//! [`Plane::Sampling`] from a sampler thread while the trainer drives
//! gradient collectives for minibatch *t* on [`Plane::Gradient`] — and
//! the per-source demultiplexer guarantees the two streams can never
//! interleave. A [`CommError`] on either plane poisons the shared
//! endpoint: the transport is shut down, every blocked receive on every
//! plane unblocks promptly, and all subsequent collectives on any handle
//! return the root-cause error instead of hanging.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use super::net::NetworkModel;

/// What a collective round moves — the paper's round taxonomy.
///
/// The `usize` discriminants are stable and public: `CommStats::rounds`
/// and `::bytes` are indexable arrays (`rounds[RoundKind::GradSync as
/// usize]`), which keeps report code free of match boilerplate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum RoundKind {
    /// Vanilla sampling: frontier nodes shipped to their owners.
    SampleRequest = 0,
    /// Vanilla sampling: sampled neighborhoods shipped back — as the
    /// columnar bulk layout (counts block + ids blob + cache-row
    /// section) or the run-length scalar stream, per the uniform
    /// [`SamplingWire`](crate::dist::SamplingWire) choice; the round
    /// kind and count are the same either way.
    SampleResponse = 1,
    /// Feature exchange: input-node ids shipped to feature owners.
    FeatureRequest = 2,
    /// Feature exchange: feature rows shipped back.
    FeatureResponse = 3,
    /// Data-parallel gradient synchronization.
    GradSync = 4,
}

impl RoundKind {
    /// Number of round kinds (the length of the counter arrays).
    pub const COUNT: usize = 5;
    /// Every kind, in discriminant order (for iteration in reports).
    pub const ALL: [RoundKind; Self::COUNT] = [
        RoundKind::SampleRequest,
        RoundKind::SampleResponse,
        RoundKind::FeatureRequest,
        RoundKind::FeatureResponse,
        RoundKind::GradSync,
    ];

    /// The stable discriminant, for indexing `CommStats` arrays.
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    /// Human-readable kind name (report rows).
    pub fn name(self) -> &'static str {
        match self {
            RoundKind::SampleRequest => "sample-request",
            RoundKind::SampleResponse => "sample-response",
            RoundKind::FeatureRequest => "feature-request",
            RoundKind::FeatureResponse => "feature-response",
            RoundKind::GradSync => "grad-sync",
        }
    }
}

/// Independent communication planes multiplexed over one transport.
///
/// A plane is a logical fabric: its own per-rank sequence stream, its own
/// per-peer inboxes (see the endpoint demultiplexer), and its own
/// [`CommStats`] slice — so a round in flight on one plane can never
/// interleave with, desynchronize, or consume frames belonging to the
/// other. The `u8` discriminant is stamped into every frame header
/// (offset 6) and is part of the wire format (FSMP protocol version 2).
///
/// Discipline: at most **one thread drives a given plane** at a time.
/// The pipelined trainer gives the sampler thread the `Sampling` handle
/// and keeps `Gradient` (the default) for itself; serial mode uses both
/// handles from one thread, which is always safe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Plane {
    /// Trainer-side traffic: gradient all-reduce plus the control
    /// collectives (barriers, fences, votes on batch counts / tasks).
    /// The plane of every freshly constructed [`Comm`].
    Gradient = 0,
    /// Sampler-side traffic: sampling miss requests/responses and the
    /// feature exchange — everything the MFG prefetcher issues.
    Sampling = 1,
}

/// Number of communication planes (the demux/seq/stat array length).
pub const PLANE_COUNT: usize = 2;

impl Plane {
    /// Every plane, in discriminant order.
    pub const ALL: [Plane; PLANE_COUNT] = [Plane::Gradient, Plane::Sampling];

    /// The stable discriminant, for indexing per-plane arrays.
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    /// Human-readable plane name (logs/reports).
    pub fn name(self) -> &'static str {
        match self {
            Plane::Gradient => "gradient",
            Plane::Sampling => "sampling",
        }
    }
}

/// Shared, thread-safe round/byte accounting. One instance per training
/// run, shared by all workers (`Arc<Counters>`); snapshot at any sync
/// point to get a [`CommStats`].
#[derive(Debug, Default)]
pub struct Counters {
    rounds: [AtomicU64; RoundKind::COUNT],
    bytes: [AtomicU64; RoundKind::COUNT],
}

impl Counters {
    /// Record one collective round of `kind`. Called once per collective
    /// (by rank 0), not once per worker — Fig 6's round counts are
    /// per-fabric, not per-machine.
    pub fn add_round(&self, kind: RoundKind) {
        self.rounds[kind.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` bytes sent off-worker under `kind`. Called by every
    /// worker for its own payloads, so bytes aggregate over the fabric.
    pub fn add_bytes(&self, kind: RoundKind, n: u64) {
        if n > 0 {
            self.bytes[kind.index()].fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Consistent-enough copy of the counters (exact at join/barrier
    /// points, which is where every reader snapshots).
    pub fn snapshot(&self) -> CommStats {
        let mut s = CommStats::default();
        for k in RoundKind::ALL {
            s.rounds[k.index()] = self.rounds[k.index()].load(Ordering::Relaxed);
            s.bytes[k.index()] = self.bytes[k.index()].load(Ordering::Relaxed);
        }
        s
    }

    /// Overwrite every counter with a previously captured snapshot — the
    /// checkpoint/resume path, called at a fenced point before any new
    /// traffic. With shared in-process counters every rank restores the
    /// identical fenced snapshot (the concurrent stores are idempotent);
    /// with per-process counters each rank restores its own.
    pub fn restore(&self, s: &CommStats) {
        for k in RoundKind::ALL {
            self.rounds[k.index()].store(s.rounds[k.index()], Ordering::Relaxed);
            self.bytes[k.index()].store(s.bytes[k.index()], Ordering::Relaxed);
        }
    }
}

/// Plain-data snapshot of [`Counters`], indexable by `RoundKind as usize`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CommStats {
    pub rounds: [u64; RoundKind::COUNT],
    pub bytes: [u64; RoundKind::COUNT],
}

impl CommStats {
    /// Collective rounds charged to `kind`.
    pub fn rounds_of(&self, kind: RoundKind) -> u64 {
        self.rounds[kind.index()]
    }

    /// Payload bytes charged to `kind` (summed over workers).
    pub fn bytes_of(&self, kind: RoundKind) -> u64 {
        self.bytes[kind.index()]
    }

    /// The paper's headline counter: sampling rounds (request + response).
    pub fn sampling_rounds(&self) -> u64 {
        self.rounds_of(RoundKind::SampleRequest) + self.rounds_of(RoundKind::SampleResponse)
    }

    /// Feature-exchange rounds (request + response).
    pub fn feature_rounds(&self) -> u64 {
        self.rounds_of(RoundKind::FeatureRequest) + self.rounds_of(RoundKind::FeatureResponse)
    }

    /// All rounds, every kind.
    pub fn total_rounds(&self) -> u64 {
        self.rounds.iter().sum()
    }

    /// All payload bytes, every kind.
    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().sum()
    }

    /// `self - before`, element-wise (for per-epoch deltas).
    pub fn diff(&self, before: &CommStats) -> CommStats {
        let mut out = CommStats::default();
        for i in 0..RoundKind::COUNT {
            out.rounds[i] = self.rounds[i].saturating_sub(before.rounds[i]);
            out.bytes[i] = self.bytes[i].saturating_sub(before.bytes[i]);
        }
        out
    }

    /// Aligned per-kind table (used by `fastsample train` and report A3).
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("{:<18} {:>10} {:>16}\n", "round kind", "rounds", "bytes"));
        for k in RoundKind::ALL {
            out.push_str(&format!(
                "{:<18} {:>10} {:>16}\n",
                k.name(),
                self.rounds_of(k),
                self.bytes_of(k)
            ));
        }
        out.push_str(&format!(
            "{:<18} {:>10} {:>16}",
            "total",
            self.total_rounds(),
            self.total_bytes()
        ));
        out
    }
}

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// What can go wrong on the fabric. Every [`Comm`] collective surfaces
/// these instead of panicking or hanging, for both transports.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CommError {
    /// The peer's end of the link closed (thread exited, socket EOF /
    /// reset) while a collective still expected traffic from it.
    PeerLost { rank: usize },
    /// Multi-process rendezvous failed: a listener could not be bound, a
    /// peer never appeared within the deadline, or a connection's FSMP
    /// handshake named the wrong protocol version, world size, or rank
    /// (see [`super::net::TcpMesh::connect`]). Always an error return at
    /// connect time — never a hang.
    Rendezvous { detail: String },
    /// A frame arrived whose round tag, element width, or sequence
    /// number does not match this rank's collective — the SPMD contract
    /// (every rank issues the same collective sequence) was violated.
    SequenceMismatch { src: usize, detail: String },
    /// A frame violated the wire format (bad length, bad handshake,
    /// payload not a whole number of elements).
    Malformed { src: usize, detail: String },
    /// Transport-level I/O failure talking to `peer` that is not a clean
    /// peer loss (e.g. a timeout or a kernel error).
    Io { peer: usize, detail: String },
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::PeerLost { rank } => {
                write!(f, "peer rank {rank} exited mid-collective")
            }
            CommError::Rendezvous { detail } => {
                write!(f, "rendezvous failed: {detail}")
            }
            CommError::SequenceMismatch { src, detail } => {
                write!(f, "collective sequence mismatch with rank {src}: {detail}")
            }
            CommError::Malformed { src, detail } => {
                write!(f, "malformed frame from rank {src}: {detail}")
            }
            CommError::Io { peer, detail } => {
                write!(f, "transport I/O error talking to rank {peer}: {detail}")
            }
        }
    }
}

impl std::error::Error for CommError {}

/// Map an I/O error on the link to `peer` into a [`CommError`]: clean
/// closes become [`CommError::PeerLost`], everything else [`CommError::Io`].
pub(crate) fn io_to_comm(peer: usize, e: std::io::Error) -> CommError {
    use std::io::ErrorKind::*;
    match e.kind() {
        UnexpectedEof | BrokenPipe | ConnectionReset | ConnectionAborted | NotConnected => {
            CommError::PeerLost { rank: peer }
        }
        _ => CommError::Io { peer, detail: e.to_string() },
    }
}

// ---------------------------------------------------------------------------
// Frames and the wire encoding
// ---------------------------------------------------------------------------

/// One transport message: the unit both mesh implementations move.
///
/// On the TCP wire a frame is length-prefixed, little-endian:
///
/// ```text
/// offset  size  field
///      0     4  payload length in bytes (u32 LE)
///      4     1  kind     — RoundKind index, or a control tag (200+)
///      5     1  elem     — element width in bytes (1, 4, or 8)
///      6     1  plane    — communication plane (Plane discriminant)
///      7     2  src      — sender rank (u16 LE)
///      9     4  seq      — sender's collective sequence number on
///                          `plane` (u32 LE — each plane counts its own)
///     13     n  payload  — n bytes, a whole number of `elem`-wide cells
/// ```
///
/// `kind`/`elem`/`seq` exist to catch lockstep bugs: a receiver knows
/// which collective it is in, so any mismatch is a diagnosable
/// [`CommError::SequenceMismatch`] instead of a silently mis-typed round.
/// `plane` routes the frame into the right per-plane inbox at the
/// receiving endpoint; the codec itself round-trips any plane byte, and
/// an out-of-range plane is rejected as [`CommError::Malformed`] at the
/// demultiplexer (not here), so the framing layer stays policy-free.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    pub kind: u8,
    pub elem: u8,
    pub plane: u8,
    pub src: u16,
    pub seq: u32,
    pub payload: Vec<u8>,
}

/// Frame header bytes on the wire (length prefix included).
pub const FRAME_HEADER: usize = 13;

/// Upper bound on a single frame's payload (sanity guard against a
/// corrupt length prefix allocating gigabytes).
pub const MAX_FRAME_PAYLOAD: usize = 1 << 30;

/// The fixed per-frame metadata without the payload — what
/// [`Transport::send_typed`] carries alongside a still-unencoded typed
/// payload so a transport can defer serialization to its writer threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    /// `RoundKind` index or control tag (see [`Frame::kind`] semantics).
    pub kind: u8,
    /// Element width in bytes of the typed payload.
    pub elem: u8,
    /// Communication plane ([`Plane`] discriminant).
    pub plane: u8,
    /// Sender rank.
    pub src: u16,
    /// Sender's collective sequence number on this plane.
    pub seq: u32,
}

impl FrameHeader {
    /// Append the 13-byte wire header for a `payload_len`-byte payload —
    /// the single source of truth for the header layout (see [`Frame`]).
    pub fn encode_to(&self, payload_len: usize, out: &mut Vec<u8>) {
        out.extend_from_slice(&(payload_len as u32).to_le_bytes());
        out.push(self.kind);
        out.push(self.elem);
        out.push(self.plane);
        out.extend_from_slice(&self.src.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
    }
}

impl Frame {
    /// This frame's metadata as a [`FrameHeader`].
    pub fn header(&self) -> FrameHeader {
        FrameHeader {
            kind: self.kind,
            elem: self.elem,
            plane: self.plane,
            src: self.src,
            seq: self.seq,
        }
    }

    /// Append the wire form (header + payload) to `out`.
    pub fn encode_to(&self, out: &mut Vec<u8>) {
        out.reserve(FRAME_HEADER + self.payload.len());
        self.header().encode_to(self.payload.len(), out);
        out.extend_from_slice(&self.payload);
    }

    /// Read one frame from `r` (blocking until the full frame arrived).
    /// I/O errors pass through for the caller to attribute to a peer;
    /// an over-long length prefix is reported as `InvalidData`.
    pub fn decode_from(r: &mut impl std::io::Read) -> std::io::Result<Frame> {
        let mut header = [0u8; FRAME_HEADER];
        r.read_exact(&mut header)?;
        let len = u32::from_le_bytes([header[0], header[1], header[2], header[3]]) as usize;
        if len > MAX_FRAME_PAYLOAD {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("frame payload length {len} exceeds {MAX_FRAME_PAYLOAD}"),
            ));
        }
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload)?;
        Ok(Frame {
            kind: header[4],
            elem: header[5],
            plane: header[6],
            src: u16::from_le_bytes([header[7], header[8]]),
            seq: u32::from_le_bytes([header[9], header[10], header[11], header[12]]),
            payload,
        })
    }
}

/// Element types that can cross the wire: fixed-width, little-endian,
/// bit-exact round trips (f32 moves by bit pattern, so NaNs and negative
/// zeros survive — the loss-curve equivalence tests depend on exactness).
pub trait Wire: Copy + Send + 'static {
    /// Encoded width in bytes (every element of a payload is this wide).
    const SIZE: usize;
    /// Append this value's little-endian encoding to `out`.
    fn put_le(self, out: &mut Vec<u8>);
    /// Decode one value from the first [`Wire::SIZE`] bytes of `b`.
    fn get_le(b: &[u8]) -> Self;
}

impl Wire for u8 {
    const SIZE: usize = 1;
    #[inline]
    fn put_le(self, out: &mut Vec<u8>) {
        out.push(self);
    }
    #[inline]
    fn get_le(b: &[u8]) -> Self {
        b[0]
    }
}

impl Wire for u32 {
    const SIZE: usize = 4;
    #[inline]
    fn put_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    #[inline]
    fn get_le(b: &[u8]) -> Self {
        u32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl Wire for u64 {
    const SIZE: usize = 8;
    #[inline]
    fn put_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    #[inline]
    fn get_le(b: &[u8]) -> Self {
        u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
    }
}

impl Wire for f32 {
    const SIZE: usize = 4;
    #[inline]
    fn put_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_bits().to_le_bytes());
    }
    #[inline]
    fn get_le(b: &[u8]) -> Self {
        f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

/// Serialize a typed payload for the wire.
pub fn encode_payload<T: Wire>(data: &[T]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * T::SIZE);
    for &x in data {
        x.put_le(&mut out);
    }
    out
}

/// A type-erased typed payload whose wire encoding can be produced
/// *later* — on a transport's per-link writer thread — instead of on the
/// collective thread. Implemented for `Vec<T: Wire>`; the encoding is
/// byte-identical to [`encode_payload`], so deferring it changes nothing
/// on the wire or in the byte counters (`byte_len` is known without
/// encoding: `len * T::SIZE`).
pub trait WirePayload: Send {
    /// Exact encoded length in bytes.
    fn byte_len(&self) -> usize;
    /// Append the little-endian wire encoding to `out` (must produce
    /// exactly [`WirePayload::byte_len`] bytes, identical to
    /// [`encode_payload`]).
    fn append_to(&self, out: &mut Vec<u8>);
}

impl<T: Wire> WirePayload for Vec<T> {
    fn byte_len(&self) -> usize {
        self.len() * T::SIZE
    }

    fn append_to(&self, out: &mut Vec<u8>) {
        out.reserve(self.len() * T::SIZE);
        for &x in self {
            x.put_le(out);
        }
    }
}

/// Deserialize a wire payload; `Err` carries a human-readable reason
/// (payload not a whole number of elements).
pub fn decode_payload<T: Wire>(bytes: &[u8]) -> Result<Vec<T>, String> {
    if bytes.len() % T::SIZE != 0 {
        return Err(format!(
            "payload of {} bytes is not a whole number of {}-byte elements",
            bytes.len(),
            T::SIZE
        ));
    }
    Ok(bytes.chunks_exact(T::SIZE).map(T::get_le).collect())
}

// ---------------------------------------------------------------------------
// The transport seam
// ---------------------------------------------------------------------------

/// A fabric endpoint for one rank: point-to-point FIFO frame delivery to
/// and from every peer. [`Comm`] is written entirely against this trait;
/// implementations decide whether frames cross threads
/// ([`ChannelMesh`]) or sockets ([`super::net::TcpMesh`]).
///
/// Contract:
/// * `send`/`recv` are FIFO per (src, dst) pair;
/// * `send` must not block on the destination's consumption (queue or
///   deliver immediately) — the collective loop relies on reaching its
///   receive phase no matter how large a round's payloads are;
/// * [`Transport::flush`] is called at every round boundary (after a
///   rank's last send of the round, before its first receive): after it
///   returns `Ok`, every frame sent so far is guaranteed to reach its
///   peer without further transport calls, and any already-failed link
///   must be reported here at the latest;
/// * a peer that goes away surfaces as [`CommError::PeerLost`] from the
///   next `send`, `flush`, or `recv` touching it — never a hang;
/// * methods take `&self` and the endpoint is `Sync`: two plane handles
///   (sampler + trainer threads) send concurrently and `shutdown` can be
///   issued while another thread is blocked in `recv` (it must unblock
///   that receive promptly — the cross-plane cancellation path). The
///   per-source receive serialization is the *caller's* job (the
///   endpoint demultiplexer admits one reader per source at a time).
pub trait Transport: Send + Sync {
    /// This endpoint's rank.
    fn rank(&self) -> usize;
    /// Number of ranks on the fabric.
    fn world(&self) -> usize;
    /// Queue `frame` for `dst` (`dst != rank`).
    fn send(&self, dst: usize, frame: Frame) -> Result<(), CommError>;
    /// Queue a *typed* payload for `dst`, letting the transport defer the
    /// wire encoding. The default encodes immediately and forwards to
    /// [`Transport::send`] — semantically and byte-identically the same;
    /// [`super::net::TcpMesh`] overrides it to encode on the link's
    /// writer thread, overlapping serialization with the wire (and with
    /// the collective thread's progress toward its receive phase) on
    /// large rounds.
    fn send_typed(
        &self,
        dst: usize,
        header: FrameHeader,
        data: Box<dyn WirePayload>,
    ) -> Result<(), CommError> {
        let mut payload = Vec::with_capacity(data.byte_len());
        data.append_to(&mut payload);
        self.send(
            dst,
            Frame {
                kind: header.kind,
                elem: header.elem,
                plane: header.plane,
                src: header.src,
                seq: header.seq,
                payload,
            },
        )
    }
    /// Push all buffered frames toward their peers (round boundary).
    fn flush(&self) -> Result<(), CommError>;
    /// Next frame from `src` (`src != rank`), blocking until it arrives
    /// or the link dies. At most one thread calls `recv` for a given
    /// `src` at a time (enforced by the endpoint demultiplexer).
    fn recv(&self, src: usize) -> Result<Frame, CommError>;
    /// Implementation name, for logs/reports (`"inproc"`, `"tcp"`).
    fn name(&self) -> &'static str;
    /// Best-effort teardown (close sockets, drop channels). Idempotent;
    /// errors are swallowed — shutdown runs on paths that are already
    /// failing. Must unblock any peer (and, where the medium allows it,
    /// any local thread) blocked on this endpoint's links.
    fn shutdown(&self) {}
}

/// Lock a mutex, recovering the inner data if a holder panicked: fabric
/// state must degrade into typed `CommError`s on the surviving threads,
/// never cascade a second panic.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// The in-process default: a fully-connected mesh of `mpsc` channels
/// between worker threads. Unbounded buffering, so `flush` is a no-op;
/// a dropped peer closes its channel ends, which `send`/`recv` report as
/// [`CommError::PeerLost`].
pub struct ChannelMesh {
    rank: usize,
    world: usize,
    /// `tx[dst]` sends to rank `dst`; the self slot is `None`, and
    /// `shutdown` takes the senders (dropping them is what surfaces
    /// `PeerLost` on every peer still receiving from this rank).
    tx: Vec<Mutex<Option<Sender<Frame>>>>,
    /// `rx[src]` receives from rank `src`; the self slot is `None`. The
    /// per-slot mutex gives `&self` receives; it is uncontended because
    /// the endpoint demultiplexer admits one reader per source.
    rx: Vec<Option<Mutex<Receiver<Frame>>>>,
}

impl ChannelMesh {
    /// Build the fully-connected mesh for `world` ranks.
    pub fn mesh(world: usize) -> Vec<ChannelMesh> {
        assert!(world >= 1, "world size must be >= 1");
        let mut tx_of_rank: Vec<Vec<Option<Sender<Frame>>>> =
            (0..world).map(|_| (0..world).map(|_| None).collect()).collect();
        let mut rx_of_rank: Vec<Vec<Option<Receiver<Frame>>>> =
            (0..world).map(|_| (0..world).map(|_| None).collect()).collect();
        for src in 0..world {
            for dst in 0..world {
                if src == dst {
                    continue;
                }
                let (tx, rx) = channel();
                tx_of_rank[src][dst] = Some(tx);
                rx_of_rank[dst][src] = Some(rx);
            }
        }
        tx_of_rank
            .into_iter()
            .zip(rx_of_rank)
            .enumerate()
            .map(|(rank, (tx, rx))| ChannelMesh {
                rank,
                world,
                tx: tx.into_iter().map(Mutex::new).collect(),
                rx: rx.into_iter().map(|r| r.map(Mutex::new)).collect(),
            })
            .collect()
    }
}

impl Transport for ChannelMesh {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&self, dst: usize, frame: Frame) -> Result<(), CommError> {
        // Clone the sender out of the slot so no lock is held across the
        // channel send (cheap: an Arc bump). A vacant self slot is a
        // routing bug on this rank, reported as Malformed rather than a
        // panic so peers observe PeerLost; a vacant peer slot means the
        // mesh was shut down.
        let tx = lock(&self.tx[dst]).clone();
        match tx {
            Some(tx) => tx.send(frame).map_err(|_| CommError::PeerLost { rank: dst }),
            None if dst == self.rank => Err(CommError::Malformed {
                src: dst,
                detail: "transport-level send to self (self slots bypass the transport)".into(),
            }),
            None => Err(CommError::PeerLost { rank: dst }),
        }
    }

    fn flush(&self) -> Result<(), CommError> {
        Ok(())
    }

    fn recv(&self, src: usize) -> Result<Frame, CommError> {
        match self.rx[src].as_ref() {
            Some(rx) => lock(rx).recv().map_err(|_| CommError::PeerLost { rank: src }),
            None => Err(CommError::Malformed {
                src,
                detail: "transport-level recv from self (self slots bypass the transport)".into(),
            }),
        }
    }

    fn name(&self) -> &'static str {
        "inproc"
    }

    fn shutdown(&self) {
        // Dropping the senders closes every outgoing link: peers blocked
        // in recv on this rank unblock with PeerLost. Local receives stay
        // open — the peers' own shutdowns (the cascade) close those.
        for slot in &self.tx {
            lock(slot).take();
        }
    }
}

// ---------------------------------------------------------------------------
// The shared endpoint: per-source, per-plane demultiplexing + poison
// ---------------------------------------------------------------------------

/// Per-source receive state: one frame queue per plane, a sticky link
/// error, and the "help protocol" flag marking that some thread is
/// currently inside `Transport::recv` for this source.
struct SrcState {
    queues: [VecDeque<Frame>; PLANE_COUNT],
    /// First transport/format error seen on this link; sticky — the link
    /// is FIFO, so nothing after an error can be trusted.
    err: Option<CommError>,
    /// A thread is blocked in `Transport::recv(src)` right now. Other
    /// planes' receivers wait on the condvar instead of double-reading.
    reading: bool,
}

/// One source's demux slot: the state plus the condvar that wakes
/// waiting planes when a frame is routed, an error lands, or the
/// in-flight reader retires.
struct SrcDemux {
    state: Mutex<SrcState>,
    cond: Condvar,
}

/// The per-rank fabric endpoint shared by every [`Comm`] plane handle:
/// the transport, the per-source/per-plane demultiplexer, one sequence
/// stream and one `Counters` per plane, and the endpoint-wide poison
/// slot that implements cross-plane cancellation.
struct Endpoint {
    transport: Box<dyn Transport>,
    demux: Vec<SrcDemux>,
    seqs: [AtomicU32; PLANE_COUNT],
    plane_counters: [Counters; PLANE_COUNT],
    /// First fabric error seen on *any* plane. Once set: the transport is
    /// shut down, all demux waiters are woken, and every subsequent
    /// collective on every handle returns a clone of this root cause.
    poison: Mutex<Option<CommError>>,
}

impl Endpoint {
    fn new(transport: Box<dyn Transport>) -> Endpoint {
        let world = transport.world();
        Endpoint {
            transport,
            demux: (0..world)
                .map(|_| SrcDemux {
                    state: Mutex::new(SrcState {
                        queues: std::array::from_fn(|_| VecDeque::new()),
                        err: None,
                        reading: false,
                    }),
                    cond: Condvar::new(),
                })
                .collect(),
            seqs: std::array::from_fn(|_| AtomicU32::new(0)),
            plane_counters: std::array::from_fn(|_| Counters::default()),
            poison: Mutex::new(None),
        }
    }

    /// The root-cause error if this endpoint is poisoned.
    fn poisoned(&self) -> Option<CommError> {
        lock(&self.poison).clone()
    }

    /// Poison the endpoint (first error wins): record the root cause,
    /// shut the transport down so peers — and, on sockets, local blocked
    /// reads — unblock, and wake every demux waiter so blocked receives
    /// on *other* planes return promptly instead of hanging.
    fn poison_with(&self, e: &CommError) {
        let first = {
            let mut slot = lock(&self.poison);
            if slot.is_none() {
                *slot = Some(e.clone());
                true
            } else {
                false
            }
        };
        if first {
            self.transport.shutdown();
            for d in &self.demux {
                d.cond.notify_all();
            }
        }
    }

    /// Next sequence number on `plane` (each plane counts its own
    /// lockstep position — that independence is what lets two planes
    /// have rounds in flight concurrently without drift errors).
    fn next_seq(&self, plane: Plane) -> u32 {
        self.seqs[plane.index()].fetch_add(1, Ordering::Relaxed)
    }

    /// Next frame from `src` belonging to `plane`.
    ///
    /// The help protocol: whichever plane's receiver arrives first with
    /// an empty queue becomes the reader — it blocks in
    /// `Transport::recv(src)`, routes whatever arrives into the stamped
    /// plane's queue, and wakes the other plane's waiter. A frame for the
    /// reader's own plane is returned directly (its queue is necessarily
    /// empty — only the reader enqueues, and it checked before reading).
    /// Errors are sticky per link; endpoint poison takes precedence so a
    /// cancelled plane reports the root cause, not the socket teardown
    /// it observed as a side effect.
    fn recv_plane(&self, plane: Plane, src: usize) -> Result<Frame, CommError> {
        let d = &self.demux[src];
        let mut st = lock(&d.state);
        loop {
            if let Some(e) = self.poisoned() {
                return Err(e);
            }
            if let Some(f) = st.queues[plane.index()].pop_front() {
                return Ok(f);
            }
            if let Some(e) = &st.err {
                return Err(e.clone());
            }
            if st.reading {
                st = d.cond.wait(st).unwrap_or_else(|poisoned| poisoned.into_inner());
                continue;
            }
            st.reading = true;
            drop(st);
            let got = self.transport.recv(src);
            st = lock(&d.state);
            st.reading = false;
            match got {
                Ok(f) => {
                    let p = f.plane as usize;
                    if p >= PLANE_COUNT {
                        st.err = Some(CommError::Malformed {
                            src,
                            detail: format!("frame stamped unknown plane {}", f.plane),
                        });
                    } else if p == plane.index() {
                        d.cond.notify_all();
                        return Ok(f);
                    } else {
                        st.queues[p].push_back(f);
                    }
                }
                Err(e) => {
                    st.err = Some(e);
                }
            }
            d.cond.notify_all();
        }
    }
}

impl Drop for Endpoint {
    fn drop(&mut self) {
        self.transport.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Comm: typed collectives over any transport
// ---------------------------------------------------------------------------

/// Tags for control-plane collectives that move no accountable data.
const TAG_BARRIER: u8 = 200;
const TAG_MIN_U64: u8 = 201;

/// One worker's handle to the fabric: rank/world identity, the pluggable
/// transport (behind the shared plane endpoint), the network cost model,
/// and the shared counters.
///
/// All collectives are *uniform per plane*: every rank in the world must
/// issue the same sequence of collectives on a given plane (the usual
/// SPMD contract, now per plane — the interleaving *across* planes is
/// free to differ per rank, which is exactly what lets a sampler thread
/// run ahead of the trainer). A violation surfaces as
/// [`CommError::SequenceMismatch`]; a peer dying mid-collective as
/// [`CommError::PeerLost`] — in both cases an error return, not a hang
/// or a panic.
///
/// A freshly constructed `Comm` is the [`Plane::Gradient`] handle;
/// [`Comm::plane`] mints a handle for another plane over the same
/// endpoint. Any collective error **poisons the shared endpoint**: both
/// planes' blocked receives unblock and every later collective on any
/// handle returns the root cause (see [`Comm::cancel`]).
pub struct Comm {
    rank: usize,
    world: usize,
    /// Shared accounting; public so trainers can snapshot per-epoch deltas.
    pub counters: Arc<Counters>,
    net: NetworkModel,
    /// The rank's fabric endpoint, shared by every plane handle.
    endpoint: Arc<Endpoint>,
    /// Which plane this handle's collectives run on.
    plane: Plane,
}

impl Comm {
    /// Wrap an already-connected transport endpoint. The returned handle
    /// is on [`Plane::Gradient`].
    pub fn from_transport(
        transport: Box<dyn Transport>,
        net: NetworkModel,
        counters: Arc<Counters>,
    ) -> Comm {
        let rank = transport.rank();
        let world = transport.world();
        Comm {
            rank,
            world,
            counters,
            net,
            endpoint: Arc::new(Endpoint::new(transport)),
            plane: Plane::Gradient,
        }
    }

    /// A handle scoped to `plane`, over this rank's same endpoint (same
    /// transport, network model, and shared counters). The handle has
    /// its own lockstep position on `plane`'s sequence stream; at most
    /// one thread should drive a given plane at a time. Typical use: the
    /// pipelined trainer hands `comm.plane(Plane::Sampling)` to the
    /// sampler thread and keeps the base (gradient) handle.
    pub fn plane(&self, plane: Plane) -> Comm {
        Comm {
            rank: self.rank,
            world: self.world,
            counters: Arc::clone(&self.counters),
            net: self.net.clone(),
            endpoint: Arc::clone(&self.endpoint),
            plane,
        }
    }

    /// The plane this handle's collectives run on.
    #[inline]
    pub fn plane_of(&self) -> Plane {
        self.plane
    }

    /// This rank's accounting for one plane: bytes are this rank's own
    /// outgoing payloads on that plane; rounds live on rank 0 (as in the
    /// fabric-global [`Counters`]). The global counters are always the
    /// element-wise sum over planes — planes split the accounting, they
    /// never double-charge it.
    pub fn plane_stats(&self, plane: Plane) -> CommStats {
        self.endpoint.plane_counters[plane.index()].snapshot()
    }

    /// Cancel the endpoint: poison every plane with `reason`, shut the
    /// transport down (peers observe `PeerLost`; local blocked socket
    /// reads unblock), and wake all demux waiters. Every later collective
    /// on any plane handle of this rank returns `reason`. This is the
    /// plane shutdown signal the pipelined trainer fires when one side
    /// fails and the other may be blocked in a receive. Idempotent —
    /// the first poison (from whatever source) wins.
    pub fn cancel(&self, reason: &CommError) {
        self.endpoint.poison_with(reason);
    }

    /// Build the in-process channel mesh for `world` ranks (the default
    /// transport — see [`super::net::TransportConfig`] for the sockets
    /// alternative).
    pub(crate) fn mesh(world: usize, net: NetworkModel, counters: Arc<Counters>) -> Vec<Comm> {
        ChannelMesh::mesh(world)
            .into_iter()
            .map(|t| Comm::from_transport(Box::new(t), net.clone(), Arc::clone(&counters)))
            .collect()
    }

    /// This worker's rank.
    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks on the fabric.
    #[inline]
    pub fn world(&self) -> usize {
        self.world
    }

    /// The network cost model charged per round.
    pub fn net(&self) -> &NetworkModel {
        &self.net
    }

    /// The underlying transport's name (`"inproc"`, `"tcp"`).
    pub fn transport_name(&self) -> &'static str {
        self.endpoint.transport.name()
    }

    /// One typed all-to-all round: `outboxes[dst]` goes to rank `dst`,
    /// the return value's `[src]` slot is what rank `src` sent here (the
    /// self slot passes through untouched and untaxed).
    ///
    /// Accounting: the round is counted **once** per collective (rank 0
    /// increments), bytes are charged per worker for off-rank payloads
    /// only — measured from the framed wire payloads, identically on
    /// both transports — and the network model injects
    /// `latency + bytes/bandwidth` of wall time on each worker.
    pub fn exchange<T: Wire>(
        &mut self,
        kind: RoundKind,
        outboxes: Vec<Vec<T>>,
    ) -> Result<Vec<Vec<T>>, CommError> {
        self.exchange_impl(kind.index() as u8, Some(kind), outboxes)
    }

    /// Rendezvous: returns once every rank has entered the barrier.
    /// Control-plane only — not charged to any `RoundKind`.
    pub fn barrier(&mut self) -> Result<(), CommError> {
        self.broadcast_impl::<u8>(TAG_BARRIER, None, &[])?;
        Ok(())
    }

    /// Global minimum (used to agree on batches/epoch). Control-plane —
    /// uncharged, like the barrier.
    pub fn all_reduce_min_u64(&mut self, v: u64) -> Result<u64, CommError> {
        let inboxes = self.broadcast_impl(TAG_MIN_U64, None, &[v])?;
        let mut m = v;
        for inbox in inboxes.iter().flatten() {
            m = m.min(inbox[0]);
        }
        Ok(m)
    }

    /// Barrier-fenced snapshot of the shared counters: every rank gets
    /// the **same** [`CommStats`], taken after all ranks' prior traffic
    /// is recorded (first barrier) and before any rank can charge new
    /// bytes (second barrier). This is the only race-free way to slice
    /// the fabric-global counters into per-epoch deltas — a bare
    /// `barrier(); snapshot()` lets a fast rank charge the next epoch's
    /// first bytes before a slow rank has marked the boundary.
    /// Collective, control-plane only (uncharged).
    pub fn fenced_snapshot(&mut self) -> Result<CommStats, CommError> {
        self.barrier()?;
        let s = self.counters.snapshot();
        self.barrier()?;
        Ok(s)
    }

    /// Round-skip vote: true iff `v == 0` on **every** rank. One
    /// uncharged control-plane min-reduce of the zero indicator — the
    /// protocol `dist::sampling` uses to skip a SampleRequest/Response
    /// pair when no rank has frontier misses (so sampling rounds are
    /// measured per level, not assumed per scheme).
    pub fn all_zero_u64(&mut self, v: u64) -> Result<bool, CommError> {
        Ok(self.all_reduce_min_u64(u64::from(v == 0))? == 1)
    }

    /// Mean all-reduce over `data`, element-wise across ranks, in place.
    ///
    /// Every rank accumulates contributions in rank order 0..W, so all
    /// ranks compute **bit-identical** results — the loss-curve
    /// equivalence tests depend on this. The transport is a direct
    /// exchange (each rank broadcasts its buffer) rather than a ring:
    /// same math, simpler lockstep; the byte accounting reflects the
    /// broadcast honestly (`(W-1) * len * 4` per worker).
    pub fn all_reduce_mean_f32(
        &mut self,
        kind: RoundKind,
        data: &mut [f32],
    ) -> Result<(), CommError> {
        let mine = data.to_vec();
        let inboxes = self.broadcast_impl(kind.index() as u8, Some(kind), &mine)?;
        data.fill(0.0);
        for src in 0..self.world {
            let part: &[f32] = match &inboxes[src] {
                None => &mine,
                Some(v) => v,
            };
            if part.len() != data.len() {
                let e = CommError::SequenceMismatch {
                    src,
                    detail: format!(
                        "all-reduce length mismatch: {} vs {} elements",
                        part.len(),
                        data.len()
                    ),
                };
                self.endpoint.poison_with(&e);
                return Err(e);
            }
            for (acc, x) in data.iter_mut().zip(part) {
                *acc += *x;
            }
        }
        let inv = 1.0 / self.world as f32;
        for x in data.iter_mut() {
            *x *= inv;
        }
        Ok(())
    }

    /// Poison the endpoint on any collective error, so the *other* plane
    /// (possibly blocked in a receive on another thread) fails promptly
    /// with the same root cause instead of hanging or diverging. If the
    /// endpoint was already poisoned, the stored root cause is returned
    /// instead of whatever teardown artifact this plane just observed.
    fn seal<T>(&self, r: Result<T, CommError>) -> Result<T, CommError> {
        match r {
            Ok(v) => Ok(v),
            Err(e) => {
                self.endpoint.poison_with(&e);
                Err(self.endpoint.poisoned().unwrap_or(e))
            }
        }
    }

    /// Fail fast if the endpoint is already poisoned (by either plane).
    fn check_open(&self) -> Result<(), CommError> {
        match self.endpoint.poisoned() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// All-to-all with per-destination payloads: hand each typed outbox
    /// to the transport (which may encode it on a writer thread —
    /// **overlapped encoding**), then collect one frame per peer (self
    /// slot passes through unserialized).
    fn exchange_impl<T: Wire>(
        &mut self,
        tag: u8,
        track: Option<RoundKind>,
        outboxes: Vec<Vec<T>>,
    ) -> Result<Vec<Vec<T>>, CommError> {
        self.check_open()?;
        let r = self.exchange_inner(tag, track, outboxes);
        self.seal(r)
    }

    fn exchange_inner<T: Wire>(
        &mut self,
        tag: u8,
        track: Option<RoundKind>,
        outboxes: Vec<Vec<T>>,
    ) -> Result<Vec<Vec<T>>, CommError> {
        assert_eq!(outboxes.len(), self.world, "need one outbox per rank");
        let seq = self.endpoint.next_seq(self.plane);
        let my_src = self.rank as u16;
        let elem = T::SIZE as u8;
        let plane = self.plane as u8;
        let mut self_data: Option<Vec<T>> = None;
        let mut sent_bytes = 0u64;
        for (dst, data) in outboxes.into_iter().enumerate() {
            if dst == self.rank {
                self_data = Some(data);
                continue;
            }
            // Byte accounting without encoding: the wire length of a
            // typed payload is exactly len * elem size, so the counters
            // stay identical whether the transport encodes now (channel
            // mesh) or on its writer threads (TcpMesh).
            sent_bytes += (data.len() * T::SIZE) as u64;
            let header = FrameHeader { kind: tag, elem, plane, src: my_src, seq };
            self.endpoint.transport.send_typed(dst, header, Box::new(data))?;
        }
        self.finish_sends(track, sent_bytes)?;
        let mut inboxes = self.recv_round::<T>(tag, seq)?;
        inboxes[self.rank] = self_data;
        let mut out = Vec::with_capacity(inboxes.len());
        for (src, slot) in inboxes.into_iter().enumerate() {
            match slot {
                Some(data) => out.push(data),
                None => {
                    return Err(CommError::Malformed {
                        src,
                        detail: "exchange inbox missing after receive round".into(),
                    })
                }
            }
        }
        Ok(out)
    }

    /// Broadcast-shaped round: every peer gets the **same** payload, so
    /// it is encoded once and only the byte buffer is cloned per peer —
    /// the grad-sync hot path skips W−1 redundant element-wise encodes.
    /// Returns one inbox per peer; the self slot is `None`.
    fn broadcast_impl<T: Wire>(
        &mut self,
        tag: u8,
        track: Option<RoundKind>,
        data: &[T],
    ) -> Result<Vec<Option<Vec<T>>>, CommError> {
        self.check_open()?;
        let r = self.broadcast_inner(tag, track, data);
        self.seal(r)
    }

    fn broadcast_inner<T: Wire>(
        &mut self,
        tag: u8,
        track: Option<RoundKind>,
        data: &[T],
    ) -> Result<Vec<Option<Vec<T>>>, CommError> {
        let seq = self.endpoint.next_seq(self.plane);
        let my_src = self.rank as u16;
        let elem = T::SIZE as u8;
        let plane = self.plane as u8;
        let payload = encode_payload(data);
        let mut sent_bytes = 0u64;
        for dst in 0..self.world {
            if dst == self.rank {
                continue;
            }
            sent_bytes += payload.len() as u64;
            let frame =
                Frame { kind: tag, elem, plane, src: my_src, seq, payload: payload.clone() };
            self.endpoint.transport.send(dst, frame)?;
        }
        self.finish_sends(track, sent_bytes)?;
        self.recv_round::<T>(tag, seq)
    }

    /// Shared send epilogue: round-boundary flush, accounting (global
    /// counters + this handle's plane slice), modeled fabric delay.
    fn finish_sends(
        &mut self,
        track: Option<RoundKind>,
        sent_bytes: u64,
    ) -> Result<(), CommError> {
        self.endpoint.transport.flush()?;
        if let Some(kind) = track {
            let plane_counters = &self.endpoint.plane_counters[self.plane.index()];
            self.counters.add_bytes(kind, sent_bytes);
            plane_counters.add_bytes(kind, sent_bytes);
            if self.rank == 0 {
                self.counters.add_round(kind);
                plane_counters.add_round(kind);
            }
        }
        self.net.delay(sent_bytes);
        Ok(())
    }

    /// Shared receive half: one frame per peer — drawn from **this
    /// plane's** inbox by the endpoint demultiplexer — validated against
    /// this rank's (tag, elem, seq) lockstep position on the plane. Self
    /// slot stays `None`.
    fn recv_round<T: Wire>(
        &mut self,
        tag: u8,
        seq: u32,
    ) -> Result<Vec<Option<Vec<T>>>, CommError> {
        let mut inboxes: Vec<Option<Vec<T>>> = (0..self.world).map(|_| None).collect();
        for (src, inbox) in inboxes.iter_mut().enumerate() {
            if src == self.rank {
                continue;
            }
            let frame = self.endpoint.recv_plane(self.plane, src)?;
            if frame.src as usize != src {
                return Err(CommError::Malformed {
                    src,
                    detail: format!("frame stamped src {} arrived on link {src}", frame.src),
                });
            }
            if frame.kind != tag || frame.elem as usize != T::SIZE || frame.seq != seq {
                return Err(CommError::SequenceMismatch {
                    src,
                    detail: format!(
                        "expected (kind {tag}, elem {}, seq {seq}), \
                         got (kind {}, elem {}, seq {}) on the {} plane — \
                         workers issued different collective sequences",
                        T::SIZE,
                        frame.kind,
                        frame.elem,
                        frame.seq,
                        self.plane.name()
                    ),
                });
            }
            let data = decode_payload::<T>(&frame.payload)
                .map_err(|detail| CommError::Malformed { src, detail })?;
            *inbox = Some(data);
        }
        Ok(inboxes)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::super::worker::{run_workers, run_workers_with};
    use super::*;

    #[test]
    fn exchange_routes_payloads_by_rank() {
        let results = run_workers(3, NetworkModel::free(), |rank, comm| {
            // Rank r sends the single value r*10 + dst to each dst.
            let outboxes: Vec<Vec<u32>> =
                (0..3).map(|dst| vec![(rank * 10 + dst) as u32]).collect();
            comm.exchange(RoundKind::SampleRequest, outboxes).unwrap()
        });
        for (rank, inboxes) in results.iter().enumerate() {
            for (src, inbox) in inboxes.iter().enumerate() {
                assert_eq!(inbox[..], [(src * 10 + rank) as u32], "src {src} -> dst {rank}");
            }
        }
    }

    #[test]
    fn rounds_count_once_per_collective_bytes_per_worker() {
        let counters = Arc::new(Counters::default());
        run_workers_with(4, NetworkModel::free(), Arc::clone(&counters), |rank, comm| {
            // Two rounds; each worker ships 8 bytes (2 u32) to each peer.
            for _ in 0..2 {
                let outboxes: Vec<Vec<u32>> = (0..4).map(|_| vec![rank as u32, 7]).collect();
                comm.exchange(RoundKind::FeatureRequest, outboxes).unwrap();
            }
        });
        let s = counters.snapshot();
        assert_eq!(s.rounds_of(RoundKind::FeatureRequest), 2);
        // 4 workers x 3 peers x 8 bytes x 2 rounds; self slot untaxed.
        assert_eq!(s.bytes_of(RoundKind::FeatureRequest), 4 * 3 * 8 * 2);
        assert_eq!(s.total_rounds(), 2);
    }

    #[test]
    fn all_reduce_mean_is_identical_on_every_rank() {
        let results = run_workers(4, NetworkModel::free(), |rank, comm| {
            let mut data = vec![rank as f32, 1.0, -2.0 * rank as f32];
            comm.all_reduce_mean_f32(RoundKind::GradSync, &mut data).unwrap();
            data
        });
        for r in &results {
            assert_eq!(r, &results[0], "ranks disagree bitwise");
        }
        assert_eq!(results[0][..], [1.5, 1.0, -3.0]);
    }

    #[test]
    fn min_and_barrier_are_uncharged() {
        let counters = Arc::new(Counters::default());
        let mins = run_workers_with(3, NetworkModel::free(), Arc::clone(&counters), |rank, comm| {
            comm.barrier().unwrap();
            comm.all_reduce_min_u64(10 + rank as u64).unwrap()
        });
        assert!(mins.iter().all(|&m| m == 10));
        let s = counters.snapshot();
        assert_eq!(s.total_rounds(), 0);
        assert_eq!(s.total_bytes(), 0);
    }

    #[test]
    fn fenced_snapshot_is_identical_on_every_rank() {
        let counters = Arc::new(Counters::default());
        let snaps = run_workers_with(3, NetworkModel::free(), Arc::clone(&counters), |rank, comm| {
            // Rank-skewed traffic before the fence; the fence must still
            // hand every rank one consistent cut of the counters.
            let outboxes: Vec<Vec<u8>> = (0..3).map(|_| vec![7u8; rank + 1]).collect();
            comm.exchange(RoundKind::GradSync, outboxes).unwrap();
            comm.fenced_snapshot().unwrap()
        });
        assert_eq!(snaps[0], snaps[1]);
        assert_eq!(snaps[1], snaps[2]);
        assert_eq!(snaps[0].rounds_of(RoundKind::GradSync), 1);
        // (1+2+3) payload bytes x 2 off-rank peers per rank.
        assert_eq!(snaps[0].bytes_of(RoundKind::GradSync), (1 + 2 + 3) * 2);
    }

    #[test]
    fn all_zero_vote_is_unanimous_and_uncharged() {
        let counters = Arc::new(Counters::default());
        let votes = run_workers_with(3, NetworkModel::free(), Arc::clone(&counters), |rank, comm| {
            // Everyone zero → true; then rank 1 non-zero → false everywhere.
            let a = comm.all_zero_u64(0).unwrap();
            let b = comm.all_zero_u64(if rank == 1 { 5 } else { 0 }).unwrap();
            (a, b)
        });
        assert!(votes.iter().all(|&(a, b)| a && !b));
        let s = counters.snapshot();
        assert_eq!(s.total_rounds(), 0);
        assert_eq!(s.total_bytes(), 0);
    }

    #[test]
    fn single_rank_world_degenerates_cleanly() {
        let out = run_workers(1, NetworkModel::free(), |_rank, comm| {
            comm.barrier().unwrap();
            let mut data = vec![3.0f32, -1.0];
            comm.all_reduce_mean_f32(RoundKind::GradSync, &mut data).unwrap();
            let m = comm.all_reduce_min_u64(9).unwrap();
            let echoed = comm.exchange(RoundKind::SampleRequest, vec![vec![42u32]]).unwrap();
            (data, m, echoed)
        });
        let (data, m, echoed) = &out[0];
        assert_eq!(data[..], [3.0, -1.0]);
        assert_eq!(*m, 9);
        assert_eq!(echoed.len(), 1);
        assert_eq!(echoed[0][..], [42u32]);
    }

    #[test]
    fn stats_diff_and_report_are_consistent() {
        let a = CommStats { rounds: [0, 0, 0, 0, 5], bytes: [0, 0, 0, 0, 1000] };
        let b = CommStats { rounds: [0, 0, 0, 0, 8], bytes: [0, 0, 0, 0, 1600] };
        let d = b.diff(&a);
        assert_eq!(d.rounds_of(RoundKind::GradSync), 3);
        assert_eq!(d.bytes_of(RoundKind::GradSync), 600);
        assert_eq!(d.total_bytes(), 600);
        let rep = b.report();
        assert!(rep.contains("grad-sync"));
        assert!(rep.contains("total"));
    }

    #[test]
    fn payload_codec_round_trips_every_wire_type() {
        let u8s: Vec<u8> = vec![0, 1, 255, 17];
        assert_eq!(decode_payload::<u8>(&encode_payload(&u8s)).unwrap(), u8s);
        let u32s: Vec<u32> = vec![0, 1, u32::MAX, 0xDEAD_BEEF];
        assert_eq!(decode_payload::<u32>(&encode_payload(&u32s)).unwrap(), u32s);
        let u64s: Vec<u64> = vec![0, u64::MAX, 1 << 40];
        assert_eq!(decode_payload::<u64>(&encode_payload(&u64s)).unwrap(), u64s);
        // f32 must round-trip by bit pattern, including NaN and -0.0.
        let f32s: Vec<f32> = vec![0.0, -0.0, 1.5, f32::NAN, f32::INFINITY, -3.25e-12];
        let back = decode_payload::<f32>(&encode_payload(&f32s)).unwrap();
        assert_eq!(f32s.len(), back.len());
        for (a, b) in f32s.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Ragged byte counts are malformed, not mis-decoded.
        assert!(decode_payload::<u32>(&[1, 2, 3]).is_err());
        assert_eq!(decode_payload::<u32>(&[]).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn frame_codec_round_trips_through_a_byte_stream() {
        let frames = [
            Frame {
                kind: 0,
                elem: 4,
                plane: 1,
                src: 3,
                seq: 9,
                payload: encode_payload(&[1u32, 2, 3]),
            },
            Frame { kind: TAG_BARRIER, elem: 1, plane: 0, src: 0, seq: 0, payload: Vec::new() },
            // The codec round-trips any plane byte — range policy lives
            // at the demultiplexer, not in the framing.
            Frame {
                kind: 4,
                elem: 4,
                plane: 255,
                src: 65535,
                seq: u32::MAX,
                payload: vec![0u8; 70_000],
            },
        ];
        let mut wire = Vec::new();
        for f in &frames {
            f.encode_to(&mut wire);
        }
        let mut cursor = std::io::Cursor::new(wire);
        for f in &frames {
            assert_eq!(&Frame::decode_from(&mut cursor).unwrap(), f);
        }
        // Stream fully consumed — framing is self-delimiting.
        assert!(Frame::decode_from(&mut cursor).is_err());
    }

    #[test]
    fn dropped_peer_surfaces_as_peer_lost_not_a_hang() {
        // Rank 1 exits before the second collective; the survivors must
        // get a clean CommError::PeerLost from their next exchange — no
        // hang. Rank 0 receives from rank 1 before anyone else can
        // abort, so it names the dead peer exactly; rank 2 may instead
        // observe the cascade (rank 0 aborting) and name rank 0.
        let results = run_workers(3, NetworkModel::free(), |rank, comm| {
            let boxes = |n: u32| (0..3).map(|_| vec![n]).collect::<Vec<Vec<u32>>>();
            let first = comm.exchange(RoundKind::GradSync, boxes(7));
            assert!(first.is_ok(), "healthy round failed: {first:?}");
            if rank == 1 {
                return None; // dies mid-run; its Comm drops here
            }
            Some(comm.exchange(RoundKind::GradSync, boxes(8)))
        });
        assert!(results[1].is_none());
        assert_eq!(results[0], Some(Err(CommError::PeerLost { rank: 1 })));
        match &results[2] {
            Some(Err(CommError::PeerLost { rank: lost })) => {
                assert!(*lost == 0 || *lost == 1, "rank 2 named rank {lost}")
            }
            other => panic!("rank 2: expected PeerLost, got {other:?}"),
        }
    }

    #[test]
    fn comm_error_display_names_the_peer() {
        let e = CommError::PeerLost { rank: 3 };
        assert!(e.to_string().contains("rank 3"));
        assert!(e.to_string().contains("exited mid-collective"));
        let m = CommError::SequenceMismatch { src: 2, detail: "kind 1 vs 2".into() };
        assert!(m.to_string().contains("rank 2"));
        let r = CommError::Rendezvous { detail: "world 3 != 2".into() };
        assert!(r.to_string().contains("rendezvous"));
        assert!(r.to_string().contains("world 3 != 2"));
    }

    #[test]
    fn deferred_encoding_is_byte_identical_to_eager() {
        // The overlapped-encoding invariant: header + WirePayload must
        // produce exactly the bytes Frame::encode_to produces.
        let data: Vec<u32> = vec![7, 0, u32::MAX, 0x0102_0304];
        let frame = Frame {
            kind: 2,
            elem: 4,
            plane: 1,
            src: 9,
            seq: 1234,
            payload: encode_payload(&data),
        };
        let mut eager = Vec::new();
        frame.encode_to(&mut eager);
        let payload: Box<dyn WirePayload> = Box::new(data);
        let mut deferred = Vec::new();
        frame.header().encode_to(payload.byte_len(), &mut deferred);
        payload.append_to(&mut deferred);
        assert_eq!(eager, deferred);
        assert_eq!(payload.byte_len(), frame.payload.len());
        // f32 payloads defer by bit pattern too (NaN survives).
        let f: Vec<f32> = vec![f32::NAN, -0.0, 3.5];
        let mut a = Vec::new();
        WirePayload::append_to(&f, &mut a);
        assert_eq!(a, encode_payload(&f));
    }

    #[test]
    fn send_typed_default_matches_send_on_the_channel_mesh() {
        // ChannelMesh uses the default (eager) send_typed; the receiver
        // must see a frame indistinguishable from a plain send.
        let mut meshes = ChannelMesh::mesh(2);
        let b = meshes.pop().unwrap();
        let a = meshes.pop().unwrap();
        let data: Vec<u64> = vec![1, 2, 1 << 40];
        let header = FrameHeader { kind: 0, elem: 8, plane: 1, src: 0, seq: 3 };
        a.send_typed(1, header, Box::new(data.clone())).unwrap();
        a.flush().unwrap();
        let got = b.recv(0).unwrap();
        assert_eq!(got.header(), header);
        assert_eq!(decode_payload::<u64>(&got.payload).unwrap(), data);
    }

    fn test_frame(plane: u8, seq: u32, byte: u8) -> Frame {
        Frame { kind: 0, elem: 1, plane, src: 0, seq, payload: vec![byte] }
    }

    #[test]
    fn endpoint_demux_routes_frames_by_plane() {
        // Rank 0 sends sampling traffic first, then gradient traffic.
        // Rank 1's endpoint must hand the gradient receive its own
        // plane's frame even though the sampling frame arrived first —
        // per-plane FIFO, cross-plane queuing.
        let mut meshes = ChannelMesh::mesh(2);
        let ep = Endpoint::new(Box::new(meshes.pop().unwrap()));
        let a = meshes.pop().unwrap();
        a.send(1, test_frame(Plane::Sampling as u8, 0, 11)).unwrap();
        a.send(1, test_frame(Plane::Sampling as u8, 1, 12)).unwrap();
        a.send(1, test_frame(Plane::Gradient as u8, 0, 21)).unwrap();
        let g = ep.recv_plane(Plane::Gradient, 0).unwrap();
        assert_eq!((g.plane, g.payload[0]), (0, 21));
        let s0 = ep.recv_plane(Plane::Sampling, 0).unwrap();
        let s1 = ep.recv_plane(Plane::Sampling, 0).unwrap();
        assert_eq!((s0.seq, s0.payload[0]), (0, 11));
        assert_eq!((s1.seq, s1.payload[0]), (1, 12));
    }

    #[test]
    fn endpoint_rejects_unknown_plane_as_malformed() {
        let mut meshes = ChannelMesh::mesh(2);
        let ep = Endpoint::new(Box::new(meshes.pop().unwrap()));
        let a = meshes.pop().unwrap();
        a.send(1, test_frame(7, 0, 1)).unwrap();
        match ep.recv_plane(Plane::Gradient, 0) {
            Err(CommError::Malformed { src: 0, detail }) => {
                assert!(detail.contains("unknown plane 7"), "{detail}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
        // The link error is sticky: the other plane sees it too.
        assert!(matches!(
            ep.recv_plane(Plane::Sampling, 0),
            Err(CommError::Malformed { src: 0, .. })
        ));
    }

    #[test]
    fn plane_handles_run_concurrent_rounds_without_interleaving() {
        // Per rank: a sampler thread drives SampleRequest exchanges on
        // the Sampling plane while the main thread drives GradSync
        // all-reduces on the Gradient plane — concurrently, different
        // per-rank interleavings. Planes must keep both streams correct,
        // and the per-plane stats must split the accounting cleanly.
        const ROUNDS: usize = 5;
        let results = run_workers(3, NetworkModel::free(), |rank, comm| {
            let mut sampler = comm.plane(Plane::Sampling);
            let world = comm.world();
            std::thread::scope(|scope| {
                let sampled = scope.spawn(move || {
                    let mut got = Vec::new();
                    for round in 0..ROUNDS {
                        let outboxes: Vec<Vec<u32>> = (0..world)
                            .map(|dst| vec![(rank * 100 + dst * 10 + round) as u32])
                            .collect();
                        let inboxes =
                            sampler.exchange(RoundKind::SampleRequest, outboxes).unwrap();
                        got.push(inboxes);
                    }
                    (sampler.plane_stats(Plane::Sampling), got)
                });
                let mut grads = Vec::new();
                for round in 0..ROUNDS {
                    let mut data = vec![rank as f32 + round as f32, 1.0];
                    comm.all_reduce_mean_f32(RoundKind::GradSync, &mut data).unwrap();
                    grads.push(data);
                }
                let (sampling_stats, sampled) = sampled.join().unwrap();
                (sampling_stats, comm.plane_stats(Plane::Gradient), sampled, grads)
            })
        });
        for (rank, (sampling, gradient, sampled, grads)) in results.iter().enumerate() {
            // Sampling-plane payloads routed exactly as in serial mode.
            for (round, inboxes) in sampled.iter().enumerate() {
                for (src, inbox) in inboxes.iter().enumerate() {
                    assert_eq!(inbox[..], [(src * 100 + rank * 10 + round) as u32]);
                }
            }
            // Gradient results identical across ranks (and correct:
            // mean over ranks of rank+round is 1.0+round at 3 ranks).
            assert_eq!(grads, &results[0].3);
            for (round, g) in grads.iter().enumerate() {
                assert_eq!(g[..], [1.0 + round as f32, 1.0]);
            }
            // Per-plane stats never cross: sampling bytes live on the
            // sampling slice, grad-sync bytes on the gradient slice.
            assert_eq!(sampling.bytes_of(RoundKind::GradSync), 0);
            assert_eq!(gradient.bytes_of(RoundKind::SampleRequest), 0);
            assert_eq!(sampling.bytes_of(RoundKind::SampleRequest), (ROUNDS * 2 * 4) as u64);
            assert_eq!(gradient.bytes_of(RoundKind::GradSync), (ROUNDS * 2 * 8) as u64);
        }
    }

    #[test]
    fn cancel_on_one_plane_unblocks_and_poisons_the_other() {
        // Rank 0's trainer cancels the endpoint while its sampler thread
        // is blocked in a Sampling-plane receive (rank 1 never sends on
        // that plane). The sampler must unblock promptly and report the
        // cancellation root cause; rank 1 observes PeerLost.
        let reason = CommError::Io { peer: 0, detail: "trainer failed; plane cancelled".into() };
        let results = run_workers(2, NetworkModel::free(), |rank, comm| {
            if rank == 1 {
                // Blocked on the gradient barrier that rank 0 never
                // joins; unblocked by rank 0's cancel → shutdown.
                return comm.barrier();
            }
            let mut sampler = comm.plane(Plane::Sampling);
            std::thread::scope(|scope| {
                let blocked = scope.spawn(move || {
                    sampler.exchange(RoundKind::SampleRequest, vec![vec![1u32], vec![2]])
                });
                // Let the sampler reach its blocking receive, then fire
                // the plane shutdown signal from the trainer side.
                std::thread::sleep(std::time::Duration::from_millis(30));
                comm.cancel(&reason);
                blocked.join().unwrap().map(|_| ())
            })
        });
        assert_eq!(results[0], Err(reason.clone()));
        assert_eq!(results[1], Err(CommError::PeerLost { rank: 0 }));
        // And the poisoned endpoint keeps failing fast with the root
        // cause — no half-open planes.
        let again = run_workers(1, NetworkModel::free(), |_, comm| {
            comm.cancel(&CommError::PeerLost { rank: 9 });
            comm.barrier()
        });
        assert_eq!(again[0], Err(CommError::PeerLost { rank: 9 }));
    }
}
