//! Worker harness: spawn W rendezvous-connected workers and collect their
//! per-rank results.
//!
//! One worker thread stands in for one machine of the paper's testbed.
//! The closure receives `(rank, &mut Comm)` and runs SPMD-style: every
//! rank must issue the same sequence of collectives (the [`Comm`] layer
//! panics loudly on divergence). Results come back in rank order.
//!
//! Threads are scoped, so worker closures may borrow stack data (shards,
//! datasets, configs) from the caller — the pattern every integration
//! test and the trainer use.

use std::sync::Arc;

use super::comm::{Comm, Counters};
use super::net::NetworkModel;

/// Run `world` workers with a fresh (throwaway) [`Counters`] instance.
pub fn run_workers<R, F>(world: usize, net: NetworkModel, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, &mut Comm) -> R + Sync,
{
    run_workers_with(world, net, Arc::new(Counters::default()), f)
}

/// Run `world` workers sharing `counters`, returning per-rank results in
/// rank order. Panics if any worker panics (after all threads finish or
/// cascade-fail through their channels).
pub fn run_workers_with<R, F>(
    world: usize,
    net: NetworkModel,
    counters: Arc<Counters>,
    f: F,
) -> Vec<R>
where
    R: Send,
    F: Fn(usize, &mut Comm) -> R + Sync,
{
    let comms = Comm::mesh(world, net, counters);
    std::thread::scope(|s| {
        let handles: Vec<_> = comms
            .into_iter()
            .enumerate()
            .map(|(rank, mut comm)| {
                let f = &f;
                s.spawn(move || f(rank, &mut comm))
            })
            .collect();
        let mut out = Vec::with_capacity(world);
        let mut panics: Vec<Box<dyn std::any::Any + Send>> = Vec::new();
        for h in handles {
            match h.join() {
                Ok(r) => out.push(r),
                Err(e) => panics.push(e),
            }
        }
        if !panics.is_empty() {
            // A worker dying mid-collective makes its peers panic with
            // "exited mid-collective"; re-raise the *root cause* (the
            // first payload that is not such a cascade) so test failures
            // show the original assertion, not the fallout.
            let pick = panics
                .iter()
                .position(|e| match e.downcast_ref::<String>() {
                    Some(msg) => !msg.contains("exited mid-collective"),
                    None => true,
                })
                .unwrap_or(0);
            std::panic::resume_unwind(panics.swap_remove(pick));
        }
        out
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::RoundKind;

    #[test]
    fn results_come_back_in_rank_order() {
        let out = run_workers(5, NetworkModel::free(), |rank, comm| {
            comm.barrier();
            rank * rank
        });
        assert_eq!(out, [0, 1, 4, 9, 16]);
    }

    #[test]
    fn workers_can_borrow_caller_stack_data() {
        let shared: Vec<u64> = (0..4).map(|i| 100 + i).collect();
        let shared_ref = &shared;
        let out = run_workers(4, NetworkModel::free(), move |rank, comm| {
            comm.all_reduce_min_u64(shared_ref[rank])
        });
        assert!(out.iter().all(|&m| m == 100));
    }

    #[test]
    fn counters_are_shared_across_calls() {
        let counters = Arc::new(Counters::default());
        for _ in 0..3 {
            run_workers_with(2, NetworkModel::free(), Arc::clone(&counters), |_, comm| {
                comm.exchange(RoundKind::GradSync, vec![vec![1u8], vec![1u8]]);
            });
        }
        assert_eq!(counters.snapshot().rounds_of(RoundKind::GradSync), 3);
    }
}
