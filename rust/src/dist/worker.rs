//! Worker harness: spawn W rendezvous-connected workers and collect their
//! per-rank results.
//!
//! One worker thread stands in for one machine of the paper's testbed.
//! The closure receives `(rank, &mut Comm)` and runs SPMD-style: every
//! rank must issue the same sequence of collectives (the [`Comm`] layer
//! surfaces divergence as a [`CommError`] instead of deadlocking).
//! Results come back in rank order.
//!
//! The fabric under the workers is pluggable: [`run_workers`] /
//! [`run_workers_with`] use the in-process channel mesh,
//! [`run_workers_on`] connects whatever a [`TransportConfig`] names
//! (channel mesh or per-peer TCP sockets on loopback), and
//! [`run_workers_over`] accepts prebuilt [`Transport`] endpoints — the
//! hook the fault-injection tests use to wrap transports.
//!
//! [`run_worker_process`] is the multi-process twin: it runs **one**
//! rank in *this* OS process, rendezvousing with the other ranks'
//! processes over real TCP ([`super::net::TcpMesh::connect`]) — the
//! harness behind the `fastsample worker` subcommand and the
//! re-exec'd children of `rust/tests/process_rendezvous.rs`.
//!
//! Threads are scoped, so worker closures may borrow stack data (shards,
//! datasets, configs) from the caller — the pattern every integration
//! test and the trainer use.
//!
//! [`CommError`]: super::comm::CommError

use std::sync::Arc;
use std::time::Duration;

use super::comm::{Comm, CommError, Counters, Transport};
use super::net::{NetworkModel, RendezvousConfig, TcpMesh, TransportConfig};

/// Run `world` workers with a fresh (throwaway) [`Counters`] instance.
pub fn run_workers<R, F>(world: usize, net: NetworkModel, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, &mut Comm) -> R + Sync,
{
    run_workers_with(world, net, Arc::new(Counters::default()), f)
}

/// Run `world` workers over the in-process channel mesh, sharing
/// `counters`, returning per-rank results in rank order. Panics if any
/// worker panics (after all threads finish or cascade-fail through their
/// links).
pub fn run_workers_with<R, F>(
    world: usize,
    net: NetworkModel,
    counters: Arc<Counters>,
    f: F,
) -> Vec<R>
where
    R: Send,
    F: Fn(usize, &mut Comm) -> R + Sync,
{
    let comms = Comm::mesh(world, net, counters);
    run_comms(comms, f)
}

/// Run `world` workers over the transport a [`TransportConfig`] names —
/// the channel mesh or a TCP loopback mesh. `Err` only for transport
/// *setup* failures (e.g. a port that cannot be bound); worker results
/// come back in rank order like [`run_workers_with`].
pub fn run_workers_on<R, F>(
    config: &TransportConfig,
    world: usize,
    net: NetworkModel,
    counters: Arc<Counters>,
    f: F,
) -> std::io::Result<Vec<R>>
where
    R: Send,
    F: Fn(usize, &mut Comm) -> R + Sync,
{
    let transports = config.build_mesh(world)?;
    Ok(run_workers_over(transports, net, counters, f))
}

/// Run one worker per prebuilt transport endpoint (rank order must match
/// endpoint order). This is the seam for test wrappers: build a mesh,
/// wrap each endpoint (delays, short writes, byte counting), hand the
/// wrapped endpoints here.
pub fn run_workers_over<R, F>(
    transports: Vec<Box<dyn Transport>>,
    net: NetworkModel,
    counters: Arc<Counters>,
    f: F,
) -> Vec<R>
where
    R: Send,
    F: Fn(usize, &mut Comm) -> R + Sync,
{
    let comms: Vec<Comm> = transports
        .into_iter()
        .map(|t| Comm::from_transport(t, net.clone(), Arc::clone(&counters)))
        .collect();
    run_comms(comms, f)
}

/// Run **one rank of a multi-process world** in this OS process: bind,
/// dial, and accept the rank's share of the TCP mesh
/// ([`TcpMesh::connect`] under `rdv`'s deadline/backoff), optionally
/// bound every blocking receive by `recv_timeout` (`None` — the default
/// posture — waits indefinitely, because a slow healthy peer is
/// indistinguishable from a hung one), then run `f` SPMD-style and
/// return its result.
///
/// Unlike the thread harnesses above, `counters` are **per-process**
/// here: rank 0's snapshot carries the fabric-global *round* counts (it
/// is the rank that increments them) while each rank's *byte* counts
/// cover only its own outgoing payloads — sum them across ranks to
/// reproduce the single-process totals (OPERATIONS.md shows how).
///
/// Rendezvous failures surface as `Err`; fabric failures inside `f`
/// (e.g. a killed peer) surface through `f`'s own result type, exactly
/// as with the thread harnesses.
pub fn run_worker_process<R>(
    rank: usize,
    peers: &[String],
    rdv: &RendezvousConfig,
    recv_timeout: Option<Duration>,
    net: NetworkModel,
    counters: Arc<Counters>,
    f: impl FnOnce(usize, &mut Comm) -> R,
) -> Result<R, CommError> {
    let mesh = TcpMesh::connect(rank, peers, rdv)?;
    if let Some(t) = recv_timeout {
        // A failure here is *this* process misconfiguring its own sockets
        // at setup time — a local fault, not a peer's. Reporting it as
        // `Io { peer }` would send the operator chasing a healthy rank.
        mesh.set_recv_timeout(Some(t)).map_err(|e| CommError::Rendezvous {
            detail: format!("local transport setup on rank {rank}: set recv timeout: {e}"),
        })?;
    }
    let mut comm = Comm::from_transport(Box::new(mesh), net, counters);
    Ok(f(rank, &mut comm))
}

fn run_comms<R, F>(comms: Vec<Comm>, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, &mut Comm) -> R + Sync,
{
    let world = comms.len();
    std::thread::scope(|s| {
        let handles: Vec<_> = comms
            .into_iter()
            .enumerate()
            .map(|(rank, mut comm)| {
                let f = &f;
                s.spawn(move || f(rank, &mut comm))
            })
            .collect();
        let mut out = Vec::with_capacity(world);
        let mut panics: Vec<Box<dyn std::any::Any + Send>> = Vec::new();
        for h in handles {
            match h.join() {
                Ok(r) => out.push(r),
                Err(e) => panics.push(e),
            }
        }
        if !panics.is_empty() {
            // A worker dying mid-collective makes its peers fail with
            // CommError::PeerLost ("exited mid-collective"), which test
            // code usually unwraps into a panic mentioning "PeerLost";
            // re-raise the *root cause* (the first payload that is not
            // such a cascade) so test failures show the original
            // assertion, not the fallout.
            let is_cascade = |msg: &str| {
                msg.contains("exited mid-collective") || msg.contains("PeerLost")
            };
            let pick = panics
                .iter()
                .position(|e| match e.downcast_ref::<String>() {
                    Some(msg) => !is_cascade(msg),
                    None => match e.downcast_ref::<&str>() {
                        Some(msg) => !is_cascade(msg),
                        None => true,
                    },
                })
                .unwrap_or(0);
            std::panic::resume_unwind(panics.swap_remove(pick));
        }
        out
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::dist::RoundKind;

    #[test]
    fn results_come_back_in_rank_order() {
        let out = run_workers(5, NetworkModel::free(), |rank, comm| {
            comm.barrier().unwrap();
            rank * rank
        });
        assert_eq!(out, [0, 1, 4, 9, 16]);
    }

    #[test]
    fn workers_can_borrow_caller_stack_data() {
        let shared: Vec<u64> = (0..4).map(|i| 100 + i).collect();
        let shared_ref = &shared;
        let out = run_workers(4, NetworkModel::free(), move |rank, comm| {
            comm.all_reduce_min_u64(shared_ref[rank]).unwrap()
        });
        assert!(out.iter().all(|&m| m == 100));
    }

    #[test]
    fn counters_are_shared_across_calls() {
        let counters = Arc::new(Counters::default());
        for _ in 0..3 {
            run_workers_with(2, NetworkModel::free(), Arc::clone(&counters), |_, comm| {
                comm.exchange(RoundKind::GradSync, vec![vec![1u8], vec![1u8]]).unwrap();
            });
        }
        assert_eq!(counters.snapshot().rounds_of(RoundKind::GradSync), 3);
    }

    #[test]
    fn tcp_and_inproc_fabrics_run_the_same_collectives() {
        for config in [TransportConfig::Inproc, TransportConfig::Tcp { base_port: 0 }] {
            let counters = Arc::new(Counters::default());
            let out = run_workers_on(
                &config,
                3,
                NetworkModel::free(),
                Arc::clone(&counters),
                |rank, comm| {
                    comm.barrier().unwrap();
                    let outboxes: Vec<Vec<u32>> =
                        (0..3).map(|dst| vec![(rank * 10 + dst) as u32]).collect();
                    let inboxes = comm.exchange(RoundKind::SampleRequest, outboxes).unwrap();
                    let m = comm.all_reduce_min_u64(rank as u64).unwrap();
                    (inboxes, m)
                },
            )
            .unwrap();
            for (rank, (inboxes, m)) in out.iter().enumerate() {
                assert_eq!(*m, 0, "{config}");
                for (src, inbox) in inboxes.iter().enumerate() {
                    assert_eq!(inbox[..], [(src * 10 + rank) as u32], "{config}");
                }
            }
            let s = counters.snapshot();
            assert_eq!(s.rounds_of(RoundKind::SampleRequest), 1, "{config}");
            // 3 workers x 2 peers x 4 bytes, identically on both fabrics.
            assert_eq!(s.bytes_of(RoundKind::SampleRequest), 3 * 2 * 4, "{config}");
        }
    }
}
