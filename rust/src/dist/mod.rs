//! Distributed runtime: typed communication rounds, the worker harness,
//! and the distributed sampling / feature-exchange collectives — the API
//! layer the trainer, experiments, benches, and equivalence tests are
//! built against (see DESIGN.md §dist for the module map and the
//! round-count table).
//!
//! Structure:
//!
//! * [`comm`] — [`RoundKind`]-tagged collectives over a pluggable
//!   [`Transport`] (length-prefixed byte [`Frame`]s), charged to shared
//!   [`Counters`] (rounds per collective, bytes per worker — measured
//!   from the framed wire payloads), split across independent
//!   communication [`Plane`]s (own seq streams, inboxes, and stats —
//!   [`Comm::plane`] hands out the per-plane handles the pipelined
//!   trainer runs on). Fabric failures surface as [`CommError`] (a
//!   lost peer is named, never hung on; [`Comm::cancel`] propagates a
//!   failure across planes).
//! * [`net`] — [`TcpMesh`]: the socket transport (per-peer loopback/real
//!   TCP, versioned rank handshake, flush at round boundaries, writer
//!   threads that encode typed outboxes off the collective thread);
//!   [`TcpMesh::connect`] + [`RendezvousConfig`]: per-rank multi-process
//!   rendezvous (retry/backoff/deadline, handshake validation →
//!   [`CommError::Rendezvous`]); [`TransportConfig`]: transport
//!   selection (`inproc` | `tcp:<base_port>`); [`NetworkModel`]:
//!   latency + bandwidth cost per round, so Fig 5/6 epoch times are
//!   simulatable on one machine.
//! * [`worker`] — [`run_workers`]/[`run_workers_with`]/[`run_workers_on`]
//!   /[`run_workers_over`]: spawn W rendezvous-connected worker threads
//!   over any transport, collect per-rank results;
//!   [`run_worker_process`]: run one rank in this OS process over the
//!   real-TCP mesh (the `fastsample worker` harness).
//! * [`sampling`] — [`sample_mfgs_distributed`]: one unified sampler
//!   over the replication-budget spectrum — frontier nodes with
//!   materialized adjacency (local rows + budgeted halo + cached rows)
//!   sample locally, only the misses cost a request/response pair, and a
//!   control-plane vote ([`Comm::all_zero_u64`]) skips the pair when no
//!   rank misses. Rounds per minibatch are measured in `0..=2(L−1)`
//!   (budget 0 ⇒ the paper's vanilla counts, full replication ⇒ hybrid's
//!   zero), bit-equal to the single-machine pipeline at every budget.
//!   Responses move on one of two [`SamplingWire`] encodings — the
//!   default columnar *bulk* layout (counts block + ids blob + cache-row
//!   section, served and decoded by parallel two-phase kernels) or the
//!   run-length *scalar* stream ([`sample_mfgs_distributed_wire`] is the
//!   wire-explicit entry point; both are bit-identical in content).
//! * [`cache`] — [`SlabCache`]: the generic byte-budgeted slab
//!   (fixed- and variable-width rows) under [`CachePolicy::StaticDegree`]
//!   or [`CachePolicy::Clock`], shared by the feature cache and the
//!   remote-adjacency overlay in [`crate::partition::TopologyView`].
//! * [`feature_store`] — [`fetch_features`]/[`prefill_cache`]: the two
//!   fixed feature rounds over the partitioned store.
//! * [`feature_cache`] — [`FeatureCache`], the fixed-width typed wrapper
//!   over the slab, plus the [`hottest_remote_nodes`] warm-up heuristic.
//! * [`serve`] — the serve-mode client plane: the `FSRQ`/`FSRP`
//!   request/reply wire, the admission-controlled rank-0 [`Frontend`]
//!   with request coalescing, exact per-request [`LatencyHistogram`]s,
//!   and the [`query_once`]/[`request_shutdown`] client helpers (the
//!   collective side lives in `crate::train::serve`).

// Panic-freedom is part of the fabric contract (spmd-lint rule R2): a rank
// that panics mid-collective hangs every peer waiting on its frames. The
// same invariant is enforced twice — structurally here (test modules carry
// an explicit allow), and lexically by `cargo run -p spmd-lint`.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod cache;
pub mod comm;
pub mod feature_cache;
pub mod feature_store;
pub mod net;
pub mod sampling;
pub mod serve;
pub mod worker;

pub use cache::{CachePolicy, SlabCache};
pub use comm::{
    ChannelMesh, Comm, CommError, CommStats, Counters, Frame, FrameHeader, Plane, RoundKind,
    Transport, Wire, WirePayload, PLANE_COUNT,
};
pub use feature_cache::{hottest_remote_nodes, FeatureCache};
pub use feature_store::{fetch_features, prefill_cache, FetchStats};
pub use net::{NetworkModel, PROTOCOL_VERSION, RendezvousConfig, TcpMesh, TransportConfig};
pub use sampling::{sample_mfgs_distributed, sample_mfgs_distributed_wire, SamplingWire};
pub use serve::{
    query_once, request_shutdown, AddrSlot, Frontend, LatencyHistogram, ServeEmbeddings,
    ServeError, ServeErrorKind, ServeOp, ServeReply, ServeRequest,
};
pub use worker::{
    run_worker_process, run_workers, run_workers_on, run_workers_over, run_workers_with,
};
