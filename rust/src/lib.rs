//! # FastSample
//!
//! Reproduction of *FastSample: Accelerating Distributed Graph Neural
//! Network Training for Billion-Scale Graphs* (Mostafa et al., 2023) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's systems contribution: the fused
//!   CSC-direct sampling kernel ([`sampling::fused`]), the DGL-style
//!   two-step baseline it is benchmarked against ([`sampling::baseline`]),
//!   METIS-like edge-cut partitioning with budgeted halo replication —
//!   the vanilla→hybrid spectrum — ([`partition`]), and the
//!   distributed training runtime (workers, collectives, feature store) in
//!   [`dist`] / [`train`] / [`coordinator`].
//! * **L2/L1 (build-time python)** — a 3-layer GraphSAGE with a Pallas
//!   aggregation kernel, AOT-lowered to HLO text (`make artifacts`) and
//!   executed from the hot path through [`runtime`] (PJRT CPU client).
//!
//! Python never runs on the training path: the rust binary is
//! self-contained once `artifacts/` is built.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! (Table 1, Fig 4, Fig 5, Fig 6 of the paper), and `EXPERIMENTS.md` for
//! measured results.

pub mod config;
pub mod coordinator;
pub mod dist;
pub mod graph;
pub mod partition;
pub mod runtime;
pub mod sampling;
pub mod train;
pub mod util;

/// Crate-wide result type (anyhow for rich error context).
pub type Result<T> = anyhow::Result<T>;
