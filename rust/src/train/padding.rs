//! Pad sampled MFGs to the fixed shapes of an AOT model variant.
//!
//! The AOT executables have static shapes (`Variant::caps`); sampled MFGs
//! are smaller and ragged. Padding appends inert rows: `cnt = 0` (the
//! aggregation kernel emits zeros), `idx = 0` (points at a real row but is
//! masked by `cnt`), `label_mask = 0` (excluded from the loss). The L2
//! tests (`python/tests/test_model.py::test_padding_nodes_are_inert`)
//! and `rust/tests/train_e2e.rs` pin the inertness.

use anyhow::{ensure, Result};

use crate::graph::NodeId;
use crate::runtime::{HostTensor, PaddedBatch, Variant};
use crate::sampling::Mfg;

/// Build a [`PaddedBatch`] from sampled MFGs (bottom layer first) and the
/// fetched input features (rows for `mfgs[0].src_nodes`, row-major).
pub fn pad_batch(
    variant: &Variant,
    mfgs: &[Mfg],
    input_feats: &[f32],
    labels_of: impl Fn(NodeId) -> i32,
) -> Result<PaddedBatch> {
    let l_count = variant.layers();
    ensure!(mfgs.len() == l_count, "expected {} MFG levels, got {}", l_count, mfgs.len());
    let f = variant.feat_dim;
    let n0 = mfgs[0].num_src();
    ensure!(
        input_feats.len() == n0 * f,
        "feature buffer holds {} rows, sampled graph has {n0}",
        input_feats.len() / f.max(1)
    );

    // ---- Features: sampled rows, then zero padding to caps[0].
    let cap0 = variant.caps[0];
    ensure!(n0 <= cap0, "level-0 nodes {n0} exceed cap {cap0} — rebuild artifacts with larger caps");
    let mut feats = Vec::with_capacity(cap0 * f);
    feats.extend_from_slice(input_feats);
    feats.resize(cap0 * f, 0.0);

    // ---- Per-layer neighbor tables.
    let mut levels = Vec::with_capacity(l_count);
    for (li, mfg) in mfgs.iter().enumerate() {
        let layer = li + 1;
        let k = variant.fanout_at_layer(layer);
        let cap_dst = variant.caps[layer];
        let cap_src = variant.caps[layer - 1];
        ensure!(
            mfg.n_dst <= cap_dst,
            "layer {layer}: {} dst nodes exceed cap {cap_dst}",
            mfg.n_dst
        );
        ensure!(
            mfg.num_src() <= cap_src,
            "layer {layer}: {} src nodes exceed cap {cap_src}",
            mfg.num_src()
        );
        let mut idx = vec![0i32; cap_dst * k];
        let mut cnt = vec![0i32; cap_dst];
        for i in 0..mfg.n_dst {
            let neigh = mfg.neighbors(i);
            ensure!(neigh.len() <= k, "layer {layer}: degree {} > fanout {k}", neigh.len());
            for (j, &p) in neigh.iter().enumerate() {
                idx[i * k + j] = p as i32;
            }
            cnt[i] = neigh.len() as i32;
        }
        levels.push((
            HostTensor::i32(idx, &[cap_dst, k]),
            HostTensor::i32(cnt, &[cap_dst]),
        ));
    }

    // ---- Seed labels + mask (seeds are the top MFG's dst prefix).
    let top = mfgs.last().unwrap();
    let batch = variant.batch;
    ensure!(top.n_dst <= batch, "seed count {} exceeds batch {batch}", top.n_dst);
    let mut labels = vec![0i32; batch];
    let mut label_mask = vec![0f32; batch];
    for (i, &v) in top.src_nodes[..top.n_dst].iter().enumerate() {
        labels[i] = labels_of(v);
        label_mask[i] = 1.0;
    }

    Ok(PaddedBatch {
        feats: HostTensor::f32(feats, &[cap0, f]),
        levels,
        labels,
        label_mask,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::erdos_renyi;
    use crate::runtime::Manifest;
    use crate::sampling::rng::RngKey;
    use crate::sampling::{sample_mfgs, KernelKind, SamplerWorkspace};

    fn variant() -> Variant {
        // Hand-built variant: B=8, fanouts (3,2) → caps (96, 32, 8).
        let text = r#"{"variants": {"t": {
            "feat_dim": 4, "hidden": 8, "classes": 3, "batch": 8,
            "fanouts": [3, 2], "caps": [96, 32, 8], "dropout": 0.0,
            "params": [{"name": "w", "shape": [4, 8]}],
            "train_hlo": "x", "eval_hlo": "x",
            "train_args": [], "eval_args": []
        }}}"#;
        Manifest::parse(text, std::path::Path::new("."))
            .unwrap()
            .variant("t")
            .unwrap()
            .clone()
    }

    #[test]
    fn shapes_and_masks() {
        let v = variant();
        let g = erdos_renyi(200, 6, RngKey::new(1));
        let seeds: Vec<NodeId> = (0..8).collect();
        let mut ws = SamplerWorkspace::new();
        let mfgs = sample_mfgs(&g, &seeds, &v.fanouts, RngKey::new(2), &mut ws, KernelKind::Fused);
        let n0 = mfgs[0].num_src();
        let feats = vec![1.5f32; n0 * v.feat_dim];
        let batch = pad_batch(&v, &mfgs, &feats, |n| (n % 3) as i32).unwrap();

        assert_eq!(batch.feats.shape(), &[96, 4]);
        assert_eq!(batch.levels.len(), 2);
        assert_eq!(batch.levels[0].0.shape(), &[32, 2]); // layer 1: fanout N_1=2
        assert_eq!(batch.levels[1].0.shape(), &[8, 3]); // layer 2: fanout N_2=3
        assert_eq!(batch.labels.len(), 8);
        assert!(batch.label_mask.iter().all(|&m| m == 1.0)); // full batch
        // Labels follow the seed prefix.
        for (i, &s) in seeds.iter().enumerate() {
            assert_eq!(batch.labels[i], (s % 3) as i32);
        }
        // Feature padding region is zeros.
        let fd = batch.feats.as_f32().unwrap();
        assert!(fd[n0 * 4..].iter().all(|&x| x == 0.0));
        assert!(fd[..n0 * 4].iter().all(|&x| x == 1.5));
        // Padded rows have cnt 0.
        let cnt1 = batch.levels[0].1.as_i32().unwrap();
        assert!(cnt1[mfgs[0].n_dst..].iter().all(|&c| c == 0));
    }

    #[test]
    fn rejects_oversized_inputs() {
        let mut v = variant();
        v.caps = vec![4, 4, 8]; // deliberately too small
        let g = erdos_renyi(200, 6, RngKey::new(1));
        let seeds: Vec<NodeId> = (0..8).collect();
        let mut ws = SamplerWorkspace::new();
        let mfgs = sample_mfgs(&g, &seeds, &v.fanouts, RngKey::new(2), &mut ws, KernelKind::Fused);
        let feats = vec![0f32; mfgs[0].num_src() * v.feat_dim];
        assert!(pad_batch(&v, &mfgs, &feats, |_| 0).is_err());
    }

    #[test]
    fn rejects_wrong_feature_count() {
        let v = variant();
        let g = erdos_renyi(100, 4, RngKey::new(3));
        let seeds: Vec<NodeId> = (0..8).collect();
        let mut ws = SamplerWorkspace::new();
        let mfgs = sample_mfgs(&g, &seeds, &v.fanouts, RngKey::new(4), &mut ws, KernelKind::Fused);
        let feats = vec![0f32; 3]; // wrong
        assert!(pad_batch(&v, &mfgs, &feats, |_| 0).is_err());
    }
}
