//! The MFG prefetcher: the sampler half of pipelined training
//! (`--pipeline on`, the `+pipe` mode suffix).
//!
//! Pipelining splits each rank into two threads. The **sampler thread**
//! runs [`sampler_epochs`]: it owns the rank's Sampling-plane comm
//! handle, the `TopologyView` overlay (remote-adjacency cache), the
//! `SamplerWorkspace`, and the optional feature cache, and produces
//! minibatch *t+1* — distributed sampling plus input-feature fetch —
//! into a depth-1 bounded channel while the trainer thread consumes
//! minibatch *t* (AOT compute + gradient all-reduce on its own plane).
//! The planes have independent sequence streams and per-peer inboxes
//! (see `dist::comm`), so the in-flight sampling round and the
//! in-flight gradient round can never interleave on the wire.
//!
//! **Determinism.** The sampler performs *exactly* the derivations the
//! serial loop performs, in the same order: the per-epoch
//! `MinibatchSchedule` from `key.fold(epoch)`, the per-batch sampling
//! key `key.fold(epoch).fold(b + 1)`, and every cache insert and RNG
//! cursor lives on this one thread. The produced MFG stream, feature
//! buffers, and multi-epoch cache decay are therefore bit-identical to
//! `--pipeline off` (pinned by the pipeline grid in
//! `rust/tests/dist_equivalence.rs`).
//!
//! **Epoch protocol.** The trainer sends this epoch's fanouts over the
//! `go` channel only *after* taking its fenced epoch-start counter
//! snapshot, and the sampler sends [`Produced::EpochEnd`] only after
//! the epoch's last fetch has been charged — so the sampler is
//! quiescent (blocked on `go.recv()`) across both of the trainer's
//! fences, and per-epoch round/byte deltas are pipeline-invariant.
//! When the run checkpoints ([`ProducerPlan::snapshot_cache`]), the
//! `EpochEnd` marker also carries the adjacency-cache resident set as
//! of the fence — the sampler owns the cache, but the trainer writes
//! the checkpoint, and a `+pipe` checkpoint must warm-start a resume
//! exactly like a serial one (the `checkpoint_resume` suite pins the
//! two resident sets bit-equal).
//! Fanouts ride the `go` channel because schedules like `Plateau`
//! depend on the trainer's smoothed loss, which only exists on the
//! trainer thread.
//!
//! **Error paths.** A fabric error inside a collective here has already
//! poisoned the shared endpoint (every plane handle of this rank now
//! fails fast, and blocked receives are woken), so returning it is
//! enough — the trainer side observes the closed item channel, joins
//! this thread, and reports the root cause. A closed channel in either
//! direction means the *trainer* stopped first; that is an orderly
//! `Ok(())` exit, never an error of its own.

use std::sync::mpsc::{Receiver, SyncSender};

use crate::dist::{
    fetch_features, sample_mfgs_distributed_wire, Comm, CommError, FeatureCache, SamplingWire,
};
use crate::graph::NodeId;
use crate::partition::{TopologyView, WorkerShard};
use crate::sampling::rng::RngKey;
use crate::sampling::{KernelKind, Mfg, MinibatchSchedule, SamplerWorkspace};

/// Everything the sampler thread needs to reproduce the serial loop's
/// sampling decisions bit-for-bit.
#[derive(Debug, Clone)]
pub struct ProducerPlan {
    /// The consuming loop's base RNG key (already folded with the entry
    /// point's tag); epoch and batch keys derive from it here exactly
    /// as they do in serial mode.
    pub key: RngKey,
    /// First epoch to produce (0 for a fresh run; the restored cursor
    /// for `--resume`). Epoch keys are positional, so starting here
    /// reproduces exactly the tail of an uninterrupted run.
    pub start_epoch: usize,
    pub epochs: usize,
    /// Batches per epoch — already cross-rank agreed (`all_reduce_min`)
    /// and capped by the trainer before the sampler spawns.
    pub batches: usize,
    /// Seeds per batch.
    pub batch: usize,
    pub kernel: KernelKind,
    pub wire: SamplingWire,
    /// Snapshot the adjacency-cache resident set into every
    /// [`Produced::EpochEnd`] marker. Set when the run checkpoints
    /// (`--checkpoint-dir`): the resident rows are cloned at each epoch
    /// fence so the trainer can persist them. Off otherwise — the clone
    /// is pure overhead when nothing will be written.
    pub snapshot_cache: bool,
}

/// One unit out of the sampler thread's bounded channel.
#[derive(Debug)]
pub enum Produced {
    /// One fully prepared minibatch: sampled MFGs plus the fetched
    /// input-feature rows of `mfgs[0].src_nodes` (row-major,
    /// `feat_dim` wide).
    Batch {
        epoch: usize,
        /// Batch index within `epoch` — the trainer reconstructs its
        /// dropout seed (`epoch * batches + index`) from this.
        index: usize,
        seeds: Vec<NodeId>,
        mfgs: Vec<Mfg>,
        feats: Vec<f32>,
    },
    /// Epoch boundary marker: every batch of `epoch` has been produced
    /// and charged. The trainer drains to this before taking its fenced
    /// end-of-epoch counter snapshot. `cache_rows` is the adjacency
    /// cache's resident set at the fence when
    /// [`ProducerPlan::snapshot_cache`] is set (empty otherwise) — the
    /// trainer folds it into the epoch's checkpoint.
    EpochEnd { epoch: usize, cache_rows: Vec<(NodeId, Vec<NodeId>)> },
}

/// Produce every epoch's minibatches into `items`, gated per epoch on
/// the trainer's `go` signal (which carries that epoch's fanouts).
///
/// Runs on the sampler thread with the rank's Sampling-plane handle —
/// and only that handle: sampler-thread code must never touch another
/// plane (spmd-lint rule R6 enforces this lexically for this module).
/// Collective in the SPMD sense: every rank's sampler issues the same
/// sequence of sampling/feature rounds.
#[allow(clippy::too_many_arguments)]
pub fn sampler_epochs(
    comm: &mut Comm,
    shard: &WorkerShard,
    view: &mut TopologyView,
    ws: &mut SamplerWorkspace,
    mut cache: Option<&mut FeatureCache>,
    plan: &ProducerPlan,
    items: &SyncSender<Produced>,
    go: &Receiver<Vec<usize>>,
) -> Result<(), CommError> {
    for epoch in plan.start_epoch..plan.epochs {
        // Block until the trainer has fenced the epoch start. A closed
        // channel means the trainer stopped (error or early shutdown):
        // exit cleanly — the trainer side owns error reporting.
        let Ok(fanouts) = go.recv() else {
            return Ok(());
        };
        let schedule =
            MinibatchSchedule::new(&shard.train_local, plan.batch, plan.key.fold(epoch as u64));
        for b in 0..plan.batches {
            let seeds = schedule.batch(b).to_vec();
            let batch_key = plan.key.fold(epoch as u64).fold(b as u64 + 1);
            let mfgs = sample_mfgs_distributed_wire(
                comm, shard, view, &seeds, &fanouts, batch_key, ws, plan.kernel, plan.wire,
            )?;
            let mut feats = Vec::new();
            fetch_features(comm, shard, &mfgs[0].src_nodes, cache.as_deref_mut(), &mut feats)?;
            let item = Produced::Batch { epoch, index: b, seeds, mfgs, feats };
            if items.send(item).is_err() {
                return Ok(());
            }
        }
        let cache_rows = if plan.snapshot_cache { view.cached_entries() } else { Vec::new() };
        if items.send(Produced::EpochEnd { epoch, cache_rows }).is_err() {
            return Ok(());
        }
    }
    Ok(())
}
