//! Optimizers applied on the rust side after the gradient all-reduce.
//!
//! The AOT train step returns raw gradients; every worker applies the
//! same update to its own (identical) parameter copy, which keeps
//! parameters consistent without a parameter server — the paper's
//! data-parallel scheme.

use anyhow::{ensure, Result};

use crate::runtime::HostTensor;

/// The full serializable state of an optimizer's update rule — everything
/// beyond the hyperparameters that the next `step` depends on. Capturing
/// and restoring this is what makes a checkpointed run resume
/// bit-identically: SGD's velocity and Adam's `t`/`m`/`v` moments all
/// feed directly into the parameter update.
#[derive(Debug, Clone, PartialEq)]
pub enum OptimizerState {
    /// Momentum buffers, one per parameter tensor (empty until the first
    /// step with nonzero momentum — restoring an empty state is valid).
    Sgd { velocity: Vec<Vec<f32>> },
    /// Step count plus first/second moment estimates per parameter tensor.
    Adam { t: i32, m: Vec<Vec<f32>>, v: Vec<Vec<f32>> },
}

/// A parameter-update rule over flat f32 tensors.
pub trait Optimizer: Send {
    fn step(&mut self, params: &mut [HostTensor], grads: &[HostTensor]) -> Result<()>;
    fn lr(&self) -> f32;
    /// Capture the update rule's full state for checkpointing.
    fn state(&self) -> OptimizerState;
    /// Restore a state captured by [`Optimizer::state`]. The state's kind
    /// must match this optimizer (a checkpoint written under `adam` cannot
    /// feed an `sgd` run); per-tensor lengths are validated lazily at the
    /// next `step` against the actual parameters.
    fn load_state(&mut self, state: OptimizerState) -> Result<()>;
}

/// SGD with optional momentum.
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    velocity: Vec<Vec<f32>>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32) -> Self {
        Self { lr, momentum, velocity: Vec::new() }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [HostTensor], grads: &[HostTensor]) -> Result<()> {
        ensure!(params.len() == grads.len());
        if self.velocity.is_empty() && self.momentum != 0.0 {
            self.velocity = params.iter().map(|p| vec![0.0; p.len()]).collect();
        }
        if !self.velocity.is_empty() {
            ensure!(
                self.velocity.len() == params.len(),
                "sgd velocity holds {} tensors but the model has {} — \
                 a restored state from a different model?",
                self.velocity.len(),
                params.len()
            );
        }
        for (pi, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let (HostTensor::F32 { data: pd, .. }, HostTensor::F32 { data: gd, .. }) = (p, g)
            else {
                anyhow::bail!("optimizer expects f32 tensors")
            };
            ensure!(pd.len() == gd.len(), "param/grad length mismatch at {pi}");
            if self.momentum == 0.0 {
                for (x, dx) in pd.iter_mut().zip(gd) {
                    *x -= self.lr * dx;
                }
            } else {
                let v = &mut self.velocity[pi];
                ensure!(v.len() == pd.len(), "sgd velocity length mismatch at {pi}");
                for ((x, dx), vi) in pd.iter_mut().zip(gd).zip(v.iter_mut()) {
                    *vi = self.momentum * *vi + dx;
                    *x -= self.lr * *vi;
                }
            }
        }
        Ok(())
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn state(&self) -> OptimizerState {
        OptimizerState::Sgd { velocity: self.velocity.clone() }
    }

    fn load_state(&mut self, state: OptimizerState) -> Result<()> {
        match state {
            OptimizerState::Sgd { velocity } => {
                self.velocity = velocity;
                Ok(())
            }
            OptimizerState::Adam { .. } => {
                anyhow::bail!("checkpointed optimizer state is adam, this run uses sgd")
            }
        }
    }
}

/// Adam (Kingma & Ba) — the de-facto default for GraphSAGE on OGB; the
/// paper's lr of 0.006 is used with this by default.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: i32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Self { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: Vec::new(), v: Vec::new() }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [HostTensor], grads: &[HostTensor]) -> Result<()> {
        ensure!(params.len() == grads.len());
        if self.m.is_empty() {
            self.m = params.iter().map(|p| vec![0.0; p.len()]).collect();
            self.v = params.iter().map(|p| vec![0.0; p.len()]).collect();
        }
        ensure!(
            self.m.len() == params.len() && self.v.len() == params.len(),
            "adam moments hold {}/{} tensors but the model has {} — \
             a restored state from a different model?",
            self.m.len(),
            self.v.len(),
            params.len()
        );
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t);
        let bc2 = 1.0 - self.beta2.powi(self.t);
        for (pi, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let (HostTensor::F32 { data: pd, .. }, HostTensor::F32 { data: gd, .. }) = (p, g)
            else {
                anyhow::bail!("optimizer expects f32 tensors")
            };
            ensure!(pd.len() == gd.len(), "param/grad length mismatch at {pi}");
            let (m, v) = (&mut self.m[pi], &mut self.v[pi]);
            ensure!(
                m.len() == pd.len() && v.len() == pd.len(),
                "adam moment length mismatch at {pi}"
            );
            for i in 0..pd.len() {
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * gd[i];
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * gd[i] * gd[i];
                let mh = m[i] / bc1;
                let vh = v[i] / bc2;
                pd[i] -= self.lr * mh / (vh.sqrt() + self.eps);
            }
        }
        Ok(())
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn state(&self) -> OptimizerState {
        OptimizerState::Adam { t: self.t, m: self.m.clone(), v: self.v.clone() }
    }

    fn load_state(&mut self, state: OptimizerState) -> Result<()> {
        match state {
            OptimizerState::Adam { t, m, v } => {
                ensure!(
                    m.len() == v.len(),
                    "adam state has {} first-moment but {} second-moment tensors",
                    m.len(),
                    v.len()
                );
                ensure!(t >= 0, "adam state has negative step count {t}");
                self.t = t;
                self.m = m;
                self.v = v;
                Ok(())
            }
            OptimizerState::Sgd { .. } => {
                anyhow::bail!("checkpointed optimizer state is sgd, this run uses adam")
            }
        }
    }
}

/// Parse `sgd`, `sgd:0.9` (momentum) or `adam` into an optimizer.
pub fn by_name(name: &str, lr: f32) -> Result<Box<dyn Optimizer>> {
    match name.split_once(':') {
        None if name == "adam" => Ok(Box::new(Adam::new(lr))),
        None if name == "sgd" => Ok(Box::new(Sgd::new(lr, 0.0))),
        Some(("sgd", m)) => {
            let m: f32 = m
                .parse()
                .map_err(|e| anyhow::anyhow!("bad sgd momentum {m:?}: {e}"))?;
            // A silent NaN/negative/≥1 momentum diverges (or freezes) the
            // run with no hint at the cause — reject it at parse time.
            ensure!(
                m.is_finite() && (0.0..1.0).contains(&m),
                "sgd momentum must be in [0, 1), got {m}"
            );
            Ok(Box::new(Sgd::new(lr, m)))
        }
        _ => anyhow::bail!("unknown optimizer {name:?} (want adam | sgd | sgd:<momentum>)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(p: &HostTensor) -> HostTensor {
        // grad of 0.5*||x||² is x.
        HostTensor::f32(p.as_f32().unwrap().to_vec(), p.shape())
    }

    fn run(opt: &mut dyn Optimizer, steps: usize) -> f32 {
        let mut params = vec![HostTensor::f32(vec![1.0, -2.0, 3.0], &[3])];
        for _ in 0..steps {
            let g = vec![quad_grad(&params[0])];
            opt.step(&mut params, &g).unwrap();
        }
        params[0].as_f32().unwrap().iter().map(|x| x * x).sum::<f32>()
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let start = 1.0f32 + 4.0 + 9.0;
        assert!(run(&mut Sgd::new(0.1, 0.0), 50) < 1e-3 * start);
    }

    #[test]
    fn momentum_and_adam_converge_too() {
        let start = 14.0f32;
        assert!(run(&mut Sgd::new(0.05, 0.9), 80) < 1e-2 * start);
        assert!(run(&mut Adam::new(0.2), 100) < 1e-2 * start);
    }

    #[test]
    fn shape_mismatch_is_error() {
        let mut opt = Sgd::new(0.1, 0.0);
        let mut params = vec![HostTensor::f32(vec![1.0], &[1])];
        let grads = vec![HostTensor::f32(vec![1.0, 2.0], &[2])];
        assert!(opt.step(&mut params, &grads).is_err());
    }

    #[test]
    fn by_name_parses() {
        assert!(by_name("adam", 0.006).is_ok());
        assert!(by_name("sgd", 0.1).is_ok());
        assert_eq!(by_name("sgd:0.9", 0.1).unwrap().lr(), 0.1);
        assert!(by_name("lbfgs", 0.1).is_err());
        // Momentum outside [0, 1) silently diverges or freezes the run —
        // every such value must be rejected with a clear error.
        assert!(by_name("sgd:0.0", 0.1).is_ok());
        assert!(by_name("sgd:0.999", 0.1).is_ok());
        for bad in ["sgd:NaN", "sgd:nan", "sgd:-0.5", "sgd:1.0", "sgd:1.5", "sgd:inf", "sgd:x"] {
            let err = by_name(bad, 0.1).unwrap_err().to_string();
            assert!(
                err.contains("momentum"),
                "{bad}: error should name the momentum, got {err:?}"
            );
        }
    }

    /// Snapshot mid-run, keep stepping on the original, and separately
    /// restore the snapshot into a fresh optimizer and replay the same
    /// gradients: the parameters must be bit-identical — the state
    /// captures *everything* the update rule depends on.
    fn state_round_trip(mut make: impl FnMut() -> Box<dyn Optimizer>) {
        let mut params = vec![HostTensor::f32(vec![1.0, -2.0, 3.0], &[3])];
        let mut opt = make();
        for _ in 0..5 {
            let g = vec![quad_grad(&params[0])];
            opt.step(&mut params, &g).unwrap();
        }
        let snap_params = params.clone();
        let snap_state = opt.state();
        // Continue the original for 5 more steps.
        for _ in 0..5 {
            let g = vec![quad_grad(&params[0])];
            opt.step(&mut params, &g).unwrap();
        }
        // Restore into a fresh optimizer and replay.
        let mut resumed = make();
        resumed.load_state(snap_state).unwrap();
        let mut rp = snap_params;
        for _ in 0..5 {
            let g = vec![quad_grad(&rp[0])];
            resumed.step(&mut rp, &g).unwrap();
        }
        let a = params[0].as_f32().unwrap();
        let b = rp[0].as_f32().unwrap();
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.to_bits(), y.to_bits(), "resumed run diverged");
        }
    }

    #[test]
    fn sgd_state_round_trips_bit_identically() {
        state_round_trip(|| Box::new(Sgd::new(0.05, 0.9)));
    }

    #[test]
    fn adam_state_round_trips_bit_identically() {
        state_round_trip(|| Box::new(Adam::new(0.1)));
    }

    #[test]
    fn load_state_rejects_wrong_kind() {
        let mut sgd = Sgd::new(0.1, 0.9);
        let adam_state = Adam::new(0.1).state();
        assert!(sgd.load_state(adam_state).is_err());
        let mut adam = Adam::new(0.1);
        assert!(adam.load_state(OptimizerState::Sgd { velocity: vec![] }).is_err());
    }

    #[test]
    fn restored_state_from_wrong_model_is_an_error_not_a_panic() {
        // Velocity/moments sized for a 2-tensor model fed a 1-tensor model.
        let mut sgd = Sgd::new(0.1, 0.9);
        sgd.load_state(OptimizerState::Sgd { velocity: vec![vec![0.0], vec![0.0]] }).unwrap();
        let mut params = vec![HostTensor::f32(vec![1.0], &[1])];
        let grads = vec![quad_grad(&params[0])];
        assert!(sgd.step(&mut params, &grads).is_err());

        let mut adam = Adam::new(0.1);
        adam.load_state(OptimizerState::Adam {
            t: 3,
            m: vec![vec![0.0, 0.0]],
            v: vec![vec![0.0, 0.0]],
        })
        .unwrap();
        assert!(adam.step(&mut params, &grads).is_err());
    }
}
