//! Optimizers applied on the rust side after the gradient all-reduce.
//!
//! The AOT train step returns raw gradients; every worker applies the
//! same update to its own (identical) parameter copy, which keeps
//! parameters consistent without a parameter server — the paper's
//! data-parallel scheme.

use anyhow::{ensure, Result};

use crate::runtime::HostTensor;

/// A parameter-update rule over flat f32 tensors.
pub trait Optimizer: Send {
    fn step(&mut self, params: &mut [HostTensor], grads: &[HostTensor]) -> Result<()>;
    fn lr(&self) -> f32;
}

/// SGD with optional momentum.
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    velocity: Vec<Vec<f32>>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32) -> Self {
        Self { lr, momentum, velocity: Vec::new() }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [HostTensor], grads: &[HostTensor]) -> Result<()> {
        ensure!(params.len() == grads.len());
        if self.velocity.is_empty() && self.momentum != 0.0 {
            self.velocity = params.iter().map(|p| vec![0.0; p.len()]).collect();
        }
        for (pi, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let (HostTensor::F32 { data: pd, .. }, HostTensor::F32 { data: gd, .. }) = (p, g)
            else {
                anyhow::bail!("optimizer expects f32 tensors")
            };
            ensure!(pd.len() == gd.len(), "param/grad length mismatch at {pi}");
            if self.momentum == 0.0 {
                for (x, dx) in pd.iter_mut().zip(gd) {
                    *x -= self.lr * dx;
                }
            } else {
                let v = &mut self.velocity[pi];
                for ((x, dx), vi) in pd.iter_mut().zip(gd).zip(v.iter_mut()) {
                    *vi = self.momentum * *vi + dx;
                    *x -= self.lr * *vi;
                }
            }
        }
        Ok(())
    }

    fn lr(&self) -> f32 {
        self.lr
    }
}

/// Adam (Kingma & Ba) — the de-facto default for GraphSAGE on OGB; the
/// paper's lr of 0.006 is used with this by default.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: i32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Self { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: Vec::new(), v: Vec::new() }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [HostTensor], grads: &[HostTensor]) -> Result<()> {
        ensure!(params.len() == grads.len());
        if self.m.is_empty() {
            self.m = params.iter().map(|p| vec![0.0; p.len()]).collect();
            self.v = params.iter().map(|p| vec![0.0; p.len()]).collect();
        }
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t);
        let bc2 = 1.0 - self.beta2.powi(self.t);
        for (pi, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let (HostTensor::F32 { data: pd, .. }, HostTensor::F32 { data: gd, .. }) = (p, g)
            else {
                anyhow::bail!("optimizer expects f32 tensors")
            };
            ensure!(pd.len() == gd.len(), "param/grad length mismatch at {pi}");
            let (m, v) = (&mut self.m[pi], &mut self.v[pi]);
            for i in 0..pd.len() {
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * gd[i];
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * gd[i] * gd[i];
                let mh = m[i] / bc1;
                let vh = v[i] / bc2;
                pd[i] -= self.lr * mh / (vh.sqrt() + self.eps);
            }
        }
        Ok(())
    }

    fn lr(&self) -> f32 {
        self.lr
    }
}

/// Parse `sgd`, `sgd:0.9` (momentum) or `adam` into an optimizer.
pub fn by_name(name: &str, lr: f32) -> Result<Box<dyn Optimizer>> {
    match name.split_once(':') {
        None if name == "adam" => Ok(Box::new(Adam::new(lr))),
        None if name == "sgd" => Ok(Box::new(Sgd::new(lr, 0.0))),
        Some(("sgd", m)) => Ok(Box::new(Sgd::new(lr, m.parse()?))),
        _ => anyhow::bail!("unknown optimizer {name:?} (want adam | sgd | sgd:<momentum>)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(p: &HostTensor) -> HostTensor {
        // grad of 0.5*||x||² is x.
        HostTensor::f32(p.as_f32().unwrap().to_vec(), p.shape())
    }

    fn run(opt: &mut dyn Optimizer, steps: usize) -> f32 {
        let mut params = vec![HostTensor::f32(vec![1.0, -2.0, 3.0], &[3])];
        for _ in 0..steps {
            let g = vec![quad_grad(&params[0])];
            opt.step(&mut params, &g).unwrap();
        }
        params[0].as_f32().unwrap().iter().map(|x| x * x).sum::<f32>()
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let start = 1.0f32 + 4.0 + 9.0;
        assert!(run(&mut Sgd::new(0.1, 0.0), 50) < 1e-3 * start);
    }

    #[test]
    fn momentum_and_adam_converge_too() {
        let start = 14.0f32;
        assert!(run(&mut Sgd::new(0.05, 0.9), 80) < 1e-2 * start);
        assert!(run(&mut Adam::new(0.2), 100) < 1e-2 * start);
    }

    #[test]
    fn shape_mismatch_is_error() {
        let mut opt = Sgd::new(0.1, 0.0);
        let mut params = vec![HostTensor::f32(vec![1.0], &[1])];
        let grads = vec![HostTensor::f32(vec![1.0, 2.0], &[2])];
        assert!(opt.step(&mut params, &grads).is_err());
    }

    #[test]
    fn by_name_parses() {
        assert!(by_name("adam", 0.006).is_ok());
        assert!(by_name("sgd", 0.1).is_ok());
        assert_eq!(by_name("sgd:0.9", 0.1).unwrap().lr(), 0.1);
        assert!(by_name("lbfgs", 0.1).is_err());
    }
}
