//! The distributed training loop: sampling → feature exchange → AOT
//! train step → gradient all-reduce → optimizer, per minibatch, across W
//! workers (paper §3.3 + §4 training setup).
//!
//! Every worker holds an identical parameter copy, applies identical
//! updates (gradients are mean-all-reduced), and draws seeds from its own
//! partition's labeled nodes — the paper's data-parallel recipe. All
//! phase times are measured per worker so Fig 5/6 can be regenerated.

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::dist::{
    fetch_features, run_workers_on, sample_mfgs_distributed_wire, CachePolicy, Comm, CommError,
    CommStats, Counters, FeatureCache, NetworkModel, Plane, RoundKind, SamplingWire,
    TransportConfig,
};
use crate::graph::{Dataset, NodeId};
use crate::partition::{
    build_shard, build_shards, partition_graph, PartitionConfig, ReplicationPolicy, WorkerShard,
};
use crate::runtime::{Engine, HostTensor, Manifest, ModelRuntime};
use crate::sampling::rng::RngKey;
use crate::sampling::{KernelKind, Mfg, MinibatchSchedule, SamplerWorkspace};

use super::checkpoint::{self, CheckpointState, Fingerprint};
use super::metrics::{accuracy, EpochStats, PhaseTimes, Stopwatch};
use super::optimizer;
use super::padding::pad_batch;
use super::prefetch::{sampler_epochs, Produced, ProducerPlan};

/// Full configuration of one distributed training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// AOT variant name from `artifacts/manifest.json`.
    pub variant: String,
    /// How much remote topology each worker replicates — the axis that
    /// subsumes the old vanilla/hybrid scheme switch.
    pub policy: ReplicationPolicy,
    pub kernel: KernelKind,
    pub workers: usize,
    pub epochs: usize,
    /// Paper default: 0.006.
    pub lr: f32,
    /// `adam` | `sgd` | `sgd:<momentum>`.
    pub optimizer: String,
    pub seed: u64,
    pub net: NetworkModel,
    /// How frames physically move between workers: the in-process
    /// channel mesh (default) or per-peer TCP sockets (`+tcp` mode
    /// suffix / `--transport tcp[:<base_port>]`). Uniform across ranks;
    /// results are bit-identical across transports.
    pub transport: TransportConfig,
    /// Remote-feature cache rows per worker (0 = disabled).
    pub cache_capacity: usize,
    pub cache_policy: CachePolicy,
    /// Remote-adjacency cache bytes per worker (0 = disabled) — the
    /// dynamic, workload-adaptive layer over the policy's static halo
    /// (`cache:<bytes>` mode suffix / `--adj-cache`). Uniform across
    /// ranks, like the policy: the sampler's wire format is keyed off it.
    pub adj_cache_bytes: u64,
    pub adj_cache_policy: CachePolicy,
    /// Response encoding of the sampler's miss rounds (`wire:<fmt>` mode
    /// suffix / `--sampling-wire`). Uniform across ranks — the wire is
    /// part of the SPMD contract; content is bit-identical either way.
    pub sampling_wire: SamplingWire,
    /// Overlap sampling + feature fetch of minibatch t+1 with compute +
    /// grad sync of minibatch t: a sampler thread per rank produces
    /// MFGs on the Sampling plane while the trainer consumes on the
    /// Gradient plane (`+pipe` mode suffix / `--pipeline on`). Results
    /// — MFG stream, loss curve, cache decay — are bit-identical to
    /// serial mode; uniform across ranks like every SPMD knob.
    pub pipeline: bool,
    /// Cap batches per epoch (benches); `None` = full epoch.
    pub max_batches: Option<usize>,
    /// Compute last-batch accuracy each epoch via the eval executable.
    pub eval_last_batch: bool,
    /// Fanout schedule (paper §5 future work). Fanouts may only shrink
    /// below the variant's compiled fanouts; padding absorbs the rest.
    pub schedule: ScheduleKind,
    /// Write per-rank checkpoints under this directory at epoch fences
    /// (`--checkpoint-dir`; `None` = no checkpointing). Uniform across
    /// ranks like every SPMD knob — each rank writes its own files.
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint cadence: write after every n-th completed epoch
    /// (`--checkpoint-every`, default 1).
    pub checkpoint_every: usize,
    /// Resume from the newest checkpoint every rank holds in
    /// `checkpoint_dir` (`--resume`). Validated against this config's
    /// fingerprint — any mismatch is a typed error, never silent
    /// divergence; with no checkpoints present the run starts fresh.
    pub resume: bool,
    pub verbose: bool,
}

/// Declarative fanout-schedule selector (see `sampling::adaptive`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScheduleKind {
    /// The paper's default: the variant's compiled fanouts every epoch.
    Fixed,
    /// Linear ramp from `start_frac` to full over `ramp_epochs`.
    Ramp { start_frac: f32, ramp_epochs: usize },
    /// Escalate on loss plateaus.
    Plateau { start_frac: f32, step_frac: f32, tol: f32 },
}

impl ScheduleKind {
    fn build(self, max: Vec<usize>) -> Box<dyn crate::sampling::adaptive::FanoutSchedule> {
        use crate::sampling::adaptive::*;
        match self {
            ScheduleKind::Fixed => Box::new(FixedSchedule { fanouts: max }),
            ScheduleKind::Ramp { start_frac, ramp_epochs } => {
                Box::new(RampSchedule { max, start_frac, ramp_epochs })
            }
            ScheduleKind::Plateau { start_frac, step_frac, tol } => {
                Box::new(PlateauSchedule::new(max, start_frac, step_frac, tol))
            }
        }
    }
}

impl TrainConfig {
    pub fn new(
        variant: &str,
        policy: ReplicationPolicy,
        kernel: KernelKind,
        workers: usize,
    ) -> Self {
        Self {
            variant: variant.to_string(),
            policy,
            kernel,
            workers,
            epochs: 3,
            lr: 0.006,
            optimizer: "adam".into(),
            seed: 0,
            net: NetworkModel::infiniband_200g(),
            transport: TransportConfig::Inproc,
            cache_capacity: 0,
            cache_policy: CachePolicy::StaticDegree,
            adj_cache_bytes: 0,
            adj_cache_policy: CachePolicy::Clock,
            sampling_wire: SamplingWire::default(),
            pipeline: false,
            max_batches: None,
            eval_last_batch: false,
            schedule: ScheduleKind::Fixed,
            checkpoint_dir: None,
            checkpoint_every: 1,
            resume: false,
            verbose: false,
        }
    }

    /// The Fig 6 scenarios by name, plus budgeted points on the
    /// replication spectrum: `budget:<bytes>` (suffixes `k`/`m`/`g`,
    /// KiB-based) and `halo:<hops>` (complete h-hop halo, no byte cap).
    /// Any base takes `+`-separated options: `+fused` (the fused
    /// kernel), `+cache:<bytes>` (the dynamic remote-adjacency cache),
    /// `+tcp` (run the collectives over loopback TCP sockets instead of
    /// the in-process channel mesh), `+wire:<scalar|bulk>` (the
    /// sampler's miss-response encoding; default bulk), and `+pipe`
    /// (the double-buffered MFG prefetcher; bit-identical results),
    /// e.g. `budget:64k+cache:32k+fused+tcp+pipe`.
    pub fn mode(variant: &str, mode: &str, workers: usize) -> Result<Self> {
        let mut parts = mode.split('+');
        let base = parts.next().unwrap_or_default();
        let policy = if base == "vanilla" {
            ReplicationPolicy::vanilla()
        } else if base == "hybrid" {
            ReplicationPolicy::hybrid()
        } else if let Some(spec) = base.strip_prefix("budget:") {
            ReplicationPolicy::from_budget(crate::config::parse_budget(spec)?)
        } else if let Some(h) = base.strip_prefix("halo:") {
            ReplicationPolicy::halo(h.parse().with_context(|| format!("mode {mode:?}"))?)
        } else {
            anyhow::bail!(
                "unknown mode {mode:?} (vanilla | hybrid | budget:<bytes> | halo:<hops>, \
                 each optionally +fused, +cache:<bytes>, +tcp, +wire:<scalar|bulk>, \
                 and/or +pipe)"
            )
        };
        let mut kernel = KernelKind::Baseline;
        let mut adj_cache_bytes = 0u64;
        let mut transport = TransportConfig::Inproc;
        let mut sampling_wire = SamplingWire::default();
        let mut pipeline = false;
        for opt in parts {
            if opt == "fused" {
                kernel = KernelKind::Fused;
            } else if opt == "tcp" {
                transport = TransportConfig::Tcp { base_port: 0 };
            } else if opt == "pipe" {
                pipeline = true;
            } else if let Some(spec) = opt.strip_prefix("cache:") {
                adj_cache_bytes = crate::config::parse_cache_bytes(spec)?;
            } else if let Some(spec) = opt.strip_prefix("wire:") {
                sampling_wire = crate::config::sampling_wire(spec)?;
            } else {
                anyhow::bail!(
                    "unknown mode option {opt:?} in {mode:?} \
                     (fused | cache:<bytes> | tcp | wire:<scalar|bulk> | pipe)"
                );
            }
        }
        let mut cfg = Self::new(variant, policy, kernel, workers);
        cfg.adj_cache_bytes = adj_cache_bytes;
        cfg.transport = transport;
        cfg.sampling_wire = sampling_wire;
        cfg.pipeline = pipeline;
        Ok(cfg)
    }
}

/// Cross-worker aggregation of one epoch.
#[derive(Debug, Clone)]
pub struct AggEpoch {
    pub epoch: usize,
    pub batches: usize,
    pub mean_loss: f32,
    /// Slowest worker's wall time — the distributed epoch time (Fig 6).
    pub wall_s: f64,
    /// Mean per-worker phase breakdown.
    pub times: PhaseTimes,
    pub comm: CommStats,
    pub acc: Option<f32>,
}

/// Result of a whole run.
#[derive(Debug)]
pub struct TrainReport {
    pub epochs: Vec<AggEpoch>,
    pub comm_total: CommStats,
    /// Worker-0 per-step loss curve (for EXPERIMENTS.md).
    pub loss_curve: Vec<f32>,
}

impl TrainReport {
    pub fn mean_epoch_wall_s(&self) -> f64 {
        if self.epochs.is_empty() {
            return 0.0;
        }
        self.epochs.iter().map(|e| e.wall_s).sum::<f64>() / self.epochs.len() as f64
    }
}

struct WorkerResult {
    epochs: Vec<EpochStats>,
    loss_curve: Vec<f32>,
}

/// Shape compatibility between a dataset and an AOT variant, checked
/// once per run (shared by the in-process and per-rank entry points).
pub(crate) fn check_variant(manifest: &Manifest, dataset: &Dataset, cfg: &TrainConfig) -> Result<()> {
    let variant = manifest.variant(&cfg.variant)?;
    ensure!(
        variant.feat_dim == dataset.feat_dim,
        "variant {} expects feat_dim {}, dataset {} has {}",
        cfg.variant,
        variant.feat_dim,
        dataset.name,
        dataset.feat_dim
    );
    ensure!(
        variant.classes >= dataset.num_classes,
        "variant has {} classes, dataset needs {}",
        variant.classes,
        dataset.num_classes
    );
    Ok(())
}

/// What one rank of a **multi-process** training run reports (see
/// [`train_rank`]). The full-run aggregation of [`TrainReport`] needs
/// every rank's results in one process, so a multi-process run reports
/// per rank and merges externally (rank 0's loss curve is the canonical
/// one — it is the curve [`TrainReport::loss_curve`] carries too).
#[derive(Debug)]
pub struct RankTrainReport {
    /// This rank's per-epoch stats (loss, wall, phase times, comm delta
    /// on rank 0).
    pub epochs: Vec<EpochStats>,
    /// Per-step loss curve — populated on rank 0 only, like
    /// [`TrainReport::loss_curve`].
    pub loss_curve: Vec<f32>,
    /// This process's counter snapshot. Multi-process counters are
    /// per-process: rank 0 carries the global *round* counts, each rank
    /// its own *byte* counts (sum over ranks = the in-process totals).
    pub comm_total: CommStats,
}

/// Train exactly **one rank** over an already-connected [`Comm`] — the
/// entry point of `fastsample worker` (one OS process per rank, fabric
/// built by [`crate::dist::run_worker_process`]). Deterministic
/// partitioning plus [`build_shard`] mean this process loads only its
/// own shard, and the run is bit-identical to the in-process
/// [`train_distributed`] with the same config (pinned by
/// `rust/tests/process_rendezvous.rs`).
pub fn train_rank(
    dataset: &Dataset,
    artifacts_dir: &Path,
    cfg: &TrainConfig,
    rank: usize,
    comm: &mut Comm,
) -> Result<RankTrainReport> {
    ensure!(
        comm.rank() == rank,
        "comm endpoint is rank {}, asked to train rank {rank}",
        comm.rank()
    );
    ensure!(
        comm.world() == cfg.workers,
        "fabric has {} ranks, config says {} workers",
        comm.world(),
        cfg.workers
    );
    let manifest = Manifest::load(artifacts_dir)?;
    check_variant(&manifest, dataset, cfg)?;
    let book = Arc::new(partition_graph(
        &dataset.graph,
        &dataset.train_ids,
        &PartitionConfig::new(cfg.workers),
    ));
    let shard = build_shard(dataset, &book, &cfg.policy, rank);
    let w = worker_loop(rank, comm, &shard, &manifest, cfg, &dataset.name)?;
    Ok(RankTrainReport {
        epochs: w.epochs,
        loss_curve: w.loss_curve,
        comm_total: comm.counters.snapshot(),
    })
}

/// What [`sample_rank`] reports for one rank.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleRankReport {
    /// Merged per-step digest curve (all-reduced in rank order, so
    /// **identical on every rank** and across transports — the
    /// artifact-free stand-in for the loss curve).
    pub curve: Vec<f32>,
    /// Steps executed (epochs × batches).
    pub steps: usize,
    /// Total edges this rank sampled across all steps and levels.
    pub sampled_edges: u64,
    /// This rank's sampled MFGs, one `Vec<Mfg>` per step — retained
    /// only under `keep_mfgs` (the equivalence tests); empty otherwise,
    /// so long CLI runs don't accumulate every step's graphs in memory.
    pub mfgs: Vec<Vec<Mfg>>,
    /// This rank's seed pool (prefix of its labeled nodes, shuffled per
    /// epoch by the schedule).
    pub seeds: Vec<NodeId>,
    /// Per-epoch fenced counter deltas (rounds + bytes charged between
    /// the epoch's two fences). The fences themselves are uncharged
    /// control rounds, so totals are unchanged by taking them; the
    /// deltas pin that per-epoch traffic — including multi-epoch
    /// adjacency-cache decay — is identical under `--pipeline on|off`.
    pub epoch_deltas: Vec<CommStats>,
    /// This process's counter snapshot (per-process semantics, as in
    /// [`RankTrainReport::comm_total`]).
    pub comm_total: CommStats,
}

/// The artifact-free **training-shaped workload** for one rank: per
/// step, distributed sampling → feature fetch → one `GradSync`
/// all-reduce of a deterministic digest of what arrived (mean feature
/// value + sampled-edge count). No AOT artifacts or PJRT engine needed,
/// so `fastsample worker --task sample` and the CI smoke can exercise
/// the full multi-process fabric anywhere; the digest curve plays the
/// loss curve's role in equivalence checks (bit-identical across ranks,
/// transports, and process layouts).
///
/// `batch` seeds per step from this rank's labeled pool; steps per
/// epoch = the cross-rank minimum of available batches, capped by
/// `cfg.max_batches`; `cfg.epochs` epochs. `keep_mfgs` retains every
/// step's MFGs in the report for bit-equality tests — leave it off for
/// real runs (memory grows with run length otherwise). SPMD-collective
/// like everything else: every rank must call it with the same config.
#[allow(clippy::too_many_arguments)]
pub fn sample_rank(
    dataset: &Dataset,
    cfg: &TrainConfig,
    batch: usize,
    fanouts: &[usize],
    keep_mfgs: bool,
    rank: usize,
    comm: &mut Comm,
) -> Result<SampleRankReport> {
    ensure!(!fanouts.is_empty(), "need at least one fanout level");
    ensure!(batch >= 1, "batch must be >= 1");
    ensure!(comm.rank() == rank, "comm endpoint is rank {}, not {rank}", comm.rank());
    ensure!(
        comm.world() == cfg.workers,
        "fabric has {} ranks, config says {} workers",
        comm.world(),
        cfg.workers
    );
    let book = Arc::new(partition_graph(
        &dataset.graph,
        &dataset.train_ids,
        &PartitionConfig::new(cfg.workers),
    ));
    let shard = build_shard(dataset, &book, &cfg.policy, rank);
    let mut view = shard.topology.clone();
    if cfg.adj_cache_bytes > 0 && !shard.policy.is_full() {
        view.enable_cache(cfg.adj_cache_bytes, cfg.adj_cache_policy);
    }
    let mut ws = SamplerWorkspace::new();
    let key = RngKey::new(cfg.seed).fold(0xD16E57);
    let batch = batch.min(shard.train_local.len().max(1));
    let my_batches = (shard.train_local.len() / batch) as u64;
    let mut batches = comm.all_reduce_min_u64(my_batches)? as usize;
    if let Some(cap) = cfg.max_batches {
        batches = batches.min(cap);
    }
    ensure!(
        batches > 0,
        "partition {rank} has too few labeled nodes ({}) for one batch of {batch}",
        shard.train_local.len()
    );

    let mut curve = Vec::new();
    let mut all_mfgs = Vec::new();
    let mut first_seeds = Vec::new();
    let mut epoch_deltas = Vec::new();
    let mut steps = 0usize;
    let mut sampled_edges = 0u64;

    // Checkpoint/resume, exactly as in the trainer's worker loop:
    // `resume_latest` is a collective guarded only by uniform config,
    // placed after the batches vote and before any epoch traffic. The
    // digest curve is all-reduced (identical on every rank), so the
    // restored prefix stitches seamlessly onto the continued run.
    // `first_seeds`/`mfgs` cover only the epochs this process runs.
    let fp = Fingerprint::new("sample", &dataset.name, cfg, Some((batch, fanouts)));
    let mut start_epoch = 0usize;
    if cfg.resume {
        if let Some(dir) = &cfg.checkpoint_dir {
            if let Some(state) = checkpoint::resume_latest(comm, dir, &fp)? {
                start_epoch = state.epochs_done as usize;
                curve = state.curve;
                steps = state.steps as usize;
                sampled_edges = state.sampled_edges;
                epoch_deltas = state.epoch_deltas;
                for (v, row) in &state.cache_rows {
                    view.cache_insert(*v, row);
                }
                comm.counters.restore(&state.comm);
            }
        }
    }

    // Sampling misses and feature rounds ride the Sampling plane in both
    // modes, so wire traffic is mode-invariant; the digest all-reduce and
    // the epoch fences stay on the base (gradient-plane) handle.
    let mut scomm = comm.plane(Plane::Sampling);

    if cfg.pipeline {
        let plan = ProducerPlan {
            key,
            start_epoch,
            epochs: cfg.epochs,
            batches,
            batch,
            kernel: cfg.kernel,
            wire: cfg.sampling_wire,
            snapshot_cache: cfg.checkpoint_dir.is_some(),
        };
        let (items_tx, items_rx) = mpsc::sync_channel::<Produced>(1);
        let (go_tx, go_rx) = mpsc::channel::<Vec<usize>>();
        let shard = &shard;
        std::thread::scope(|s| {
            let sampler = {
                let scomm = &mut scomm;
                let view = &mut view;
                let ws = &mut ws;
                let plan = &plan;
                s.spawn(move || -> Result<(), CommError> {
                    sampler_epochs(scomm, shard, view, ws, None, plan, &items_tx, &go_rx)
                })
            };
            let mut body = || -> Result<()> {
                for epoch in start_epoch..cfg.epochs {
                    let mark = comm.fenced_snapshot()?;
                    let _ = go_tx.send(fanouts.to_vec());
                    for b in 0..batches {
                        let item = items_rx
                            .recv()
                            .map_err(|_| anyhow::anyhow!("sampler thread stopped early"))?;
                        let Produced::Batch { epoch: ie, index, seeds, mfgs, feats } = item
                        else {
                            anyhow::bail!("prefetcher sent an epoch marker mid-epoch");
                        };
                        ensure!(
                            (ie, index) == (epoch, b),
                            "prefetcher out of order: got ({ie},{index}), want ({epoch},{b})"
                        );
                        if epoch == 0 && b == 0 {
                            first_seeds = seeds;
                        }
                        // Same digest as the serial arm below.
                        let mut acc = 0.0f32;
                        for &x in &feats {
                            acc += x;
                        }
                        let edges: usize = mfgs.iter().map(|m| m.num_edges()).sum();
                        let mut digest =
                            [acc / (feats.len().max(1) as f32) + edges as f32 * 1e-3];
                        comm.all_reduce_mean_f32(RoundKind::GradSync, &mut digest)?;
                        curve.push(digest[0]);
                        steps += 1;
                        sampled_edges += edges as u64;
                        if keep_mfgs {
                            all_mfgs.push(mfgs);
                        }
                    }
                    // Drain to the epoch marker before fencing: it means
                    // the sampler has charged every byte of this epoch
                    // and is quiescent again (blocked on `go`). The
                    // marker hands back the cache resident set as of the
                    // fence (the sampler thread owns the view), so
                    // pipelined checkpoints warm-start a resume exactly
                    // like serial ones.
                    let fenced_cache_rows = match items_rx.recv() {
                        Ok(Produced::EpochEnd { epoch: e, cache_rows }) if e == epoch => cache_rows,
                        Ok(_) => anyhow::bail!("prefetcher desynchronized at epoch boundary"),
                        Err(_) => anyhow::bail!("sampler thread stopped early"),
                    };
                    let end = comm.fenced_snapshot()?;
                    epoch_deltas.push(end.diff(&mark));
                    // Checkpoint at the fence (sampler quiescent on `go`).
                    if let Some(dir) = &cfg.checkpoint_dir {
                        if (epoch + 1) % cfg.checkpoint_every.max(1) == 0 {
                            let state = CheckpointState {
                                epochs_done: (epoch + 1) as u64,
                                smoothed_loss: None,
                                curve: curve.clone(),
                                comm: end,
                                epoch_deltas: epoch_deltas.clone(),
                                params: Vec::new(),
                                opt: None,
                                cache_rows: fenced_cache_rows,
                                steps: steps as u64,
                                sampled_edges,
                            };
                            checkpoint::write_checkpoint(dir, &fp, rank, &state)?;
                        }
                    }
                }
                Ok(())
            };
            let trainer = body();
            drop(go_tx);
            drop(items_rx);
            if trainer.is_err() {
                comm.cancel(&CommError::Io {
                    peer: rank,
                    detail: "trainer thread failed; sampling plane cancelled".into(),
                });
            }
            let sampler = match sampler.join() {
                Ok(r) => r,
                Err(p) => std::panic::resume_unwind(p),
            };
            merge_pipeline_outcome(trainer, sampler)
        })?;
    } else {
        let mut feat = Vec::new();
        for epoch in start_epoch..cfg.epochs {
            let mark = comm.fenced_snapshot()?;
            let schedule =
                MinibatchSchedule::new(&shard.train_local, batch, key.fold(epoch as u64));
            for b in 0..batches {
                let seeds = schedule.batch(b);
                if epoch == 0 && b == 0 {
                    first_seeds = seeds.to_vec();
                }
                let batch_key = key.fold(epoch as u64).fold(b as u64 + 1);
                let mfgs = sample_mfgs_distributed_wire(
                    &mut scomm,
                    &shard,
                    &mut view,
                    seeds,
                    fanouts,
                    batch_key,
                    &mut ws,
                    cfg.kernel,
                    cfg.sampling_wire,
                )?;
                fetch_features(&mut scomm, &shard, &mfgs[0].src_nodes, None, &mut feat)?;
                // Deterministic digest: sequential f32 sum (fixed order)
                // of the fetched features, plus the sampled-edge count —
                // then rank-order all-reduced, so every rank (and every
                // transport/process layout) holds the identical value.
                let mut acc = 0.0f32;
                for &x in &feat {
                    acc += x;
                }
                let edges: usize = mfgs.iter().map(|m| m.num_edges()).sum();
                let mut digest = [acc / (feat.len().max(1) as f32) + edges as f32 * 1e-3];
                comm.all_reduce_mean_f32(RoundKind::GradSync, &mut digest)?;
                curve.push(digest[0]);
                steps += 1;
                sampled_edges += edges as u64;
                if keep_mfgs {
                    all_mfgs.push(mfgs);
                }
            }
            let end = comm.fenced_snapshot()?;
            epoch_deltas.push(end.diff(&mark));
            // Checkpoint at the fence — purely local I/O, uniform-config
            // cadence. Serial mode owns the view, so the adjacency
            // cache's resident rows ride along for a warm resume.
            if let Some(dir) = &cfg.checkpoint_dir {
                if (epoch + 1) % cfg.checkpoint_every.max(1) == 0 {
                    let state = CheckpointState {
                        epochs_done: (epoch + 1) as u64,
                        smoothed_loss: None,
                        curve: curve.clone(),
                        comm: end,
                        epoch_deltas: epoch_deltas.clone(),
                        params: Vec::new(),
                        opt: None,
                        cache_rows: view.cached_entries(),
                        steps: steps as u64,
                        sampled_edges,
                    };
                    checkpoint::write_checkpoint(dir, &fp, rank, &state)?;
                }
            }
        }
    }
    Ok(SampleRankReport {
        curve,
        steps,
        sampled_edges,
        mfgs: all_mfgs,
        seeds: first_seeds,
        epoch_deltas,
        comm_total: comm.counters.snapshot(),
    })
}

/// Run distributed training of `cfg` over `dataset`, loading AOT
/// artifacts from `artifacts_dir`.
pub fn train_distributed(
    dataset: &Dataset,
    artifacts_dir: &Path,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let manifest = Manifest::load(artifacts_dir)?;
    check_variant(&manifest, dataset, cfg)?;

    let book = Arc::new(partition_graph(
        &dataset.graph,
        &dataset.train_ids,
        &PartitionConfig::new(cfg.workers),
    ));
    let shards = build_shards(dataset, &book, &cfg.policy);
    let counters = Arc::new(Counters::default());

    let shards_ref = &shards;
    let results: Vec<Result<WorkerResult>> = run_workers_on(
        &cfg.transport,
        cfg.workers,
        cfg.net.clone(),
        Arc::clone(&counters),
        move |rank, comm| worker_loop(rank, comm, &shards_ref[rank], &manifest, cfg, &dataset.name),
    )
    .context("transport setup failed")?;

    // Surface the *root cause*: a failing worker makes its peers fail
    // with cascade PeerLost errors, so prefer any non-cascade error over
    // the first-by-rank one.
    let mut workers = Vec::with_capacity(results.len());
    let mut cascade: Option<anyhow::Error> = None;
    for (rank, r) in results.into_iter().enumerate() {
        match r {
            Ok(w) => workers.push(w),
            Err(e) => {
                let is_cascade = matches!(
                    e.downcast_ref::<CommError>(),
                    Some(CommError::PeerLost { .. })
                );
                let e = e.context(format!("worker {rank}"));
                if !is_cascade {
                    return Err(e);
                }
                cascade.get_or_insert(e);
            }
        }
    }
    if let Some(e) = cascade {
        return Err(e);
    }

    // Aggregate per epoch.
    let epochs = (0..workers[0].epochs.len())
        .map(|e| {
            let per: Vec<&EpochStats> = workers.iter().map(|w| &w.epochs[e]).collect();
            let mut times = PhaseTimes::default();
            for s in &per {
                times.add(&s.times);
            }
            AggEpoch {
                epoch: e,
                batches: per[0].batches,
                mean_loss: per.iter().map(|s| s.mean_loss).sum::<f32>() / per.len() as f32,
                wall_s: per.iter().map(|s| s.wall_s).fold(0.0, f64::max),
                times: times.scale(1.0 / per.len() as f64),
                comm: per[0].comm.clone().unwrap_or_default(),
                acc: per[0].batch_acc,
            }
        })
        .collect();

    Ok(TrainReport {
        epochs,
        comm_total: counters.snapshot(),
        loss_curve: workers.swap_remove(0).loss_curve,
    })
}

fn worker_loop(
    rank: usize,
    comm: &mut Comm,
    shard: &WorkerShard,
    manifest: &Manifest,
    cfg: &TrainConfig,
    dataset_name: &str,
) -> Result<WorkerResult> {
    // Each worker owns a PJRT client + executables (PjRtClient is Rc-based
    // and not Send; one client per worker also mirrors one per machine).
    let engine = Engine::cpu()?;
    let rt = ModelRuntime::load(&engine, manifest, &cfg.variant)?;
    let variant = &rt.variant;
    let mut params = rt.init_params(cfg.seed);
    let mut opt = optimizer::by_name(&cfg.optimizer, cfg.lr)?;
    let mut ws = SamplerWorkspace::new();
    let key = RngKey::new(cfg.seed).fold(0xF00D);

    // This worker's topology view: a cheap clone of the shard's, plus the
    // optional remote-adjacency cache overlay. Gate on the *policy* —
    // uniform across ranks — so cache-mode wire framing stays in lockstep
    // (full replication never misses, so a cache would be dead weight).
    let mut view = shard.topology.clone();
    if cfg.adj_cache_bytes > 0 && !shard.policy.is_full() {
        view.enable_cache(cfg.adj_cache_bytes, cfg.adj_cache_policy);
    }

    // Sampling-plane handle: sampling misses and feature rounds ride it
    // in **both** modes (so wire traffic, seq streams, and per-plane
    // stats are mode-invariant); grad sync and the control rounds stay
    // on the base gradient-plane handle. In pipelined mode this handle
    // moves to the sampler thread.
    let mut scomm = comm.plane(Plane::Sampling);

    // Optional remote-feature cache (paper §5 extension).
    let mut cache = (cfg.cache_capacity > 0).then(|| {
        FeatureCache::new(cfg.cache_policy, cfg.cache_capacity, shard.feat_dim)
    });
    // Static-degree prefill needs every node's degree, which only full
    // replication guarantees; partial-budget runs skip the warm-up (the
    // cache still fills on demand). Gate on the *policy* — uniform
    // across ranks — so the prefill collective stays in lockstep even
    // when a finite budget happens to cover everything on some rank.
    if let Some(c) = &mut cache {
        if cfg.cache_policy == CachePolicy::StaticDegree && shard.policy.is_full() {
            let topo = &shard.topology;
            let hot = crate::dist::feature_cache::hottest_remote_nodes(
                |v| topo.try_neighbors(v).map_or(0, |n| n.len()),
                shard.book.num_nodes(),
                |v| shard.owns(v),
                cfg.cache_capacity,
            );
            crate::dist::feature_store::prefill_cache(&mut scomm, shard, &hot, c)?;
        }
    }

    // Agree on batches/epoch (paper balances labeled nodes per machine so
    // every worker generates the same number of minibatches).
    let my_batches = (shard.train_local.len() / variant.batch) as u64;
    let mut batches = comm.all_reduce_min_u64(my_batches)? as usize;
    if let Some(cap) = cfg.max_batches {
        batches = batches.min(cap);
    }
    ensure!(
        batches > 0,
        "partition {rank} has too few labeled nodes ({}) for one batch of {} — use a larger dataset scale or a smaller-batch variant",
        shard.train_local.len(),
        variant.batch
    );

    let mut epochs = Vec::with_capacity(cfg.epochs);
    let mut loss_curve = Vec::new();
    let mut grad_buf: Vec<f32> = Vec::new();
    let sched = cfg.schedule.build(variant.fanouts.clone());
    let mut smoothed_loss: Option<f32> = None;

    // Checkpoint/resume. `resume_latest` is a collective (the world
    // agrees on the epoch and cross-checks state digests), guarded only
    // by uniform config — every rank takes this branch together. All
    // restores land here, after the setup collectives (prefill, batches
    // vote) and before any epoch traffic, so the counter stream and the
    // positional RNG cursor continue exactly where the checkpointing
    // run fenced. Per-epoch stats of already-completed epochs are not
    // replayed: `epochs` reports only the epochs this process ran.
    let fp = Fingerprint::new("train", dataset_name, cfg, None);
    let mut start_epoch = 0usize;
    if cfg.resume {
        if let Some(dir) = &cfg.checkpoint_dir {
            if let Some(state) = checkpoint::resume_latest(comm, dir, &fp)? {
                ensure!(
                    state.params.len() == params.len()
                        && state.params.iter().zip(&params).all(|(a, b)| a.shape() == b.shape()),
                    "checkpoint parameter shapes do not match variant {}",
                    cfg.variant
                );
                start_epoch = state.epochs_done as usize;
                params = state.params;
                if let Some(os) = state.opt {
                    opt.load_state(os)?;
                }
                smoothed_loss = state.smoothed_loss;
                loss_curve = state.curve;
                for (v, row) in &state.cache_rows {
                    view.cache_insert(*v, row);
                }
                comm.counters.restore(&state.comm);
                if cfg.verbose && rank == 0 {
                    eprintln!(
                        "[resume] restored {start_epoch} completed epoch(s) from {}",
                        dir.display()
                    );
                }
            }
        }
    }

    if cfg.pipeline {
        // Pipelined: a sampler thread produces minibatch t+1 (phases 1+2
        // on the Sampling plane, owning view/workspace/cache so every
        // RNG cursor and cache insert happens in serial order) into a
        // depth-1 channel while this thread runs phases 3+4 on batch t.
        let plan = ProducerPlan {
            key,
            start_epoch,
            epochs: cfg.epochs,
            batches,
            batch: variant.batch,
            kernel: cfg.kernel,
            wire: cfg.sampling_wire,
            snapshot_cache: cfg.checkpoint_dir.is_some(),
        };
        let (items_tx, items_rx) = mpsc::sync_channel::<Produced>(1);
        let (go_tx, go_rx) = mpsc::channel::<Vec<usize>>();
        std::thread::scope(|s| {
            let sampler = {
                let scomm = &mut scomm;
                let view = &mut view;
                let ws = &mut ws;
                let cache = cache.as_mut();
                let plan = &plan;
                s.spawn(move || -> Result<(), CommError> {
                    sampler_epochs(scomm, shard, view, ws, cache, plan, &items_tx, &go_rx)
                })
            };
            let mut body = || -> Result<()> {
                for epoch in start_epoch..cfg.epochs {
                    // Fenced epoch mark, exactly as in the serial arm —
                    // the sampler is quiescent (blocked on `go`) across
                    // it, so the delta cuts at the same traffic point.
                    let epoch_mark = comm.fenced_snapshot()?;
                    let comm_before = (rank == 0).then_some(epoch_mark);
                    let epoch_sw = Stopwatch::start();
                    let mut times = PhaseTimes::default();
                    let mut loss_sum = 0f64;
                    let mut batch_acc = None;

                    // Fanouts ride the go channel: Plateau needs this
                    // thread's smoothed loss.
                    let fanouts = sched.fanouts(epoch, smoothed_loss);
                    debug_assert!(fanouts.iter().zip(&variant.fanouts).all(|(a, b)| a <= b));
                    let _ = go_tx.send(fanouts);

                    for b in 0..batches {
                        let mut sw = Stopwatch::start();
                        // ---- Phases 1+2 collapse into the wait for the
                        // prefetched item: sample_s measures only the
                        // *exposed* sampling + fetch latency (feature_s
                        // stays 0 — the split happens off-thread).
                        let item = items_rx
                            .recv()
                            .map_err(|_| anyhow::anyhow!("sampler thread stopped early"))?;
                        let Produced::Batch { epoch: ie, index, mfgs, feats, .. } = item
                        else {
                            anyhow::bail!("prefetcher sent an epoch marker mid-epoch");
                        };
                        ensure!(
                            (ie, index) == (epoch, b),
                            "prefetcher out of order: got ({ie},{index}), want ({epoch},{b})"
                        );
                        times.sample_s += sw.lap();

                        // ---- Phase 3: padded AOT train step (identical
                        // to the serial arm).
                        let labels = &shard.labels;
                        let padded =
                            pad_batch(variant, &mfgs, &feats, |v| labels[v as usize])?;
                        let dropout_seed = (epoch * batches + b) as i32;
                        let out = rt.train_step(&params, &padded, dropout_seed)?;
                        ensure!(
                            out.loss.is_finite(),
                            "loss diverged at epoch {epoch} batch {b}"
                        );
                        loss_sum += out.loss as f64;
                        if rank == 0 {
                            loss_curve.push(out.loss);
                        }
                        times.compute_s += sw.lap();

                        // ---- Phase 4: gradient all-reduce + update, on
                        // the gradient plane, concurrent with the
                        // sampler's in-flight rounds.
                        flatten_into(&out.grads, &mut grad_buf);
                        comm.all_reduce_mean_f32(RoundKind::GradSync, &mut grad_buf)?;
                        let mut grads = out.grads;
                        unflatten_from(&grad_buf, &mut grads);
                        opt.step(&mut params, &grads)?;
                        times.sync_s += sw.lap();

                        // ---- Optional accuracy on the final batch.
                        if cfg.eval_last_batch && b == batches - 1 {
                            let ev = rt.eval_step(&params, &padded)?;
                            batch_acc = Some(accuracy(
                                &ev.logits,
                                &padded.labels,
                                &padded.label_mask,
                            )?);
                        }
                    }

                    // Drain to the epoch marker before the end fence: it
                    // means the sampler has charged every byte of this
                    // epoch and is quiescent again, so the fenced delta
                    // is pipeline-invariant. The marker also hands back
                    // the adjacency-cache resident set at the fence.
                    let fenced_cache_rows = match items_rx.recv() {
                        Ok(Produced::EpochEnd { epoch: e, cache_rows }) if e == epoch => cache_rows,
                        Ok(_) => anyhow::bail!("prefetcher desynchronized at epoch boundary"),
                        Err(_) => anyhow::bail!("sampler thread stopped early"),
                    };
                    let comm_end = comm.fenced_snapshot()?;
                    let mut sw_end = epoch_sw;
                    let wall_s = sw_end.lap();
                    smoothed_loss = Some((loss_sum / batches as f64) as f32);
                    let comm_delta = comm_before.map(|before| comm_end.diff(&before));
                    let stats = EpochStats {
                        epoch,
                        batches,
                        mean_loss: (loss_sum / batches as f64) as f32,
                        times,
                        wall_s,
                        comm: comm_delta,
                        batch_acc,
                    };
                    if cfg.verbose && rank == 0 {
                        eprintln!(
                            "[epoch {epoch}] loss {:.4} wall {:.2}s sample {:.2}s feat {:.2}s compute {:.2}s sync {:.2}s acc {:?}",
                            stats.mean_loss,
                            stats.wall_s,
                            stats.times.sample_s,
                            stats.times.feature_s,
                            stats.times.compute_s,
                            stats.times.sync_s,
                            stats.batch_acc
                        );
                    }
                    epochs.push(stats);

                    // Checkpoint at the fence just taken: both planes are
                    // quiescent (the sampler is blocked on `go`), so the
                    // cumulative `comm_end` is exact. Purely local I/O.
                    // The sampler thread owns view/cache for the whole
                    // scope, so the resident set rides the `EpochEnd`
                    // marker — pipelined checkpoints carry the same
                    // cache section a serial run would write.
                    if let Some(dir) = &cfg.checkpoint_dir {
                        if (epoch + 1) % cfg.checkpoint_every.max(1) == 0 {
                            let state = CheckpointState {
                                epochs_done: (epoch + 1) as u64,
                                smoothed_loss,
                                curve: loss_curve.clone(),
                                comm: comm_end,
                                epoch_deltas: Vec::new(),
                                params: params.clone(),
                                opt: Some(opt.state()),
                                cache_rows: fenced_cache_rows,
                                steps: 0,
                                sampled_edges: 0,
                            };
                            checkpoint::write_checkpoint(dir, &fp, rank, &state)?;
                        }
                    }
                }
                Ok(())
            };
            let trainer = body();
            // Closing both channel ends tells a still-healthy sampler to
            // exit at its next send/recv; cancelling the fabric wakes one
            // that is blocked mid-collective.
            drop(go_tx);
            drop(items_rx);
            if trainer.is_err() {
                comm.cancel(&CommError::Io {
                    peer: rank,
                    detail: "trainer thread failed; sampling plane cancelled".into(),
                });
            }
            let sampler = match sampler.join() {
                Ok(r) => r,
                Err(p) => std::panic::resume_unwind(p),
            };
            merge_pipeline_outcome(trainer, sampler)
        })?;
    } else {
        let mut feat_buf: Vec<f32> = Vec::new();
        for epoch in start_epoch..cfg.epochs {
            // Fenced epoch mark: the counters are fabric-global, so the
            // per-epoch delta is only exact if no rank can charge this
            // epoch's first bytes before every rank has taken the
            // snapshot.
            let epoch_mark = comm.fenced_snapshot()?;
            let comm_before = (rank == 0).then_some(epoch_mark);
            let epoch_sw = Stopwatch::start();
            let mut times = PhaseTimes::default();
            let mut loss_sum = 0f64;
            let mut batch_acc = None;

            let schedule = MinibatchSchedule::new(
                &shard.train_local,
                variant.batch,
                key.fold(epoch as u64),
            );
            // Fanouts for this epoch (Fixed ⇒ the compiled tuple).
            let fanouts = sched.fanouts(epoch, smoothed_loss);
            debug_assert!(fanouts.iter().zip(&variant.fanouts).all(|(a, b)| a <= b));

            for b in 0..batches {
                let seeds = schedule.batch(b);
                let batch_key = key.fold(epoch as u64).fold(b as u64 + 1);
                let mut sw = Stopwatch::start();

                // ---- Phase 1: sampling (0..=2(L−1) measured rounds; the
                // adjacency cache makes later batches/epochs cheaper).
                let mfgs = sample_mfgs_distributed_wire(
                    &mut scomm,
                    shard,
                    &mut view,
                    seeds,
                    &fanouts,
                    batch_key,
                    &mut ws,
                    cfg.kernel,
                    cfg.sampling_wire,
                )?;
                times.sample_s += sw.lap();

                // ---- Phase 2: input feature exchange (2 rounds).
                let input_nodes = &mfgs[0].src_nodes;
                fetch_features(&mut scomm, shard, input_nodes, cache.as_mut(), &mut feat_buf)?;
                times.feature_s += sw.lap();

                // ---- Phase 3: padded AOT train step.
                let labels = &shard.labels;
                let padded =
                    pad_batch(variant, &mfgs, &feat_buf, |v| labels[v as usize])?;
                let dropout_seed = (epoch * batches + b) as i32;
                let out = rt.train_step(&params, &padded, dropout_seed)?;
                ensure!(out.loss.is_finite(), "loss diverged at epoch {epoch} batch {b}");
                loss_sum += out.loss as f64;
                if rank == 0 {
                    loss_curve.push(out.loss);
                }
                times.compute_s += sw.lap();

                // ---- Phase 4: gradient all-reduce + local update.
                flatten_into(&out.grads, &mut grad_buf);
                comm.all_reduce_mean_f32(RoundKind::GradSync, &mut grad_buf)?;
                let mut grads = out.grads;
                unflatten_from(&grad_buf, &mut grads);
                opt.step(&mut params, &grads)?;
                times.sync_s += sw.lap();

                // ---- Optional accuracy on the final batch of the epoch.
                if cfg.eval_last_batch && b == batches - 1 {
                    let ev = rt.eval_step(&params, &padded)?;
                    batch_acc =
                        Some(accuracy(&ev.logits, &padded.labels, &padded.label_mask)?);
                }
            }

            // Fenced like the epoch start, so the delta stays exact even
            // if a future step charges bytes right after the epoch loop.
            let comm_end = comm.fenced_snapshot()?;
            let mut sw_end = epoch_sw;
            let wall_s = sw_end.lap();
            smoothed_loss = Some((loss_sum / batches as f64) as f32);
            let comm_delta = comm_before.map(|before| comm_end.diff(&before));
            let stats = EpochStats {
                epoch,
                batches,
                mean_loss: (loss_sum / batches as f64) as f32,
                times,
                wall_s,
                comm: comm_delta,
                batch_acc,
            };
            if cfg.verbose && rank == 0 {
                eprintln!(
                    "[epoch {epoch}] loss {:.4} wall {:.2}s sample {:.2}s feat {:.2}s compute {:.2}s sync {:.2}s acc {:?}",
                    stats.mean_loss,
                    stats.wall_s,
                    stats.times.sample_s,
                    stats.times.feature_s,
                    stats.times.compute_s,
                    stats.times.sync_s,
                    stats.batch_acc
                );
            }
            epochs.push(stats);

            // Checkpoint at the fence just taken (both planes quiescent;
            // `comm_end` is the exact cumulative snapshot). Purely local
            // I/O — no collectives, so cadence conditions stay uniform
            // by construction (they read only uniform config).
            if let Some(dir) = &cfg.checkpoint_dir {
                if (epoch + 1) % cfg.checkpoint_every.max(1) == 0 {
                    let state = CheckpointState {
                        epochs_done: (epoch + 1) as u64,
                        smoothed_loss,
                        curve: loss_curve.clone(),
                        comm: comm_end,
                        epoch_deltas: Vec::new(),
                        params: params.clone(),
                        opt: Some(opt.state()),
                        cache_rows: view.cached_entries(),
                        steps: 0,
                        sampled_edges: 0,
                    };
                    checkpoint::write_checkpoint(dir, &fp, rank, &state)?;
                }
            }
        }
    }

    Ok(WorkerResult { epochs, loss_curve })
}

/// Combine the trainer-side and sampler-side results of a pipelined run,
/// preferring the **root cause** over cascade fallout: a trainer error
/// that is just "the sampler's channel closed" (or the PeerLost wake
/// that a sampler-side failure triggers on the gradient plane via the
/// shared endpoint) defers to the sampler's typed error.
fn merge_pipeline_outcome(trainer: Result<()>, sampler: Result<(), CommError>) -> Result<()> {
    match (trainer, sampler) {
        (Ok(()), Ok(())) => Ok(()),
        (Ok(()), Err(se)) => Err(anyhow::Error::new(se).context("sampler thread")),
        (Err(te), Ok(())) => Err(te),
        (Err(te), Err(se)) => {
            let cascade = te.to_string().contains("sampler thread stopped early")
                || matches!(te.downcast_ref::<CommError>(), Some(CommError::PeerLost { .. }));
            if cascade {
                Err(anyhow::Error::new(se).context("sampler thread"))
            } else {
                Err(te)
            }
        }
    }
}

/// Concatenate grad tensors into one flat buffer (reused across steps).
fn flatten_into(grads: &[HostTensor], buf: &mut Vec<f32>) {
    buf.clear();
    for g in grads {
        buf.extend_from_slice(g.as_f32().expect("grads are f32"));
    }
}

/// Scatter the flat (all-reduced) buffer back into the grad tensors.
fn unflatten_from(buf: &[f32], grads: &mut [HostTensor]) {
    let mut off = 0;
    for g in grads {
        if let HostTensor::F32 { data, .. } = g {
            let n = data.len();
            data.copy_from_slice(&buf[off..off + n]);
            off += n;
        }
    }
    debug_assert_eq!(off, buf.len());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flatten_unflatten_round_trip() {
        let grads = vec![
            HostTensor::f32(vec![1.0, 2.0], &[2]),
            HostTensor::f32(vec![3.0], &[1]),
        ];
        let mut buf = Vec::new();
        flatten_into(&grads, &mut buf);
        assert_eq!(buf, vec![1.0, 2.0, 3.0]);
        let mut back = vec![
            HostTensor::f32(vec![0.0, 0.0], &[2]),
            HostTensor::f32(vec![0.0], &[1]),
        ];
        unflatten_from(&buf, &mut back);
        assert_eq!(back, grads);
    }

    #[test]
    fn mode_names_map_to_policy_points() {
        let v = TrainConfig::mode("x", "vanilla", 4).unwrap();
        assert_eq!((v.policy, v.kernel), (ReplicationPolicy::vanilla(), KernelKind::Baseline));
        let h = TrainConfig::mode("x", "hybrid", 4).unwrap();
        assert_eq!((h.policy, h.kernel), (ReplicationPolicy::hybrid(), KernelKind::Baseline));
        let hf = TrainConfig::mode("x", "hybrid+fused", 4).unwrap();
        assert_eq!((hf.policy, hf.kernel), (ReplicationPolicy::hybrid(), KernelKind::Fused));
        let b = TrainConfig::mode("x", "budget:64k", 4).unwrap();
        assert_eq!(
            (b.policy, b.kernel),
            (ReplicationPolicy::budgeted(64 * 1024), KernelKind::Baseline)
        );
        let bf = TrainConfig::mode("x", "budget:0+fused", 4).unwrap();
        assert_eq!((bf.policy, bf.kernel), (ReplicationPolicy::vanilla(), KernelKind::Fused));
        let h1 = TrainConfig::mode("x", "halo:1", 4).unwrap();
        assert_eq!(h1.policy, ReplicationPolicy::halo(1));
        let inf = TrainConfig::mode("x", "budget:inf", 4).unwrap();
        assert_eq!(inf.policy, ReplicationPolicy::hybrid());
        assert!(TrainConfig::mode("x", "nope", 4).is_err());
        assert!(TrainConfig::mode("x", "halo:x", 4).is_err());
    }

    #[test]
    fn mode_cache_suffix_sets_the_adjacency_cache() {
        let plain = TrainConfig::mode("x", "vanilla", 4).unwrap();
        assert_eq!(plain.adj_cache_bytes, 0);
        let c = TrainConfig::mode("x", "vanilla+cache:32k", 4).unwrap();
        assert_eq!(c.adj_cache_bytes, 32 << 10);
        assert_eq!(c.kernel, KernelKind::Baseline);
        // Options compose in either order, with +fused.
        let bcf = TrainConfig::mode("x", "budget:64k+cache:8k+fused", 4).unwrap();
        assert_eq!(bcf.policy, ReplicationPolicy::budgeted(64 * 1024));
        assert_eq!(bcf.adj_cache_bytes, 8 << 10);
        assert_eq!(bcf.kernel, KernelKind::Fused);
        let bfc = TrainConfig::mode("x", "budget:64k+fused+cache:8k", 4).unwrap();
        assert_eq!((bfc.adj_cache_bytes, bfc.kernel), (8 << 10, KernelKind::Fused));
        // An unbounded cache spec maps to an effectively infinite budget.
        let inf = TrainConfig::mode("x", "vanilla+cache:inf", 4).unwrap();
        assert!(inf.adj_cache_bytes > 1 << 40);
        assert!(TrainConfig::mode("x", "vanilla+turbo", 4).is_err());
        assert!(TrainConfig::mode("x", "vanilla+cache:lots", 4).is_err());
    }

    #[test]
    fn mode_tcp_suffix_selects_the_socket_transport() {
        let plain = TrainConfig::mode("x", "vanilla", 4).unwrap();
        assert_eq!(plain.transport, TransportConfig::Inproc);
        let t = TrainConfig::mode("x", "vanilla+tcp", 4).unwrap();
        assert_eq!(t.transport, TransportConfig::Tcp { base_port: 0 });
        // Composes with the other options in any order.
        let all = TrainConfig::mode("x", "budget:64k+tcp+cache:8k+fused", 4).unwrap();
        assert_eq!(all.transport, TransportConfig::Tcp { base_port: 0 });
        assert_eq!(all.kernel, KernelKind::Fused);
        assert_eq!(all.adj_cache_bytes, 8 << 10);
    }

    #[test]
    fn mode_wire_suffix_selects_the_sampling_encoding() {
        // Bulk is the default; `wire:` overrides either way.
        let plain = TrainConfig::mode("x", "vanilla", 4).unwrap();
        assert_eq!(plain.sampling_wire, SamplingWire::Bulk);
        let s = TrainConfig::mode("x", "vanilla+wire:scalar", 4).unwrap();
        assert_eq!(s.sampling_wire, SamplingWire::Scalar);
        let b = TrainConfig::mode("x", "budget:64k+wire:bulk", 4).unwrap();
        assert_eq!(b.sampling_wire, SamplingWire::Bulk);
        // Composes with the other options in any order.
        let all = TrainConfig::mode("x", "budget:64k+wire:scalar+cache:8k+fused", 4).unwrap();
        assert_eq!(all.sampling_wire, SamplingWire::Scalar);
        assert_eq!(all.kernel, KernelKind::Fused);
        assert_eq!(all.adj_cache_bytes, 8 << 10);
        assert!(TrainConfig::mode("x", "vanilla+wire:columnar", 4).is_err());
    }

    #[test]
    fn mode_pipe_suffix_enables_the_prefetcher() {
        let plain = TrainConfig::mode("x", "vanilla", 4).unwrap();
        assert!(!plain.pipeline);
        let p = TrainConfig::mode("x", "vanilla+pipe", 4).unwrap();
        assert!(p.pipeline);
        // Composes with the other options in any order.
        let all =
            TrainConfig::mode("x", "budget:64k+pipe+cache:8k+fused+wire:scalar", 4).unwrap();
        assert!(all.pipeline);
        assert_eq!(all.kernel, KernelKind::Fused);
        assert_eq!(all.adj_cache_bytes, 8 << 10);
        assert_eq!(all.sampling_wire, SamplingWire::Scalar);
        assert!(TrainConfig::mode("x", "vanilla+pipe:2", 4).is_err());
    }
}
