//! Training stack: MFG padding, optimizers, metrics, the distributed
//! trainer that drives sampling → feature exchange → AOT compute → grad
//! sync per minibatch, and the MFG prefetcher that overlaps the first
//! two phases with the last two (`--pipeline on`).

pub mod metrics;
pub mod optimizer;
pub mod padding;
pub mod prefetch;
pub mod trainer;

pub use metrics::{accuracy, EpochStats, PhaseTimes, Stopwatch};
pub use optimizer::{Adam, Optimizer, Sgd};
pub use padding::pad_batch;
pub use trainer::{
    sample_rank, train_distributed, train_rank, AggEpoch, RankTrainReport, SampleRankReport,
    ScheduleKind, TrainConfig, TrainReport,
};
