//! Training stack: MFG padding, optimizers, metrics, and the distributed
//! trainer that drives sampling → feature exchange → AOT compute → grad
//! sync per minibatch.

pub mod metrics;
pub mod optimizer;
pub mod padding;
pub mod trainer;

pub use metrics::{accuracy, EpochStats, PhaseTimes, Stopwatch};
pub use optimizer::{Adam, Optimizer, Sgd};
pub use padding::pad_batch;
pub use trainer::{
    sample_rank, train_distributed, train_rank, AggEpoch, RankTrainReport, SampleRankReport,
    ScheduleKind, TrainConfig, TrainReport,
};
