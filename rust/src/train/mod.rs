//! Training stack: MFG padding, optimizers, metrics, the distributed
//! trainer that drives sampling → feature exchange → AOT compute → grad
//! sync per minibatch, the MFG prefetcher that overlaps the first two
//! phases with the last two (`--pipeline on`), the fenced
//! checkpoint/resume subsystem (`--checkpoint-dir` / `--resume`), and
//! the resident serve loop (`--task serve`) that answers embedding
//! queries over the same collectives after training.

pub mod checkpoint;
pub mod metrics;
pub mod optimizer;
pub mod padding;
pub mod prefetch;
pub mod serve;
pub mod trainer;

pub use checkpoint::{
    load_checkpoint, resume_latest, write_checkpoint, CheckpointError, CheckpointState,
    Fingerprint,
};
pub use metrics::{accuracy, EpochStats, PhaseTimes, Stopwatch};
pub use optimizer::{Adam, Optimizer, OptimizerState, Sgd};
pub use padding::pad_batch;
pub use serve::{
    propagate_mean, serve_key, serve_query_batch, serve_rank, ServeAnswer, ServeConfig,
    ServeReport, FRONTEND_RANK,
};
pub use trainer::{
    sample_rank, train_distributed, train_rank, AggEpoch, RankTrainReport, SampleRankReport,
    ScheduleKind, TrainConfig, TrainReport,
};
