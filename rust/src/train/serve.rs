//! Serve mode: after training (or a checkpoint restore) the ranks stay
//! resident and answer embedding/classification queries for arbitrary
//! node ids — the online-inference leg of the north star.
//!
//! Division of labor per query batch:
//!
//! * **Rank 0 (the frontend, [`FRONTEND_RANK`])** owns the client
//!   listener ([`crate::dist::serve::Frontend`]): it coalesces
//!   concurrent requests into one batch (bounded by `--serve-max-batch`
//!   nodes and a `--serve-max-wait-ms` window), validates node ids
//!   *before* any collective, and dedups the batch.
//! * **Every rank** then runs the same lockstep sequence: a continue/stop
//!   vote (`all_zero_u64`, the frontend is the only rank voting
//!   "continue"), a batch broadcast on the Sampling plane's
//!   `SampleRequest` round, cooperative L-hop sampling + feature fetch
//!   ([`serve_query_batch`] — the exact `sample_mfgs_distributed_wire` /
//!   `fetch_features` path training uses), and a uniform answer
//!   computation. Inputs are identical on every rank, so answers are
//!   bit-identical everywhere; only the frontend splits rows back per
//!   request and replies.
//!
//! **Determinism contract.** Sampling streams are keyed per *node*
//! ([`serve_key`] folds a serve-specific constant over the run seed;
//! `sample_node` then streams on the node id), so the tree sampled for
//! node v is independent of which other nodes share its batch. That is
//! what makes coalescing sound: a coalesced batch answers every request
//! bit-identically to one-at-a-time queries, and both match the
//! single-machine pipeline (`sample_mfgs`) under the same key — pinned
//! by `tests/serve_equivalence.rs` across the wire × transport × policy
//! grid.
//!
//! **Failure contract.** Any fabric error breaks the loop on every rank
//! (typed `CommError`, never a hang); the frontend then answers every
//! in-flight and queued request with a typed `PeerLost`/`Internal`
//! reply before returning the error. The contract holds even with no
//! client traffic: after [`ServeConfig::idle_heartbeat`] without a
//! request the frontend runs an empty liveness round (vote + empty
//! broadcast, no sampling), so a rank that dies while the mesh is idle
//! is detected within one interval instead of whenever the next query
//! happens to arrive. A clean stop (client `Shutdown` request, or a
//! `max_batches` cap) drains the queue with typed `ShuttingDown`
//! replies.

use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::dist::serve::{AddrSlot, Frontend, LatencyHistogram, Pending, ServeErrorKind, ServeReply};
use crate::dist::{
    fetch_features, sample_mfgs_distributed_wire, Comm, CommError, Plane, RoundKind, SamplingWire,
};
use crate::graph::{Dataset, NodeId};
use crate::partition::{build_shard, partition_graph, PartitionConfig, TopologyView, WorkerShard};
use crate::runtime::{Engine, HostTensor, Manifest, ModelRuntime};
use crate::sampling::rng::RngKey;
use crate::sampling::{KernelKind, Mfg, SamplerWorkspace};

use super::checkpoint::{self, Fingerprint};
use super::padding::pad_batch;
use super::trainer::{check_variant, TrainConfig};

/// The rank that owns the client listener. Every rank reads this slot of
/// the batch-broadcast round.
pub const FRONTEND_RANK: usize = 0;

/// The serve-session sampling key: a serve-specific fold over the run
/// seed. Fixed for the whole session — *not* folded per batch — so each
/// node's sampling stream depends only on (seed, level, node id) and a
/// node's sampled tree is the same in every batch it appears in. The
/// single-machine reference (`fastsample query --reference`) uses the
/// same key, which is what makes served answers diffable against it.
pub fn serve_key(seed: u64) -> RngKey {
    RngKey::new(seed).fold(0x5E12E5)
}

/// What a query answer contains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeAnswer {
    /// Deterministic L-hop mean feature propagation ([`propagate_mean`])
    /// — artifact-free, so serve mode (like `--task sample`) runs
    /// anywhere; the tier-1 equivalence grid pins this mode.
    Features,
    /// The trained model's seed logits (`eval_step` on the checkpointed
    /// parameters) — needs AOT artifacts, batches are capped at the
    /// variant's seed count.
    Logits,
}

impl ServeAnswer {
    /// Parse a `--serve-answer` value.
    pub fn parse(name: &str) -> Result<ServeAnswer> {
        match name {
            "features" => Ok(ServeAnswer::Features),
            "logits" => Ok(ServeAnswer::Logits),
            other => bail!("unknown serve answer {other:?} (features | logits)"),
        }
    }
}

/// Configuration of one serve session (uniform across ranks, like
/// [`TrainConfig`] — only [`ServeConfig::max_batches`] may legitimately
/// differ, and then only in fault tests simulating a kill).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Client listener port on the frontend (0 ⇒ ephemeral; published
    /// through [`ServeConfig::ready`] when set).
    pub port: u16,
    /// Admission-control bound: requests admitted but not yet answered.
    /// Beyond it clients get a typed `Overloaded` reply immediately.
    pub max_inflight: usize,
    /// Coalescing cap: target node ids per collective query batch.
    pub max_batch: usize,
    /// Coalescing window: how long the frontend waits for more requests
    /// after the first one before closing the batch.
    pub max_wait: Duration,
    /// Liveness cadence while idle: with no client traffic for this
    /// long, the frontend runs an empty heartbeat round (vote + empty
    /// broadcast, no sampling) so a dead peer surfaces as a typed
    /// `CommError` within one interval instead of hanging the mesh
    /// until the next query.
    pub idle_heartbeat: Duration,
    /// Sampling fanouts per level, as in `--task sample`.
    pub fanouts: Vec<usize>,
    /// What the answer rows are.
    pub answer: ServeAnswer,
    /// Where the frontend publishes its bound address (tests, port 0).
    pub ready: Option<Arc<AddrSlot>>,
    /// Stop after serving this many batches. `None` for a real server.
    /// Tests hand a non-frontend rank a smaller cap than its peers to
    /// simulate a mid-query kill (the survivors' next collective then
    /// surfaces a typed `CommError`).
    pub max_batches: Option<usize>,
    /// Which task's checkpoints `--resume` loads: `"sample"` restores
    /// the adjacency-cache resident set, `"train"` additionally restores
    /// model parameters (the Logits answer mode).
    pub ckpt_task: String,
    /// The batch size the checkpointing `--task sample` run used (part
    /// of its fingerprint); ignored for `ckpt_task == "train"`.
    pub ckpt_batch: usize,
}

impl ServeConfig {
    /// Defaults: ephemeral port, 4 in-flight batches, 64-node batches,
    /// 2 ms coalescing window, 250 ms idle heartbeat, feature answers,
    /// sample-task checkpoints.
    pub fn new(fanouts: Vec<usize>) -> ServeConfig {
        ServeConfig {
            port: 0,
            max_inflight: 4,
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            idle_heartbeat: Duration::from_millis(250),
            fanouts,
            answer: ServeAnswer::Features,
            ready: None,
            max_batches: None,
            ckpt_task: "sample".to_string(),
            ckpt_batch: 8,
        }
    }
}

/// What one rank reports after a serve session. `requests`, `rejected`,
/// and `latency` are frontend-side quantities (zero/empty elsewhere);
/// `batches` counts collective query rounds and is identical on every
/// rank that ran to completion.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub batches: usize,
    pub requests: u64,
    pub rejected: u64,
    pub latency: LatencyHistogram,
}

impl ServeReport {
    /// The one-line report the worker prints (CI greps `p50=`).
    pub fn summary_line(&self) -> String {
        format!(
            "serve report: batches={} requests={} rejected={} {}",
            self.batches,
            self.requests,
            self.rejected,
            self.latency.summary()
        )
    }
}

/// Deterministic L-hop mean propagation over sampled MFGs: per level,
/// `next[i] = (h[i] + Σ h[p] for p in neighbors(i)) / (1 + degree(i))`,
/// summed in compacted-index order (self row first). `feats` is the
/// row-major feature matrix of `mfgs[0].src_nodes`; the result holds one
/// row per destination of the top level, i.e. per query node, in batch
/// order. Bit-deterministic: the summation order is fixed by the MFG,
/// and the MFG is bit-identical across wires, transports, and budgets.
pub fn propagate_mean(mfgs: &[Mfg], feats: &[f32], dim: usize) -> Vec<f32> {
    let mut h = feats.to_vec();
    for m in mfgs {
        let mut next = vec![0.0f32; m.n_dst * dim];
        for i in 0..m.n_dst {
            let row = &mut next[i * dim..(i + 1) * dim];
            // Destination i is source i (the dst-prefix convention).
            row.copy_from_slice(&h[i * dim..(i + 1) * dim]);
            for &p in m.neighbors(i) {
                let src = &h[p as usize * dim..(p as usize + 1) * dim];
                for (acc, x) in row.iter_mut().zip(src) {
                    *acc += *x;
                }
            }
            let inv = 1.0 / (1 + m.degree(i)) as f32;
            for acc in row.iter_mut() {
                *acc *= inv;
            }
        }
        h = next;
    }
    h
}

/// One cooperative query round: distributed L-hop sampling of `batch`
/// (every rank passes the same batch and key) followed by the feature
/// fetch for the level-0 frontier into `feats`. Collective — every rank
/// must call it in lockstep with identical arguments; the returned MFGs
/// and features are bit-identical on every rank.
#[allow(clippy::too_many_arguments)]
pub fn serve_query_batch(
    comm: &mut Comm,
    shard: &WorkerShard,
    view: &mut TopologyView,
    batch: &[NodeId],
    fanouts: &[usize],
    key: RngKey,
    ws: &mut SamplerWorkspace,
    kernel: KernelKind,
    wire: SamplingWire,
    feats: &mut Vec<f32>,
) -> Result<Vec<Mfg>, CommError> {
    let mfgs = sample_mfgs_distributed_wire(comm, shard, view, batch, fanouts, key, ws, kernel, wire)?;
    fetch_features(comm, shard, &mfgs[0].src_nodes, None, feats)?;
    Ok(mfgs)
}

/// The answer engine: what turns a sampled batch into reply rows.
enum Answerer {
    Features,
    Logits {
        // The engine must outlive the loaded executables.
        _engine: Engine,
        rt: Box<ModelRuntime>,
        params: Vec<HostTensor>,
    },
}

/// Uniform answer computation: identical (mfgs, feats) on every rank in,
/// identical rows out — `n` rows of `dim` values, batch order. Failures
/// (padding caps, engine errors) are deterministic functions of the same
/// inputs, so every rank takes the same branch and the mesh stays in
/// lockstep; the frontend turns the message into typed error replies.
fn compute_answer(
    answerer: &Answerer,
    mfgs: &[Mfg],
    feats: &[f32],
    n: usize,
    feat_dim: usize,
) -> Result<Vec<f32>, String> {
    match answerer {
        Answerer::Features => Ok(propagate_mean(mfgs, feats, feat_dim)),
        Answerer::Logits { rt, params, .. } => {
            let padded = pad_batch(&rt.variant, mfgs, feats, |_| 0).map_err(|e| e.to_string())?;
            let out = rt.eval_step(params, &padded).map_err(|e| e.to_string())?;
            let logits = out.logits.as_f32().map_err(|e| e.to_string())?;
            Ok(logits[..n * rt.variant.classes].to_vec())
        }
    }
}

/// Reject a request before it costs the mesh anything: out-of-range node
/// ids always, oversized requests when the answer mode caps a batch.
fn validate_request(
    p: &Pending,
    num_nodes: usize,
    req_cap: Option<usize>,
) -> Result<(), (ServeErrorKind, String)> {
    if let Some(cap) = req_cap {
        if p.nodes.len() > cap {
            return Err((
                ServeErrorKind::BadRequest,
                format!("request has {} nodes; the model variant caps a batch at {cap}", p.nodes.len()),
            ));
        }
    }
    if let Some(&bad) = p.nodes.iter().find(|&&v| (v as usize) >= num_nodes) {
        return Err((
            ServeErrorKind::BadRequest,
            format!("node id {bad} out of range (graph has {num_nodes} nodes)"),
        ));
    }
    Ok(())
}

fn error_kind(e: &CommError) -> ServeErrorKind {
    match e {
        CommError::PeerLost { .. } => ServeErrorKind::PeerLost,
        _ => ServeErrorKind::Internal,
    }
}

/// Run one rank of a serve session until a client shutdown request, a
/// `max_batches` cap, or a fabric error. SPMD-collective: every rank
/// must call it with uniform `cfg`/`scfg` (see [`ServeConfig`] for the
/// one sanctioned exception). Returns this rank's [`ServeReport`]; a
/// fabric failure returns the typed error *after* the frontend has
/// answered every in-flight client.
#[allow(clippy::too_many_arguments)]
pub fn serve_rank(
    dataset: &Dataset,
    artifacts_dir: &Path,
    cfg: &TrainConfig,
    scfg: &ServeConfig,
    rank: usize,
    comm: &mut Comm,
) -> Result<ServeReport> {
    ensure!(!scfg.fanouts.is_empty(), "need at least one fanout level");
    ensure!(scfg.max_batch >= 1, "serve max-batch must be >= 1");
    ensure!(comm.rank() == rank, "comm endpoint is rank {}, not {rank}", comm.rank());
    ensure!(
        comm.world() == cfg.workers,
        "fabric has {} ranks, config says {} workers",
        comm.world(),
        cfg.workers
    );

    let book = Arc::new(partition_graph(
        &dataset.graph,
        &dataset.train_ids,
        &PartitionConfig::new(cfg.workers),
    ));
    let shard = build_shard(dataset, &book, &cfg.policy, rank);
    let mut view = shard.topology.clone();
    if cfg.adj_cache_bytes > 0 && !shard.policy.is_full() {
        view.enable_cache(cfg.adj_cache_bytes, cfg.adj_cache_policy);
    }
    let mut ws = SamplerWorkspace::new();
    let key = serve_key(cfg.seed);
    let num_nodes = dataset.num_nodes();

    // The answer engine. Features mode is artifact-free; Logits compiles
    // the variant's eval executable and starts from Xavier weights until
    // a train-task checkpoint restore below replaces them.
    let mut answerer = match scfg.answer {
        ServeAnswer::Features => Answerer::Features,
        ServeAnswer::Logits => {
            let manifest = Manifest::load(artifacts_dir)?;
            check_variant(&manifest, dataset, cfg)?;
            let engine = Engine::cpu()?;
            let rt = ModelRuntime::load(&engine, &manifest, &cfg.variant)?;
            ensure!(
                scfg.fanouts.len() == rt.variant.layers(),
                "serve fanouts have {} levels, variant {} has {}",
                scfg.fanouts.len(),
                cfg.variant,
                rt.variant.layers()
            );
            let params = rt.init_params(cfg.seed);
            Answerer::Logits { _engine: engine, rt: Box::new(rt), params }
        }
    };
    let (dim, req_cap) = match &answerer {
        Answerer::Features => (shard.feat_dim, None),
        Answerer::Logits { rt, .. } => (rt.variant.classes, Some(rt.variant.batch)),
    };
    let max_batch = match req_cap {
        Some(cap) => scfg.max_batch.min(cap),
        None => scfg.max_batch,
    };

    // Warm start from a checkpoint: `resume_latest` is a collective
    // guarded only by uniform config. The sample-task fingerprint
    // restores the adjacency-cache resident set (serial *and* pipelined
    // checkpoints carry it — see the EpochEnd handoff in prefetch);
    // the train-task fingerprint additionally restores parameters.
    if cfg.resume {
        if let Some(dir) = &cfg.checkpoint_dir {
            let fp = match scfg.ckpt_task.as_str() {
                "sample" => Fingerprint::new(
                    "sample",
                    &dataset.name,
                    cfg,
                    Some((scfg.ckpt_batch, &scfg.fanouts)),
                ),
                "train" => Fingerprint::new("train", &dataset.name, cfg, None),
                other => bail!("unknown serve checkpoint task {other:?} (sample | train)"),
            };
            if let Some(state) = checkpoint::resume_latest(comm, dir, &fp)? {
                for (v, row) in &state.cache_rows {
                    view.cache_insert(*v, row);
                }
                if let Answerer::Logits { params, .. } = &mut answerer {
                    if !state.params.is_empty() {
                        ensure!(
                            state.params.len() == params.len()
                                && state.params.iter().zip(params.iter()).all(|(a, b)| a.shape() == b.shape()),
                            "checkpoint parameter shapes do not match variant {}",
                            cfg.variant
                        );
                        *params = state.params;
                    }
                }
            }
        }
    }

    // The frontend lives on rank 0 only; no collective happens inside
    // this block (the lint-visible contract: collectives below are
    // reached by every rank unconditionally).
    let mut frontend = match rank {
        FRONTEND_RANK => {
            let f = Frontend::bind(scfg.port, scfg.max_inflight)
                .with_context(|| format!("binding serve listener on port {}", scfg.port))?;
            if let Some(slot) = &scfg.ready {
                slot.publish(f.local_addr());
            }
            if cfg.verbose {
                eprintln!("[serve] rank {rank} listening on {}", f.local_addr());
            }
            Some(f)
        }
        _ => None,
    };

    // Query traffic rides the Sampling plane (the plane split training
    // established); the continue/stop vote stays on the base handle.
    let mut scomm = comm.plane(Plane::Sampling);
    let world = comm.world();
    let mut report = ServeReport::default();
    let mut inflight: Vec<Pending> = Vec::new();
    let mut feats: Vec<f32> = Vec::new();
    let mut stopping = false;

    let outcome: Result<(), CommError> = loop {
        // Batch-count seam: a capped frontend votes stop; a capped
        // non-frontend rank leaves unilaterally (the fault tests'
        // simulated kill — survivors get a typed error from their next
        // collective, never a hang).
        if let Some(cap) = scfg.max_batches {
            if report.batches >= cap {
                if frontend.is_some() {
                    stopping = true;
                } else {
                    break Ok(());
                }
            }
        }

        // Frontend: gather a batch worth serving (every request is
        // validated and possibly rejected *before* the mesh is asked to
        // do anything), then dedup node ids preserving first-occurrence
        // order — replies re-expand rows per request. The gather is
        // bounded by the idle heartbeat: no traffic for that long
        // yields an empty batch, which still runs the vote and the
        // broadcast below as a liveness round.
        let mut batch: Vec<NodeId> = Vec::new();
        if let Some(f) = frontend.as_mut() {
            if !stopping && inflight.is_empty() {
                let mut gathered = f.next_batch(max_batch, scfg.max_wait, scfg.idle_heartbeat);
                stopping |= gathered.shutdown;
                for p in gathered.pending.drain(..) {
                    match validate_request(&p, num_nodes, req_cap) {
                        Ok(()) => inflight.push(p),
                        Err((kind, detail)) => {
                            let _ = p.reply.send(ServeReply::error(p.id, kind, detail));
                        }
                    }
                }
            }
            let mut seen: HashSet<NodeId> = HashSet::new();
            for p in &inflight {
                for &v in &p.nodes {
                    if seen.insert(v) {
                        batch.push(v);
                    }
                }
            }
        }

        // Continue/stop vote (uncharged control round): only the
        // frontend ever votes "continue" — with a real batch or as an
        // idle heartbeat — so all-zero means stop for all, and a rank
        // that died while the mesh was idle fails this vote (or the
        // broadcast below) within one heartbeat, typed, never a hang.
        let go = match &frontend {
            Some(_) => u64::from(!stopping || !batch.is_empty()),
            None => 0,
        };
        match comm.all_zero_u64(go) {
            Ok(true) => break Ok(()),
            Ok(false) => {}
            Err(e) => break Err(e),
        }

        // Batch broadcast on the Sampling plane: the frontend fills every
        // slot (its own passes through), other ranks send empties, and
        // every rank reads the frontend's slot.
        let outbox: Vec<Vec<NodeId>> = if batch.is_empty() {
            vec![Vec::new(); world]
        } else {
            vec![batch.clone(); world]
        };
        let batch = match scomm.exchange(RoundKind::SampleRequest, outbox) {
            Ok(mut got) => std::mem::take(&mut got[FRONTEND_RANK]),
            Err(e) => break Err(e),
        };

        // Heartbeat round: the broadcast batch is empty on every rank
        // (uniform — it is the frontend's slot), liveness is proven,
        // nothing to sample or answer.
        if batch.is_empty() {
            continue;
        }

        // Cooperative sampling + feature fetch, then a uniform answer.
        let mfgs = match serve_query_batch(
            &mut scomm,
            &shard,
            &mut view,
            &batch,
            &scfg.fanouts,
            key,
            &mut ws,
            cfg.kernel,
            cfg.sampling_wire,
            &mut feats,
        ) {
            Ok(m) => m,
            Err(e) => break Err(e),
        };
        report.batches += 1;
        let answer = compute_answer(&answerer, &mfgs, &feats, batch.len(), shard.feat_dim);

        // Split rows back per request and reply (frontend only — other
        // ranks have no in-flight requests, so this is a no-op there).
        match answer {
            Ok(rows) => {
                let index: HashMap<NodeId, usize> =
                    batch.iter().enumerate().map(|(i, &v)| (v, i)).collect();
                for p in inflight.drain(..) {
                    let mut out = Vec::with_capacity(p.nodes.len() * dim);
                    let mut complete = true;
                    for v in &p.nodes {
                        match index.get(v) {
                            Some(&i) => out.extend_from_slice(&rows[i * dim..(i + 1) * dim]),
                            None => {
                                complete = false;
                                break;
                            }
                        }
                    }
                    let reply = if complete {
                        ServeReply::ok(p.id, dim, out)
                    } else {
                        ServeReply::error(
                            p.id,
                            ServeErrorKind::Internal,
                            "answer row missing from batch",
                        )
                    };
                    let _ = p.reply.send(reply);
                    report.latency.record_duration(p.arrived.elapsed());
                    report.requests += 1;
                }
            }
            Err(detail) => {
                for p in inflight.drain(..) {
                    let _ = p.reply.send(ServeReply::error(p.id, ServeErrorKind::Internal, detail.clone()));
                    report.latency.record_duration(p.arrived.elapsed());
                    report.requests += 1;
                }
            }
        }
    };

    // Teardown: every still-unanswered client gets a typed reply — a
    // fabric failure maps to PeerLost/Internal, a clean stop to
    // ShuttingDown — then the listener closes.
    if let Some(f) = frontend.as_mut() {
        match &outcome {
            Err(e) => f.fail_all(std::mem::take(&mut inflight), error_kind(e), &format!("mesh failure: {e}")),
            Ok(()) => f.fail_all(std::mem::take(&mut inflight), ServeErrorKind::ShuttingDown, "server stopping"),
        }
        f.stop();
        report.rejected = f.rejected();
    }
    outcome.map_err(anyhow::Error::from)?;
    Ok(report)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::config;
    use crate::sampling::sample_mfgs;

    #[test]
    fn propagate_mean_matches_hand_rolled_full_fanout_average() {
        let d = config::dataset("quickstart", 7).unwrap();
        let key = serve_key(7);
        let mut ws = SamplerWorkspace::new();
        let batch: Vec<NodeId> = vec![0, 3, 5, 3];
        // One level with a fanout above every degree: the sampled
        // neighborhood is the full neighbor list in graph order, so the
        // answer must be the plain mean over {v} ∪ N(v), summed in the
        // same order.
        let fanouts = [d.num_nodes()];
        let mfgs = sample_mfgs(&d.graph, &batch, &fanouts, key, &mut ws, KernelKind::Fused);
        let dim = d.feat_dim;
        let mut feats = Vec::new();
        for &v in &mfgs[0].src_nodes {
            feats.extend_from_slice(d.feat(v));
        }
        let got = propagate_mean(&mfgs, &feats, dim);
        assert_eq!(got.len(), batch.len() * dim);
        for (i, &v) in batch.iter().enumerate() {
            let neigh = d.graph.neighbors(v);
            let mut want = d.feat(v).to_vec();
            for &u in neigh {
                for (acc, x) in want.iter_mut().zip(d.feat(u)) {
                    *acc += *x;
                }
            }
            let inv = 1.0 / (1 + neigh.len()) as f32;
            for acc in want.iter_mut() {
                *acc *= inv;
            }
            let got_bits: Vec<u32> = got[i * dim..(i + 1) * dim].iter().map(|x| x.to_bits()).collect();
            let want_bits: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
            assert_eq!(got_bits, want_bits, "node {v}");
        }
        // The duplicate query node answers identically per occurrence.
        assert_eq!(
            got[dim..2 * dim].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            got[3 * dim..4 * dim].iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn serve_key_is_stable_and_distinct_from_task_keys() {
        // The constant is load-bearing: the CLI reference path and the
        // serving ranks must derive the same key from the same seed.
        assert_eq!(serve_key(11), RngKey::new(11).fold(0x5E12E5));
        assert_ne!(serve_key(11), RngKey::new(11).fold(0xD16E57));
        assert_ne!(serve_key(11), serve_key(12));
    }
}
