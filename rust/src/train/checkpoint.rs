//! Deterministic checkpoint/resume: fenced epoch snapshots that make a
//! kill recoverable.
//!
//! At each epoch fence (`Comm::fenced_snapshot`, both planes quiescent)
//! every rank **atomically** writes a per-rank checkpoint: flattened
//! model parameters, the full optimizer state
//! ([`super::optimizer::OptimizerState`]), the positional RNG cursor
//! (just the epoch index — sampling/dropout keys are derived as
//! `key.fold(epoch).fold(b+1)`, so nothing else needs saving), the
//! cumulative fenced [`CommStats`], and optionally the adjacency-cache
//! resident set (rewarming erases the cold epoch; cache contents shape
//! *traffic* only, never sampled MFGs, so replaying them is curve-safe).
//!
//! Two files per rank per checkpointed epoch, both written tmp + rename:
//!
//! ```text
//! <dir>/ckpt-000002/rank0.bin    # binary state (magic "FSCK", LE)
//! <dir>/ckpt-000002/rank0.json   # manifest: fingerprint, checksum, digest
//! ```
//!
//! The manifest is renamed into place **after** the binary, so a
//! `rank<r>.json` that exists implies a complete `rank<r>.bin`; a kill
//! mid-write leaves at worst an ignored `.tmp` and an epoch directory
//! without this rank's manifest, which resume skips. The manifest
//! carries a config **fingerprint** (task/dataset/policy/cache/wire/
//! pipeline/world/seed/…), an FNV-1a checksum of the binary, and a
//! state **digest** that is identical on every rank (parameters for the
//! train task, the all-reduced digest curve for the sample task).
//!
//! [`resume_latest`] is the SPMD-collective entry point: each rank scans
//! locally for its newest complete checkpoint, the world agrees on the
//! newest epoch **every** rank has (`all_reduce_min`), each rank loads
//! and validates it (checksum, fingerprint, digest — every mismatch a
//! typed [`CheckpointError`], never a silent divergence or a panic), and
//! a final min/max reduce proves all ranks hold the same digest. Resume
//! then restarts the epoch loop at `epochs_done` and the run continues
//! bit-identically to one that was never killed (pinned by
//! `rust/tests/checkpoint_resume.rs`).

use std::fmt;
use std::path::{Path, PathBuf};

use crate::dist::{Comm, CommStats, RoundKind};
use crate::graph::NodeId;
use crate::runtime::HostTensor;

use super::optimizer::OptimizerState;
use super::trainer::TrainConfig;
use crate::util::json::Json;

/// Format magic + version of the binary file. Bump the version on any
/// layout change; old files then fail loudly instead of misparsing.
const MAGIC: &[u8; 4] = b"FSCK";
const VERSION: u32 = 1;

/// Everything that can go wrong writing, finding, or validating a
/// checkpoint. Typed so tests (and the elastic-world follow-up) can
/// distinguish "file rotted" from "operator changed the config".
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CheckpointError {
    /// Unreadable, truncated, checksum-failed, or misparsed file.
    Corrupt { path: String, detail: String },
    /// The on-disk fingerprint disagrees with this run's config —
    /// resuming would diverge silently, so it is refused. `expected` is
    /// what the checkpoint was written under, `found` this run's value.
    FingerprintMismatch { field: String, expected: String, found: String },
    /// `--resume` found checkpoints on some ranks but not others (or no
    /// epoch common to all) — a partial restore would desynchronize.
    RankDisagreement { detail: String },
    /// Ranks loaded checkpoints whose state digests differ — the files
    /// are individually valid but not from the same consistent cut.
    DigestMismatch { detail: String },
    /// Filesystem failure writing the checkpoint (tmp create / rename).
    Write { path: String, detail: String },
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Corrupt { path, detail } => {
                write!(f, "corrupt checkpoint {path}: {detail}")
            }
            CheckpointError::FingerprintMismatch { field, expected, found } => write!(
                f,
                "checkpoint fingerprint mismatch on {field:?}: checkpoint was written \
                 under {expected}, this run has {found} — resuming would diverge"
            ),
            CheckpointError::RankDisagreement { detail } => {
                write!(f, "ranks disagree on resumable checkpoints: {detail}")
            }
            CheckpointError::DigestMismatch { detail } => {
                write!(f, "checkpoint digests differ across ranks: {detail}")
            }
            CheckpointError::Write { path, detail } => {
                write!(f, "cannot write checkpoint {path}: {detail}")
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

// ---------------------------------------------------------------------------
// Config fingerprint
// ---------------------------------------------------------------------------

/// Ordered `(field, value)` rendering of every config knob a resumed run
/// must share with the checkpointing run for bit-identical continuation.
///
/// Deliberately **excluded**: `epochs` (extending a run is the point of
/// resuming; epoch *content* is positional and independent of the
/// total), the transport (inproc vs TCP is bit-identical by the
/// equivalence suites), and `verbose`/`eval_last_batch` (observation
/// only). `lr` is fingerprinted by f32 **bit pattern** — exact, no
/// formatting round-trip.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fingerprint(Vec<(String, String)>);

impl Fingerprint {
    /// Build this run's fingerprint. `task` is `"train"` or `"sample"`;
    /// `sample_shape` carries the sample task's CLI batch/fanouts (the
    /// train task gets both from the AOT variant, covered by its name).
    pub fn new(
        task: &str,
        dataset: &str,
        cfg: &TrainConfig,
        sample_shape: Option<(usize, &[usize])>,
    ) -> Self {
        let mut f = vec![
            ("task".to_string(), task.to_string()),
            ("dataset".to_string(), dataset.to_string()),
            ("world".to_string(), cfg.workers.to_string()),
            ("seed".to_string(), cfg.seed.to_string()),
            ("policy".to_string(), format!("{:?}", cfg.policy)),
            ("kernel".to_string(), format!("{:?}", cfg.kernel)),
            ("variant".to_string(), cfg.variant.clone()),
            ("optimizer".to_string(), cfg.optimizer.clone()),
            ("lr_bits".to_string(), format!("{:08x}", cfg.lr.to_bits())),
            (
                "feature_cache".to_string(),
                format!("{}:{:?}", cfg.cache_capacity, cfg.cache_policy),
            ),
            (
                "adj_cache".to_string(),
                format!("{}:{:?}", cfg.adj_cache_bytes, cfg.adj_cache_policy),
            ),
            ("wire".to_string(), format!("{:?}", cfg.sampling_wire)),
            ("pipeline".to_string(), cfg.pipeline.to_string()),
            (
                "max_batches".to_string(),
                cfg.max_batches.map_or_else(|| "none".to_string(), |c| c.to_string()),
            ),
            ("schedule".to_string(), format!("{:?}", cfg.schedule)),
        ];
        if let Some((batch, fanouts)) = sample_shape {
            f.push(("batch".to_string(), batch.to_string()));
            f.push(("fanouts".to_string(), format!("{fanouts:?}")));
        }
        Fingerprint(f)
    }

    fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        for (k, v) in &self.0 {
            m.insert(k.clone(), Json::Str(v.clone()));
        }
        Json::Obj(m)
    }

    /// Field-wise comparison against a manifest's fingerprint object.
    /// Any difference — value, missing field, extra field — is a typed
    /// [`CheckpointError::FingerprintMismatch`].
    fn check(&self, disk: &Json) -> Result<(), CheckpointError> {
        let disk = disk.as_obj().map_err(|e| CheckpointError::FingerprintMismatch {
            field: "fingerprint".into(),
            expected: format!("<not an object: {e}>"),
            found: "<object>".into(),
        })?;
        for (k, v) in &self.0 {
            let on_disk = match disk.get(k).map(Json::as_str) {
                Some(Ok(s)) => s,
                _ => {
                    return Err(CheckpointError::FingerprintMismatch {
                        field: k.clone(),
                        expected: "<absent>".into(),
                        found: v.clone(),
                    })
                }
            };
            if on_disk != v {
                return Err(CheckpointError::FingerprintMismatch {
                    field: k.clone(),
                    expected: on_disk.to_string(),
                    found: v.clone(),
                });
            }
        }
        for k in disk.keys() {
            if !self.0.iter().any(|(f, _)| f == k) {
                return Err(CheckpointError::FingerprintMismatch {
                    field: k.clone(),
                    expected: "<present>".into(),
                    found: "<absent in this build>".into(),
                });
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// State
// ---------------------------------------------------------------------------

/// One rank's full resumable state at an epoch fence.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointState {
    /// Epochs fully completed — the positional RNG cursor. A resumed run
    /// restarts its epoch loop at this index; every sampling, shuffle,
    /// and dropout key is derived from it.
    pub epochs_done: u64,
    /// Trainer's smoothed loss (feeds adaptive fanout schedules).
    pub smoothed_loss: Option<f32>,
    /// The curve so far: rank 0's per-step losses for the train task
    /// (empty on other ranks), the all-reduced digest curve (identical
    /// on every rank) for the sample task.
    pub curve: Vec<f32>,
    /// Cumulative fenced counter snapshot at the checkpoint's fence.
    pub comm: CommStats,
    /// Per-epoch fenced counter deltas so far (sample task reporting).
    pub epoch_deltas: Vec<CommStats>,
    /// Flattened model parameters (train task; empty for sample).
    pub params: Vec<HostTensor>,
    /// Full optimizer state (train task).
    pub opt: Option<OptimizerState>,
    /// Adjacency-cache resident rows in slot order. Serial runs snapshot
    /// the view directly at the fence; pipelined runs get the identical
    /// set handed back through the sampler thread's `EpochEnd` marker
    /// (the sampler owns the cache, the trainer writes the checkpoint) —
    /// the `checkpoint_resume` suite pins the two bit-equal. Correctness
    /// is unaffected either way, only warm-up traffic.
    pub cache_rows: Vec<(NodeId, Vec<NodeId>)>,
    /// Steps executed so far (sample task reporting).
    pub steps: u64,
    /// Edges sampled so far (sample task reporting).
    pub sampled_edges: u64,
}

impl CheckpointState {
    /// Rank-invariant digest of the resumable state: FNV-1a over the
    /// parameter encoding when parameters are present (the train task —
    /// every rank holds the identical copy), else over the curve's f32
    /// bit patterns (the sample task — all-reduced, identical on every
    /// rank). Resume cross-checks it across the world.
    pub fn digest(&self) -> u64 {
        let mut w = Wr(Vec::new());
        if self.params.is_empty() {
            for v in &self.curve {
                w.f32(*v);
            }
        } else {
            encode_params(&mut w, &self.params);
        }
        fnv1a64(&w.0)
    }

    fn encode(&self) -> Vec<u8> {
        let mut w = Wr(Vec::new());
        w.0.extend_from_slice(MAGIC);
        w.u32(VERSION);
        w.u64(self.epochs_done);
        match self.smoothed_loss {
            None => w.u8(0),
            Some(v) => {
                w.u8(1);
                w.f32(v);
            }
        }
        w.u64(self.curve.len() as u64);
        for v in &self.curve {
            w.f32(*v);
        }
        encode_stats(&mut w, &self.comm);
        w.u64(self.epoch_deltas.len() as u64);
        for d in &self.epoch_deltas {
            encode_stats(&mut w, d);
        }
        encode_params(&mut w, &self.params);
        match &self.opt {
            None => w.u8(0),
            Some(OptimizerState::Sgd { velocity }) => {
                w.u8(1);
                encode_f32_mat(&mut w, velocity);
            }
            Some(OptimizerState::Adam { t, m, v }) => {
                w.u8(2);
                w.u64(*t as u64);
                encode_f32_mat(&mut w, m);
                encode_f32_mat(&mut w, v);
            }
        }
        w.u64(self.cache_rows.len() as u64);
        for (node, row) in &self.cache_rows {
            w.u32(*node);
            w.u32(row.len() as u32);
            for id in row {
                w.u32(*id);
            }
        }
        w.u64(self.steps);
        w.u64(self.sampled_edges);
        w.0
    }

    fn decode(bytes: &[u8]) -> Result<Self, String> {
        let mut r = Rd { b: bytes, pos: 0 };
        let magic = r.take(4)?;
        if magic != MAGIC {
            return Err(format!("bad magic {magic:?} (want {MAGIC:?})"));
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(format!("unsupported checkpoint format {version} (want {VERSION})"));
        }
        let epochs_done = r.u64()?;
        let smoothed_loss = match r.u8()? {
            0 => None,
            1 => Some(r.f32()?),
            t => return Err(format!("bad smoothed-loss tag {t}")),
        };
        let curve = r.f32_vec()?;
        let comm = decode_stats(&mut r)?;
        let n = r.len_checked(size_of_stats())?;
        let mut epoch_deltas = Vec::with_capacity(n);
        for _ in 0..n {
            epoch_deltas.push(decode_stats(&mut r)?);
        }
        let params = decode_params(&mut r)?;
        let opt = match r.u8()? {
            0 => None,
            1 => Some(OptimizerState::Sgd { velocity: decode_f32_mat(&mut r)? }),
            2 => {
                let t = r.u64()?;
                if t > i32::MAX as u64 {
                    return Err(format!("adam step count {t} out of range"));
                }
                Some(OptimizerState::Adam {
                    t: t as i32,
                    m: decode_f32_mat(&mut r)?,
                    v: decode_f32_mat(&mut r)?,
                })
            }
            t => return Err(format!("bad optimizer tag {t}")),
        };
        let n = r.len_checked(8)?;
        let mut cache_rows = Vec::with_capacity(n);
        for _ in 0..n {
            let node = r.u32()?;
            let len = r.u32()? as usize;
            let mut row = Vec::with_capacity(r.cap(len, 4)?);
            for _ in 0..len {
                row.push(r.u32()?);
            }
            cache_rows.push((node, row));
        }
        let steps = r.u64()?;
        let sampled_edges = r.u64()?;
        r.done()?;
        Ok(CheckpointState {
            epochs_done,
            smoothed_loss,
            curve,
            comm,
            epoch_deltas,
            params,
            opt,
            cache_rows,
            steps,
            sampled_edges,
        })
    }
}

// ---------------------------------------------------------------------------
// Little-endian codec helpers
// ---------------------------------------------------------------------------

struct Wr(Vec<u8>);

impl Wr {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }
}

/// Bounds-checked reader: every take can fail (truncated file), never
/// panic; length prefixes are validated against the remaining bytes
/// before any allocation, so a corrupt prefix cannot OOM the process.
struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.b.len() - self.pos < n {
            return Err(format!(
                "truncated: wanted {n} bytes at offset {}, file has {}",
                self.pos,
                self.b.len()
            ));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, String> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
    fn u64(&mut self) -> Result<u64, String> {
        let s = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(s);
        Ok(u64::from_le_bytes(a))
    }
    fn f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_bits(self.u32()?))
    }
    /// A u64 element count, validated so `count * elem_bytes` fits in
    /// the remaining input.
    fn len_checked(&mut self, elem_bytes: usize) -> Result<usize, String> {
        let n = self.u64()?;
        self.cap(n as usize, elem_bytes)
    }
    fn cap(&self, n: usize, elem_bytes: usize) -> Result<usize, String> {
        let remaining = self.b.len() - self.pos;
        if n.checked_mul(elem_bytes).map_or(true, |bytes| bytes > remaining) {
            return Err(format!(
                "length prefix {n} (x{elem_bytes}B) exceeds the {remaining} remaining bytes"
            ));
        }
        Ok(n)
    }
    fn f32_vec(&mut self) -> Result<Vec<f32>, String> {
        let n = self.len_checked(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }
    fn done(&self) -> Result<(), String> {
        if self.pos != self.b.len() {
            return Err(format!("{} trailing bytes after the state", self.b.len() - self.pos));
        }
        Ok(())
    }
}

fn size_of_stats() -> usize {
    8 + 2 * 8 * RoundKind::COUNT
}

fn encode_stats(w: &mut Wr, s: &CommStats) {
    w.u64(RoundKind::COUNT as u64);
    for k in RoundKind::ALL {
        w.u64(s.rounds[k.index()]);
    }
    for k in RoundKind::ALL {
        w.u64(s.bytes[k.index()]);
    }
}

fn decode_stats(r: &mut Rd) -> Result<CommStats, String> {
    let n = r.u64()?;
    if n != RoundKind::COUNT as u64 {
        return Err(format!(
            "counter block has {n} kinds, this build has {} — mixed builds?",
            RoundKind::COUNT
        ));
    }
    let mut s = CommStats::default();
    for k in RoundKind::ALL {
        s.rounds[k.index()] = r.u64()?;
    }
    for k in RoundKind::ALL {
        s.bytes[k.index()] = r.u64()?;
    }
    Ok(s)
}

fn encode_params(w: &mut Wr, params: &[HostTensor]) {
    w.u64(params.len() as u64);
    for p in params {
        let shape = p.shape();
        w.u32(shape.len() as u32);
        for d in shape {
            w.u64(*d as u64);
        }
        match p.as_f32() {
            Ok(data) => {
                w.u64(data.len() as u64);
                for v in data {
                    w.f32(*v);
                }
            }
            // Parameters are f32 by construction (init_params); an i32
            // tensor here would be a bug upstream — encode it empty so
            // the digest/decode mismatch surfaces as a typed error.
            Err(_) => w.u64(0),
        }
    }
}

fn decode_params(r: &mut Rd) -> Result<Vec<HostTensor>, String> {
    let n = r.len_checked(12)?;
    let mut params = Vec::with_capacity(n);
    for _ in 0..n {
        let ndim = r.u32()? as usize;
        let mut shape = Vec::with_capacity(r.cap(ndim, 8)?);
        for _ in 0..ndim {
            shape.push(r.u64()? as usize);
        }
        let data = r.f32_vec()?;
        let elems: usize = shape.iter().product();
        if elems != data.len() {
            return Err(format!(
                "param shape {shape:?} implies {elems} values, file carries {}",
                data.len()
            ));
        }
        params.push(HostTensor::f32(data, &shape));
    }
    Ok(params)
}

fn encode_f32_mat(w: &mut Wr, m: &[Vec<f32>]) {
    w.u64(m.len() as u64);
    for row in m {
        w.u64(row.len() as u64);
        for v in row {
            w.f32(*v);
        }
    }
}

fn decode_f32_mat(r: &mut Rd) -> Result<Vec<Vec<f32>>, String> {
    let n = r.len_checked(8)?;
    let mut m = Vec::with_capacity(n);
    for _ in 0..n {
        m.push(r.f32_vec()?);
    }
    Ok(m)
}

/// FNV-1a 64-bit — the checksum and digest hash. Not cryptographic;
/// guards against bit rot and truncation, not an adversary.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// File layout
// ---------------------------------------------------------------------------

fn epoch_dir(dir: &Path, epochs_done: u64) -> PathBuf {
    dir.join(format!("ckpt-{epochs_done:06}"))
}

fn bin_path(dir: &Path, epochs_done: u64, rank: usize) -> PathBuf {
    epoch_dir(dir, epochs_done).join(format!("rank{rank}.bin"))
}

fn json_path(dir: &Path, epochs_done: u64, rank: usize) -> PathBuf {
    epoch_dir(dir, epochs_done).join(format!("rank{rank}.json"))
}

fn atomic_write(path: &Path, bytes: &[u8]) -> Result<(), CheckpointError> {
    let shown = path.display().to_string();
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, bytes)
        .map_err(|e| CheckpointError::Write { path: shown.clone(), detail: e.to_string() })?;
    std::fs::rename(&tmp, path)
        .map_err(|e| CheckpointError::Write { path: shown, detail: format!("rename: {e}") })
}

// ---------------------------------------------------------------------------
// Write / load / resume
// ---------------------------------------------------------------------------

/// Atomically write one rank's checkpoint for `state.epochs_done`
/// completed epochs. Purely local I/O (no collectives): the caller
/// invokes it right after the epoch's end fence, where every plane is
/// quiescent and the fenced `CommStats` are exact. The binary lands
/// before the manifest, so a manifest's existence implies a complete
/// checkpoint. Old epochs' checkpoints are retained (the operator
/// prunes; keeping them makes "resume from an earlier epoch" a matter
/// of deleting directories).
pub fn write_checkpoint(
    dir: &Path,
    fp: &Fingerprint,
    rank: usize,
    state: &CheckpointState,
) -> Result<(), CheckpointError> {
    let edir = epoch_dir(dir, state.epochs_done);
    std::fs::create_dir_all(&edir).map_err(|e| CheckpointError::Write {
        path: edir.display().to_string(),
        detail: e.to_string(),
    })?;
    let bin = state.encode();
    let mut m = std::collections::BTreeMap::new();
    m.insert("format".to_string(), Json::Num(VERSION as f64));
    m.insert("epoch".to_string(), Json::Num(state.epochs_done as f64));
    m.insert("rank".to_string(), Json::Num(rank as f64));
    m.insert("bin_bytes".to_string(), Json::Num(bin.len() as f64));
    m.insert("checksum".to_string(), Json::Str(format!("{:016x}", fnv1a64(&bin))));
    m.insert("digest".to_string(), Json::Str(format!("{:016x}", state.digest())));
    m.insert("fingerprint".to_string(), fp.to_json());
    let manifest = Json::Obj(m).dump();
    atomic_write(&bin_path(dir, state.epochs_done, rank), &bin)?;
    atomic_write(&json_path(dir, state.epochs_done, rank), manifest.as_bytes())
}

/// Load and fully validate one rank's checkpoint for `epochs_done`:
/// manifest parse, format/rank/epoch fields, fingerprint match,
/// checksum over the binary, state decode, and digest recomputation.
/// Every failure is a typed [`CheckpointError`].
pub fn load_checkpoint(
    dir: &Path,
    fp: &Fingerprint,
    rank: usize,
    epochs_done: u64,
) -> Result<CheckpointState, CheckpointError> {
    let jpath = json_path(dir, epochs_done, rank);
    let jshown = jpath.display().to_string();
    let corrupt = |detail: String| CheckpointError::Corrupt { path: jshown.clone(), detail };
    let text = std::fs::read_to_string(&jpath).map_err(|e| corrupt(e.to_string()))?;
    let manifest = Json::parse(&text).map_err(|e| corrupt(format!("manifest: {e}")))?;
    let field_usize = |key: &str| -> Result<usize, CheckpointError> {
        manifest
            .get(key)
            .and_then(Json::as_usize)
            .map_err(|e| corrupt(format!("manifest field {key:?}: {e}")))
    };
    let format = field_usize("format")?;
    if format != VERSION as usize {
        return Err(corrupt(format!("unsupported checkpoint format {format} (want {VERSION})")));
    }
    let mrank = field_usize("rank")?;
    if mrank != rank {
        return Err(corrupt(format!("manifest is for rank {mrank}, this is rank {rank}")));
    }
    let mepoch = field_usize("epoch")?;
    if mepoch as u64 != epochs_done {
        return Err(corrupt(format!("manifest is for epoch {mepoch}, wanted {epochs_done}")));
    }
    let fp_disk = manifest
        .get("fingerprint")
        .map_err(|e| corrupt(format!("manifest: {e}")))?;
    fp.check(fp_disk)?;
    let checksum = manifest
        .get("checksum")
        .and_then(Json::as_str)
        .ok()
        .and_then(|s| u64::from_str_radix(s, 16).ok())
        .ok_or_else(|| corrupt("manifest checksum missing or non-hex".into()))?;
    let digest = manifest
        .get("digest")
        .and_then(Json::as_str)
        .ok()
        .and_then(|s| u64::from_str_radix(s, 16).ok())
        .ok_or_else(|| corrupt("manifest digest missing or non-hex".into()))?;
    let bin_bytes = field_usize("bin_bytes")?;

    let bpath = bin_path(dir, epochs_done, rank);
    let bshown = bpath.display().to_string();
    let bcorrupt = |detail: String| CheckpointError::Corrupt { path: bshown.clone(), detail };
    let bin = std::fs::read(&bpath).map_err(|e| bcorrupt(e.to_string()))?;
    if bin.len() != bin_bytes {
        return Err(bcorrupt(format!(
            "file is {} bytes, manifest says {bin_bytes}",
            bin.len()
        )));
    }
    let actual = fnv1a64(&bin);
    if actual != checksum {
        return Err(bcorrupt(format!(
            "checksum {actual:016x} != manifest {checksum:016x} — the file rotted or was \
             partially overwritten"
        )));
    }
    let state = CheckpointState::decode(&bin).map_err(bcorrupt)?;
    if state.epochs_done != epochs_done {
        return Err(CheckpointError::Corrupt {
            path: bshown,
            detail: format!(
                "state says {} epochs done, manifest says {epochs_done}",
                state.epochs_done
            ),
        });
    }
    let sdigest = state.digest();
    if sdigest != digest {
        return Err(CheckpointError::Corrupt {
            path: bshown,
            detail: format!("state digest {sdigest:016x} != manifest {digest:016x}"),
        });
    }
    Ok(state)
}

/// This rank's newest epoch directory containing its **complete**
/// checkpoint (manifest present — the manifest is renamed last, so its
/// presence implies the binary landed). Content validation happens at
/// load; a newest-but-corrupt file must surface as a typed error, not
/// be silently skipped for an older one (the operator should know).
fn my_latest_epoch(dir: &Path, rank: usize) -> Option<u64> {
    let entries = std::fs::read_dir(dir).ok()?;
    let mut best: Option<u64> = None;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(epoch) = name.to_str().and_then(|n| n.strip_prefix("ckpt-")) else {
            continue;
        };
        let Ok(epoch) = epoch.parse::<u64>() else {
            continue;
        };
        if json_path(dir, epoch, rank).exists() {
            best = Some(best.map_or(epoch, |b| b.max(epoch)));
        }
    }
    best
}

/// SPMD-collective resume: agree on the newest checkpoint epoch **every**
/// rank holds, load + validate it on each rank, and cross-check the
/// state digests across the world. Returns `Ok(None)` when no rank has
/// any checkpoint (a fresh start); `Ok(Some(state))` with
/// `state.epochs_done` as the restart cursor otherwise. Every rank must
/// call this at the same point (it issues `all_reduce_min_u64` rounds);
/// mismatched availability, fingerprints, corruption, and digest
/// disagreement all surface as typed errors on every rank — never a
/// silent partial restore.
pub fn resume_latest(
    comm: &mut Comm,
    dir: &Path,
    fp: &Fingerprint,
) -> anyhow::Result<Option<CheckpointState>> {
    let me = comm.rank();
    // Code each rank's newest complete epoch as epoch+1 (0 = none), then
    // min/max-reduce: min == 0 with max > 0 means some ranks have
    // checkpoints and some do not — refuse rather than desynchronize.
    let code = my_latest_epoch(dir, me).map_or(0, |e| e + 1);
    let min_code = comm.all_reduce_min_u64(code)?;
    let max_code = !comm.all_reduce_min_u64(!code)?;
    if min_code == 0 {
        if max_code != 0 {
            return Err(CheckpointError::RankDisagreement {
                detail: format!(
                    "some ranks have checkpoints up to epoch {} but at least one rank has \
                     none (this rank's newest: {}) — same --checkpoint-dir on every rank?",
                    max_code - 1,
                    if code == 0 { "none".to_string() } else { (code - 1).to_string() }
                ),
            }
            .into());
        }
        return Ok(None);
    }
    // The newest epoch present on all ranks. Ranks checkpoint the same
    // epoch set (same config ⇒ same cadence), so min is safe even when
    // a kill left some ranks one epoch ahead.
    let epochs_done = min_code - 1;
    let state = load_checkpoint(dir, fp, me, epochs_done)?;
    let d = state.digest();
    let dmin = comm.all_reduce_min_u64(d)?;
    let dmax = !comm.all_reduce_min_u64(!d)?;
    if dmin != dmax {
        return Err(CheckpointError::DigestMismatch {
            detail: format!(
                "epoch {epochs_done}: digests range over [{dmin:016x}, {dmax:016x}] \
                 (this rank: {d:016x}) — checkpoints are not from one consistent run"
            ),
        }
        .into());
    }
    Ok(Some(state))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::ReplicationPolicy;
    use crate::sampling::KernelKind;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("fastsample-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn cfg() -> TrainConfig {
        TrainConfig::new("q", ReplicationPolicy::vanilla(), KernelKind::Baseline, 4)
    }

    fn sample_state() -> CheckpointState {
        let mut comm = CommStats::default();
        comm.rounds[0] = 7;
        comm.bytes[0] = 1234;
        CheckpointState {
            epochs_done: 2,
            smoothed_loss: Some(0.25),
            curve: vec![1.5, -0.25, f32::MIN_POSITIVE],
            comm: comm.clone(),
            epoch_deltas: vec![comm],
            params: vec![
                HostTensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]),
                HostTensor::f32(vec![-1.0], &[1]),
            ],
            opt: Some(OptimizerState::Adam {
                t: 6,
                m: vec![vec![0.1; 4], vec![0.2]],
                v: vec![vec![0.3; 4], vec![0.4]],
            }),
            cache_rows: vec![(9, vec![1, 2, 3]), (4, vec![])],
            steps: 12,
            sampled_edges: 3456,
        }
    }

    #[test]
    fn encode_decode_round_trips() {
        let s = sample_state();
        let back = CheckpointState::decode(&s.encode()).unwrap();
        assert_eq!(s, back);
        assert_eq!(s.digest(), back.digest());
    }

    #[test]
    fn write_then_load_round_trips() {
        let dir = tmp_dir("roundtrip");
        let fp = Fingerprint::new("train", "quickstart", &cfg(), None);
        let s = sample_state();
        write_checkpoint(&dir, &fp, 1, &s).unwrap();
        let back = load_checkpoint(&dir, &fp, 1, 2).unwrap();
        assert_eq!(s, back);
        // No stray tmp files survive the atomic writes.
        let leftovers: Vec<_> = std::fs::read_dir(dir.join("ckpt-000002"))
            .unwrap()
            .flatten()
            .filter(|e| e.path().extension().is_some_and(|x| x == "tmp"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_binary_is_a_typed_corrupt_error() {
        let dir = tmp_dir("truncated");
        let fp = Fingerprint::new("train", "quickstart", &cfg(), None);
        let s = sample_state();
        write_checkpoint(&dir, &fp, 0, &s).unwrap();
        let bpath = dir.join("ckpt-000002").join("rank0.bin");
        let bytes = std::fs::read(&bpath).unwrap();
        std::fs::write(&bpath, &bytes[..bytes.len() / 2]).unwrap();
        match load_checkpoint(&dir, &fp, 0, 2) {
            Err(CheckpointError::Corrupt { .. }) => {}
            other => panic!("wanted Corrupt, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn flipped_byte_fails_the_checksum() {
        let dir = tmp_dir("bitrot");
        let fp = Fingerprint::new("sample", "quickstart", &cfg(), Some((8, &[3, 2])));
        let mut s = sample_state();
        s.params.clear();
        s.opt = None;
        write_checkpoint(&dir, &fp, 2, &s).unwrap();
        let bpath = dir.join("ckpt-000002").join("rank2.bin");
        let mut bytes = std::fs::read(&bpath).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&bpath, &bytes).unwrap();
        match load_checkpoint(&dir, &fp, 2, 2) {
            Err(CheckpointError::Corrupt { detail, .. }) => {
                assert!(detail.contains("checksum"), "{detail}");
            }
            other => panic!("wanted a checksum Corrupt, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_mismatch_is_typed_and_names_the_field() {
        let dir = tmp_dir("fingerprint");
        let fp = Fingerprint::new("train", "quickstart", &cfg(), None);
        write_checkpoint(&dir, &fp, 0, &sample_state()).unwrap();
        // Same layout, different seed: refuse with the field named.
        let mut other = cfg();
        other.seed = 99;
        let fp2 = Fingerprint::new("train", "quickstart", &other, None);
        match load_checkpoint(&dir, &fp2, 0, 2) {
            Err(CheckpointError::FingerprintMismatch { field, expected, found }) => {
                assert_eq!(field, "seed");
                assert_eq!(expected, "0");
                assert_eq!(found, "99");
            }
            other => panic!("wanted FingerprintMismatch, got {other:?}"),
        }
        // Different world size: also refused.
        let mut w = cfg();
        w.workers = 8;
        let fpw = Fingerprint::new("train", "quickstart", &w, None);
        assert!(matches!(
            load_checkpoint(&dir, &fpw, 0, 2),
            Err(CheckpointError::FingerprintMismatch { .. })
        ));
        // Wrong task: refused too.
        let fps = Fingerprint::new("sample", "quickstart", &cfg(), Some((8, &[3])));
        assert!(matches!(
            load_checkpoint(&dir, &fps, 0, 2),
            Err(CheckpointError::FingerprintMismatch { .. })
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_manifest_means_incomplete_and_is_skipped_by_the_scan() {
        let dir = tmp_dir("scan");
        let fp = Fingerprint::new("train", "quickstart", &cfg(), None);
        let mut s = sample_state();
        s.epochs_done = 1;
        write_checkpoint(&dir, &fp, 0, &s).unwrap();
        s.epochs_done = 2;
        write_checkpoint(&dir, &fp, 0, &s).unwrap();
        assert_eq!(my_latest_epoch(&dir, 0), Some(2));
        // A kill between the bin and json renames leaves the newest epoch
        // manifest-less: the scan must fall back to the previous one.
        std::fs::remove_file(dir.join("ckpt-000002").join("rank0.json")).unwrap();
        assert_eq!(my_latest_epoch(&dir, 0), Some(1));
        // Another rank's files don't count for this rank.
        assert_eq!(my_latest_epoch(&dir, 1), None);
        // No directory, no checkpoint — not an error.
        assert_eq!(my_latest_epoch(Path::new("/nonexistent-ckpt-dir"), 0), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn garbage_length_prefix_cannot_allocate_unboundedly() {
        // A "curve length = u64::MAX" prefix must fail the bounds check,
        // not attempt the allocation.
        let mut w = Wr(Vec::new());
        w.0.extend_from_slice(MAGIC);
        w.u32(VERSION);
        w.u64(0); // epochs_done
        w.u8(0); // no smoothed loss
        w.u64(u64::MAX); // curve length: absurd
        let err = CheckpointState::decode(&w.0).unwrap_err();
        assert!(err.contains("length prefix"), "{err}");
    }
}
