//! Per-epoch metrics: loss/accuracy plus the time breakdown that Fig 5/6
//! are made of (sampling vs feature exchange vs compute vs grad sync).

use std::time::Instant;

use crate::dist::CommStats;
use crate::runtime::HostTensor;

/// Wall-clock phase accumulator for one worker's epoch.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimes {
    pub sample_s: f64,
    pub feature_s: f64,
    pub compute_s: f64,
    pub sync_s: f64,
}

impl PhaseTimes {
    pub fn total(&self) -> f64 {
        self.sample_s + self.feature_s + self.compute_s + self.sync_s
    }

    pub fn add(&mut self, other: &PhaseTimes) {
        self.sample_s += other.sample_s;
        self.feature_s += other.feature_s;
        self.compute_s += other.compute_s;
        self.sync_s += other.sync_s;
    }

    pub fn scale(&self, k: f64) -> PhaseTimes {
        PhaseTimes {
            sample_s: self.sample_s * k,
            feature_s: self.feature_s * k,
            compute_s: self.compute_s * k,
            sync_s: self.sync_s * k,
        }
    }
}

/// Scoped phase timer: `let _t = Phase::new(&mut times.sample_s);`…
/// explicit `stop` keeps borrowck simple instead.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(Instant::now())
    }

    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let dt = (now - self.0).as_secs_f64();
        self.0 = now;
        dt
    }
}

/// One worker's summary for one epoch.
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: usize,
    pub batches: usize,
    pub mean_loss: f32,
    pub times: PhaseTimes,
    pub wall_s: f64,
    /// Communication delta for this epoch (rank 0 only; empty elsewhere).
    pub comm: Option<CommStats>,
    /// Accuracy on the last batch of the epoch (if eval was run).
    pub batch_acc: Option<f32>,
}

/// Masked argmax accuracy of `[batch, classes]` logits.
pub fn accuracy(logits: &HostTensor, labels: &[i32], mask: &[f32]) -> f32 {
    let shape = logits.shape();
    let (b, c) = (shape[0], shape[1]);
    let data = logits.as_f32().expect("logits are f32");
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..b {
        if mask[i] == 0.0 {
            continue;
        }
        let row = &data[i * c..(i + 1) * c];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        if pred as i32 == labels[i] {
            correct += 1;
        }
        total += 1;
    }
    if total == 0 {
        0.0
    } else {
        correct as f32 / total as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_masked() {
        let logits = HostTensor::f32(vec![1.0, 0.0, 0.0, 9.0, 0.5, 0.4], &[3, 2]);
        let labels = [0, 1, 1];
        // Row 2 predicts 0 but is masked out.
        assert_eq!(accuracy(&logits, &labels, &[1.0, 1.0, 0.0]), 1.0);
        assert!((accuracy(&logits, &labels, &[1.0, 1.0, 1.0]) - 2.0 / 3.0).abs() < 1e-6);
        assert_eq!(accuracy(&logits, &labels, &[0.0, 0.0, 0.0]), 0.0);
    }

    #[test]
    fn phase_times_accumulate() {
        let mut a = PhaseTimes { sample_s: 1.0, feature_s: 2.0, compute_s: 3.0, sync_s: 4.0 };
        a.add(&a.clone());
        assert_eq!(a.total(), 20.0);
        let h = a.scale(0.5);
        assert_eq!(h.total(), 10.0);
    }

    #[test]
    fn stopwatch_laps_monotonically() {
        let mut sw = Stopwatch::start();
        let a = sw.lap();
        let b = sw.lap();
        assert!(a >= 0.0 && b >= 0.0);
    }
}
