//! Per-epoch metrics: loss/accuracy plus the time breakdown that Fig 5/6
//! are made of (sampling vs feature exchange vs compute vs grad sync).

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::dist::CommStats;
use crate::runtime::HostTensor;

/// Wall-clock phase accumulator for one worker's epoch.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimes {
    pub sample_s: f64,
    pub feature_s: f64,
    pub compute_s: f64,
    pub sync_s: f64,
}

impl PhaseTimes {
    pub fn total(&self) -> f64 {
        self.sample_s + self.feature_s + self.compute_s + self.sync_s
    }

    pub fn add(&mut self, other: &PhaseTimes) {
        self.sample_s += other.sample_s;
        self.feature_s += other.feature_s;
        self.compute_s += other.compute_s;
        self.sync_s += other.sync_s;
    }

    pub fn scale(&self, k: f64) -> PhaseTimes {
        PhaseTimes {
            sample_s: self.sample_s * k,
            feature_s: self.feature_s * k,
            compute_s: self.compute_s * k,
            sync_s: self.sync_s * k,
        }
    }
}

/// Manual lap timer: `let mut sw = Stopwatch::start();` then
/// `times.sample_s += sw.lap();` after each phase. Explicit laps (rather
/// than a scoped guard holding `&mut` into the accumulator) keep the
/// borrow story trivial inside the epoch loop.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(Instant::now())
    }

    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let dt = (now - self.0).as_secs_f64();
        self.0 = now;
        dt
    }
}

/// One worker's summary for one epoch.
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: usize,
    pub batches: usize,
    pub mean_loss: f32,
    pub times: PhaseTimes,
    pub wall_s: f64,
    /// Communication delta for this epoch (rank 0 only; empty elsewhere).
    pub comm: Option<CommStats>,
    /// Accuracy on the last batch of the epoch (if eval was run).
    pub batch_acc: Option<f32>,
}

/// Masked argmax accuracy of `[batch, classes]` logits.
///
/// Comparison is `f32::total_cmp` (IEEE total order), so a NaN logit —
/// the signature of a diverged model — yields a deterministic (wrong)
/// prediction and a bad accuracy number instead of a panic mid-epoch.
pub fn accuracy(logits: &HostTensor, labels: &[i32], mask: &[f32]) -> Result<f32> {
    let shape = logits.shape();
    ensure!(shape.len() == 2, "logits must be [batch, classes], got shape {shape:?}");
    let (b, c) = (shape[0], shape[1]);
    ensure!(c > 0, "logits need at least one class column, got shape {shape:?}");
    let data = logits.as_f32()?;
    ensure!(
        data.len() == b * c,
        "logits hold {} values but shape {shape:?} implies {}",
        data.len(),
        b * c
    );
    ensure!(
        labels.len() >= b && mask.len() >= b,
        "labels/mask cover {}/{} rows but the batch has {b}",
        labels.len(),
        mask.len()
    );
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..b {
        if mask[i] == 0.0 {
            continue;
        }
        let row = &data[i * c..(i + 1) * c];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(j, _)| j)
            .unwrap_or(0);
        if pred as i32 == labels[i] {
            correct += 1;
        }
        total += 1;
    }
    Ok(if total == 0 { 0.0 } else { correct as f32 / total as f32 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_masked() {
        let logits = HostTensor::f32(vec![1.0, 0.0, 0.0, 9.0, 0.5, 0.4], &[3, 2]);
        let labels = [0, 1, 1];
        // Row 2 predicts 0 but is masked out.
        assert_eq!(accuracy(&logits, &labels, &[1.0, 1.0, 0.0]).unwrap(), 1.0);
        assert!((accuracy(&logits, &labels, &[1.0, 1.0, 1.0]).unwrap() - 2.0 / 3.0).abs() < 1e-6);
        assert_eq!(accuracy(&logits, &labels, &[0.0, 0.0, 0.0]).unwrap(), 0.0);
    }

    /// A diverged model emits NaN logits; accuracy must report a (bad)
    /// number deterministically, not panic the trainer.
    #[test]
    fn nan_logits_report_instead_of_panicking() {
        let nan = f32::NAN;
        // Row 0 is all-NaN, row 1 has a NaN beaten by nothing finite in
        // total order (NaN sorts above +inf), row 2 is healthy.
        let logits = HostTensor::f32(vec![nan, nan, 0.1, nan, 0.9, 0.2], &[3, 2]);
        let labels = [0, 1, 0];
        let acc = accuracy(&logits, &labels, &[1.0, 1.0, 1.0]).unwrap();
        // Row 1's NaN column (index 1) wins in total order → "correct";
        // row 2 predicts 0 → correct; row 0's argmax is deterministic
        // regardless of which NaN wins. acc is therefore ≥ 2/3 and finite.
        assert!(acc.is_finite());
        assert!(acc >= 2.0 / 3.0 - 1e-6);
        // And crucially: calling it twice gives the identical answer.
        assert_eq!(acc, accuracy(&logits, &labels, &[1.0, 1.0, 1.0]).unwrap());
    }

    /// Short label/mask slices are an error, not an out-of-bounds panic.
    #[test]
    fn short_labels_or_mask_are_typed_errors() {
        let logits = HostTensor::f32(vec![1.0, 0.0, 0.0, 9.0], &[2, 2]);
        assert!(accuracy(&logits, &[0], &[1.0, 1.0]).is_err());
        assert!(accuracy(&logits, &[0, 1], &[1.0]).is_err());
        let bad_shape = HostTensor::f32(vec![1.0, 2.0], &[2]);
        assert!(accuracy(&bad_shape, &[0, 1], &[1.0, 1.0]).is_err());
    }

    #[test]
    fn phase_times_accumulate() {
        let mut a = PhaseTimes { sample_s: 1.0, feature_s: 2.0, compute_s: 3.0, sync_s: 4.0 };
        a.add(&a.clone());
        assert_eq!(a.total(), 20.0);
        let h = a.scale(0.5);
        assert_eq!(h.total(), 10.0);
    }

    #[test]
    fn stopwatch_laps_monotonically() {
        let mut sw = Stopwatch::start();
        let a = sw.lap();
        let b = sw.lap();
        assert!(a >= 0.0 && b >= 0.0);
    }
}
