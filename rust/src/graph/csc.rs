//! CSC (Compressed Sparse Column) adjacency — the canonical topology
//! format (paper Fig 2): `indptr[v+1] - indptr[v]` in-edges for node `v`,
//! their sources at `indices[indptr[v]..indptr[v+1]]`.

use anyhow::{ensure, Result};

use super::{CooGraph, NodeId};

/// Immutable CSC graph over in-edges. `A ≡ (R, C)` in the paper's
/// notation: `R = indptr`, `C = indices`.
#[derive(Debug, Clone, PartialEq)]
pub struct CscGraph {
    indptr: Vec<usize>,
    indices: Vec<NodeId>,
}

impl CscGraph {
    /// Build from raw arrays, validating the CSC invariants.
    pub fn new(indptr: Vec<usize>, indices: Vec<NodeId>) -> Result<Self> {
        ensure!(!indptr.is_empty(), "indptr must have at least one entry");
        ensure!(indptr[0] == 0, "indptr[0] must be 0");
        ensure!(
            indptr.windows(2).all(|w| w[0] <= w[1]),
            "indptr must be non-decreasing"
        );
        ensure!(
            *indptr.last().unwrap() == indices.len(),
            "indptr[-1] ({}) != nnz ({})",
            indptr.last().unwrap(),
            indices.len()
        );
        let n = indptr.len() - 1;
        ensure!(
            indices.iter().all(|&s| (s as usize) < n),
            "edge source out of range"
        );
        Ok(Self { indptr, indices })
    }

    /// Internal constructor for callers that uphold the invariants
    /// themselves (generators, partitioner); debug-checked.
    pub(crate) fn new_unchecked(indptr: Vec<usize>, indices: Vec<NodeId>) -> Self {
        debug_assert!(Self::new(indptr.clone(), indices.clone()).is_ok());
        Self { indptr, indices }
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.indptr.len() - 1
    }

    #[inline]
    pub fn num_edges(&self) -> usize {
        self.indices.len()
    }

    /// In-degree of `v`.
    #[inline]
    pub fn degree(&self, v: NodeId) -> usize {
        self.indptr[v as usize + 1] - self.indptr[v as usize]
    }

    /// In-neighbors of `v` (edge sources), O(1) slice — the property the
    /// paper's fused kernel exploits.
    #[inline]
    pub fn neighbors(&self, v: NodeId) -> &[NodeId] {
        &self.indices[self.indptr[v as usize]..self.indptr[v as usize + 1]]
    }

    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    pub fn indices(&self) -> &[NodeId] {
        &self.indices
    }

    pub fn max_degree(&self) -> usize {
        (0..self.num_nodes() as NodeId).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    pub fn avg_degree(&self) -> f64 {
        if self.num_nodes() == 0 {
            return 0.0;
        }
        self.num_edges() as f64 / self.num_nodes() as f64
    }

    /// Bytes held by the adjacency arrays (Fig 4 "topology" accounting).
    pub fn storage_bytes(&self) -> usize {
        self.indptr.len() * std::mem::size_of::<usize>()
            + self.indices.len() * std::mem::size_of::<NodeId>()
    }

    /// Expand back to COO (used by tests and the baseline pipeline).
    pub fn to_coo(&self) -> CooGraph {
        let mut src = Vec::with_capacity(self.num_edges());
        let mut dst = Vec::with_capacity(self.num_edges());
        for v in 0..self.num_nodes() as NodeId {
            for &s in self.neighbors(v) {
                src.push(s);
                dst.push(v);
            }
        }
        CooGraph::new(self.num_nodes(), src, dst).expect("CSC expands to valid COO")
    }

    /// Restrict to the in-edges of a node subset, relabeling nothing:
    /// returns (indptr over `nodes` order, concatenated neighbor lists).
    /// Used by the partitioner to build per-partition halo graphs.
    pub fn induce_in_edges(&self, nodes: &[NodeId]) -> (Vec<usize>, Vec<NodeId>) {
        let mut indptr = Vec::with_capacity(nodes.len() + 1);
        indptr.push(0);
        let total: usize = nodes.iter().map(|&v| self.degree(v)).sum();
        let mut indices = Vec::with_capacity(total);
        for &v in nodes {
            indices.extend_from_slice(self.neighbors(v));
            indptr.push(indices.len());
        }
        (indptr, indices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 0 <- 1, 0 <- 2, 1 <- 2, 3 isolated.
    fn toy() -> CscGraph {
        CscGraph::new(vec![0, 2, 3, 3, 3], vec![1, 2, 2]).unwrap()
    }

    #[test]
    fn basic_accessors() {
        let g = toy();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(3), &[] as &[NodeId]);
        assert_eq!(g.max_degree(), 2);
        assert!((g.avg_degree() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn invalid_graphs_rejected() {
        assert!(CscGraph::new(vec![], vec![]).is_err());
        assert!(CscGraph::new(vec![1, 2], vec![0]).is_err()); // indptr[0] != 0
        assert!(CscGraph::new(vec![0, 2, 1], vec![0, 0]).is_err()); // decreasing
        assert!(CscGraph::new(vec![0, 1], vec![5]).is_err()); // src out of range
        assert!(CscGraph::new(vec![0, 3], vec![0]).is_err()); // nnz mismatch
    }

    #[test]
    fn coo_round_trip() {
        let g = toy();
        let coo = g.to_coo();
        let back = coo.to_csc();
        assert_eq!(g, back);
    }

    #[test]
    fn induce_in_edges_subsets() {
        let g = toy();
        let (indptr, indices) = g.induce_in_edges(&[2, 0]);
        assert_eq!(indptr, vec![0, 0, 2]);
        assert_eq!(indices, vec![1, 2]);
    }

    #[test]
    fn storage_bytes_counts_both_arrays() {
        let g = toy();
        assert_eq!(g.storage_bytes(), 5 * 8 + 3 * 4);
    }
}
