//! COO (COOrdinate) edge-list format — the intermediate representation the
//! baseline (DGL-style) sampling pipeline materializes and the fused kernel
//! avoids (paper Fig 2 and §3.2).

use anyhow::{ensure, Result};

use super::{CscGraph, NodeId};

/// Edge list `(src[i], dst[i])`, unordered.
#[derive(Debug, Clone, PartialEq)]
pub struct CooGraph {
    num_nodes: usize,
    src: Vec<NodeId>,
    dst: Vec<NodeId>,
}

impl CooGraph {
    pub fn new(num_nodes: usize, src: Vec<NodeId>, dst: Vec<NodeId>) -> Result<Self> {
        ensure!(src.len() == dst.len(), "src/dst length mismatch");
        ensure!(
            src.iter().chain(dst.iter()).all(|&v| (v as usize) < num_nodes),
            "endpoint out of range"
        );
        Ok(Self { num_nodes, src, dst })
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    #[inline]
    pub fn num_edges(&self) -> usize {
        self.src.len()
    }

    pub fn src(&self) -> &[NodeId] {
        &self.src
    }

    pub fn dst(&self) -> &[NodeId] {
        &self.dst
    }

    /// Counting-sort conversion to CSC keyed on `dst` (in-edges). This is
    /// the exact two-pass conversion the baseline sampler pays per level
    /// and the fused kernel skips.
    pub fn to_csc(&self) -> CscGraph {
        let n = self.num_nodes;
        let mut indptr = vec![0usize; n + 1];
        for &d in &self.dst {
            indptr[d as usize + 1] += 1;
        }
        for i in 0..n {
            indptr[i + 1] += indptr[i];
        }
        let mut indices = vec![0 as NodeId; self.src.len()];
        let mut cursor = indptr.clone();
        for (&s, &d) in self.src.iter().zip(&self.dst) {
            indices[cursor[d as usize]] = s;
            cursor[d as usize] += 1;
        }
        CscGraph::new_unchecked(indptr, indices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_csc_groups_by_dst() {
        // edges: 1->0, 2->0, 2->1
        let coo = CooGraph::new(4, vec![1, 2, 2], vec![0, 0, 1]).unwrap();
        let csc = coo.to_csc();
        assert_eq!(csc.indptr(), &[0, 2, 3, 3, 3]);
        assert_eq!(csc.neighbors(0), &[1, 2]);
        assert_eq!(csc.neighbors(1), &[2]);
    }

    #[test]
    fn rejects_bad_edges() {
        assert!(CooGraph::new(2, vec![0], vec![5]).is_err());
        assert!(CooGraph::new(2, vec![0, 1], vec![0]).is_err());
    }

    #[test]
    fn empty_graph_ok() {
        let coo = CooGraph::new(3, vec![], vec![]).unwrap();
        let csc = coo.to_csc();
        assert_eq!(csc.num_nodes(), 3);
        assert_eq!(csc.num_edges(), 0);
    }

    #[test]
    fn preserves_duplicate_edges() {
        let coo = CooGraph::new(2, vec![0, 0], vec![1, 1]).unwrap();
        assert_eq!(coo.to_csc().neighbors(1), &[0, 0]);
    }
}
