//! Dataset registry: scaled synthetic analogs of the paper's benchmark
//! graphs (Table 1) and the published-metadata storage model behind Fig 4.

use super::generator::{make_dataset, DatasetParams};
use super::Dataset;

/// Published metadata of the graphs the paper references. Node/edge counts
/// and dims are from Table 1 (ogbn-*) and the OGB-LSC / IGB papers
/// (MAG240M, IGBH-full) — used for Table 1 and the Fig 4 storage model.
#[derive(Debug, Clone)]
pub struct PublishedGraph {
    pub name: &'static str,
    pub num_nodes: u64,
    pub num_edges: u64,
    pub feat_dim: u64,
    pub num_classes: u64,
    /// Bytes per feature scalar in the official release (f16 for MAG240M,
    /// f32 for the others).
    pub feat_bytes: u64,
}

pub const OGBN_PRODUCTS: PublishedGraph = PublishedGraph {
    name: "ogbn-products",
    num_nodes: 2_500_000,
    num_edges: 124_000_000,
    feat_dim: 100,
    num_classes: 47,
    feat_bytes: 4,
};

pub const OGBN_PAPERS100M: PublishedGraph = PublishedGraph {
    name: "ogbn-papers100M",
    num_nodes: 111_000_000,
    num_edges: 3_200_000_000,
    feat_dim: 128,
    num_classes: 172,
    feat_bytes: 4,
};

pub const MAG240M: PublishedGraph = PublishedGraph {
    name: "MAG240M",
    num_nodes: 244_160_499,
    num_edges: 1_728_364_232,
    feat_dim: 768,
    num_classes: 153,
    feat_bytes: 2,
};

pub const IGBH_FULL: PublishedGraph = PublishedGraph {
    name: "IGBH-full",
    num_nodes: 269_346_174,
    num_edges: 3_995_777_033,
    feat_dim: 1024,
    num_classes: 2983,
    feat_bytes: 4,
};

impl PublishedGraph {
    /// Adjacency bytes under the same CSC accounting we use for our own
    /// graphs: 8-byte indptr entries + 4-byte neighbor ids.
    pub fn topology_bytes(&self) -> u64 {
        (self.num_nodes + 1) * 8 + self.num_edges * 4
    }

    pub fn feature_bytes(&self) -> u64 {
        self.num_nodes * self.feat_dim * self.feat_bytes
    }

    /// Fraction of total storage taken by topology — the Fig 4 message:
    /// "the adjacency matrix is a small fraction of total graph size".
    pub fn topology_fraction(&self) -> f64 {
        let t = self.topology_bytes() as f64;
        t / (t + self.feature_bytes() as f64)
    }
}

/// Scaled synthetic analog of ogbn-products. `scale` multiplies the node
/// count; degree, feature dim and class count match the real graph.
pub fn products_sim(scale: f64, seed: u64) -> Dataset {
    let n = ((2_500_000f64 * scale) as usize).max(1000);
    make_dataset(&DatasetParams {
        name: format!("products-sim(x{scale})"),
        num_nodes: n,
        avg_degree: 50, // 124M / 2.5M
        feat_dim: 100,
        num_classes: 47,
        labeled_frac: 0.08, // ~196k/2.45M in the real split
        p_intra: 0.8,
        noise: 0.8,
        seed,
    })
}

/// Scaled synthetic analog of ogbn-papers100M.
pub fn papers100m_sim(scale: f64, seed: u64) -> Dataset {
    let n = ((111_000_000f64 * scale) as usize).max(1000);
    make_dataset(&DatasetParams {
        name: format!("papers100m-sim(x{scale})"),
        num_nodes: n,
        avg_degree: 29, // 3.2B / 111M
        feat_dim: 128,
        num_classes: 172,
        labeled_frac: 0.011, // ~1.2M labeled papers
        p_intra: 0.8,
        noise: 0.8,
        seed,
    })
}

/// Tiny graph for unit tests and the quickstart example (matches the
/// `quickstart` AOT variant dims: F=32, C=8).
pub fn quickstart(seed: u64) -> Dataset {
    make_dataset(&DatasetParams {
        name: "quickstart".into(),
        num_nodes: 2_000,
        avg_degree: 10,
        feat_dim: 32,
        num_classes: 8,
        labeled_frac: 0.25,
        p_intra: 0.85,
        noise: 0.5,
        seed,
    })
}

/// Resolve a dataset by name (CLI entry point). Names:
/// `products-sim`, `papers100m-sim`, `quickstart`, with `:<scale>` suffix.
pub fn by_name(spec: &str, seed: u64) -> anyhow::Result<Dataset> {
    let (name, scale) = match spec.split_once(':') {
        Some((n, s)) => (n, s.parse::<f64>()?),
        None => (spec, 0.01),
    };
    match name {
        "products-sim" => Ok(products_sim(scale, seed)),
        "papers100m-sim" => Ok(papers100m_sim(scale, seed)),
        "quickstart" => Ok(quickstart(seed)),
        other => anyhow::bail!("unknown dataset {other:?} (want products-sim | papers100m-sim | quickstart)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_topology_is_small_fraction() {
        // The paper's Fig 4 point: topology ≪ features for MAG240M / IGBH.
        assert!(MAG240M.topology_fraction() < 0.05, "{}", MAG240M.topology_fraction());
        assert!(IGBH_FULL.topology_fraction() < 0.10, "{}", IGBH_FULL.topology_fraction());
    }

    #[test]
    fn published_numbers_match_table1() {
        assert_eq!(OGBN_PRODUCTS.feat_dim, 100);
        assert_eq!(OGBN_PRODUCTS.num_classes, 47);
        assert_eq!(OGBN_PAPERS100M.feat_dim, 128);
        assert_eq!(OGBN_PAPERS100M.num_classes, 172);
    }

    #[test]
    fn sims_match_real_dims() {
        let d = products_sim(0.001, 1);
        assert_eq!(d.feat_dim, 100);
        assert_eq!(d.num_classes, 47);
        let p = papers100m_sim(0.0001, 1);
        assert_eq!(p.feat_dim, 128);
        assert_eq!(p.num_classes, 172);
    }

    #[test]
    fn by_name_parses_scale() {
        let d = by_name("products-sim:0.001", 3).unwrap();
        assert!(d.num_nodes() >= 1000);
        assert!(by_name("nope", 0).is_err());
    }
}
