//! Synthetic graph generators.
//!
//! The paper benchmarks on OGB graphs we cannot ship; these generators
//! produce scaled analogs with the properties that drive sampling cost:
//! power-law in-degree skew (RMAT / hub mixture), community structure
//! (so the edge-cut partitioner and the planted classification task are
//! both meaningful), and matching feature/class dimensions
//! (DESIGN.md §Substitutions).
//!
//! All generators are deterministic in the [`RngKey`] and parallelized
//! with scoped threads via counter-based streams (one stream per
//! node/edge), so the output is independent of thread count.

use crate::sampling::rng::RngKey;
use crate::util::par;

use super::{CooGraph, CscGraph, Dataset, NodeId};

/// Erdős–Rényi-ish: every node draws `avg_degree` in-neighbors uniformly.
pub fn erdos_renyi(n: usize, avg_degree: usize, key: RngKey) -> CscGraph {
    let key = key.fold(0xE2D0);
    per_node_graph(n, |v, out| {
        let mut s = key.stream(v as u64);
        let d = if n <= 1 { 0 } else { avg_degree };
        for _ in 0..d {
            out.push(s.next_below(n) as NodeId);
        }
    })
}

/// RMAT (Chakrabarti et al.): recursive quadrant choice with probabilities
/// `(a, b, c, d)`; produces the heavy-tailed degree distribution of
/// real-world web/citation graphs. Self-loops allowed (as in the OGB
/// preprocessing they are rare and harmless to sampling).
pub fn rmat(n: usize, num_edges: usize, probs: (f64, f64, f64, f64), key: RngKey) -> CscGraph {
    assert!(n.is_power_of_two(), "rmat requires power-of-two node count");
    let scale = n.trailing_zeros();
    let (a, b, c, _d) = probs;
    let key = key.fold(0x12A7);
    let edges: Vec<(NodeId, NodeId)> = par::par_map(num_edges, |e| {
        let mut s = key.stream(e as u64);
        let (mut src, mut dst) = (0u64, 0u64);
        for _ in 0..scale {
            let r = s.next_f32() as f64;
            let (si, di) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            src = (src << 1) | si;
            dst = (dst << 1) | di;
        }
        (src as NodeId, dst as NodeId)
    });
    let (src, dst): (Vec<_>, Vec<_>) = edges.into_iter().unzip();
    CooGraph::new(n, src, dst).expect("rmat edges in range").to_csc()
}

/// Planted-community graph + labels: node `v` belongs to community
/// `v * classes / n` (contiguous blocks, so edge-cut partitioners have
/// real structure to find). Each node draws in-neighbors, intra-community
/// with probability `p_intra`. Degrees follow a hub mixture: a fraction of
/// nodes are hubs with ~10x the base degree, giving the skew that makes
/// neighbor sampling non-trivial.
pub fn planted_communities(
    n: usize,
    classes: usize,
    avg_degree: usize,
    p_intra: f32,
    key: RngKey,
) -> (CscGraph, Vec<i32>) {
    assert!(classes >= 1 && n >= classes);
    let labels: Vec<i32> = (0..n).map(|v| (v * classes / n) as i32).collect();
    let block = n / classes;
    let key = key.fold(0xC0117);
    let graph = per_node_graph(n, |v, out| {
        let mut s = key.stream(v as u64);
        // Hub mixture: 5% of nodes get 10x degree.
        let base = avg_degree.max(1);
        let d = if s.next_f32() < 0.05 { base * 10 } else { (base as f32 * s.next_range_f32(0.2, 1.6)) as usize };
        let c = (v * classes / n) as usize;
        let (lo, hi) = (c * block, ((c + 1) * block).min(n));
        for _ in 0..d.max(1) {
            let u = if s.next_f32() < p_intra && hi > lo {
                lo + s.next_below(hi - lo)
            } else {
                s.next_below(n)
            };
            out.push(u as NodeId);
        }
    });
    (graph, labels)
}

/// Parameters for a full synthetic dataset (graph + features + labels).
#[derive(Debug, Clone)]
pub struct DatasetParams {
    pub name: String,
    pub num_nodes: usize,
    pub avg_degree: usize,
    pub feat_dim: usize,
    pub num_classes: usize,
    /// Fraction of nodes that are labeled (seed pool), as in OGB splits.
    pub labeled_frac: f64,
    /// Intra-community edge probability (community signal strength).
    pub p_intra: f32,
    /// Feature noise stddev around the class centroid.
    pub noise: f32,
    pub seed: u64,
}

/// Build a learnable node-classification dataset: planted communities,
/// features = class centroid (±1 pattern) + gaussian noise.
pub fn make_dataset(p: &DatasetParams) -> Dataset {
    let key = RngKey::new(p.seed);
    let (graph, labels) =
        planted_communities(p.num_nodes, p.num_classes, p.avg_degree, p.p_intra, key);

    // Class centroids: deterministic ±1 patterns.
    let cent_key = key.fold(0xCE17);
    let centroids: Vec<f32> = (0..p.num_classes * p.feat_dim)
        .map(|i| {
            let mut s = cent_key.stream(i as u64);
            if s.next_f32() < 0.5 {
                -1.0
            } else {
                1.0
            }
        })
        .collect();

    let feat_key = key.fold(0xFEA7);
    let f = p.feat_dim;
    let mut feats = vec![0f32; p.num_nodes * f];
    par::par_chunks_mut(&mut feats, f, |v, row| {
        let mut s = feat_key.stream(v as u64);
        let c = labels[v] as usize;
        for (j, x) in row.iter_mut().enumerate() {
            // Box–Muller gaussian.
            let u1 = s.next_f32().max(1e-7);
            let u2 = s.next_f32();
            let gauss = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos();
            *x = centroids[c * f + j] + p.noise * gauss;
        }
    });

    // Labeled subset: evenly strided so every community contributes seeds.
    let stride = (1.0 / p.labeled_frac.max(1e-9)).round().max(1.0) as usize;
    let train_ids: Vec<NodeId> =
        (0..p.num_nodes).step_by(stride).map(|v| v as NodeId).collect();

    Dataset {
        name: p.name.clone(),
        graph,
        feats,
        feat_dim: f,
        labels,
        num_classes: p.num_classes,
        train_ids,
    }
}

/// Helper: build a CSC graph by generating each node's in-neighbor list
/// independently (parallel), then stitching indptr/indices.
fn per_node_graph(n: usize, fill: impl Fn(usize, &mut Vec<NodeId>) + Sync) -> CscGraph {
    let lists: Vec<Vec<NodeId>> = par::par_map(n, |v| {
        let mut out = Vec::new();
        fill(v, &mut out);
        out
    });
    let mut indptr = Vec::with_capacity(n + 1);
    indptr.push(0usize);
    let mut total = 0usize;
    for l in &lists {
        total += l.len();
        indptr.push(total);
    }
    let mut indices = Vec::with_capacity(total);
    for l in &lists {
        indices.extend_from_slice(l);
    }
    CscGraph::new_unchecked(indptr, indices)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erdos_renyi_shape_and_determinism() {
        let g1 = erdos_renyi(100, 5, RngKey::new(1));
        let g2 = erdos_renyi(100, 5, RngKey::new(1));
        let g3 = erdos_renyi(100, 5, RngKey::new(2));
        assert_eq!(g1, g2);
        assert_ne!(g1, g3);
        assert_eq!(g1.num_nodes(), 100);
        assert_eq!(g1.num_edges(), 500);
    }

    #[test]
    fn rmat_is_skewed() {
        let g = rmat(1 << 12, 40_000, (0.57, 0.19, 0.19, 0.05), RngKey::new(7));
        assert_eq!(g.num_nodes(), 1 << 12);
        assert_eq!(g.num_edges(), 40_000);
        // Heavy tail: max degree far above average.
        assert!(g.max_degree() as f64 > 5.0 * g.avg_degree(), "max {} avg {}", g.max_degree(), g.avg_degree());
    }

    #[test]
    fn planted_communities_are_assortative() {
        let (g, labels) = planted_communities(1000, 4, 10, 0.9, RngKey::new(3));
        let mut intra = 0usize;
        let mut total = 0usize;
        for v in 0..1000u32 {
            for &u in g.neighbors(v) {
                total += 1;
                if labels[u as usize] == labels[v as usize] {
                    intra += 1;
                }
            }
        }
        assert!(intra as f64 / total as f64 > 0.8, "{intra}/{total}");
    }

    #[test]
    fn make_dataset_contract() {
        let d = make_dataset(&DatasetParams {
            name: "t".into(),
            num_nodes: 500,
            avg_degree: 8,
            feat_dim: 16,
            num_classes: 5,
            labeled_frac: 0.1,
            p_intra: 0.8,
            noise: 0.2,
            seed: 9,
        });
        assert_eq!(d.num_nodes(), 500);
        assert_eq!(d.feats.len(), 500 * 16);
        assert_eq!(d.labels.len(), 500);
        assert!((45..=55).contains(&d.train_ids.len()), "{}", d.train_ids.len());
        assert!(d.labels.iter().all(|&l| (0..5).contains(&l)));
        // Features carry class signal: same-class rows closer than cross-class.
        let dist = |a: u32, b: u32| -> f32 {
            d.feat(a).iter().zip(d.feat(b)).map(|(x, y)| (x - y).powi(2)).sum()
        };
        // nodes 0,1 share class 0; node 499 is class 4.
        assert!(dist(0, 1) < dist(0, 499));
    }

    #[test]
    fn dataset_storage_accounting() {
        let d = make_dataset(&DatasetParams {
            name: "t".into(),
            num_nodes: 100,
            avg_degree: 4,
            feat_dim: 8,
            num_classes: 2,
            labeled_frac: 0.5,
            p_intra: 0.5,
            noise: 0.1,
            seed: 1,
        });
        assert_eq!(d.feature_bytes(), 100 * 8 * 4);
        assert_eq!(d.topology_bytes(), d.graph.storage_bytes());
    }
}
