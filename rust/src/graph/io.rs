//! Binary dataset serialization: generate once, reuse across bench runs.
//!
//! Format (little-endian):
//!   magic "FSDS" | version u32 | name_len u32 | name bytes |
//!   num_nodes u64 | num_edges u64 | feat_dim u64 | num_classes u64 |
//!   num_train u64 | indptr u64[n+1] | indices u32[m] | feats f32[n*f] |
//!   labels i32[n] | train_ids u32[num_train]

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{ensure, Result, Context};

use super::{CscGraph, Dataset, NodeId};

const MAGIC: &[u8; 4] = b"FSDS";
const VERSION: u32 = 1;

pub fn save(dataset: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    let name = dataset.name.as_bytes();
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name)?;
    for v in [
        dataset.num_nodes() as u64,
        dataset.num_edges() as u64,
        dataset.feat_dim as u64,
        dataset.num_classes as u64,
        dataset.train_ids.len() as u64,
    ] {
        w.write_all(&v.to_le_bytes())?;
    }
    write_slice(&mut w, dataset.graph.indptr())?;
    write_slice(&mut w, dataset.graph.indices())?;
    write_slice(&mut w, &dataset.feats)?;
    write_slice(&mut w, &dataset.labels)?;
    write_slice(&mut w, &dataset.train_ids)?;
    w.flush()?;
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC, "not a FastSample dataset file");
    let version = read_u32(&mut r)?;
    ensure!(version == VERSION, "unsupported version {version}");
    let name_len = read_u32(&mut r)? as usize;
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let n = read_u64(&mut r)? as usize;
    let m = read_u64(&mut r)? as usize;
    let feat_dim = read_u64(&mut r)? as usize;
    let num_classes = read_u64(&mut r)? as usize;
    let num_train = read_u64(&mut r)? as usize;

    let indptr: Vec<usize> = read_vec::<u64>(&mut r, n + 1)?.into_iter().map(|v| v as usize).collect();
    let indices: Vec<NodeId> = read_vec(&mut r, m)?;
    let feats: Vec<f32> = read_vec(&mut r, n * feat_dim)?;
    let labels: Vec<i32> = read_vec(&mut r, n)?;
    let train_ids: Vec<NodeId> = read_vec(&mut r, num_train)?;

    Ok(Dataset {
        name: String::from_utf8(name)?,
        graph: CscGraph::new(indptr, indices)?,
        feats,
        feat_dim,
        labels,
        num_classes,
        train_ids,
    })
}

fn write_slice<T: Copy>(w: &mut impl Write, data: &[T]) -> Result<()> {
    // Safety: plain-old-data slices written as raw little-endian bytes
    // (all field types are u32/u64/usize/i32/f32 on a LE target).
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr().cast::<u8>(), std::mem::size_of_val(data))
    };
    w.write_all(bytes)?;
    Ok(())
}

fn read_vec<T: Copy + Default>(r: &mut impl Read, len: usize) -> Result<Vec<T>> {
    let mut out = vec![T::default(); len];
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr().cast::<u8>(), len * std::mem::size_of::<T>())
    };
    r.read_exact(bytes)?;
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{make_dataset, DatasetParams};

    #[test]
    fn save_load_round_trip() {
        let d = make_dataset(&DatasetParams {
            name: "roundtrip".into(),
            num_nodes: 300,
            avg_degree: 6,
            feat_dim: 12,
            num_classes: 3,
            labeled_frac: 0.2,
            p_intra: 0.7,
            noise: 0.3,
            seed: 11,
        });
        let tmp = std::env::temp_dir().join("fastsample_io_test.bin");
        save(&d, &tmp).unwrap();
        let back = load(&tmp).unwrap();
        std::fs::remove_file(&tmp).ok();
        assert_eq!(d.name, back.name);
        assert_eq!(d.graph, back.graph);
        assert_eq!(d.feats, back.feats);
        assert_eq!(d.labels, back.labels);
        assert_eq!(d.train_ids, back.train_ids);
        assert_eq!(d.num_classes, back.num_classes);
    }

    #[test]
    fn rejects_garbage() {
        let tmp = std::env::temp_dir().join("fastsample_io_garbage.bin");
        std::fs::write(&tmp, b"not a dataset").unwrap();
        assert!(load(&tmp).is_err());
        std::fs::remove_file(&tmp).ok();
    }
}
