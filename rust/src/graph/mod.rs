//! Graph substrate: sparse storage, synthetic generators, dataset registry
//! and binary I/O.
//!
//! Storage follows the paper's preference (§3.2): CSC is the canonical
//! format because fetching a node's in-neighbors is O(1); COO exists as the
//! intermediate the *baseline* sampling pipeline produces (and the fused
//! kernel avoids).

mod coo;
mod csc;
pub mod datasets;
pub mod generator;
pub mod io;

pub use coo::CooGraph;
pub use csc::CscGraph;

/// Node identifier. `u32` covers the node counts we simulate (the paper's
/// largest graph, ogbn-papers100M, has 111M nodes — also within u32);
/// edge *counts* use `usize`/`u64` (papers100M has 3.2B edges).
pub type NodeId = u32;

/// A node-classification dataset: graph topology + dense node features +
/// labels + the labeled (trainable) node set.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub graph: CscGraph,
    /// Row-major `[num_nodes, feat_dim]`.
    pub feats: Vec<f32>,
    pub feat_dim: usize,
    /// One label per node (only meaningful where `labeled` is true).
    pub labels: Vec<i32>,
    pub num_classes: usize,
    /// Labeled nodes — the pool top-level sampling seeds are drawn from.
    pub train_ids: Vec<NodeId>,
}

impl Dataset {
    pub fn num_nodes(&self) -> usize {
        self.graph.num_nodes()
    }

    pub fn num_edges(&self) -> usize {
        self.graph.num_edges()
    }

    /// Feature row of one node.
    #[inline]
    pub fn feat(&self, v: NodeId) -> &[f32] {
        let f = self.feat_dim;
        &self.feats[v as usize * f..(v as usize + 1) * f]
    }

    /// Bytes of the adjacency structure (indptr + indices) — the
    /// "topology" bar of the paper's Fig 4.
    pub fn topology_bytes(&self) -> usize {
        self.graph.storage_bytes()
    }

    /// Bytes of the dense feature tensor — the "features" bar of Fig 4.
    pub fn feature_bytes(&self) -> usize {
        self.feats.len() * std::mem::size_of::<f32>()
    }
}
