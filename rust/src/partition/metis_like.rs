//! From-scratch multilevel edge-cut partitioner (METIS stand-in).
//!
//! Same objective as the paper's use of METIS (§4): minimize the number of
//! edges crossing partition boundaries while balancing (a) nodes,
//! (b) edges, and (c) **labeled nodes** — the paper equalizes labeled
//! nodes so every machine draws the same number of top-level seeds per
//! epoch.
//!
//! Classic three-phase multilevel scheme:
//! 1. **Coarsen** by heavy-edge matching until the graph is small;
//! 2. **Initial partition** by balanced region growing (BFS) on the
//!    coarsest graph;
//! 3. **Uncoarsen + refine** with greedy boundary moves (FM-lite) under a
//!    balance constraint, then a final labeled-node balancing pass.

use crate::graph::{CscGraph, NodeId};
use crate::sampling::rng::RngKey;

use super::book::PartitionBook;

/// Partitioner knobs.
#[derive(Debug, Clone)]
pub struct PartitionConfig {
    pub num_parts: usize,
    /// Max allowed node-count imbalance (max/mean), e.g. 1.05.
    pub balance_factor: f64,
    /// Boundary-refinement passes per uncoarsening level.
    pub refine_passes: usize,
    pub seed: u64,
}

impl PartitionConfig {
    pub fn new(num_parts: usize) -> Self {
        Self { num_parts, balance_factor: 1.05, refine_passes: 3, seed: 0x9E17 }
    }
}

/// Undirected weighted working graph for the multilevel phases.
struct WorkGraph {
    /// CSR: adj[xadj[v]..xadj[v+1]] = (neighbor, edge weight).
    xadj: Vec<usize>,
    adj: Vec<(u32, u32)>,
    /// Node weights (number of fine nodes folded into this vertex).
    vwgt: Vec<u32>,
}

impl WorkGraph {
    fn n(&self) -> usize {
        self.vwgt.len()
    }

    fn neighbors(&self, v: usize) -> &[(u32, u32)] {
        &self.adj[self.xadj[v]..self.xadj[v + 1]]
    }

    /// Symmetrize a directed CSC graph into the undirected working form,
    /// coalescing parallel edges into weights.
    fn from_csc(g: &CscGraph) -> Self {
        let n = g.num_nodes();
        // Count symmetric degree first.
        let mut deg = vec![0usize; n];
        for v in 0..n as NodeId {
            for &u in g.neighbors(v) {
                if u != v {
                    deg[v as usize] += 1;
                    deg[u as usize] += 1;
                }
            }
        }
        let mut xadj = vec![0usize; n + 1];
        for v in 0..n {
            xadj[v + 1] = xadj[v] + deg[v];
        }
        let mut flat = vec![0u32; xadj[n]];
        let mut cursor = xadj.clone();
        for v in 0..n as NodeId {
            for &u in g.neighbors(v) {
                if u != v {
                    flat[cursor[v as usize]] = u;
                    cursor[v as usize] += 1;
                    flat[cursor[u as usize]] = v;
                    cursor[u as usize] += 1;
                }
            }
        }
        // Coalesce duplicates per node by sorting each adjacency range.
        let mut new_xadj = vec![0usize; n + 1];
        let mut adj: Vec<(u32, u32)> = Vec::with_capacity(flat.len());
        for v in 0..n {
            let range = &mut flat[xadj[v]..xadj[v + 1]];
            range.sort_unstable();
            let mut i = 0;
            while i < range.len() {
                let u = range[i];
                let mut w = 0u32;
                while i < range.len() && range[i] == u {
                    w += 1;
                    i += 1;
                }
                adj.push((u, w));
            }
            new_xadj[v + 1] = adj.len();
        }
        WorkGraph { xadj: new_xadj, adj, vwgt: vec![1; n] }
    }

    /// Heavy-edge matching coarsening. Returns (coarse graph, fine→coarse map).
    fn coarsen(&self, key: RngKey) -> (WorkGraph, Vec<u32>) {
        let n = self.n();
        let mut order: Vec<u32> = (0..n as u32).collect();
        let mut s = key.stream(0);
        for i in (1..n).rev() {
            order.swap(i, s.next_below(i + 1));
        }
        const UNMATCHED: u32 = u32::MAX;
        let mut mate = vec![UNMATCHED; n];
        for &v in &order {
            let v = v as usize;
            if mate[v] != UNMATCHED {
                continue;
            }
            // Heaviest unmatched neighbor.
            let mut best: Option<(u32, u32)> = None;
            for &(u, w) in self.neighbors(v) {
                if mate[u as usize] == UNMATCHED && u as usize != v {
                    if best.map_or(true, |(_, bw)| w > bw) {
                        best = Some((u, w));
                    }
                }
            }
            match best {
                Some((u, _)) => {
                    mate[v] = u;
                    mate[u as usize] = v as u32;
                }
                None => mate[v] = v as u32, // matched with itself
            }
        }
        // Assign coarse ids (pair → one id).
        let mut cmap = vec![u32::MAX; n];
        let mut next = 0u32;
        for v in 0..n {
            if cmap[v] == u32::MAX {
                let m = mate[v] as usize;
                cmap[v] = next;
                cmap[m] = next;
                next += 1;
            }
        }
        // Build coarse graph by merging adjacencies.
        let cn = next as usize;
        let mut cvwgt = vec![0u32; cn];
        for v in 0..n {
            cvwgt[cmap[v] as usize] += self.vwgt[v];
        }
        // Accumulate coarse edges via a stamped scratch map (one sweep).
        let mut cxadj = vec![0usize; cn + 1];
        let mut cadj: Vec<(u32, u32)> = Vec::new();
        let mut stamp = vec![u32::MAX; cn];
        let mut slot = vec![0usize; cn];
        // Group fine nodes by coarse id.
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); cn];
        for v in 0..n {
            members[cmap[v] as usize].push(v as u32);
        }
        for c in 0..cn {
            let start = cadj.len();
            for &v in &members[c] {
                for &(u, w) in self.neighbors(v as usize) {
                    let cu = cmap[u as usize];
                    if cu as usize == c {
                        continue;
                    }
                    if stamp[cu as usize] == c as u32 {
                        cadj[slot[cu as usize]].1 += w;
                    } else {
                        stamp[cu as usize] = c as u32;
                        slot[cu as usize] = cadj.len();
                        cadj.push((cu, w));
                    }
                }
            }
            let _ = start;
            cxadj[c + 1] = cadj.len();
        }
        (WorkGraph { xadj: cxadj, adj: cadj, vwgt: cvwgt }, cmap)
    }

    /// Balanced region-growing initial partition on the coarsest graph.
    fn initial_partition(&self, parts: usize, key: RngKey) -> Vec<u16> {
        let n = self.n();
        let total: u64 = self.vwgt.iter().map(|&w| w as u64).sum();
        let target = total.div_ceil(parts as u64);
        let mut assign = vec![u16::MAX; n];
        let mut s = key.stream(1);
        let mut queue = std::collections::VecDeque::new();
        for p in 0..parts {
            let mut grown = 0u64;
            // Seed: a random unassigned node (retry a few times, then scan).
            let mut seed = None;
            for _ in 0..32 {
                let c = s.next_below(n);
                if assign[c] == u16::MAX {
                    seed = Some(c);
                    break;
                }
            }
            let seed = seed.or_else(|| (0..n).find(|&v| assign[v] == u16::MAX));
            let Some(seed) = seed else { break };
            queue.clear();
            queue.push_back(seed);
            while grown < target {
                let Some(v) = queue.pop_front() else {
                    // Region exhausted; jump to another unassigned node.
                    match (0..n).find(|&v| assign[v] == u16::MAX) {
                        Some(v) => {
                            queue.push_back(v);
                            continue;
                        }
                        None => break,
                    }
                };
                if assign[v] != u16::MAX {
                    continue;
                }
                assign[v] = p as u16;
                grown += self.vwgt[v] as u64;
                for &(u, _) in self.neighbors(v) {
                    if assign[u as usize] == u16::MAX {
                        queue.push_back(u as usize);
                    }
                }
            }
        }
        // Any stragglers go to the lightest part.
        let mut loads = vec![0u64; parts];
        for v in 0..n {
            if assign[v] != u16::MAX {
                loads[assign[v] as usize] += self.vwgt[v] as u64;
            }
        }
        for v in 0..n {
            if assign[v] == u16::MAX {
                let p = (0..parts).min_by_key(|&p| loads[p]).unwrap();
                assign[v] = p as u16;
                loads[p] += self.vwgt[v] as u64;
            }
        }
        assign
    }

    /// One FM-lite refinement sweep: move boundary nodes to the partition
    /// with the highest positive gain, respecting the balance ceiling.
    /// Returns the number of moves.
    fn refine_pass(
        &self,
        assign: &mut [u16],
        parts: usize,
        max_load: u64,
        loads: &mut [u64],
    ) -> usize {
        let n = self.n();
        let mut moves = 0usize;
        let mut conn = vec![0u64; parts]; // edge weight to each part (stamped)
        let mut touched: Vec<usize> = Vec::new();
        for v in 0..n {
            let pv = assign[v] as usize;
            // Connectivity of v to each partition.
            touched.clear();
            for &(u, w) in self.neighbors(v) {
                let pu = assign[u as usize] as usize;
                if conn[pu] == 0 {
                    touched.push(pu);
                }
                conn[pu] += w as u64;
            }
            let own = conn[pv];
            let mut best: Option<(usize, u64)> = None;
            for &p in &touched {
                if p != pv
                    && conn[p] > own
                    && loads[p] + self.vwgt[v] as u64 <= max_load
                    && best.map_or(true, |(_, bw)| conn[p] > bw)
                {
                    best = Some((p, conn[p]));
                }
            }
            if let Some((p, _)) = best {
                loads[pv] -= self.vwgt[v] as u64;
                loads[p] += self.vwgt[v] as u64;
                assign[v] = p as u16;
                moves += 1;
            }
            for &p in &touched {
                conn[p] = 0;
            }
        }
        moves
    }
}

/// Multilevel edge-cut partitioning with labeled-node balancing.
pub fn partition_graph(
    graph: &CscGraph,
    train_ids: &[NodeId],
    cfg: &PartitionConfig,
) -> PartitionBook {
    let parts = cfg.num_parts;
    let n = graph.num_nodes();
    if parts <= 1 || n <= parts {
        // Trivial: round-robin (also covers n <= parts).
        let assign: Vec<u16> = (0..n).map(|v| (v % parts.max(1)) as u16).collect();
        return PartitionBook::new(parts.max(1), assign).unwrap();
    }
    let key = RngKey::new(cfg.seed);

    // ---- Phase 1: coarsen.
    let mut levels: Vec<WorkGraph> = vec![WorkGraph::from_csc(graph)];
    let mut maps: Vec<Vec<u32>> = Vec::new();
    let coarse_target = (parts * 64).max(256);
    loop {
        let cur = levels.last().unwrap();
        if cur.n() <= coarse_target {
            break;
        }
        let (coarse, cmap) = cur.coarsen(key.fold(levels.len() as u64));
        // Matching stalled (e.g. star graphs): stop coarsening.
        if coarse.n() as f64 > 0.95 * cur.n() as f64 {
            break;
        }
        maps.push(cmap);
        levels.push(coarse);
    }

    // ---- Phase 2: initial partition on the coarsest level.
    let coarsest = levels.last().unwrap();
    let mut assign = coarsest.initial_partition(parts, key.fold(0xA11));

    // ---- Phase 3: uncoarsen with refinement.
    for li in (0..levels.len()).rev() {
        let wg = &levels[li];
        if li < maps.len() {
            // Project from level li+1 down to li.
            let cmap = &maps[li];
            let mut fine = vec![0u16; wg.n()];
            for v in 0..wg.n() {
                fine[v] = assign[cmap[v] as usize];
            }
            assign = fine;
        }
        let total: u64 = wg.vwgt.iter().map(|&w| w as u64).sum();
        let max_load = ((total as f64 / parts as f64) * cfg.balance_factor).ceil() as u64;
        let mut loads = vec![0u64; parts];
        for v in 0..wg.n() {
            loads[assign[v] as usize] += wg.vwgt[v] as u64;
        }
        for _ in 0..cfg.refine_passes {
            if wg.refine_pass(&mut assign, parts, max_load, &mut loads) == 0 {
                break;
            }
        }
    }

    // ---- Phase 4: labeled-node balancing (paper: equal seeds/machine).
    balance_labels(graph, train_ids, &mut assign, parts);

    PartitionBook::new(parts, assign).unwrap()
}

/// Greedy labeled-node rebalancing: move labeled nodes from over-seeded to
/// under-seeded partitions, preferring moves that cut the fewest edges.
fn balance_labels(graph: &CscGraph, train_ids: &[NodeId], assign: &mut [u16], parts: usize) {
    if train_ids.is_empty() {
        return;
    }
    let mut counts = vec![0isize; parts];
    for &v in train_ids {
        counts[assign[v as usize] as usize] += 1;
    }
    let target = train_ids.len() as isize / parts as isize;
    // Collect candidate movable labeled nodes per over-full partition.
    for p in 0..parts {
        while counts[p] > target + 1 {
            // Receiver: most under-full partition.
            let q = (0..parts).min_by_key(|&q| counts[q]).unwrap();
            if counts[q] >= target {
                break;
            }
            // Pick the labeled node in p with the most edges toward q
            // (cheapest to move). Scan is O(|train|·deg) worst case but
            // runs once at setup time.
            let mut best: Option<(NodeId, i64)> = None;
            for &v in train_ids {
                if assign[v as usize] as usize != p {
                    continue;
                }
                let mut toward_q = 0i64;
                let mut toward_p = 0i64;
                for &u in graph.neighbors(v) {
                    let pu = assign[u as usize] as usize;
                    if pu == q {
                        toward_q += 1;
                    } else if pu == p {
                        toward_p += 1;
                    }
                }
                let gain = toward_q - toward_p;
                if best.map_or(true, |(_, bg)| gain > bg) {
                    best = Some((v, gain));
                }
            }
            match best {
                Some((v, _)) => {
                    assign[v as usize] = q as u16;
                    counts[p] -= 1;
                    counts[q] += 1;
                }
                None => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{erdos_renyi, planted_communities};
    use crate::partition::book::PartitionBook;

    #[test]
    fn finds_community_structure() {
        // 4 well-separated communities → a 4-way partition should cut far
        // fewer edges than random assignment.
        let (g, _) = planted_communities(2000, 4, 10, 0.95, RngKey::new(1));
        let train: Vec<NodeId> = (0..2000).step_by(10).collect();
        let book = partition_graph(&g, &train, &PartitionConfig::new(4));
        let cut = book.cut_fraction(&g);
        assert!(cut < 0.25, "cut fraction {cut}");
        // Balance: nodes within 20% of mean.
        assert!(PartitionBook::imbalance(&book.node_counts()) < 1.2);
    }

    #[test]
    fn beats_random_on_er_too() {
        let g = erdos_renyi(1000, 8, RngKey::new(2));
        let train: Vec<NodeId> = (0..1000).step_by(5).collect();
        let book = partition_graph(&g, &train, &PartitionConfig::new(4));
        // Random 4-way cut ≈ 75%; refinement must do better.
        assert!(book.cut_fraction(&g) < 0.74, "{}", book.cut_fraction(&g));
    }

    #[test]
    fn labels_are_balanced() {
        let (g, _) = planted_communities(1500, 3, 8, 0.9, RngKey::new(3));
        // Labeled nodes concentrated in one community — the balancer must
        // still spread them.
        let train: Vec<NodeId> = (0..400).collect();
        let book = partition_graph(&g, &train, &PartitionConfig::new(4));
        let lc = book.label_counts(&train);
        let imb = PartitionBook::imbalance(&lc);
        assert!(imb < 1.25, "label counts {lc:?}");
    }

    #[test]
    fn partitioner_keeps_the_replication_halo_small() {
        // The 1-hop halo is what a ReplicationPolicy byte budget buys
        // back; a cut-minimizing partition must keep it well under the
        // full topology (a random assignment would reference nearly
        // every remote node on every worker).
        let (g, _) = planted_communities(2000, 4, 10, 0.95, RngKey::new(7));
        let train: Vec<NodeId> = (0..2000).step_by(10).collect();
        let book = partition_graph(&g, &train, &PartitionConfig::new(4));
        let interleaved = PartitionBook::new(
            4,
            (0..g.num_nodes()).map(|v| (v % 4) as u16).collect(),
        )
        .unwrap();
        let halo_max = |b: &PartitionBook| {
            b.halo_profile(&g).iter().map(|p| p.halo_bytes).max().unwrap()
        };
        let (real, bad) = (halo_max(&book), halo_max(&interleaved));
        assert!(real < bad / 2, "partitioned halo {real} vs interleaved {bad}");
        let full_bytes = (g.num_nodes() as u64) * 8 + (g.num_edges() as u64) * 4;
        assert!(real < full_bytes, "halo must be a strict subset of the topology");
    }

    #[test]
    fn single_part_and_tiny_graphs() {
        let g = erdos_renyi(50, 3, RngKey::new(4));
        let book = partition_graph(&g, &[], &PartitionConfig::new(1));
        assert_eq!(book.num_parts(), 1);
        assert_eq!(book.edge_cut(&g), 0);
        let book2 = partition_graph(&g, &[], &PartitionConfig::new(64));
        assert_eq!(book2.num_parts(), 64); // n <= parts*? round robin path
    }

    #[test]
    fn deterministic_in_seed() {
        let (g, _) = planted_communities(800, 4, 6, 0.9, RngKey::new(5));
        let train: Vec<NodeId> = (0..80).collect();
        let a = partition_graph(&g, &train, &PartitionConfig::new(4));
        let b = partition_graph(&g, &train, &PartitionConfig::new(4));
        for v in 0..800 {
            assert_eq!(a.part_of(v), b.part_of(v));
        }
    }
}
