//! Graph partitioning (paper §3.3, generalized to a replication budget).
//!
//! * [`book`] — the partition assignment + quality metrics (edge cut,
//!   node/edge/label balance, per-partition 1-hop halo profile — the
//!   natural denominator for replication budgets).
//! * [`metis_like`] — a from-scratch multilevel edge-cut partitioner
//!   (heavy-edge-matching coarsening → greedy region growing → boundary
//!   refinement), standing in for METIS with the same objectives the
//!   paper lists: minimize cut edges, balance nodes/edges, and balance
//!   labeled nodes so every machine draws the same number of seeds.
//! * [`shard`] — materialize per-worker shards under a
//!   [`ReplicationPolicy`]: local in-edges always, plus a budgeted
//!   boundary-BFS halo of replicated adjacency. `byte_budget = Some(0)`
//!   is the paper's vanilla arm (topology *and* features partitioned),
//!   `byte_budget = None` its hybrid arm (topology replicated, features
//!   partitioned), and finite budgets interpolate between them.

pub mod book;
pub mod metis_like;
pub mod shard;

pub use book::{HaloProfile, PartitionBook};
pub use metis_like::{partition_graph, PartitionConfig};
pub use shard::{
    build_shard, build_shards, HaloPriority, ReplicationPolicy, TopologyView, WorkerShard,
};
