//! Graph partitioning (paper §3.3).
//!
//! * [`book`] — the partition assignment + quality metrics (edge cut,
//!   node/edge/label balance).
//! * [`metis_like`] — a from-scratch multilevel edge-cut partitioner
//!   (heavy-edge-matching coarsening → greedy region growing → boundary
//!   refinement), standing in for METIS with the same objectives the
//!   paper lists: minimize cut edges, balance nodes/edges, and balance
//!   labeled nodes so every machine draws the same number of seeds.
//! * [`shard`] — materialize per-worker shards under either scheme:
//!   **vanilla** (topology *and* features partitioned; remote sampling
//!   rounds required) or **hybrid** (topology replicated, features
//!   partitioned; the paper's contribution).

pub mod book;
pub mod metis_like;
pub mod shard;

pub use book::PartitionBook;
pub use metis_like::{partition_graph, PartitionConfig};
pub use shard::{build_shards, Scheme, TopologyView, WorkerShard};
