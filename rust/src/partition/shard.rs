//! Per-worker shards under the two partitioning schemes of the paper.
//!
//! **Vanilla** (DistDGL-style, §3.3): each worker stores its partition's
//! node features *and only* the incoming edges of its partition nodes
//! (topology halo). Sampling a non-local node requires a remote request —
//! 2(L−1) communication rounds per minibatch.
//!
//! **Hybrid** (the paper's scheme): the full topology is replicated on
//! every worker (it is small, Fig 4) while features stay partitioned.
//! Sampling is then fully local; only the 2 feature-exchange rounds
//! remain.

use std::sync::Arc;

use crate::graph::{CscGraph, Dataset, NodeId};

use super::book::PartitionBook;

/// Partitioning scheme selector (the Fig 6 comparison axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    Vanilla,
    Hybrid,
}

/// What a worker can see of the graph topology.
pub enum TopologyView {
    /// Hybrid: the whole adjacency, shared (one copy per *process*; in the
    /// paper it is one copy per machine).
    Full(Arc<CscGraph>),
    /// Vanilla: in-edges of local nodes only. `row_of[v]` is the local row
    /// of global node `v`, or `u32::MAX` if `v` is not local.
    Halo { indptr: Vec<usize>, indices: Vec<NodeId>, row_of: Vec<u32> },
}

impl TopologyView {
    /// In-neighbors of `v`, or `None` when `v` is not sampleable locally
    /// (vanilla scheme, remote node) — the caller must issue a remote
    /// sampling request.
    #[inline]
    pub fn try_neighbors(&self, v: NodeId) -> Option<&[NodeId]> {
        match self {
            TopologyView::Full(g) => Some(g.neighbors(v)),
            TopologyView::Halo { indptr, indices, row_of } => {
                let row = row_of[v as usize];
                if row == u32::MAX {
                    None
                } else {
                    Some(&indices[indptr[row as usize]..indptr[row as usize + 1]])
                }
            }
        }
    }

    pub fn is_full(&self) -> bool {
        matches!(self, TopologyView::Full(_))
    }

    /// Bytes of adjacency data this worker holds (per-worker memory cost
    /// of the scheme — the compromise the paper's §5 discusses).
    pub fn storage_bytes(&self) -> usize {
        match self {
            TopologyView::Full(g) => g.storage_bytes(),
            TopologyView::Halo { indptr, indices, row_of } => {
                indptr.len() * 8 + indices.len() * 4 + row_of.len() * 4
            }
        }
    }
}

/// Everything one worker owns.
pub struct WorkerShard {
    pub part: usize,
    pub num_parts: usize,
    pub book: Arc<PartitionBook>,
    pub topology: TopologyView,
    /// Global ids of nodes whose features this worker stores (sorted).
    pub local_nodes: Vec<NodeId>,
    /// `feat_row[v]` = local feature row of global `v`, `u32::MAX` if remote.
    pub feat_row: Vec<u32>,
    /// Row-major `[local_nodes.len(), feat_dim]`.
    pub feats: Vec<f32>,
    pub feat_dim: usize,
    /// Labels, replicated (they are 4 bytes/node — negligible next to
    /// features; DistDGL replicates them inside the partition book too).
    pub labels: Arc<Vec<i32>>,
    /// Labeled nodes owned by this worker — its top-level seed pool.
    pub train_local: Vec<NodeId>,
}

impl WorkerShard {
    /// Feature row of a *local* node.
    #[inline]
    pub fn local_feat(&self, v: NodeId) -> &[f32] {
        let row = self.feat_row[v as usize];
        debug_assert_ne!(row, u32::MAX, "node {v} is not local to part {}", self.part);
        let f = self.feat_dim;
        &self.feats[row as usize * f..(row as usize + 1) * f]
    }

    #[inline]
    pub fn owns(&self, v: NodeId) -> bool {
        self.feat_row[v as usize] != u32::MAX
    }

    pub fn feature_bytes(&self) -> usize {
        self.feats.len() * 4
    }
}

/// Materialize all worker shards for a dataset under `scheme`.
pub fn build_shards(
    dataset: &Dataset,
    book: &Arc<PartitionBook>,
    scheme: Scheme,
) -> Vec<WorkerShard> {
    let parts = book.num_parts();
    let labels = Arc::new(dataset.labels.clone());
    let full_graph = match scheme {
        Scheme::Hybrid => Some(Arc::new(dataset.graph.clone())),
        Scheme::Vanilla => None,
    };
    (0..parts)
        .map(|p| {
            let local_nodes = book.nodes_of(p);
            let mut feat_row = vec![u32::MAX; dataset.num_nodes()];
            for (i, &v) in local_nodes.iter().enumerate() {
                feat_row[v as usize] = i as u32;
            }
            let f = dataset.feat_dim;
            let mut feats = Vec::with_capacity(local_nodes.len() * f);
            for &v in &local_nodes {
                feats.extend_from_slice(dataset.feat(v));
            }
            let topology = match &full_graph {
                Some(g) => TopologyView::Full(Arc::clone(g)),
                None => {
                    let (indptr, indices) = dataset.graph.induce_in_edges(&local_nodes);
                    let mut row_of = vec![u32::MAX; dataset.num_nodes()];
                    for (i, &v) in local_nodes.iter().enumerate() {
                        row_of[v as usize] = i as u32;
                    }
                    TopologyView::Halo { indptr, indices, row_of }
                }
            };
            let train_local: Vec<NodeId> =
                dataset.train_ids.iter().copied().filter(|&v| book.part_of(v) == p).collect();
            WorkerShard {
                part: p,
                num_parts: parts,
                book: Arc::clone(book),
                topology,
                local_nodes,
                feat_row,
                feats,
                feat_dim: f,
                labels: Arc::clone(&labels),
                train_local,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{make_dataset, DatasetParams};
    use crate::partition::metis_like::{partition_graph, PartitionConfig};

    fn toy_dataset() -> Dataset {
        make_dataset(&DatasetParams {
            name: "shard-test".into(),
            num_nodes: 600,
            avg_degree: 8,
            feat_dim: 6,
            num_classes: 4,
            labeled_frac: 0.2,
            p_intra: 0.9,
            noise: 0.1,
            seed: 42,
        })
    }

    fn build(scheme: Scheme) -> (Dataset, Vec<WorkerShard>) {
        let d = toy_dataset();
        let book =
            Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(4)));
        let shards = build_shards(&d, &book, scheme);
        (d, shards)
    }

    #[test]
    fn shards_cover_all_nodes_exactly_once() {
        for scheme in [Scheme::Vanilla, Scheme::Hybrid] {
            let (d, shards) = build(scheme);
            let mut seen = vec![0u8; d.num_nodes()];
            for s in &shards {
                for &v in &s.local_nodes {
                    seen[v as usize] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "{scheme:?}");
        }
    }

    #[test]
    fn features_match_dataset_rows() {
        let (d, shards) = build(Scheme::Hybrid);
        for s in &shards {
            for &v in s.local_nodes.iter().take(20) {
                assert_eq!(s.local_feat(v), d.feat(v));
                assert!(s.owns(v));
            }
        }
    }

    #[test]
    fn hybrid_sees_all_vanilla_sees_local_only() {
        let (d, shards) = build(Scheme::Vanilla);
        for s in &shards {
            for v in 0..d.num_nodes() as NodeId {
                let visible = s.topology.try_neighbors(v).is_some();
                assert_eq!(visible, s.owns(v), "vanilla: node {v}");
                if visible {
                    assert_eq!(s.topology.try_neighbors(v).unwrap(), d.graph.neighbors(v));
                }
            }
        }
        let (d2, shards2) = build(Scheme::Hybrid);
        for s in &shards2 {
            assert!(s.topology.is_full());
            for v in 0..d2.num_nodes() as NodeId {
                assert_eq!(s.topology.try_neighbors(v).unwrap(), d2.graph.neighbors(v));
            }
        }
    }

    #[test]
    fn train_pools_partition_the_train_set() {
        let (d, shards) = build(Scheme::Hybrid);
        let total: usize = shards.iter().map(|s| s.train_local.len()).sum();
        assert_eq!(total, d.train_ids.len());
        for s in &shards {
            for &v in &s.train_local {
                assert_eq!(s.book.part_of(v), s.part);
            }
        }
    }

    #[test]
    fn memory_accounting_reflects_schemes() {
        let (d, vanilla) = build(Scheme::Vanilla);
        let (_, hybrid) = build(Scheme::Hybrid);
        // Hybrid: every worker stores the full topology.
        for s in &hybrid {
            assert_eq!(s.topology.storage_bytes(), d.graph.storage_bytes());
        }
        // Vanilla: workers store strictly less adjacency than the total
        // (halo row_of vector aside, indices are a partition subset).
        for s in &vanilla {
            if let TopologyView::Halo { indices, .. } = &s.topology {
                assert!(indices.len() < d.graph.num_edges());
            } else {
                panic!("expected halo view");
            }
        }
        // Features always partition exactly.
        let total_feat: usize = vanilla.iter().map(|s| s.feats.len()).sum();
        assert_eq!(total_feat, d.feats.len());
    }
}
